"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

``input_specs`` returns exactly the pytrees the step functions consume — no
device allocation (dry-run pattern). Modality frontends are stubs: VLM cells
get precomputed patch embeddings, audio cells get precomputed frame
embeddings (per the assignment brief).
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from .config import ModelConfig

if TYPE_CHECKING:  # avoid circular import (configs -> models -> inputs)
    from ..configs import ShapeSpec

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    specs = {
        "tokens": SDS((batch, seq), jnp.int32),
        "labels": SDS((batch, seq), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["image_embeds"] = SDS((batch, cfg.n_image_tokens, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.is_enc_dec:
        specs["audio_frames"] = SDS((batch, cfg.n_audio_frames, cfg.d_model),
                                    jnp.bfloat16)
    return specs


def decode_specs(cfg: ModelConfig, batch: int, kv_len: int) -> dict:
    """Inputs of serve_step: one new token + the cache pytree."""
    from .transformer import init_cache
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, kv_len))
    specs = {
        "token": SDS((batch,), jnp.int32),
        "pos": SDS((), jnp.int32),
        "cache": cache,
    }
    if cfg.family == "vlm":
        specs["memory"] = SDS((batch, cfg.n_image_tokens, cfg.d_model),
                              jnp.bfloat16)
    if cfg.is_enc_dec:
        specs["memory"] = SDS((batch, cfg.n_audio_frames, cfg.d_model),
                              jnp.bfloat16)
    return specs


def input_specs(cfg: ModelConfig, shape: "ShapeSpec") -> dict:
    if shape.phase == "train":
        return train_batch_specs(cfg, shape.global_batch, shape.seq_len)
    if shape.phase == "prefill":
        specs = train_batch_specs(cfg, shape.global_batch, shape.seq_len)
        specs.pop("labels")
        return specs
    return decode_specs(cfg, shape.global_batch, shape.seq_len)


def synth_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0) -> dict:
    """Materialized synthetic batch (for smoke tests / examples)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    out = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab),
        "labels": jax.random.randint(k2, (batch, seq), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        out["image_embeds"] = jax.random.normal(
            k3, (batch, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_enc_dec:
        out["audio_frames"] = jax.random.normal(
            k3, (batch, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    return out

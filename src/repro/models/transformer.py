"""Model assembly: embedding → scanned heterogeneous blocks → head.

Layers are grouped into repeating *blocks* (cfg.block_size) so heterogeneous
patterns (Jamba 7:1 mamba:attn, Llama-Vision cross-attn every 5th) scan as
stacked identical pytrees — one block body in the HLO regardless of depth,
which keeps 94-layer × 512-device dry-run compiles tractable.

Public entry points (all pure):
  init_params(cfg, key)
  forward(cfg, params, tokens, ...)                  -> logits
  loss_fn(cfg, params, batch)                        -> scalar loss
  prefill(cfg, params, tokens, ...)                  -> logits, Cache
  decode_step(cfg, params, cache, token, pos, ...)   -> logits, Cache
  init_cache(cfg, batch, max_len)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from ..parallel.logical import shard
from . import layers as L
from .config import ModelConfig

# remat policies for the block scan (cfg.remat selects; §Perf hillclimb):
#   full — save nothing, recompute the whole block in backward (min memory)
#   dots — save matmul outputs, recompute only cheap elementwise/norm work
#   none — no rematerialization (max memory, no recompute)
REMAT_POLICIES = {
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


# ================================ init =======================================
def _init_layer(key, cfg: ModelConfig, idx: int, cross_ok: bool) -> dict:
    norm_init, _ = L.make_norm(cfg)
    keys = jax.random.split(key, 8)
    p: dict = {"ln1": norm_init(keys[0], cfg.d_model)}
    if cfg.layer_kind(idx) == "attn":
        p["attn"] = L.init_attention(keys[1], cfg)
        if cross_ok and cfg.layer_is_cross(idx):
            p["lnx"] = norm_init(keys[2], cfg.d_model)
            p["xattn"] = L.init_attention(keys[3], cfg, cross=True)
    else:
        p["ssm"] = L.init_ssm(keys[1], cfg)
    if cfg.d_ff:
        p["ln2"] = norm_init(keys[4], cfg.d_model)
        if cfg.layer_is_moe(idx):
            p["moe"] = L.init_moe(keys[5], cfg)
        else:
            p["mlp"] = L.init_mlp(keys[5], cfg)
    return p


def _init_block(key, cfg: ModelConfig, cross_ok: bool = True) -> dict:
    keys = jax.random.split(key, cfg.block_size)
    return {f"l{i}": _init_layer(keys[i], cfg, i, cross_ok)
            for i in range(cfg.block_size)}


def _init_encoder_layer(key, cfg: ModelConfig) -> dict:
    norm_init, _ = L.make_norm(cfg)
    keys = jax.random.split(key, 4)
    return {"ln1": norm_init(keys[0], cfg.d_model),
            "attn": L.init_attention(keys[1], cfg),
            "ln2": norm_init(keys[2], cfg.d_model),
            "mlp": L.init_mlp(keys[3], cfg)}


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    k_embed, k_blocks, k_enc, k_head, k_fn = jax.random.split(key, 5)
    norm_init, _ = L.make_norm(cfg)
    params: dict = {
        "embed": L._dense_init(k_embed, cfg.d_model,
                               (cfg.vocab, cfg.d_model)),
        "final_norm": norm_init(k_fn, cfg.d_model),
        "stack": jax.vmap(lambda k: _init_block(k, cfg))(
            jax.random.split(k_blocks, cfg.n_blocks)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(k_head, cfg.d_model,
                                          (cfg.d_model, cfg.vocab))
    if cfg.is_enc_dec:
        params["enc_stack"] = jax.vmap(
            lambda k: _init_encoder_layer(k, cfg))(
                jax.random.split(k_enc, cfg.encoder_layers))
        params["enc_final_norm"] = norm_init(k_fn, cfg.d_model)
    if cfg.param_dtype == "bfloat16":
        # mixed precision: live params in bf16, fp32 master in the optimizer
        params = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if x.dtype == jnp.float32 else x, params)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ============================== block bodies =================================
def _block_fwd(cfg: ModelConfig, bp: dict, x: jax.Array,
               positions: jax.Array, memory: jax.Array | None) -> jax.Array:
    _, norm = L.make_norm(cfg)
    for i in range(cfg.block_size):
        lp = bp[f"l{i}"]
        if cfg.layer_kind(i) == "attn":
            x = x + L.self_attention(lp["attn"], norm(lp["ln1"], x), cfg,
                                     positions)
            if cfg.layer_is_cross(i) and memory is not None:
                x = x + L.cross_attention(lp["xattn"], norm(lp["lnx"], x),
                                          memory, cfg)
        else:
            x = x + L.ssm_layer(lp["ssm"], norm(lp["ln1"], x), cfg)
        if cfg.d_ff:
            h = norm(lp["ln2"], x)
            if cfg.layer_is_moe(i):
                x = x + L.moe(lp["moe"], h, cfg)
            else:
                x = x + L.mlp(lp["mlp"], h, cfg)
        x = shard(x, "batch", "seq", None)
    return x


def _encoder_fwd(cfg: ModelConfig, ep: dict, x: jax.Array,
                 positions: jax.Array) -> jax.Array:
    _, norm = L.make_norm(cfg)
    x = x + L.self_attention(ep["attn"], norm(ep["ln1"], x), cfg, positions,
                             causal=False)
    x = x + L.mlp(ep["mlp"], norm(ep["ln2"], x), cfg)
    return shard(x, "batch", "seq", None)


def _scan_stack(body, x: jax.Array, stack, remat: bool = True,
                policy: str = "full"):
    if remat and policy != "none":
        fn = jax.checkpoint(body, policy=REMAT_POLICIES[policy])
    else:
        fn = body

    def step(carry, bp):
        return fn(bp, carry), None

    out, _ = jax.lax.scan(step, x, stack)
    return out


# ================================ forward ====================================
def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """Run the encoder over (precomputed) frontend embeddings (B, T, d)."""
    pos = jnp.arange(frames.shape[1])
    x = shard(frames, "batch", "seq", None)
    x = _scan_stack(lambda ep, h: _encoder_fwd(cfg, ep, h, pos),
                    x, params["enc_stack"], policy=cfg.remat)
    _, norm = L.make_norm(cfg)
    return norm(params["enc_final_norm"], x)


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            memory: jax.Array | None = None,
            remat: bool = True) -> jax.Array:
    """Decoder forward. tokens: (B, S) int32; memory: (B, M, d) for
    VLM image embeddings or encoder output. Returns logits (B, S, V)."""
    compute_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"][tokens].astype(compute_dtype)
    x = shard(x, "batch", "seq", None)
    pos = jnp.arange(tokens.shape[1])
    if memory is not None:
        memory = memory.astype(compute_dtype)
    x = _scan_stack(lambda bp, h: _block_fwd(cfg, bp, h, pos, memory),
                    x, params["stack"], remat=remat, policy=cfg.remat)
    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(compute_dtype)
    logits = x @ head
    return shard(logits, "batch", "seq", "vocab")


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """Mean next-token cross-entropy (+ router aux loss hooks in trainer)."""
    memory = _memory_from_batch(cfg, params, batch)
    logits = forward(cfg, params, batch["tokens"], memory=memory)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    nll = (logz - gold) * mask
    return nll.sum() / jnp.clip(mask.sum(), 1.0)


def _memory_from_batch(cfg: ModelConfig, params: dict, batch: dict):
    if cfg.family == "vlm":
        return batch["image_embeds"]
    if cfg.is_enc_dec:
        return encode(cfg, params, batch["audio_frames"])
    return None


# ============================= KV / state cache ==============================
@dataclasses.dataclass
class CacheSpec:
    n_attn: int          # attention layers per block
    n_ssm: int           # ssm layers per block
    attn_slots: list     # layer idx within block -> cache slot (or -1)
    ssm_slots: list


def cache_spec(cfg: ModelConfig) -> CacheSpec:
    a, s, aslot, sslot = 0, 0, [], []
    for i in range(cfg.block_size):
        if cfg.layer_kind(i) == "attn":
            aslot.append(a); sslot.append(-1); a += 1
        else:
            aslot.append(-1); sslot.append(s); s += 1
    return CacheSpec(a, s, aslot, sslot)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    spec = cache_spec(cfg)
    nb = cfg.n_blocks
    cache: dict = {}
    if spec.n_attn:
        cache["k"] = jnp.zeros((nb, spec.n_attn, batch, max_len,
                                cfg.n_kv_heads, cfg.hd), dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
    if spec.n_ssm:
        d_in = cfg.ssm_expand * cfg.d_model
        h = d_in // cfg.ssm_head_dim
        conv_ch = d_in + 2 * cfg.ssm_state
        cache["ssm"] = jnp.zeros((nb, spec.n_ssm, batch, h,
                                  cfg.ssm_head_dim, cfg.ssm_state),
                                 jnp.float32)
        cache["conv"] = jnp.zeros((nb, spec.n_ssm, batch, cfg.ssm_conv - 1,
                                   conv_ch), dtype)
    return cache


def _block_decode(cfg: ModelConfig, bp: dict, bc: dict, x: jax.Array,
                  pos: jax.Array, memory: jax.Array | None):
    _, norm = L.make_norm(cfg)
    spec = cache_spec(cfg)
    new_c = {k: v for k, v in bc.items()}
    for i in range(cfg.block_size):
        lp = bp[f"l{i}"]
        if cfg.layer_kind(i) == "attn":
            slot = spec.attn_slots[i]
            h, ck, cv = L.decode_self_attention(
                lp["attn"], norm(lp["ln1"], x), new_c["k"][slot],
                new_c["v"][slot], pos, cfg)
            x = x + h
            new_c["k"] = new_c["k"].at[slot].set(ck)
            new_c["v"] = new_c["v"].at[slot].set(cv)
            if cfg.layer_is_cross(i) and memory is not None:
                x = x + L.cross_attention(lp["xattn"], norm(lp["lnx"], x),
                                          memory, cfg)
        else:
            slot = spec.ssm_slots[i]
            h, st, cc = L.ssm_decode_step(
                lp["ssm"], norm(lp["ln1"], x), new_c["ssm"][slot],
                new_c["conv"][slot], cfg)
            x = x + h
            new_c["ssm"] = new_c["ssm"].at[slot].set(st)
            new_c["conv"] = new_c["conv"].at[slot].set(cc)
        if cfg.d_ff:
            hh = norm(lp["ln2"], x)
            if cfg.layer_is_moe(i):
                x = x + L.moe_dense(lp["moe"], hh, cfg)  # dropless at T=1
            else:
                x = x + L.mlp(lp["mlp"], hh, cfg)
    return x, new_c


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                token: jax.Array, pos: jax.Array,
                memory: jax.Array | None = None):
    """One autoregressive step. token: (B,) int32; pos: scalar int32.

    Returns (logits (B, V), updated cache)."""
    compute_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"][token][:, None, :].astype(compute_dtype)  # (B,1,d)
    if memory is not None:
        memory = memory.astype(compute_dtype)

    def step(carry, inp):
        bp, bc = inp
        y, nc = _block_decode(cfg, bp, bc, carry, pos, memory)
        return y, nc

    x, new_cache = jax.lax.scan(step, x, (params["stack"], cache))
    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(compute_dtype)
    logits = (x[:, 0, :] @ head)
    return shard(logits, "batch", "vocab"), new_cache


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            memory: jax.Array | None = None):
    """Prefill pass: logits for the prompt + a cache filled up to S.

    The cache is produced by replaying K/V projections per block — traffic-
    equivalent to fused prefill for the dry-run's purposes, and exactly
    correct w.r.t. decode_step (tested).
    """
    logits = forward(cfg, params, tokens, memory=memory)
    cache = init_cache(cfg, tokens.shape[0], tokens.shape[1])
    cache = _fill_cache(cfg, params, tokens, cache, memory)
    return logits, cache


def _fill_cache(cfg: ModelConfig, params: dict, tokens: jax.Array,
                cache: dict, memory: jax.Array | None):
    """Recompute per-layer inputs and write K/V + SSM states into the cache."""
    compute_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"][tokens].astype(compute_dtype)
    if memory is not None:
        memory = memory.astype(compute_dtype)
    pos = jnp.arange(tokens.shape[1])
    _, norm = L.make_norm(cfg)
    spec = cache_spec(cfg)

    def step(carry, inp):
        h = carry
        bp, bc = inp
        nc = dict(bc)
        for i in range(cfg.block_size):
            lp = bp[f"l{i}"]
            if cfg.layer_kind(i) == "attn":
                slot = spec.attn_slots[i]
                xin = norm(lp["ln1"], h)
                b, s, _ = xin.shape
                k = (xin @ lp["attn"]["wk"].astype(xin.dtype)).reshape(
                    b, s, cfg.n_kv_heads, cfg.hd)
                v = (xin @ lp["attn"]["wv"].astype(xin.dtype)).reshape(
                    b, s, cfg.n_kv_heads, cfg.hd)
                k = L.apply_rope(k, pos, cfg.rope_theta)
                nc["k"] = nc["k"].at[slot, :, :s].set(k.astype(nc["k"].dtype))
                nc["v"] = nc["v"].at[slot, :, :s].set(v.astype(nc["v"].dtype))
                h = h + L.self_attention(lp["attn"], xin, cfg, pos)
                if cfg.layer_is_cross(i) and memory is not None:
                    h = h + L.cross_attention(lp["xattn"], norm(lp["lnx"], h),
                                              memory, cfg)
            else:
                slot = spec.ssm_slots[i]
                xin = norm(lp["ln1"], h)
                y, st, conv_tail = _ssm_with_state(lp["ssm"], xin, cfg)
                nc["ssm"] = nc["ssm"].at[slot].set(st)
                nc["conv"] = nc["conv"].at[slot].set(
                    conv_tail.astype(nc["conv"].dtype))
                h = h + y
            if cfg.d_ff:
                hh = norm(lp["ln2"], h)
                h = h + (L.moe(lp["moe"], hh, cfg) if cfg.layer_is_moe(i)
                         else L.mlp(lp["mlp"], hh, cfg))
        return h, nc

    _, new_cache = jax.lax.scan(step, x, (params["stack"], cache))
    return new_cache


def _ssm_with_state(p: dict, x: jax.Array, cfg: ModelConfig):
    """ssm_layer variant that also returns (final_state, conv_tail)."""
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = d_in // cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n], axis=-1)
    conv_tail = xbc[:, -(cfg.ssm_conv - 1):, :]
    xbc = L._causal_conv(xbc, p["conv_w"].astype(x.dtype))
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xs = xs.reshape(b, s, h, cfg.ssm_head_dim)
    y, state = L._ssd_chunk_scan(xs.astype(jnp.float32), dt,
                                 Bm.astype(jnp.float32),
                                 Cm.astype(jnp.float32),
                                 p["A_log"], chunk=min(128, s))
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return (y @ p["out_proj"].astype(x.dtype)), state, conv_tail

"""Model configuration covering all assigned architecture families.

One ``ModelConfig`` describes dense / MoE / hybrid(SSM+attn) / VLM / enc-dec
/ pure-SSM transformers. Heterogeneous layer patterns (Jamba's 7:1
mamba:attention interleave, Llama-3.2-Vision's cross-attention every 5th
layer) are expressed as a repeating *block pattern* so the runtime can scan
over stacked identical blocks (small HLO, fast compile — essential for the
512-device dry-run).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1           # a layer is MoE iff (idx % moe_every == moe_offset)
    moe_offset: int = 0
    moe_capacity_factor: float = 1.25

    # hybrid / SSM (Mamba2/SSD)
    attn_every: int = 0          # 0: all layers attend; k>0: 1 attn per k layers
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4

    # VLM cross-attention
    cross_attn_every: int = 0    # k>0: layers with idx % k == k-1 cross-attend
    n_image_tokens: int = 0

    # encoder-decoder
    encoder_layers: int = 0      # >0 → enc-dec; decoder gets cross-attn
    n_audio_frames: int = 0      # stub frontend sequence length

    norm: str = "rmsnorm"        # rmsnorm | layernorm | nonparam_ln
    gated: bool = True           # SwiGLU vs plain GELU MLP
    qkv_bias: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- execution knobs (perf hillclimbing; EXPERIMENTS.md §Perf) ----------
    remat: str = "full"          # full | dots | none  (scan remat policy)
    moe_dispatch: str = "gspmd"  # gspmd | shard_map  (EP dispatch schedule)
    param_dtype: str = "float32" # float32 | bfloat16 (live params; bf16 ⇒
                                 # fp32 master lives in the optimizer state)
    decode_attn: str = "gspmd"   # gspmd | context_parallel: decode-attention
                                 # schedule over the seq-sharded KV cache
    matmul_out: str = "f32"      # f32 | bf16: dot output dtype. JAX lowers
                                 # bf16 matmuls as f32-accumulating dots +
                                 # convert, so GSPMD all-reduces row-parallel
                                 # partial sums in F32; 'bf16' emits bf16
                                 # dots and halves those collectives.

    # --- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.attn_every < 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def block_size(self) -> int:
        """Layers per repeated heterogeneous block (lcm of the patterns)."""
        b = 1
        if self.attn_every > 0:
            b = math.lcm(b, self.attn_every)
        if self.cross_attn_every > 0:
            b = math.lcm(b, self.cross_attn_every)
        if self.moe_experts and self.moe_every > 1:
            b = math.lcm(b, self.moe_every)
        return b

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.block_size == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by block "
            f"pattern {self.block_size}")
        return self.n_layers // self.block_size

    def layer_kind(self, idx: int) -> str:
        """'attn' | 'ssm' for layer ``idx`` within a block."""
        if self.attention_free:
            return "ssm"
        if self.attn_every > 0:
            # Jamba: one attention layer per attn_every, at the middle slot
            return "attn" if idx % self.attn_every == self.attn_every // 2 else "ssm"
        return "attn"

    def layer_is_moe(self, idx: int) -> bool:
        return bool(self.moe_experts) and idx % self.moe_every == self.moe_offset

    def layer_is_cross(self, idx: int) -> bool:
        return (self.cross_attn_every > 0
                and idx % self.cross_attn_every == self.cross_attn_every - 1)

    # --- parameter counts (for roofline MODEL_FLOPS) -------------------------
    def param_count(self, active_only: bool = False) -> float:
        d, hd = self.d_model, self.hd
        total = 0.0
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                total += self.n_heads * hd * d
                if self.layer_is_cross(i):
                    total += 2 * (d * self.n_heads * hd) + 2 * d * self.n_kv_heads * hd
            else:
                d_in = self.ssm_expand * d
                nheads = d_in // self.ssm_head_dim
                total += d * (2 * d_in + 2 * self.ssm_state + nheads)
                total += d_in * self.ssm_conv + d_in * d
            if self.d_ff:
                n_mats = 3 if self.gated else 2
                if self.layer_is_moe(i):
                    e = self.moe_top_k if active_only else self.moe_experts
                    total += e * n_mats * d * self.d_ff + d * self.moe_experts
                else:
                    total += n_mats * d * self.d_ff
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.is_enc_dec:
            # encoder layers: self-attn + FFN at the same width
            total += self.encoder_layers * (
                d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d
                + (3 if self.gated else 2) * d * self.d_ff)
        return total

    def model_flops(self, tokens: float, training: bool = True,
                    decode_kv: int = 0) -> float:
        """6·N·D (training) or 2·N·D (inference) with N = active params.

        ``decode_kv`` adds the attention KV-cache FLOPs (4·kv·d_attn per
        token per attn layer), which 6·N·D omits."""
        n = self.param_count(active_only=True)
        base = (6.0 if training else 2.0) * n * tokens
        if decode_kv:
            n_attn = sum(1 for i in range(self.n_layers)
                         if self.layer_kind(i) == "attn")
            base += (4.0 * decode_kv * self.n_heads * self.hd
                     * n_attn * tokens) * (3.0 if training else 1.0)
        return base

"""Model layers — pure-functional JAX, mesh-agnostic.

Sharding is expressed through logical-axis annotations (``repro.parallel.
logical.shard``) which are no-ops until the launcher installs axis rules, so
the same code runs single-device tests and the 512-chip dry-run.

The attention and SSD implementations here are the *reference* paths (also
serving as the structural twins of the Pallas kernels in ``repro.kernels``);
``use_kernels=True`` in the call context swaps in the fused kernels on TPU.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from ..parallel.logical import shard
from .config import ModelConfig

Pytree = object


def _mm(x: jax.Array, w: jax.Array, cfg: "ModelConfig | None" = None):
    """Projection matmul. With cfg.matmul_out == 'bf16' the dot itself emits
    bf16 (instead of JAX's default f32-accumulate + convert), so GSPMD's
    row-parallel partial-sum all-reduces move bf16 — half the link bytes
    (§Perf knob; numerically the standard Megatron practice)."""
    w = w.astype(x.dtype)
    if (cfg is not None and cfg.matmul_out == "bf16"
            and x.dtype == jnp.bfloat16):
        return jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.bfloat16)
    return x @ w


# =============================== initializers ================================
def _dense_init(key, fan_in: int, shape) -> jax.Array:
    std = 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype=jnp.float32) * std


# ================================ norms ======================================
def rmsnorm(x: jax.Array, w: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if w is not None:
        y = y * w
    return y.astype(dt)


def layernorm(x: jax.Array, w: jax.Array | None, b: jax.Array | None,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        y = y * w
    if b is not None:
        y = y + b
    return y.astype(dt)


def make_norm(cfg: ModelConfig):
    """Returns (init_fn, apply_fn) for the config's norm flavor.

    OLMo's non-parametric LayerNorm carries no weights at all."""
    if cfg.norm == "nonparam_ln":
        return (lambda key, d: {},
                lambda p, x: layernorm(x, None, None))
    if cfg.norm == "layernorm":
        return (lambda key, d: {"w": jnp.ones((d,), jnp.float32),
                                "b": jnp.zeros((d,), jnp.float32)},
                lambda p, x: layernorm(x, p["w"], p["b"]))
    return (lambda key, d: {"w": jnp.ones((d,), jnp.float32)},
            lambda p, x: rmsnorm(x, p["w"]))


# ================================ RoPE =======================================
def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); pos: (S,) or scalar broadcastable positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs    # (S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ============================ attention (ref) ================================
def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Hkv, hd) -> (B, S, Hkv*n_rep, hd) for GQA."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, q_offset: int = 0) -> jax.Array:
    """Dense reference attention. q: (B,Sq,H,hd); k,v: (B,Sk,Hkv,hd)."""
    b, sq, h, hd = q.shape
    n_rep = h // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(ki <= qi, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, block_q: int = 1024,
                      block_k: int = 1024) -> jax.Array:
    """Memory-efficient online-softmax attention (FlashAttention schedule in
    pure jnp — the structural twin of kernels/flash_attention). O(S) memory.

    Shapes as in attention_ref, Sq == Sk required when causal.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    n_rep = h // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(hd)
    nq = sq // block_q
    nk = sk // block_k
    qb = q.reshape(b, nq, block_q, h, hd)

    def q_block(carry, qi):
        qblk = qb[:, qi]                                   # (B, bq, H, hd)
        acc0 = jnp.zeros((b, block_q, h, hd), jnp.float32)
        m0 = jnp.full((b, h, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)

        def kv_block(state, ki):
            acc, m, l = state
            kblk = jax.lax.dynamic_slice_in_dim(k, ki * block_k, block_k, 1)
            vblk = jax.lax.dynamic_slice_in_dim(v, ki * block_k, block_k, 1)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            if causal:
                qpos = qi * block_q + jnp.arange(block_q)[:, None]
                kpos = ki * block_k + jnp.arange(block_k)[None, :]
                s = jnp.where(kpos <= qpos, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = (acc * alpha.transpose(0, 2, 1)[..., None]
                       + jnp.einsum("bhqk,bkhd->bqhd", p,
                                    vblk.astype(jnp.float32)))
            return (acc_new, m_new, l_new), None

        if causal:
            # only lower-triangular kv blocks contribute; still scan all for
            # static shape, masked blocks are numerically no-ops
            pass
        (acc, m, l), _ = jax.lax.scan(kv_block, (acc0, m0, l0),
                                      jnp.arange(nk))
        out = acc / l.transpose(0, 2, 1)[..., None]
        return carry, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(nq))
    # blocks: (nq, B, bq, H, hd) -> (B, S, H, hd)
    return blocks.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def attention(q, k, v, causal=True, q_offset: int = 0,
              chunked_threshold: int = 8192):
    """Dispatch dense vs chunked by sequence length."""
    sk = k.shape[1]
    sq = q.shape[1]
    if sq * sk > chunked_threshold * chunked_threshold // 16 and sq > 1 \
            and sq % 1024 == 0 and sk % 1024 == 0 and q_offset == 0:
        return attention_chunked(q, k, v, causal)
    return attention_ref(q, k, v, causal, q_offset)


# ============================ GQA attention layer ============================
def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": _dense_init(kq, d, (d, cfg.n_heads * hd)),
        "wk": _dense_init(kk, d, (d, cfg.n_kv_heads * hd)),
        "wv": _dense_init(kv, d, (d, cfg.n_kv_heads * hd)),
        "wo": _dense_init(ko, cfg.n_heads * hd, (cfg.n_heads * hd, d)),
    }


def self_attention(p: dict, x: jax.Array, cfg: ModelConfig,
                   positions: jax.Array, causal: bool = True) -> jax.Array:
    b, s, d = x.shape
    hd = cfg.hd
    q = _mm(x, p["wq"], cfg).reshape(b, s, cfg.n_heads, hd)
    k = _mm(x, p["wk"], cfg).reshape(b, s, cfg.n_kv_heads, hd)
    v = _mm(x, p["wv"], cfg).reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    o = attention(q, k, v, causal=causal)
    o = o.reshape(b, s, cfg.n_heads * hd)
    return shard(_mm(o, p["wo"], cfg), "batch", "seq", None)


def cross_attention(p: dict, x: jax.Array, memory: jax.Array,
                    cfg: ModelConfig) -> jax.Array:
    """x: (B,S,d) queries; memory: (B,M,d) (image/audio/encoder states)."""
    b, s, d = x.shape
    hd = cfg.hd
    q = _mm(x, p["wq"], cfg).reshape(b, s, cfg.n_heads, hd)
    k = _mm(memory, p["wk"], cfg).reshape(
        b, memory.shape[1], cfg.n_kv_heads, hd)
    v = _mm(memory, p["wv"], cfg).reshape(
        b, memory.shape[1], cfg.n_kv_heads, hd)
    q = shard(q, "batch", "seq", "heads", None)
    o = attention(q, k, v, causal=False)
    o = o.reshape(b, s, cfg.n_heads * hd)
    return shard(_mm(o, p["wo"], cfg), "batch", "seq", None)


def decode_self_attention(p: dict, x: jax.Array, cache_k: jax.Array,
                          cache_v: jax.Array, pos: jax.Array,
                          cfg: ModelConfig):
    """One-token decode. x: (B,1,d); cache_{k,v}: (B,Smax,Hkv,hd); pos scalar.

    Under the production mesh the cache sequence dim is sharded on 'model'
    (context parallelism): GSPMD turns the softmax/O reductions into
    collectives; the hand-fused path is kernels/decode_attention.
    """
    b, _, d = x.shape
    hd = cfg.hd
    q = _mm(x, p["wq"], cfg).reshape(b, 1, cfg.n_heads, hd)
    k = _mm(x, p["wk"], cfg).reshape(b, 1, cfg.n_kv_heads, hd)
    v = _mm(x, p["wv"], cfg).reshape(b, 1, cfg.n_kv_heads, hd)
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k = apply_rope(k, pos[None], cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos, axis=1)
    if cfg.decode_attn == "context_parallel":
        from ..parallel.logical import current_mesh
        mesh = current_mesh()
        if (mesh is not None and "model" in mesh.axis_names
                and cache_k.shape[1] % mesh.shape["model"] == 0):
            from ..parallel.context import decode_attention_cache_layout
            ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
            o = decode_attention_cache_layout(
                mesh, q[:, 0].astype(jnp.float32),
                cache_k, cache_v, pos + 1, ba)
            o = o.astype(x.dtype).reshape(b, 1, cfg.n_heads * hd)
            return _mm(o, p["wo"], cfg), cache_k, cache_v
    smax = cache_k.shape[1]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    kk = _repeat_kv(cache_k, n_rep)
    vv = _repeat_kv(cache_v, n_rep)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / math.sqrt(hd)
    mask = jnp.arange(smax)[None, None, None, :] <= pos
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    # keep the PV contraction in f32: downcasting probs to the cache dtype
    # costs ~3 decimal digits for nothing and makes greedy decode disagree
    # with the context-parallel path (which reduces in f32) on near-ties
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, vv.astype(jnp.float32))
    o = o.astype(x.dtype).reshape(b, 1, cfg.n_heads * hd)
    return o @ p["wo"].astype(x.dtype), cache_k, cache_v


# ================================= MLP =======================================
def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ki, kg, ko = jax.random.split(key, 3)
    p = {"wi": _dense_init(ki, d, (d, f)),
         "wo": _dense_init(ko, f, (f, d))}
    if cfg.gated:
        p["wg"] = _dense_init(kg, d, (d, f))
    return p


def mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = _mm(x, p["wi"], cfg)
    h = shard(h, "batch", "seq", "ff")
    if "wg" in p:
        h = jax.nn.silu(_mm(x, p["wg"], cfg)) * h
    else:
        h = jax.nn.gelu(h)
    return shard(_mm(h, p["wo"], cfg), "batch", "seq", None)


# ================================= MoE =======================================
def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    kr, ki, kg, ko = jax.random.split(key, 4)
    p = {"router": _dense_init(kr, d, (d, e)),
         "wi": _dense_init(ki, d, (e, d, f)),
         "wo": _dense_init(ko, f, (e, f, d))}
    if cfg.gated:
        p["wg"] = _dense_init(kg, d, (e, d, f))
    return p


def moe(p: dict, x: jax.Array, cfg: ModelConfig,
        capacity_factor: float | None = None) -> jax.Array:
    """Top-k token-choice MoE with capacity-bounded scatter dispatch
    (Switch/GShard style). Experts are sharded on the 'model' axis (EP);
    under GSPMD the dispatch/combine scatters lower to all-to-alls.

    With ``cfg.moe_dispatch == 'shard_map'`` and an active mesh, the
    hand-scheduled expert-parallel dispatch (parallel/moe.py) replaces the
    GSPMD auto-partitioned scatter — O(T·d) collective instead of
    O(E·cap·d). See EXPERIMENTS.md §Perf.
    """
    if cfg.moe_dispatch == "shard_map":
        from ..parallel.logical import current_mesh
        mesh = current_mesh()
        if (mesh is not None and "model" in mesh.axis_names
                and cfg.moe_experts % mesh.shape["model"] == 0):
            from ..parallel.moe import moe_shard_map
            return moe_shard_map(p, x, cfg, mesh, capacity_factor)
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                             # (T,k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    cf = cfg.moe_capacity_factor if capacity_factor is None else capacity_factor
    cap = int(max(1, math.ceil(t * k / e * cf)))
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)                 # (T,k,E)
    flat = onehot.reshape(t * k, e)
    # position of each (token, slot) within its expert's buffer
    rank = jnp.cumsum(flat, axis=0) - 1                              # (T*k,E)
    rank = (rank * flat).sum(-1).reshape(t, k)
    eidx = idx                                                       # (T,k)
    keep = rank < cap
    # scatter tokens into (E, cap, d)
    buf = jnp.zeros((e, cap, d), x.dtype)
    tok_rep = jnp.broadcast_to(xt[:, None, :], (t, k, d)).reshape(t * k, d)
    ei = jnp.where(keep, eidx, 0).reshape(-1)
    ri = jnp.where(keep, rank, 0).reshape(-1)
    w_keep = (gates * keep).reshape(-1)
    buf = buf.at[ei, ri].add(tok_rep * (w_keep > 0)[:, None].astype(x.dtype))
    buf = shard(buf, "experts", None, None)
    # expert computation (E, cap, d) x (E, d, f)
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(x.dtype))
    if "wg" in p:
        g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))
    out = shard(out, "experts", None, None)
    # combine: gather each (token, slot)'s result and weight by gate
    y = out[ei, ri].reshape(t, k, d)
    y = (y * (w_keep.reshape(t, k, 1)).astype(x.dtype)).sum(axis=1)
    return y.reshape(b, s, d)


def moe_dense(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Dropless MoE for tiny token counts (decode): every expert processes
    all tokens; outputs combine by top-k gates. Exact (no capacity drops),
    and with experts sharded on 'model' the combine is a psum — no dispatch
    all-to-all, which at T=batch tokens/step is the cheaper schedule.
    """
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    xt = x.reshape(b * s, d)
    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    combine = jnp.zeros((b * s, e), jnp.float32)
    combine = combine.at[jnp.arange(b * s)[:, None], idx].add(gates)
    h = jnp.einsum("td,edf->etf", xt, p["wi"].astype(x.dtype))
    if "wg" in p:
        g = jnp.einsum("td,edf->etf", xt, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("etf,efd->etd", h, p["wo"].astype(x.dtype))
    y = jnp.einsum("etd,te->td", y, combine.astype(x.dtype))
    return y.reshape(b, s, d)


def moe_aux_loss(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Load-balancing auxiliary loss (Switch §2.2)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    _, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    frac = jax.nn.one_hot(idx, cfg.moe_experts).mean(axis=(0, 1))
    imp = probs.mean(0)
    return cfg.moe_experts * jnp.sum(frac * imp)


# =========================== Mamba2 / SSD layer ==============================
def init_ssm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * n
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "in_proj": _dense_init(k1, d, (d, 2 * d_in + 2 * n + h)),
        "conv_w": _dense_init(k2, cfg.ssm_conv, (cfg.ssm_conv, conv_ch)),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_w": jnp.ones((d_in,), jnp.float32),
        "out_proj": _dense_init(k3, d_in, (d_in, d)),
    }


def _ssd_chunk_scan(xs, dt, Bm, Cm, A_log, chunk: int = 128):
    """SSD chunked algorithm (Mamba2 [arXiv:2405.21060] listing 1, jnp ref).

    xs: (B,S,H,P)  dt: (B,S,H)  Bm/Cm: (B,S,N)  A_log: (H,)
    Returns y: (B,S,H,P) and final state (B,H,P,N).
    """
    b, s, h, p = xs.shape
    n = Bm.shape[-1]
    nc = s // chunk
    A = -jnp.exp(A_log)                                   # (H,)
    dA = dt * A                                           # (B,S,H)

    xs = xs.reshape(b, nc, chunk, h, p)
    dt_c = dt.reshape(b, nc, chunk, h)
    dA_c = dA.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)

    # cumulative decay within chunk
    csum = jnp.cumsum(dA_c, axis=2)                       # (B,nc,Q,H)
    # intra-chunk: L[i,j] = exp(csum_i - csum_j) for i >= j
    diff = csum[:, :, :, None, :] - csum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)            # (B,nc,Q,Q)
    xdt = xs * dt_c[..., None]                            # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp",
                         cb, L.transpose(0, 1, 2, 3, 4), xdt)

    # chunk states: S_c = sum_j exp(csum_last - csum_j) B_j x_j dt_j
    decay_out = jnp.exp(csum[:, :, -1:, :] - csum)        # (B,nc,Q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchnp",
                        Bc, decay_out, xdt)               # (B,nc,H,N,P)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(csum[:, :, -1, :])              # (B,nc,H)

    def step(hstate, inp):
        st, dec = inp                                     # (B,H,N,P), (B,H)
        out = hstate
        hstate = hstate * dec[..., None, None] + st
        return hstate, out

    h0 = jnp.zeros((b, h, n, p), xs.dtype)
    hfinal, h_prev = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)              # (B,nc,H,N,P)

    decay_in = jnp.exp(csum)                              # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cc, decay_in, h_prev)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, hfinal.transpose(0, 1, 3, 2)                # state (B,H,P,N)


def ssm_layer(p: dict, x: jax.Array, cfg: ModelConfig,
              chunk: int = 128) -> jax.Array:
    """Mamba2 block forward (training/prefill)."""
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = d_in // cfg.ssm_head_dim
    zxbcdt = _mm(x, p["in_proj"], cfg)
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n], axis=-1)
    # causal depthwise conv over [x;B;C]
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype))
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xs = xs.reshape(b, s, h, cfg.ssm_head_dim)
    xs = shard(xs, "batch", "seq", "heads", None)
    y, _ = _ssd_chunk_scan(xs.astype(jnp.float32), dt,
                           Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                           p["A_log"], chunk=min(chunk, s))
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])          # gated norm
    return shard(_mm(y, p["out_proj"], cfg), "batch", "seq", None)


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal 1-D conv. x: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out


def ssm_decode_step(p: dict, x: jax.Array, state: jax.Array,
                    conv_cache: jax.Array, cfg: ModelConfig):
    """One-token SSD recurrence. x: (B,1,d); state: (B,H,P,N);
    conv_cache: (B, K-1, conv_ch). Returns (y, state, conv_cache)."""
    b, _, d = x.shape
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    zxbcdt = _mm(x, p["in_proj"], cfg)
    z, xbc, dt = jnp.split(zxbcdt[:, 0], [d_in, 2 * d_in + 2 * n], axis=-1)
    w = p["conv_w"].astype(x.dtype)                       # (K, C)
    window = jnp.concatenate([conv_cache, xbc[:, None, :]], axis=1)  # (B,K,C)
    xbc_c = jnp.einsum("bkc,kc->bc", window, w)
    conv_cache = window[:, 1:]
    xbc_c = jax.nn.silu(xbc_c)
    xs, Bm, Cm = jnp.split(xbc_c, [d_in, d_in + n], axis=-1)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"])                              # (H,)
    dA = jnp.exp(dtf * A)                                 # (B,H)
    xs = xs.reshape(b, h, P).astype(jnp.float32)
    state = (state * dA[..., None, None]
             + jnp.einsum("bhp,bn,bh->bhpn", xs, Bm.astype(jnp.float32), dtf))
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(b, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return (y @ p["out_proj"].astype(x.dtype))[:, None, :], state, conv_cache

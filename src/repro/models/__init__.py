from .config import ModelConfig
from .inputs import decode_specs, input_specs, synth_batch, train_batch_specs
from .transformer import (decode_step, encode, forward, init_cache,
                          init_params, loss_fn, param_count, prefill)

__all__ = [
    "ModelConfig", "decode_specs", "input_specs", "synth_batch",
    "train_batch_specs", "decode_step", "encode", "forward", "init_cache",
    "init_params", "loss_fn", "param_count", "prefill",
]

"""Runtime transformer model stack (jax).

Attributes resolve lazily (PEP 562): ``ModelConfig`` lives in the
jax-free :mod:`.config`, everything else imports jax on first touch.
Eager imports here used to drag jax into the *analytical* DSE layer
through the model-config references in ``repro.configs`` — which silently
flipped ``DSEEngine``'s pool auto-detection from fork to spawn (forking a
jax-threaded process is a deadlock risk) and cost every sweep its cheap
fork workers.  ``from repro.models import init_params`` still works; it
just pays the jax import only where the runtime stack is actually used.
"""
from .config import ModelConfig

_INPUTS = ("decode_specs", "input_specs", "synth_batch",
           "train_batch_specs")
_TRANSFORMER = ("decode_step", "encode", "forward", "init_cache",
                "init_params", "loss_fn", "param_count", "prefill")

__all__ = ["ModelConfig", *_INPUTS, *_TRANSFORMER]


def __getattr__(name: str):
    if name in _INPUTS:
        from . import inputs as mod
    elif name in _TRANSFORMER:
        from . import transformer as mod
    elif name in ("inputs", "transformer", "layers"):
        import importlib

        return importlib.import_module(f".{name}", __name__)
    else:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(mod, name)

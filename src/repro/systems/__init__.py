from .chips import (ChipSpec, MemorySpec, InterconnectSpec, CHIPS, MEMORIES,
                    INTERCONNECTS, TPU_V5E)
from .topology import (TopologyDim, Topology, ring, fully_connected, switch,
                       torus2d, torus3d, dgx1, dgx2, dragonfly, TOPOLOGIES,
                       make_topology)
from .system import SystemSpec

__all__ = [
    "ChipSpec", "MemorySpec", "InterconnectSpec", "CHIPS", "MEMORIES",
    "INTERCONNECTS", "TPU_V5E", "TopologyDim", "Topology", "ring",
    "fully_connected", "switch", "torus2d", "torus3d", "dgx1", "dgx2",
    "dragonfly", "TOPOLOGIES", "make_topology", "SystemSpec",
]

"""Full system specification = chips × memory × interconnect topology."""
from __future__ import annotations

import dataclasses

from .chips import ChipSpec, MemorySpec
from .topology import Topology


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """A homogeneous distributed system (paper Fig 5 left).

    ``topology.total_chips`` chips, each ``chip`` with off-chip ``memory``;
    dims of ``topology`` are assignable to parallelization strategies.
    """

    name: str
    chip: ChipSpec
    memory: MemorySpec
    topology: Topology

    @property
    def n_chips(self) -> int:
        return self.topology.total_chips

    @property
    def peak_flops(self) -> float:
        """Aggregate peak FLOP/s."""
        return self.n_chips * self.chip.peak_flops

    # --- price / power (paper §VI.C: silicon + memory + links) -------------
    def price(self) -> float:
        per_chip = (self.chip.price + self.memory.price
                    + self.topology.links_per_chip()
                    * max(d.link.price_per_link for d in self.topology.dims))
        return per_chip * self.n_chips

    def power(self) -> float:
        per_chip = (self.chip.power + self.memory.power
                    + self.topology.links_per_chip()
                    * max(d.link.power_per_link for d in self.topology.dims))
        return per_chip * self.n_chips

"""Interconnection topologies + collective communication cost models.

Paper §IV.C: a multi-dimensional topology is a hierarchical composition of
1-D topologies (ring / fully-connected / switch), following ASTRA-sim [71];
each network dimension is assigned to exactly one parallelization strategy.

Collective latencies use the bandwidth-term formulas from Thakur et al. [77]
(MPICH collectives) and BlueConnect [19] multi-dim decomposition:

  ring     all-gather / reduce-scatter: (p-1)/p · n / bw
           all-reduce: 2(p-1)/p · n / bw
           all-to-all: each chip exchanges n/p with p-1 peers over ring links →
                        (p-1)/p · n / bw (store-and-forward, bidirectional links)
  fully-connected (one direct link per peer, per-link bandwidth bw):
           all-gather: each chip sends its n/p shard on p-1 links in parallel →
                        n / (p · bw)
           all-reduce: reduce-scatter + all-gather = 2n / (p · bw)
           all-to-all: each pair exchanges n/p directly → n / (p · bw)
  switch   (non-blocking, bw per chip port): bandwidth-optimal algorithms →
           same as ring bandwidth terms (halving-doubling): all-reduce
           2(p-1)/p·n/bw; all-to-all limited by port: (p-1)/p · n / bw

Latency (alpha) terms use hops × link latency; they matter only for tiny
messages (decode serving) and are included additively.

All sizes n are *total* collective payload bytes (e.g. full gradient size for
an all-reduce); bw is per-link bytes/s.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal, Sequence

from .chips import InterconnectSpec

DimKind = Literal["ring", "fc", "switch"]


@dataclasses.dataclass(frozen=True)
class TopologyDim:
    """One 1-D dimension of a composed topology."""

    size: int                    # chips along this dimension
    kind: DimKind
    link: InterconnectSpec

    # -- per-collective bandwidth+latency cost (seconds) ---------------------
    def _alpha(self, steps: int) -> float:
        return steps * self.link.latency

    def all_gather(self, n: float) -> float:
        p, bw = self.size, self.link.bandwidth
        if p == 1:
            return 0.0
        if self.kind == "fc":
            return n / (p * bw) + self._alpha(1)
        return (p - 1) / p * n / bw + self._alpha(p - 1)

    def reduce_scatter(self, n: float) -> float:
        return self.all_gather(n)  # bandwidth-symmetric

    def all_reduce(self, n: float) -> float:
        p = self.size
        if p == 1:
            return 0.0
        return self.reduce_scatter(n) + self.all_gather(n)

    def all_to_all(self, n: float) -> float:
        """n is the *global* tensor size; each chip holds n/p and exchanges
        (p-1)/p of its shard.

        ring:   pairwise byte·hops = n·(p-1)/p · mean_dist(p/4), balanced over
                2p directed links → n·(p-1)/(8p·bw)
        fc:     each pair exchanges n/p² on its own link → n/(p²·bw)
        switch: port-limited: each chip injects n/p·(p-1)/p → n(p-1)/(p²·bw)
        """
        p, bw = self.size, self.link.bandwidth
        if p == 1:
            return 0.0
        if self.kind == "fc":
            return n / (p * p * bw) + self._alpha(1)
        if self.kind == "switch":
            return n * (p - 1) / (p * p * bw) + self._alpha(1)
        return n * (p - 1) / (8 * p * bw) + self._alpha(p // 2)

    def broadcast(self, n: float) -> float:
        p, bw = self.size, self.link.bandwidth
        if p == 1:
            return 0.0
        if self.kind == "fc":
            return n / bw / (p - 1) + self._alpha(1)  # scatter+allgather pipelined
        return n / bw + self._alpha(p - 1)            # pipelined ring broadcast

    def p2p(self, n: float) -> float:
        return n / self.link.bandwidth + self._alpha(1)

    # links owned per chip along this dim (for price/power)
    @property
    def links_per_chip(self) -> float:
        if self.size == 1:
            return 0.0
        if self.kind == "ring":
            return 2.0
        if self.kind == "fc":
            return float(self.size - 1)
        return 1.0  # switch port


@dataclasses.dataclass(frozen=True)
class Topology:
    """A hierarchical composition of 1-D dims (innermost first)."""

    name: str
    dims: tuple[TopologyDim, ...]

    @property
    def total_chips(self) -> int:
        out = 1
        for d in self.dims:
            out *= d.size
        return out

    def links_per_chip(self) -> float:
        return sum(d.links_per_chip for d in self.dims)

    # BlueConnect-style multi-dim collective over a *subset* of dims:
    # run the per-dim collective sequentially; for all-reduce, reduce-scatter
    # inward then all-gather outward so later dims operate on shrunken shards.
    def all_reduce(self, n: float, dim_idx: Sequence[int]) -> float:
        t, shard = 0.0, n
        dims = [self.dims[i] for i in dim_idx]
        for d in dims:                       # reduce-scatter inward
            t += d.reduce_scatter(shard)
            shard /= d.size
        for d in reversed(dims):             # all-gather outward
            t += d.all_gather(shard * d.size)
            shard *= d.size
        return t

    def all_gather(self, n: float, dim_idx: Sequence[int]) -> float:
        t = 0.0
        shard = n / math.prod(self.dims[i].size for i in dim_idx)
        for i in dim_idx:
            d = self.dims[i]
            shard *= d.size
            t += d.all_gather(shard)
        return t

    def reduce_scatter(self, n: float, dim_idx: Sequence[int]) -> float:
        t, shard = 0.0, n
        for i in dim_idx:
            d = self.dims[i]
            t += d.reduce_scatter(shard)
            shard /= d.size
        return t

    def all_to_all(self, n: float, dim_idx: Sequence[int]) -> float:
        return sum(self.dims[i].all_to_all(n) for i in dim_idx)

    def broadcast(self, n: float, dim_idx: Sequence[int]) -> float:
        return sum(self.dims[i].broadcast(n) for i in dim_idx)

    def p2p(self, n: float, dim_idx: Sequence[int]) -> float:
        # point-to-point between neighbors along the first listed dim
        if not dim_idx:
            return 0.0
        return self.dims[dim_idx[0]].p2p(n)


# --- the paper's five topology families (§VI.C), parameterized by chip count -
def ring(p: int, link: InterconnectSpec) -> Topology:
    return Topology(f"ring{p}", (TopologyDim(p, "ring", link),))


def fully_connected(p: int, link: InterconnectSpec) -> Topology:
    return Topology(f"fc{p}", (TopologyDim(p, "fc", link),))


def switch(p: int, link: InterconnectSpec) -> Topology:
    return Topology(f"switch{p}", (TopologyDim(p, "switch", link),))


def _near_square(p: int) -> tuple[int, int]:
    a = int(math.isqrt(p))
    while p % a:
        a -= 1
    return a, p // a


def torus2d(p: int, link: InterconnectSpec) -> Topology:
    a, b = _near_square(p)
    return Topology(f"torus2d_{a}x{b}",
                    (TopologyDim(a, "ring", link), TopologyDim(b, "ring", link)))


def torus3d(p: int, link: InterconnectSpec) -> Topology:
    a = round(p ** (1 / 3))
    while p % a:
        a -= 1
    b, c = _near_square(p // a)
    return Topology(f"torus3d_{a}x{b}x{c}",
                    (TopologyDim(a, "ring", link), TopologyDim(b, "ring", link),
                     TopologyDim(c, "ring", link)))


def dgx1(p: int, link: InterconnectSpec, scale_out: InterconnectSpec | None = None) -> Topology:
    """8-chip NVLink hybrid-mesh node (modeled fc8), switch scale-out."""
    nodes = max(p // 8, 1)
    return Topology(f"dgx1_{nodes}x8",
                    (TopologyDim(min(p, 8), "fc", link),
                     TopologyDim(nodes, "switch", scale_out or link)))


def dgx2(p: int, link: InterconnectSpec, scale_out: InterconnectSpec | None = None) -> Topology:
    """16-chip NVSwitch node, switch scale-out."""
    nodes = max(p // 16, 1)
    return Topology(f"dgx2_{nodes}x16",
                    (TopologyDim(min(p, 16), "switch", link),
                     TopologyDim(nodes, "switch", scale_out or link)))


def dragonfly(p: int, link: InterconnectSpec) -> Topology:
    """Dragonfly [47]: fully-connected groups, fully-connected global links."""
    g = int(math.isqrt(p))
    while p % g:
        g -= 1
    return Topology(f"dragonfly_{g}x{p // g}",
                    (TopologyDim(g, "fc", link), TopologyDim(p // g, "fc", link)))


TOPOLOGIES = {
    "ring": ring,
    "torus2d": torus2d,
    "torus3d": torus3d,
    "dgx1": dgx1,
    "dgx2": dgx2,
    "dragonfly": dragonfly,
    "switch": switch,
    "fc": fully_connected,
}


def make_topology(kind: str, p: int, link: InterconnectSpec) -> Topology:
    return TOPOLOGIES[kind](p, link)

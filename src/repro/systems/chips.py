"""Accelerator / memory / interconnect specifications (paper Table V + §VI.C).

All bandwidths are bytes/s, capacities bytes, throughputs FLOP/s.
Price in USD, power in watts. Price/power constants follow the paper's cited
sources; where the paper gives only relative statements we use public figures
and keep them in one place so DSE conclusions are reproducible.
"""
from __future__ import annotations

import dataclasses

GB = 1e9
MB = 1e6
TFLOPS = 1e12


@dataclasses.dataclass(frozen=True)
class MemorySpec:
    name: str
    bandwidth: float          # bytes/s per chip
    capacity: float           # bytes per chip
    price: float              # USD per chip's worth
    power: float              # W per chip's worth


@dataclasses.dataclass(frozen=True)
class InterconnectSpec:
    name: str
    bandwidth: float          # bytes/s per link (unidirectional)
    latency: float            # s per hop
    price_per_link: float     # USD
    power_per_link: float     # W


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """A data-parallel accelerator chip (paper Fig 5 right).

    ``tiles × tile_flops`` is the peak FLOP/s; SRAM is the on-chip capacity
    that bounds intra-chip fusion (VMEM for TPUs). ``dataflow`` marks
    spatial-dataflow architectures (RDU/WSE) vs kernel-by-kernel (GPU/TPU) —
    the *default* execution model; DFModel can map dataflow execution onto
    either (the paper's Fig 19 sweep does exactly that).
    """

    name: str
    tiles: int                # t_lim
    tile_flops: float         # t_flop (FLOP/s per tile)
    sram_capacity: float      # s_cap bytes
    price: float              # USD (silicon only)
    power: float              # W (silicon only)
    dataflow: bool = False

    @property
    def peak_flops(self) -> float:
        return self.tiles * self.tile_flops


# --- paper Table V chips (half precision) -----------------------------------
H100 = ChipSpec("H100", tiles=132, tile_flops=993 * TFLOPS / 132,
                sram_capacity=113 * MB, price=30_000, power=700, dataflow=False)
TPU_V4 = ChipSpec("TPUv4", tiles=8, tile_flops=275 * TFLOPS / 8,
                  sram_capacity=160 * MB, price=12_000, power=192, dataflow=False)
SN30 = ChipSpec("SN30", tiles=1280, tile_flops=614 * TFLOPS / 1280,
                sram_capacity=640 * MB, price=25_000, power=350, dataflow=True)
WSE2 = ChipSpec("WSE2", tiles=850_000, tile_flops=7500 * TFLOPS / 850_000,
                sram_capacity=40 * GB, price=2_500_000, power=15_000, dataflow=True)

# §VII case-study chips
SN10 = ChipSpec("SN10", tiles=1024, tile_flops=307.2 * TFLOPS / 1024,
                sram_capacity=320 * MB, price=20_000, power=300, dataflow=True)
SN40L = ChipSpec("SN40L", tiles=1040, tile_flops=640 * TFLOPS / 1040,
                 sram_capacity=520 * MB, price=28_000, power=350, dataflow=True)

# our deployment target (roofline constants from the prompt):
# 197 bf16 TFLOP/s, 819 GB/s HBM, 50 GB/s/link ICI, 128 MiB VMEM.
TPU_V5E = ChipSpec("TPUv5e", tiles=4, tile_flops=197 * TFLOPS / 4,
                   sram_capacity=128 * 2**20, price=6_000, power=200,
                   dataflow=False)

A100 = ChipSpec("A100", tiles=108, tile_flops=312 * TFLOPS / 108,
                sram_capacity=40 * MB, price=15_000, power=400, dataflow=False)

CHIPS: dict[str, ChipSpec] = {c.name: c for c in
                              [H100, TPU_V4, SN30, WSE2, SN10, SN40L, TPU_V5E, A100]}

# --- memory technologies (paper §VI.C: DDR4 200GB/s, HBM3 3TB/s) ------------
DDR = MemorySpec("DDR", bandwidth=200 * GB, capacity=1536 * GB,
                 price=4_000, power=40)
HBM = MemorySpec("HBM", bandwidth=3000 * GB, capacity=96 * GB,
                 price=12_000, power=120)
# §VIII.C 3D memory sweep points
DDR_2D = MemorySpec("DDR2D", bandwidth=100 * GB, capacity=1536 * GB,
                    price=3_000, power=30)
HBM_25D = MemorySpec("HBM2.5D", bandwidth=1000 * GB, capacity=96 * GB,
                     price=10_000, power=100)
MEM_3D = MemorySpec("3D", bandwidth=100_000 * GB, capacity=64 * GB,
                    price=20_000, power=160)
HBM_V5E = MemorySpec("HBMv5e", bandwidth=819 * GB, capacity=16 * GB,
                     price=4_000, power=60)

MEMORIES: dict[str, MemorySpec] = {m.name: m for m in
                                   [DDR, HBM, DDR_2D, HBM_25D, MEM_3D, HBM_V5E]}

# --- interconnect technologies (paper §VI.C: PCIe4 25GB/s, NVLink4 900GB/s) --
PCIE = InterconnectSpec("PCIe", bandwidth=25 * GB, latency=500e-9,
                        price_per_link=100, power_per_link=5)
NVLINK = InterconnectSpec("NVLink", bandwidth=900 * GB, latency=150e-9,
                          price_per_link=2_000, power_per_link=30)
ICI = InterconnectSpec("ICI", bandwidth=50 * GB, latency=200e-9,
                       price_per_link=400, power_per_link=10)

INTERCONNECTS: dict[str, InterconnectSpec] = {i.name: i
                                              for i in [PCIE, NVLINK, ICI]}


# --- scaled variants ("H100@x1.25") ------------------------------------------
# Dense DSE grids (repro.search.DenseGridSpec) interpolate between the
# paper's Table V technology points by scaling a registered spec's
# *performance* fields.  The variants are resolved by pure functions from
# the name alone — no registry mutation — so a grid cell naming
# "H100@x1.25" builds the same SystemSpec in every process regardless of
# pool start method (spawn workers re-import this module fresh).
#
# Scaling deliberately leaves price/power untouched: a ×1.25 chip at ×1.0
# cost is strictly better on cost efficiency, which is what creates
# genuine Pareto trade-offs across the scale axis instead of a uniform
# shift.
_SCALE_SEP = "@x"


def _split_scaled(name: str) -> tuple[str, float]:
    """``"H100@x1.25"`` → ``("H100", 1.25)``; plain names → scale 1.0."""
    base, sep, suffix = name.partition(_SCALE_SEP)
    if not sep:
        return name, 1.0
    try:
        scale = float(suffix)
    except ValueError:
        raise ValueError(f"bad scale suffix in spec name {name!r}") from None
    if not scale > 0.0:
        raise ValueError(f"scale must be positive in spec name {name!r}")
    return base, scale


def resolve_chip(name: str) -> ChipSpec:
    base, scale = _split_scaled(name)
    chip = CHIPS[base]
    if scale == 1.0:
        return chip
    return dataclasses.replace(chip, name=name,
                               tile_flops=chip.tile_flops * scale)


def resolve_memory(name: str) -> MemorySpec:
    base, scale = _split_scaled(name)
    mem = MEMORIES[base]
    if scale == 1.0:
        return mem
    return dataclasses.replace(mem, name=name,
                               bandwidth=mem.bandwidth * scale,
                               capacity=mem.capacity * scale)


def resolve_interconnect(name: str) -> InterconnectSpec:
    base, scale = _split_scaled(name)
    net = INTERCONNECTS[base]
    if scale == 1.0:
        return net
    return dataclasses.replace(net, name=name,
                               bandwidth=net.bandwidth * scale)

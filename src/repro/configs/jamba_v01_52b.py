"""AI21 Jamba-v0.1 52B [arXiv:2403.19887; hf].

Hybrid: 1 attention layer per 8 (7 Mamba : 1 attn), MoE 16 experts top-2 on
every other layer, GQA 32 q / 8 kv.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba_v01_52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65_536,
    moe_experts=16, moe_top_k=2, moe_every=2, moe_offset=1,
    attn_every=8, ssm_state=16, ssm_expand=2, ssm_head_dim=64,
)

SMOKE = ModelConfig(
    moe_capacity_factor=8.0,
    name="jamba_smoke", family="hybrid",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512,
    moe_experts=4, moe_top_k=2, moe_every=2, moe_offset=1,
    attn_every=2, ssm_state=16, ssm_expand=2, ssm_head_dim=32,
)

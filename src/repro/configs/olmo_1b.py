"""AI2 OLMo-1B [arXiv:2402.00838; hf].

Dense decoder with NON-PARAMETRIC LayerNorm (no scale/bias — the arch's
distinguishing feature), MHA (16/16), vocab 50304.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo_1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=50_304, norm="nonparam_ln", gated=False,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="olmo_smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab=512, norm="nonparam_ln", gated=False,
    tie_embeddings=True,
)

"""Mistral-Nemo 12B [hf:mistralai/Mistral-Nemo-Base-2407; hf].

Dense decoder, GQA (32 q / 8 kv), 128k context, head_dim 128 (d_model 5120).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral_nemo_12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131_072, head_dim=128, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="mistral_nemo_smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=384, vocab=512, head_dim=32,
)

"""Cohere Command-R 35B [hf:CohereForAI/c4ai-command-r-v01; unverified].

Dense decoder, GQA (64 q heads / 8 kv), no biases, large 256k vocab.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="command_r_35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256_000, norm="layernorm", gated=True,
    rope_theta=8e6,
)

SMOKE = ModelConfig(
    name="command_r_smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=352, vocab=512, norm="layernorm", gated=True,
)

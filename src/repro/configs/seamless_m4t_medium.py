"""Meta SeamlessM4T-medium backbone [arXiv:2308.11596; hf].

Encoder–decoder; the speech frontend is a STUB supplying precomputed frame
embeddings (per the assignment). 12 encoder + 12 decoder layers, MHA 16/16,
every decoder layer cross-attends to the encoder memory.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless_m4t_medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256_206, norm="layernorm", gated=False,
    encoder_layers=12, cross_attn_every=1, n_audio_frames=1024,
)

SMOKE = ModelConfig(
    name="seamless_smoke", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512, norm="layernorm", gated=False,
    encoder_layers=2, cross_attn_every=1, n_audio_frames=32,
)

"""Mamba2-130M — SSD (state-space duality) [arXiv:2405.21060; unverified].

Pure SSM: attention-free, 24 layers, d_model 768, ssm_state 128; no FFN
(d_ff=0) — the Mamba block is the whole layer.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2_130m", family="ssm",
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=0, vocab=50_280, attn_every=-1,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2_smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=512, attn_every=-1,
    ssm_state=32, ssm_expand=2, ssm_head_dim=32,
    tie_embeddings=True,
)

"""GPT-3 175B [Brown et al., arXiv:2005.14165] — the paper's §VII workload,
runnable through the same stack for the mapping case study.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gpt3_175b", family="dense",
    n_layers=96, d_model=12288, n_heads=96, n_kv_heads=96,
    d_ff=49152, vocab=50_257, norm="layernorm", gated=False,
)

SMOKE = ModelConfig(
    name="gpt3_smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=8,
    d_ff=512, vocab=512, norm="layernorm", gated=False,
)

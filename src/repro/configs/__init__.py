"""Assigned-architecture registry (``--arch <id>``) + input-shape sets.

Each module defines ``CONFIG`` (the exact public-literature configuration)
and ``SMOKE`` (a reduced same-family config for CPU tests). Sources are cited
in each file.
"""
from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig

ARCH_IDS = [
    "command_r_35b",
    "minitron_4b",
    "mistral_nemo_12b",
    "olmo_1b",
    "llama32_vision_11b",
    "olmoe_1b_7b",
    "qwen3_moe_235b",
    "jamba_v01_52b",
    "seamless_m4t_medium",
    "mamba2_130m",
    # paper's own workloads, runnable through the same stack
    "gpt3_175b",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    phase: str               # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE if smoke else mod.CONFIG


def cells(arch: str) -> list[str]:
    """Applicable shape names for an arch (long_500k only for sub-quadratic
    families — full-attention archs skip it, per DESIGN.md §4)."""
    cfg = get_config(arch)
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return names

"""AI2 OLMoE-1B-7B [arXiv:2409.02060; hf].

MoE decoder: 64 experts, top-8, per-expert d_ff=1024, MHA (16/16).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe_1b_7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50_304, moe_experts=64, moe_top_k=8,
)

SMOKE = ModelConfig(
    moe_capacity_factor=8.0,
    name="olmoe_smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab=512, moe_experts=8, moe_top_k=2,
)

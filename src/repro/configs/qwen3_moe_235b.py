"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf].

MoE decoder: 94 layers, 128 experts top-8, per-expert d_ff=1536,
GQA 64 q / 4 kv heads, head_dim 128.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_moe_235b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151_936, head_dim=128,
    moe_experts=128, moe_top_k=8, rope_theta=1e6,
)

SMOKE = ModelConfig(
    moe_capacity_factor=8.0,
    name="qwen3_moe_smoke", family="moe",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=64, vocab=512, head_dim=16, moe_experts=8, moe_top_k=2,
)

"""NVIDIA Minitron-4B — pruned Nemotron [arXiv:2407.14679; hf].

Dense decoder, GQA (24 q / 8 kv), huge-vocab (256k) distillation target.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron_4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab=256_000, norm="layernorm", gated=False,
)

SMOKE = ModelConfig(
    name="minitron_smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=288, vocab=512, norm="layernorm", gated=False,
)

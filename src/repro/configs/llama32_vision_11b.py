"""Llama-3.2-Vision 11B backbone [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]. Backbone ONLY per the assignment: the vision tower is a stub
that supplies precomputed patch embeddings; every 5th decoder layer
cross-attends to them.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama32_vision_11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128_256, rope_theta=5e5,
    cross_attn_every=5, n_image_tokens=1601,
)

SMOKE = ModelConfig(
    name="llama32_vision_smoke", family="vlm",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=384, vocab=512, cross_attn_every=2, n_image_tokens=16,
)

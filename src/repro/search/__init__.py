"""Budgeted search policies over DSE design grids (``repro.search``).

Public surface:

* :class:`~repro.search.policy.SearchPolicy` — the ask/tell interface
  :meth:`repro.core.dse_engine.DSEEngine.search` drives, plus the three
  shipped policies: :class:`~repro.search.policy.RandomSearch`,
  :class:`~repro.search.policy.SuccessiveHalving` (cheap selection-bound
  rung → full-pricing promotion) and
  :class:`~repro.search.surrogate.SurrogateSearch` (ridge on system
  features, refit + re-rank each round).
* :class:`~repro.search.grid.DenseGridSpec` — scaled-variant grids far
  denser than the paper's 80 systems.
* :func:`~repro.search.surrogate.plan_feature_rows` /
  :func:`~repro.search.surrogate.fit_plan_ridge` — the memo-store
  harvest feeding plan-level surrogates; :mod:`repro.learned` builds
  the shipped learned rank stage on the same harvest.
"""
from .grid import DenseGridSpec, ScaledWorkFn, scale_lattice, scaled_name
from .policy import (POLICY_NAMES, Observation, RandomSearch, SearchContext,
                     SearchPolicy, SearchResult, SuccessiveHalving,
                     make_policy)
from .surrogate import (PLAN_FEATURE_FIELDS, RidgeModel, SurrogateSearch,
                        cell_features, fit_plan_ridge, plan_feature_rows)

__all__ = [
    "DenseGridSpec",
    "Observation",
    "POLICY_NAMES",
    "make_policy",
    "ScaledWorkFn",
    "scale_lattice",
    "PLAN_FEATURE_FIELDS",
    "RandomSearch",
    "RidgeModel",
    "SearchContext",
    "SearchPolicy",
    "SearchResult",
    "SuccessiveHalving",
    "SurrogateSearch",
    "cell_features",
    "fit_plan_ridge",
    "plan_feature_rows",
    "scaled_name",
]

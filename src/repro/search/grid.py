"""Dense design grids: scaled technology variants between Table V points.

The paper's §VI.C sweep covers 80 systems; the ROADMAP targets spaces
orders of magnitude denser.  :class:`DenseGridSpec` generates such a
space by interpolating each registered technology along a performance
scale axis — ``"H100@x1.25"`` is an H100 with 1.25× the per-tile
compute at unchanged price/power, resolved by the pure name parsers in
:mod:`repro.systems.chips` (no registry mutation, so a grid cell means
the same system in every pool worker under any start method).

Scaling compute/bandwidth while holding price and power fixed keeps the
scale axis *interesting*: a faster variant is better on utilization AND
cost efficiency, so the Pareto surface shifts instead of merely
stretching, and the search policies have real structure to exploit.

The default shape is 12 chips × 24 memory/interconnect combinations ×
3 topologies = 864 cells — the ≥ 10×-the-paper grid
``benchmarks/bench_dse.py``'s ``search`` block runs budgeted policies
against.
"""
from __future__ import annotations

import dataclasses

from ..core.dse_engine import SweepSpec
from ..systems.chips import _split_scaled


def scaled_name(base: str, scale: float) -> str:
    """Canonical scaled-variant name (``scale == 1`` keeps the base name)."""
    if scale == 1.0:
        return base
    name = f"{base}@x{scale:g}"
    _split_scaled(name)  # validate base/scale round-trip early
    return name


@dataclasses.dataclass(frozen=True)
class DenseGridSpec:
    """Cartesian generator of scaled-variant design grids.

    ``spec()`` materializes the grid as a plain
    :class:`~repro.core.dse_engine.SweepSpec`, so every engine entry
    point (``sweep`` / ``sweep_iter`` / ``search``) consumes it
    unchanged.
    """

    n_chips: int = 64
    base_chips: tuple[str, ...] = ("H100", "TPUv4", "SN30")
    chip_scales: tuple[float, ...] = (0.75, 1.0, 1.25, 1.5)
    base_memories: tuple[str, ...] = ("DDR", "HBM")
    memory_scales: tuple[float, ...] = (0.75, 1.0, 1.25)
    base_nets: tuple[str, ...] = ("PCIe", "NVLink")
    net_scales: tuple[float, ...] = (1.0, 1.5)
    topologies: tuple[str, ...] = ("torus2d", "dragonfly", "dgx2")
    max_tp: int | None = 16
    max_pp: int | None = None
    execution: str = "auto"

    def chips(self) -> tuple[str, ...]:
        return tuple(scaled_name(c, s) for c in self.base_chips
                     for s in self.chip_scales)

    def mem_net(self) -> tuple[tuple[str, str], ...]:
        return tuple((scaled_name(m, ms), scaled_name(n, ns))
                     for m in self.base_memories for ms in self.memory_scales
                     for n in self.base_nets for ns in self.net_scales)

    def n_cells(self) -> int:
        return (len(self.base_chips) * len(self.chip_scales)
                * len(self.base_memories) * len(self.memory_scales)
                * len(self.base_nets) * len(self.net_scales)
                * len(self.topologies))

    def spec(self) -> SweepSpec:
        return SweepSpec(n_chips=self.n_chips, chips=self.chips(),
                         topologies=self.topologies,
                         mem_net=self.mem_net(), max_tp=self.max_tp,
                         max_pp=self.max_pp, execution=self.execution)

"""Dense design grids: scaled technology variants between Table V points.

The paper's §VI.C sweep covers 80 systems; the ROADMAP targets spaces
orders of magnitude denser.  :class:`DenseGridSpec` generates such a
space by interpolating each registered technology along a performance
scale axis — ``"H100@x1.25"`` is an H100 with 1.25× the per-tile
compute at unchanged price/power, resolved by the pure name parsers in
:mod:`repro.systems.chips` (no registry mutation, so a grid cell means
the same system in every pool worker under any start method).

Scaling compute/bandwidth while holding price and power fixed keeps the
scale axis *interesting*: a faster variant is better on utilization AND
cost efficiency, so the Pareto surface shifts instead of merely
stretching, and the search policies have real structure to exploit.

The default shape is 12 chips × 24 memory/interconnect combinations ×
3 topologies = 864 cells — the ≥ 10×-the-paper grid
``benchmarks/bench_dse.py``'s ``search`` block runs budgeted policies
against.  :meth:`DenseGridSpec.dense` scales the same generator to the
10⁵-cell regime by densifying the memory-scale lattice (memory variants
share their group's plan phase, so cells along that axis are nearly
free), and ``workload_scales`` multiplies the space once more through
workload variants (:func:`ScaledWorkFn`) for the 10⁶-cell
``DSEEngine.reprice_grid`` regime.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.dse_engine import SweepSpec
from ..core.interchip import TrainWorkload
from ..systems.chips import _split_scaled


def scaled_name(base: str, scale: float) -> str:
    """Canonical scaled-variant name (``scale == 1`` keeps the base name)."""
    if scale == 1.0:
        return base
    name = f"{base}@x{scale:g}"
    _split_scaled(name)  # validate base/scale round-trip early
    return name


def scale_lattice(lo: float, hi: float, n: int) -> tuple[float, ...]:
    """``n`` evenly spaced scale factors in [lo, hi], rounded to 6
    decimals so every factor formats to a distinct ``@x%g`` name."""
    if n < 1:
        raise ValueError(f"lattice size must be >= 1, got {n}")
    if not (0.0 < lo <= hi):
        raise ValueError(f"need 0 < lo <= hi, got [{lo}, {hi}]")
    if n == 1:
        return (round(lo, 6),)
    step = (hi - lo) / (n - 1)
    out = tuple(round(lo + i * step, 6) for i in range(n))
    # distinctness must hold through the ``@x%g`` name format, not just
    # the float values — names are the identity a grid cell travels as
    if len({f"{v:g}" for v in out}) != n:
        raise ValueError(
            f"lattice [{lo}, {hi}] × {n} collapses at name resolution; "
            f"widen the range or shrink the lattice")
    return out


@dataclasses.dataclass(frozen=True)
class ScaledWorkFn:
    """A workload factory scaled along the global-batch axis.

    Wraps a base ``work_fn`` so the scaled variant stays picklable (pool
    workers under spawn/forkserver ship the factory itself — a lambda
    would break them). The scaled batch is rounded to a whole multiple
    of the microbatch (minimum one), and the workload name is suffixed
    ``@b<scale>`` so grid rows from different variants stay
    distinguishable.
    """

    work_fn: object                   # Callable[[SystemSpec], TrainWorkload]
    scale: float = 1.0

    def __call__(self, system) -> TrainWorkload:
        work = self.work_fn(system)
        if self.scale == 1.0:
            return work
        mb = max(1, int(work.microbatch))
        batch = mb * max(1, round(work.global_batch * self.scale / mb))
        return dataclasses.replace(
            work, global_batch=batch, name=f"{work.name}@b{self.scale:g}")


@dataclasses.dataclass(frozen=True)
class DenseGridSpec:
    """Cartesian generator of scaled-variant design grids.

    ``spec()`` materializes the grid as a plain
    :class:`~repro.core.dse_engine.SweepSpec`, so every engine entry
    point (``sweep`` / ``sweep_iter`` / ``search``) consumes it
    unchanged.
    """

    n_chips: int = 64
    base_chips: tuple[str, ...] = ("H100", "TPUv4", "SN30")
    chip_scales: tuple[float, ...] = (0.75, 1.0, 1.25, 1.5)
    base_memories: tuple[str, ...] = ("DDR", "HBM")
    memory_scales: tuple[float, ...] = (0.75, 1.0, 1.25)
    base_nets: tuple[str, ...] = ("PCIe", "NVLink")
    net_scales: tuple[float, ...] = (1.0, 1.5)
    topologies: tuple[str, ...] = ("torus2d", "dragonfly", "dgx2")
    max_tp: int | None = 16
    max_pp: int | None = None
    execution: str = "auto"
    #: Workload-axis variants (global-batch scale factors): the grid is
    #: swept once per variant (:meth:`work_variants`), multiplying the
    #: total cell count without touching the system axes.
    workload_scales: tuple[float, ...] = (1.0,)

    def chips(self) -> tuple[str, ...]:
        return tuple(scaled_name(c, s) for c in self.base_chips
                     for s in self.chip_scales)

    def mem_net(self) -> tuple[tuple[str, str], ...]:
        return tuple((scaled_name(m, ms), scaled_name(n, ns))
                     for m in self.base_memories for ms in self.memory_scales
                     for n in self.base_nets for ns in self.net_scales)

    def n_cells(self) -> int:
        """System-grid cells of ONE workload variant."""
        return (len(self.base_chips) * len(self.chip_scales)
                * len(self.base_memories) * len(self.memory_scales)
                * len(self.base_nets) * len(self.net_scales)
                * len(self.topologies))

    def n_total_cells(self) -> int:
        """Total cells across every workload variant — the number a
        whole-space :meth:`~repro.core.dse_engine.DSEEngine.reprice_grid`
        pass over :meth:`work_variants` covers."""
        return self.n_cells() * len(self.workload_scales)

    def work_variants(self, work_fn) -> tuple[ScaledWorkFn, ...]:
        """One picklable scaled workload factory per ``workload_scales``
        entry (scale 1 included as-is, wrapped for uniformity)."""
        return tuple(ScaledWorkFn(work_fn, s) for s in self.workload_scales)

    def spec(self) -> SweepSpec:
        return SweepSpec(n_chips=self.n_chips, chips=self.chips(),
                         topologies=self.topologies,
                         mem_net=self.mem_net(), max_tp=self.max_tp,
                         max_pp=self.max_pp, execution=self.execution)

    @classmethod
    def dense(cls, target_cells: int = 100_000,
              workload_scales: tuple[float, ...] = (1.0,),
              **overrides) -> "DenseGridSpec":
        """A grid with ≥ ``target_cells`` system cells (per workload
        variant), densified along the memory-scale axis.

        The memory axis is the cheap direction: every memory variant of a
        (chip, net, topology) group shares the group's plan phase, so a
        100× denser memory lattice costs ~100× more *pricing rows* but no
        extra discrete solves — exactly the shape the chunked compiled
        backend is built for. ``workload_scales`` multiplies the space
        once more (``n_total_cells``) for the 10⁶-cell regime.
        """
        base = cls(workload_scales=tuple(workload_scales), **overrides)
        per_scale = base.n_cells() // len(base.memory_scales)
        need = max(1, math.ceil(target_cells / per_scale))
        return dataclasses.replace(
            base, memory_scales=scale_lattice(0.5, 2.0, need))

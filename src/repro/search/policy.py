"""Budgeted search policies over the DSE design grid.

The exhaustive sweep (:meth:`repro.core.dse_engine.DSEEngine.sweep`)
prices every grid cell; that stops scaling exactly where DFModel becomes
most useful — dense grids interpolating between the paper's Table V
technology points (:class:`repro.search.grid.DenseGridSpec`) run to
thousands of cells and beyond.  A :class:`SearchPolicy` explores such a
grid under a fixed *evaluation budget*: the engine repeatedly asks the
policy for a batch of grid indices, plans + prices exactly that batch
through the columnar pipeline (one ``plan_design_cells`` +
``price_planned`` call per batch, so the jax/pallas backend sees real
batches, never single rows), and feeds the priced results back via
:meth:`SearchPolicy.tell`.

The engine-side loop lives in :meth:`repro.core.dse_engine.DSEEngine.search`;
it enforces the contract strictly — every proposed index in range,
proposed at most once, never more proposals than budget — and certifies
the search winner against the exhaustive pruned sweep's true argmin
(house rule: certified or raised, never silently wrong).

Objective
---------
A cell's objective is the lexicographic key
``(not feasible, iter_time, grid index)`` — memory-feasible systems
first, fastest iteration time among them, first grid index on exact
ties.  This is precisely the key the exhaustive pipeline minimizes per
cell (``interchip.winner_rows`` + the priced feasibility bit), so a
search winner and the exhaustive winner are comparable bit-for-bit.
Undecomposable cells (the exhaustive sweep *skips* them) enter as
``(infeasible, inf)`` — they sort last and can never win against a
decomposable cell.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import numpy as np

from ..core.dse import DesignPoint, GridCell


@dataclasses.dataclass(frozen=True)
class Observation:
    """One evaluated grid cell, as fed back to a policy."""

    index: int                    # grid index
    cell: GridCell
    feasible: bool                # winner fits the memory capacity
    iter_time: float              # winner iteration time (inf: undecomposable)
    utilization: float
    point: DesignPoint | None     # None for undecomposable cells

    @property
    def objective(self) -> tuple[bool, float, int]:
        """The lexicographic minimization key (see module docstring)."""
        return (not self.feasible, self.iter_time, self.index)


@dataclasses.dataclass
class SearchContext:
    """What the engine hands a policy at :meth:`SearchPolicy.reset`.

    ``budget`` is the number of *full* evaluations the engine will grant
    (already clamped to the grid size); ``cheap_bound`` is the
    low-fidelity oracle — the numpy selection prepass
    (:func:`repro.core.pricing.selection_columns`) over the cell's
    candidate enumeration, whose ``iter_time`` / memory columns are
    bit-identical to full pricing, so the returned
    ``(infeasible, iter_time)`` key per index is the cell's EXACT
    objective prefix, obtained without the full pricing formula, the
    intra-chip refinement, or the efficiency terms.  ``features`` maps a
    grid index to its system-level feature vector (chip / memory /
    interconnect / topology numbers — no planning involved), the input
    space surrogate policies regress on.
    """

    n_points: int
    budget: int
    cheap_bound: Callable[[Sequence[int]], list[tuple[bool, float]]]
    features: Callable[[int], np.ndarray]


@dataclasses.dataclass
class SearchResult:
    """Outcome of one :meth:`DSEEngine.search` run."""

    policy: str                   # policy name
    budget: int                   # granted full-evaluation budget
    evals_used: int               # full evaluations actually spent
    cheap_evals: int              # low-fidelity bound evaluations
    rounds: list[dict]            # per-round progress records (with ETA)
    best_index: int               # grid index of the search winner (-1: none)
    best_point: DesignPoint | None
    best_objective: tuple[bool, float] | None  # (feasible, iter_time)
    evaluated: dict[int, Observation]
    certified: bool               # oracle comparison ran and matched
    oracle_index: int | None      # exhaustive argmin (when certified)
    seconds: float


class SearchPolicy:
    """Ask/tell interface the engine drives.

    Lifecycle: ``reset(ctx)`` once per search, then rounds of
    ``ask() -> [indices]`` / ``tell([observations])`` until the policy
    returns an empty ask or the budget is spent.  Policies must be
    deterministic given their seed: same seed → same proposal sequence →
    same winner (``tests/test_search.py`` locks this in).

    Contract (enforced by the engine, violations raise): each ask may
    only propose in-range indices, never an index twice across the whole
    search, and never more total indices than ``ctx.budget``.
    """

    name = "policy"

    def reset(self, ctx: SearchContext) -> None:
        self.ctx = ctx

    def ask(self) -> list[int]:  # pragma: no cover - interface
        raise NotImplementedError

    def tell(self, observations: Sequence[Observation]) -> None:
        pass

    # shared budget bookkeeping for subclasses
    def _grant(self, want: int, asked_so_far: int) -> int:
        return max(0, min(want, self.ctx.budget - asked_so_far))


class RandomSearch(SearchPolicy):
    """Pure random exploration: a seeded permutation of the grid,
    proposed in fixed-size batches.  The baseline every adaptive policy
    must beat — and, given ``budget >= n_points``, an exhaustive sweep in
    shuffled order (which is how the smoke certification exercises it).
    """

    name = "random"

    def __init__(self, seed: int = 0, batch_size: int = 16) -> None:
        self.seed = seed
        self.batch_size = batch_size

    def reset(self, ctx: SearchContext) -> None:
        super().reset(ctx)
        rng = np.random.default_rng(self.seed)
        self._order = [int(i) for i in rng.permutation(ctx.n_points)]
        self._asked = 0

    def ask(self) -> list[int]:
        k = self._grant(self.batch_size, self._asked)
        out = self._order[self._asked:self._asked + k]
        self._asked += len(out)
        return out


class SuccessiveHalving(SearchPolicy):
    """Two-fidelity successive halving over the cheap selection bound.

    Rung 0 prices the *cheap lower-bound columns* of every grid cell
    (``ctx.cheap_bound`` → ``pricing.selection_columns`` over the
    candidate enumeration: one numpy prepass per system group, no full
    pricing formula, no intra-chip refinement).  Survivors — the top
    ``ceil(n / eta)`` cells by the bound's ``(infeasible, iter_time)``
    key — are promoted to full pricing, proposed in rank order.

    Because the selection prepass's ``iter_time`` and memory columns are
    bit-identical to full pricing (the certified property the pruning
    stage is built on), the cheap key here is not an estimate but the
    cell's exact objective prefix: further halving rungs could never
    re-rank survivors, so the classic multi-rung ladder collapses to a
    single promotion round — and the true argmin is, by construction,
    the *first* cell promoted.  That makes certification deterministic
    at any ``budget >= 1`` while spending only ``ceil(n / eta)`` full
    evaluations (the ≤ 20 %-of-exhaustive figure
    ``benchmarks/bench_dse.py`` records for the dense grid).
    """

    name = "halving"

    def __init__(self, eta: int = 8, batch_size: int = 32,
                 max_promoted: int | None = None) -> None:
        if eta < 2:
            raise ValueError(f"eta must be >= 2, got {eta}")
        self.eta = eta
        self.batch_size = batch_size
        self.max_promoted = max_promoted

    def reset(self, ctx: SearchContext) -> None:
        super().reset(ctx)
        bounds = ctx.cheap_bound(range(ctx.n_points))
        rank = sorted(range(ctx.n_points),
                      key=lambda i: (bounds[i][0], bounds[i][1], i))
        promote = max(1, math.ceil(ctx.n_points / self.eta))
        if self.max_promoted is not None:
            promote = min(promote, self.max_promoted)
        self._queue = rank[:min(promote, ctx.budget)]
        self._asked = 0

    def ask(self) -> list[int]:
        k = self._grant(self.batch_size, self._asked)
        out = self._queue[self._asked:self._asked + k]
        self._asked += len(out)
        return out


#: The policy spellings a DSE-service query may carry (`mode="search"`).
POLICY_NAMES = ("random", "halving", "surrogate")


def make_policy(name: str, *, seed: int = 0,
                batch_size: int | None = None) -> SearchPolicy:
    """Construct a shipped policy from its wire name.

    The DSE service (:mod:`repro.service`) ships policies *by name* —
    a request is plain data, never a pickled callable — and this is the
    one place those names resolve. Unknown names raise ``ValueError``
    (the daemon turns that into a structured error reply).
    """
    kwargs = {} if batch_size is None else {"batch_size": int(batch_size)}
    if name == "random":
        return RandomSearch(seed=seed, **kwargs)
    if name == "halving":
        return SuccessiveHalving(**kwargs)
    if name == "surrogate":
        from .surrogate import SurrogateSearch

        return SurrogateSearch(seed=seed, **kwargs)
    raise ValueError(f"unknown search policy {name!r}; "
                     f"available: {POLICY_NAMES}")

"""Surrogate-guided search + the memo-store harvest that trains it.

Two regression surfaces share the ridge machinery here:

* **cell level** — :func:`cell_features` maps a grid cell to a
  system-spec feature vector (chip / memory / interconnect / topology
  numbers, no planning required), and :class:`SurrogateSearch` regresses
  observed winner iteration times on those features to re-rank the
  unevaluated cells each round.
* **plan level** — :func:`plan_feature_rows` harvests the memoised
  candidate sets (memo space ``"candmat"``, via
  :meth:`repro.core.memo.SolveCache.harvest`) into
  ``(PlanVector-feature rows → selection iter_time)`` training pairs,
  and :func:`fit_plan_ridge` fits the same ridge on them.  The harvest
  merges tiers: the local in-process tier first, then shared-store
  entries other workers of the sweep computed — deduplicated by key
  with the local entry winning a collision, and shared entries that
  fail to unpickle (version skew) skipped rather than raised.  Each
  cell observation's target is exactly the minimum of its group's
  plan-level targets, so the two surfaces are consistent by
  construction.

The plan-level harvest is the training feed of the *shipped* learned
cost model: :mod:`repro.learned` extends these feature rows with an
Eq. 7-shaped derived basis plus a per-group system block, calibrates a
keep-threshold, and runs as a certified third pruning stage inside
``plan_design_groups`` (see ``docs/LEARNED.md``).

Everything is deterministic: the ridge solves closed-form normal
equations (no iterative optimizer), and the only randomness —
exploration picks in :class:`SurrogateSearch` — flows from the
constructor seed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import numpy as np

from ..core.memo import GLOBAL_CACHE, SolveCache
from ..systems.chips import (resolve_chip, resolve_interconnect,
                             resolve_memory)
from .policy import Observation, SearchContext, SearchPolicy

#: PlanVector fields the plan-level surrogate regresses on: the inputs of
#: the iter_time expression in ``pricing._price`` (stage times, pipeline
#: shape, backward multipliers) — deliberately NOT the outputs.
PLAN_FEATURE_FIELDS = ("t_comp_stage", "t_net_stage", "t_p2p", "t_dp",
                       "n_micro", "tp", "pp", "layers_per_stage",
                       "bwd_flop_mult", "bwd_comm_mult")


def cell_features(cell: Sequence[str], n_chips: int,
                  topo_vocab: Mapping[str, int]) -> np.ndarray:
    """System-spec feature vector for one grid cell.

    Log-scaled hardware magnitudes (they span orders of magnitude
    across a dense grid) plus a one-hot over the grid's topology
    vocabulary.  Resolves scaled variant names (``"H100@x1.25"``)
    through the same pure resolvers ``dse.build_system`` uses, so
    features and evaluation always describe the same system.
    """
    chip = resolve_chip(cell[0])
    mem = resolve_memory(cell[1])
    net = resolve_interconnect(cell[2])
    base = [math.log10(chip.peak_flops),
            math.log10(chip.sram_capacity),
            float(chip.dataflow),
            math.log10(mem.bandwidth),
            math.log10(mem.capacity),
            math.log10(net.bandwidth),
            math.log10(net.latency * 1e9),
            math.log10(n_chips)]
    onehot = [0.0] * len(topo_vocab)
    onehot[topo_vocab[cell[3]]] = 1.0
    return np.asarray(base + onehot, dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class RidgeModel:
    """Standardized ridge regression, fit by closed-form normal equations."""

    mean: np.ndarray              # per-feature standardization mean
    std: np.ndarray               # per-feature standardization scale
    beta: np.ndarray              # coefficients, intercept last

    @classmethod
    def fit(cls, X: np.ndarray, y: np.ndarray,
            lam: float = 1e-3) -> "RidgeModel":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        mean = X.mean(axis=0)
        std = X.std(axis=0)
        std = np.where(std > 0, std, 1.0)
        Z = np.column_stack([(X - mean) / std, np.ones(len(X))])
        A = Z.T @ Z + lam * np.eye(Z.shape[1])
        beta = np.linalg.solve(A, Z.T @ y)
        return cls(mean=mean, std=std, beta=beta)

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        Z = np.column_stack([(X - self.mean) / self.std, np.ones(len(X))])
        return Z @ self.beta


def plan_feature_rows(cache: SolveCache | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Harvest ``(plan-feature matrix, iter_time targets)`` from the
    memoised candidate sets.

    Every planned system group leaves its :class:`CandidateSet` in memo
    space ``"candmat"``; each candidate row contributes one training
    pair: its :data:`PLAN_FEATURE_FIELDS` columns and its exact
    ``selection_columns`` iteration time.  With a shared store attached
    the harvest also merges in candidate sets computed by other
    processes of the sweep — local tier first, shared entries
    deduplicated against it (see :meth:`SolveCache.harvest`).  The
    richer-featured variant powering the learned rank stage is
    :func:`repro.learned.features.harvest_rows`.
    """
    cache = GLOBAL_CACHE if cache is None else cache
    xs, ys = [], []
    for _key, cands in cache.harvest("candmat"):
        if not len(cands):
            continue
        sel = cands.selection()
        cols = cands.matrix.cols
        xs.append(np.stack([np.asarray(cols[f], dtype=np.float64)
                            for f in PLAN_FEATURE_FIELDS], axis=1))
        ys.append(np.asarray(sel["iter_time"], dtype=np.float64))
    if not xs:
        return (np.zeros((0, len(PLAN_FEATURE_FIELDS))), np.zeros(0))
    return np.concatenate(xs), np.concatenate(ys)


def fit_plan_ridge(cache: SolveCache | None = None,
                   lam: float = 1e-3) -> RidgeModel | None:
    """Fit the plan-level surrogate on the harvested training set
    (``None`` when the cache holds no candidate sets yet)."""
    X, y = plan_feature_rows(cache)
    if not len(X):
        return None
    # iter_time spans orders of magnitude — regress its log
    return RidgeModel.fit(X, np.log10(np.maximum(y, 1e-30)), lam=lam)


#: Log-target penalty for memory-infeasible observations: large enough
#: that any feasible cell predicts better than any infeasible one (the
#: lexicographic objective), small enough to keep the solve conditioned.
_INFEASIBLE_PENALTY = 100.0
#: Stand-in target for undecomposable cells (iter_time = inf).
_UNDECOMPOSABLE_Y = 1e6


class SurrogateSearch(SearchPolicy):
    """Ridge-surrogate search: observe, refit, re-rank, repeat.

    Each round fits :class:`RidgeModel` on the cell features of every
    observation so far (target: log winner iteration time, plus a fixed
    penalty for memory-infeasible cells so feasibility dominates the
    ranking, mirroring the lexicographic objective) and proposes the
    unevaluated cells with the best predictions — salted with an
    ``explore`` fraction of seeded random picks so a misfit model cannot
    lock the search out of a region.  Until ``min_train`` observations
    exist the policy explores randomly (a model fit on two points is
    noise).

    ``warm_start`` accepts ``(features, target)`` arrays in the same
    cell-feature space — e.g. rows carried over from a previous search
    on an overlapping grid — which join every refit as extra training
    rows.  The plan-level counterpart (training pairs harvested from the
    shared memo store) is exposed by :func:`plan_feature_rows` /
    :func:`fit_plan_ridge`.
    """

    name = "surrogate"

    def __init__(self, seed: int = 0, batch_size: int = 16,
                 explore: float = 0.25, min_train: int = 8,
                 ridge_lambda: float = 1e-3,
                 warm_start: tuple[np.ndarray, np.ndarray] | None = None
                 ) -> None:
        if not 0.0 <= explore <= 1.0:
            raise ValueError(f"explore must be in [0, 1], got {explore}")
        self.seed = seed
        self.batch_size = batch_size
        self.explore = explore
        self.min_train = min_train
        self.ridge_lambda = ridge_lambda
        self.warm_start = warm_start

    def reset(self, ctx: SearchContext) -> None:
        super().reset(ctx)
        self._rng = np.random.default_rng(self.seed)
        self._features = np.stack([ctx.features(i)
                                   for i in range(ctx.n_points)])
        if self.warm_start is not None:
            wx = np.asarray(self.warm_start[0], dtype=np.float64)
            if wx.ndim != 2 or wx.shape[1] != self._features.shape[1]:
                raise ValueError(
                    f"warm_start features have shape {wx.shape}; expected "
                    f"(*, {self._features.shape[1]})")
        self._train_idx: list[int] = []
        self._train_y: list[float] = []
        self._proposed: set[int] = set()
        self._asked = 0

    def ask(self) -> list[int]:
        k = self._grant(self.batch_size, self._asked)
        pool = [i for i in range(self.ctx.n_points)
                if i not in self._proposed]
        k = min(k, len(pool))
        if k == 0:
            return []
        if len(self._train_y) < self.min_train:
            picked = [int(pool[j]) for j in
                      self._rng.choice(len(pool), size=k, replace=False)]
        else:
            model = self._fit()
            pred = model.predict(self._features[pool])
            order = np.lexsort((pool, pred))  # prediction, grid index
            n_explore = int(math.floor(k * self.explore))
            exploit = [int(pool[j]) for j in order[:k - n_explore]]
            rest = [int(pool[j]) for j in order[k - n_explore:]]
            explore = ([int(rest[j]) for j in
                        self._rng.choice(len(rest), size=min(n_explore,
                                                             len(rest)),
                                         replace=False)]
                       if rest and n_explore else [])
            picked = exploit + explore
        self._proposed.update(picked)
        self._asked += len(picked)
        return picked

    def tell(self, observations: Sequence[Observation]) -> None:
        for obs in observations:
            y = (math.log10(obs.iter_time)
                 if math.isfinite(obs.iter_time) and obs.iter_time > 0
                 else _UNDECOMPOSABLE_Y)
            if not obs.feasible:
                y += _INFEASIBLE_PENALTY
            self._train_idx.append(obs.index)
            self._train_y.append(float(y))

    def _fit(self) -> RidgeModel:
        X = self._features[self._train_idx]
        y = np.asarray(self._train_y)
        if self.warm_start is not None:
            X = np.concatenate([X, np.asarray(self.warm_start[0],
                                              dtype=np.float64)])
            y = np.concatenate([y, np.asarray(self.warm_start[1],
                                              dtype=np.float64)])
        return RidgeModel.fit(X, y, lam=self.ridge_lambda)

from .optimizer import adamw_init, adamw_update, cosine_schedule
from .trainer import TrainState, make_train_step, train_loop
from .checkpoint import CheckpointManager
from .data import SyntheticTokens, MemmapTokens
from .fault import StragglerMonitor, retry_step

__all__ = [
    "adamw_init", "adamw_update", "cosine_schedule",
    "TrainState", "make_train_step", "train_loop",
    "CheckpointManager", "SyntheticTokens", "MemmapTokens",
    "StragglerMonitor", "retry_step",
]

"""AdamW + schedules, pure-pytree implementation (no optax dependency)."""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params, master: bool = False):
    """``master=True`` enables mixed precision: moments and a master copy of
    the weights are kept in fp32 while the live params stay in their compute
    dtype (bf16) — halves weight-gather / grad-reduce traffic at equal
    convergence (§Perf hillclimb, EXPERIMENTS.md)."""
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    out = {
        "m": jax.tree.map(f32 if master else jnp.zeros_like, params),
        "v": jax.tree.map(f32 if master else jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master:
        out["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return out


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0):
    """One AdamW step with global-norm clipping. Returns (params, state).

    If ``state`` carries a fp32 ``master`` tree (mixed precision), the update
    is applied to the master weights and the live params are re-cast from it.
    """
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    gf = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)
    m = jax.tree.map(lambda mu, g: cfg.b1 * mu + (1 - cfg.b1) * g,
                     state["m"], gf)
    v = jax.tree.map(lambda nu, g: cfg.b2 * nu + (1 - cfg.b2) * g * g,
                     state["v"], gf)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, mu, nu):
        mhat = mu / bc1
        vhat = nu / bc2
        return p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                         + cfg.weight_decay * p)

    if "master" in state:
        master = jax.tree.map(upd, state["master"], m, v)
        params = jax.tree.map(lambda mp, p: mp.astype(p.dtype),
                              master, params)
        return params, {"m": m, "v": v, "master": master, "step": step}
    params = jax.tree.map(
        lambda p, mu, nu: upd(p.astype(jnp.float32), mu, nu).astype(p.dtype),
        params, m, v)
    return params, {"m": m, "v": v, "step": step}


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(math.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn

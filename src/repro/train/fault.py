"""Fault-tolerance utilities: straggler detection + step retry.

At thousand-node scale the failure model is (a) slow steps from a degraded
host/link (stragglers) and (b) hard faults that kill the step. The monitor
keeps an EWMA of step times and flags outliers (the signal a scheduler uses
to re-layout or evict a pod); ``retry_step`` is the hard-fault wrapper: on
exception it restores the latest checkpoint and replays.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than threshold × mean."""

    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 5

    def __post_init__(self):
        self.mean: float | None = None
        self.events: list[tuple[int, float, float]] = []
        self.count = 0

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        self.count += 1
        if self.mean is None:
            self.mean = dt
            return False
        flagged = (self.count > self.warmup
                   and dt > self.threshold * self.mean)
        if flagged:
            self.events.append((step, dt, self.mean))
        else:
            # stragglers don't poison the baseline
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
        return flagged

    @property
    def straggler_fraction(self) -> float:
        return len(self.events) / max(self.count, 1)


def retry_step(step_fn: Callable, checkpoint_manager, max_retries: int = 2):
    """Wrap a train step with restore-and-replay on hard faults."""

    def wrapped(params, opt_state, batch, step: int):
        attempt = 0
        while True:
            try:
                return step_fn(params, opt_state, batch)
            except Exception:
                attempt += 1
                if attempt > max_retries or checkpoint_manager is None:
                    raise
                _, tree = checkpoint_manager.restore()
                params, opt_state = tree["params"], tree["opt"]

    return wrapped


class Heartbeat:
    """Liveness file for an external supervisor (touch every step)."""

    def __init__(self, path):
        self.path = path

    def beat(self, step: int):
        import pathlib
        pathlib.Path(self.path).write_text(f"{step} {time.time()}\n")

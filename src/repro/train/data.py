"""Token data pipeline: synthetic stream (benchmark/dry-run) and a
memmap-backed shard reader (the production path: fixed-length token files,
per-host sharding by data-parallel rank, deterministic resume).
"""
from __future__ import annotations

import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    """Deterministic synthetic token batches (model-free throughput tests)."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0
    extras: dict | None = None   # e.g. image_embeds spec for VLM

    def __iter__(self):
        rng = np.random.default_rng(self.seed)
        while True:
            toks = rng.integers(0, self.vocab,
                                (self.batch, self.seq + 1), dtype=np.int32)
            out = {"tokens": jnp.asarray(toks[:, :-1]),
                   "labels": jnp.asarray(toks[:, 1:])}
            if self.extras:
                for k, shape in self.extras.items():
                    out[k] = jnp.asarray(
                        rng.standard_normal((self.batch, *shape),
                                            dtype=np.float32),
                        dtype=jnp.bfloat16)
            yield out


class MemmapTokens:
    """Reads token shards written as flat .bin int32 files.

    Supports data-parallel sharding (rank/world) and exact resume via a step
    cursor — the two properties a restartable multi-pod job needs.
    """

    def __init__(self, path: str | pathlib.Path, batch: int, seq: int,
                 rank: int = 0, world: int = 1, start_step: int = 0):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.batch, self.seq = batch, seq
        self.rank, self.world = rank, world
        self.step = start_step
        self.tokens_per_step = batch * (seq + 1) * world

    def __iter__(self):
        return self

    def __next__(self):
        need = self.batch * (self.seq + 1)
        base = (self.step * self.tokens_per_step + self.rank * need)
        base = base % max(len(self.tokens) - need, 1)
        chunk = np.asarray(self.tokens[base:base + need]).reshape(
            self.batch, self.seq + 1)
        self.step += 1
        return {"tokens": jnp.asarray(chunk[:, :-1]),
                "labels": jnp.asarray(chunk[:, 1:])}

    @staticmethod
    def write_corpus(path, n_tokens: int, vocab: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        arr = rng.integers(0, vocab, n_tokens, dtype=np.int32)
        arr.tofile(path)
        return path

"""Training step + loop: grad accumulation, donation, optional gradient
compression, straggler accounting.

``make_train_step`` builds the jitted step used by both the single-device
smoke tests and the 512-device dry-run (the launcher wraps it with mesh
shardings). Everything is a pure function of (state, batch).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from ..models import loss_fn
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule


@dataclasses.dataclass
class TrainState:
    params: dict
    opt: dict
    step: int = 0

    def pytree(self):
        return {"params": self.params, "opt": self.opt}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    accum: int = 1, schedule: Callable | None = None,
                    compress_dp_grads: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``accum`` > 1 splits the batch into microbatches along dim 0 and
    accumulates gradients in fp32 via lax.scan (bounded live memory).
    """
    opt_cfg = opt_cfg or AdamWConfig()
    schedule = schedule or (lambda s: 1.0)

    def loss_wrapped(params, batch):
        return loss_fn(cfg, params, batch)

    grad_fn = jax.value_and_grad(loss_wrapped)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)

            def acc_fn(carry, mb):
                tot_loss, acc_g = carry
                l, g = grad_fn(params, mb)
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (tot_loss + l, acc_g), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (0.0, zeros), micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        if compress_dp_grads:
            # int8 round-trip stands in for the compressed DP all-reduce;
            # under pjit the actual collective is emitted by GSPMD on the
            # dequantized values (error feedback handled by caller loop).
            from ..parallel.compression import quantize_int8, dequantize_int8
            grads = jax.tree.map(
                lambda g: dequantize_int8(*quantize_int8(g)), grads)

        lr_scale = schedule(opt_state["step"])
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg,
                                         lr_scale)
        metrics = {"loss": loss,
                   "grad_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(g.astype(jnp.float32)))
                       for g in jax.tree.leaves(grads)))}
        return params, opt_state, metrics

    return train_step


def train_loop(cfg: ModelConfig, params, data_iter, steps: int,
               opt_cfg: AdamWConfig | None = None, accum: int = 1,
               checkpoint_manager=None, checkpoint_every: int = 0,
               straggler_monitor=None, log_every: int = 10,
               start_step: int = 0):
    """Synchronous training loop with checkpointing + straggler accounting."""
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, accum), donate_argnums=(0, 1))
    history = []
    for step in range(start_step, steps):
        batch = next(data_iter)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        metrics["loss"].block_until_ready()
        dt = time.perf_counter() - t0
        if straggler_monitor is not None:
            straggler_monitor.record(step, dt)
        history.append(float(metrics["loss"]))
        if log_every and step % log_every == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"({dt * 1e3:.1f} ms)")
        if checkpoint_manager is not None and checkpoint_every \
                and (step + 1) % checkpoint_every == 0:
            checkpoint_manager.save(step + 1,
                                    {"params": params, "opt": opt_state})
    return params, opt_state, history

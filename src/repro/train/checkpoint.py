"""Fault-tolerant checkpointing: atomic writes, async save thread, elastic
restore across data-parallel widths.

Format: flattened pytree → npz (one array per leaf, path-encoded keys) plus a
msgpack manifest with step + tree structure. Writes go to a temp file then
os.replace (atomic on POSIX) — a partially written checkpoint can never be
loaded. ``save_async`` offloads serialization to a worker thread so the train
loop only blocks on device→host copies.

Elastic restore: checkpoints store *full* (unsharded) arrays; on load the
caller re-shards with device_put against whatever mesh is now alive — a
restart at DP=8 can read a DP=16 run's checkpoint unchanged (tested).
"""
from __future__ import annotations

import json
import os
import pathlib
import queue
import threading
import time

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        if not tree:
            # sentinel leaf: records structurally-empty dicts (e.g.
            # non-parametric norms) so restore is lossless
            out[f"{prefix}~empty~"] = np.zeros(0, np.uint8)
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for key, val in flat.items():
        node = tree
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        if parts[-1] != "~empty~":
            node[parts[-1]] = val
    return tree


class CheckpointManager:
    """Directory of step-numbered checkpoints with retention + async saves."""

    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._queue: queue.Queue = queue.Queue()
        self._worker: threading.Thread | None = None
        self._errors: list[Exception] = []

    # ---------------- sync API ----------------
    def save(self, step: int, tree) -> pathlib.Path:
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        return self._write(step, host)

    def _write(self, step: int, host: dict) -> pathlib.Path:
        path = self.dir / f"ckpt_{step:08d}.npz"
        tmp = path.with_suffix(".tmp.npz")
        np.savez(tmp, **host)
        os.replace(tmp, path)  # atomic
        manifest = self.dir / "MANIFEST.json"
        mtmp = manifest.with_suffix(".tmp")
        mtmp.write_text(json.dumps({"latest_step": step,
                                    "time": time.time()}))
        os.replace(mtmp, manifest)
        self._gc()
        return path

    def _gc(self):
        ckpts = sorted(self.dir.glob("ckpt_*.npz"))
        for old in ckpts[:-self.keep]:
            old.unlink(missing_ok=True)

    # ---------------- async API ----------------
    def save_async(self, step: int, tree):
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()
        self._queue.put((step, host))

    def _drain(self):
        while True:
            try:
                step, host = self._queue.get(timeout=5.0)
            except queue.Empty:
                return
            try:
                self._write(step, host)
            except Exception as e:  # surfaced on wait()
                self._errors.append(e)

    def wait(self):
        while not self._queue.empty():
            time.sleep(0.01)
        if self._worker is not None:
            self._worker.join(timeout=30)
        if self._errors:
            raise self._errors[0]

    # ---------------- restore ----------------
    def latest_step(self) -> int | None:
        manifest = self.dir / "MANIFEST.json"
        if not manifest.exists():
            ckpts = sorted(self.dir.glob("ckpt_*.npz"))
            if not ckpts:
                return None
            return int(ckpts[-1].stem.split("_")[1])
        return int(json.loads(manifest.read_text())["latest_step"])

    def restore(self, step: int | None = None, shardings=None):
        """Load a checkpoint; optionally re-shard onto a (new) mesh.

        ``shardings``: matching pytree of jax.sharding.Sharding — enables
        elastic restarts onto different topologies."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"ckpt_{step:08d}.npz"
        with np.load(path) as data:
            flat = {k: data[k] for k in data.files}
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
        return step, tree

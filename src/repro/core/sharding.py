"""Tensor-parallel sharding schemes + layout conversion costs (paper §IV, Fig 4).

Every kernel kind exposes a small set of sharding schemes. A scheme fixes
  - the layout it requires for its activation inputs,
  - the layout it produces,
  - its inherent collective cost (paper's c_i, Eq. 5),
  - how its FLOPs and weight bytes divide across the TP group.

Layouts (of an activation tensor over the TP group of t chips):
  R  replicated
  M  sharded along the leading (batch·seq / row) dimension
  N  sharded along the trailing (feature / head) dimension

Layout conversion between a producer's output layout and a consumer's required
input layout gives the tensor cost matrix C_j (Eq. 6):

      to:   R             M             N
  from: R   0             0 (slice)     0 (slice)
        M   all-gather    0             all-to-all
        N   all-gather    all-to-all    0

The canonical Megatron pattern (QKV col-sharded → attention head-local →
Proj row-sharded + all-reduce; FFN0 col → FFN1 row + all-reduce) emerges from
this scheme set as the minimum-communication assignment — the paper validates
DFModel by recovering exactly that (4 all-reduces / layer / iteration, §VI.A).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import numpy as np

from ..systems.topology import Topology
from .graph import DataflowGraph, Kernel, KernelKind

Layout = str  # 'R' | 'M' | 'N'


@dataclasses.dataclass(frozen=True)
class Scheme:
    """One sharding strategy for a kernel over a TP group of ``t`` chips."""

    name: str
    in_layout: Layout          # layout required on activation inputs
    out_layout: Layout         # layout produced
    flop_factor: float         # per-chip FLOPs = flops * flop_factor
    weight_factor: float       # per-chip weight bytes = weight_bytes * factor
    # inherent collective seconds: fn(out_bytes, topo, tp_dims) -> s
    comm: Callable[[float, Topology, Sequence[int]], float]
    # inherent collective payload bytes (for roofline accounting)
    comm_bytes: Callable[[float], float]
    # price the collective on the full logical output (a2a-style kernels)
    # instead of the replicated/sharded local size
    price_on_full: bool = False


def _zero(_b: float, _t: Topology, _d: Sequence[int]) -> float:
    return 0.0


# Scheme collective/payload callables are module-level functions (closures
# over the TP degree go through functools.partial) so Scheme — and with it
# ShardingSolution, InterChipPlan and DesignPoint — pickles cleanly across
# the DSEEngine worker-process boundary.
def _comm_all_reduce(b: float, topo: Topology, dims: Sequence[int]) -> float:
    return topo.all_reduce(b, dims)


def _comm_reduce_scatter(b: float, topo: Topology,
                         dims: Sequence[int]) -> float:
    return topo.reduce_scatter(b, dims)


def _comm_all_to_all(b: float, topo: Topology, dims: Sequence[int]) -> float:
    return topo.all_to_all(b, dims)


def _comm_all_to_all_2x(b: float, topo: Topology,
                        dims: Sequence[int]) -> float:
    return 2.0 * topo.all_to_all(b, dims)


def _bytes_zero(b: float) -> float:
    return 0.0


def _bytes_all_reduce(b: float, t: int = 2) -> float:
    return 2.0 * b * (t - 1) / t


def _bytes_shard(b: float, t: int = 2) -> float:
    return b * (t - 1) / t


def schemes_for(kernel: Kernel, t: int, seq_shardable: bool = False,
                expert_region: bool = False) -> list[Scheme]:
    """Sharding schemes available to ``kernel`` on a TP group of size ``t``.

    ``seq_shardable`` exposes batch/sequence (M) sharding inside the TP
    group. It is OFF by default: TP shards *within* one microbatch (data
    parallelism over sequences is modeled separately at the inter-chip
    level), and M-sharding self-attention would silently drop the K/V
    all-gather it actually requires. The M schemes exist for sequence-
    parallel extensions (``allow_sp``) where the norm/elementwise region is
    legitimately token-sharded between a reduce-scatter and an all-gather.

    ``t`` == 1 collapses everything to a single no-op scheme.
    """
    if t <= 1:
        return [Scheme("solo", "R", "R", 1.0, 1.0, _zero, _bytes_zero)]

    inv = 1.0 / t
    ar, rs, a2a = _comm_all_reduce, _comm_reduce_scatter, _comm_all_to_all
    ar_bytes = functools.partial(_bytes_all_reduce, t=t)
    shard_bytes = functools.partial(_bytes_shard, t=t)

    k = kernel.kind
    out: list[Scheme] = []
    if k == KernelKind.GEMM and expert_region:
        # expert-parallel GEMM: tokens already dispatched (M layout), expert
        # weights sharded, combine priced at the router.
        return [Scheme("expert_mm", "M", "M", inv, inv, _zero, _bytes_zero),
                Scheme("expert_mr", "M", "R", inv, inv, _zero, _bytes_zero)]
    if k == KernelKind.GEMM:
        # Fig 4 scheme A/B analogues + Megatron col/row pair.
        out.append(Scheme("col", "R", "N", inv, inv, _zero, _bytes_zero))
        out.append(Scheme("row_ar", "N", "R", inv, inv, ar, ar_bytes))
        # beyond-paper: Megatron-SP style reduce-scatter epilogue (output M)
        out.append(Scheme("row_rs", "N", "M", inv, inv, rs, shard_bytes))
        if seq_shardable:
            out.append(Scheme("data", "M", "M", inv, 1.0, _zero, _bytes_zero))
    elif k == KernelKind.ATTENTION:
        # head-sharded attention: inputs/outputs live in N (head) layout
        out.append(Scheme("head", "N", "N", inv, inv, _zero, _bytes_zero))
        if seq_shardable:
            out.append(Scheme("seq", "M", "M", inv, 1.0, _zero, _bytes_zero))
    elif k in (KernelKind.SOFTMAX, KernelKind.NORM, KernelKind.ELEMENTWISE):
        for lay in ("M", "N") if seq_shardable else ("N",):
            out.append(Scheme(f"ew_{lay}", lay, lay, inv, 1.0, _zero,
                              _bytes_zero))
        out.append(Scheme("ew_R", "R", "R", 1.0, 1.0, _zero, _bytes_zero))
    elif k == KernelKind.EMBEDDING:
        # vocab-sharded table: each chip gathers its hits, partial rows → AR
        out.append(Scheme("vocab_ar", "R", "R", inv, inv, ar, ar_bytes))
        out.append(Scheme("replicated", "M", "M", inv, 1.0, _zero,
                          _bytes_zero))
    elif k == KernelKind.ROUTER:
        # MoE dispatch+combine: tokens cross the EP group twice (a2a each
        # way); both directions are priced here on the dispatched tensor,
        # so downstream expert GEMMs are comm-free ('expert' schemes).
        out.append(Scheme("ep_a2a", "R", "M", inv, inv, _comm_all_to_all_2x,
                          ar_bytes, price_on_full=True))
    elif k == KernelKind.SCAN:
        # SSM: shard inner channels/heads; recurrence is along seq (local)
        out.append(Scheme("chan", "N", "N", inv, inv, _zero, _bytes_zero))
        if seq_shardable:
            out.append(Scheme("data", "M", "M", inv, 1.0, _zero, _bytes_zero))
    elif k == KernelKind.FFT:
        # distributed FFT stage: local FFTs on pencils; the transpose between
        # stages is the conversion (M<->N all-to-all) or an explicit COMM node
        out.append(Scheme("pencil_m", "M", "M", inv, 1.0, _zero, _bytes_zero))
        out.append(Scheme("pencil_n", "N", "N", inv, 1.0, _zero, _bytes_zero))
    elif k == KernelKind.COMM:
        out.append(Scheme("a2a", "M", "M", 1.0, 1.0, a2a,
                          shard_bytes, price_on_full=True))
    if not out:
        out.append(Scheme("rep", "R", "R", 1.0, 1.0, _zero, _bytes_zero))
    return out


def conversion_cost(from_lay: Layout, to_lay: Layout, bytes_: float,
                    topo: Topology, dims: Sequence[int], t: int) -> float:
    """C_j entry: seconds to convert a tensor between layouts (Eq. 6)."""
    if t <= 1 or from_lay == to_lay or from_lay == "R":
        return 0.0
    if to_lay == "R":
        return topo.all_gather(bytes_, dims)
    # M <-> N resharding
    return topo.all_to_all(bytes_, dims)


def conversion_bytes(from_lay: Layout, to_lay: Layout, bytes_: float,
                     t: int) -> float:
    """Collective payload bytes of a layout conversion (roofline term)."""
    if t <= 1 or from_lay == to_lay or from_lay == "R":
        return 0.0
    return bytes_ * (t - 1) / t


@dataclasses.dataclass
class ShardingSolution:
    """Per-kernel scheme choice + the resulting comm times.

    ``h_n[i]`` kernel inherent comm seconds (Eq. 5), ``h_m[j]`` tensor
    conversion seconds (Eq. 6); ``comm_bytes`` total collective payload.
    """

    scheme_idx: list[int]
    schemes: list[Scheme]
    h_n: list[float]
    h_m: list[float]
    comm_bytes: float
    total_comm: float


def expert_region_of(graph: DataflowGraph) -> set[str]:
    """GEMM kernels downstream of a ROUTER (until a non-GEMM): these run on
    dispatched tokens with expert-sharded weights (MoE expert parallelism)."""
    region: set[str] = set()
    frontier = [k.name for k in graph.kernels if k.kind == KernelKind.ROUTER]
    while frontier:
        cur = frontier.pop()
        for succ in graph.successors(cur):
            if succ in region:
                continue
            if graph.kernel(succ).kind == KernelKind.GEMM:
                region.add(succ)
                frontier.append(succ)
    return region


def solve_sharding(graph: DataflowGraph, t: int, topo: Topology,
                   dims: Sequence[int], exhaustive_limit: int = 12,
                   allow_sp: bool = False,
                   seq_shardable: bool = False) -> ShardingSolution:
    """Select one scheme per kernel minimizing total comm (h_n + h_m).

    This is a pairwise energy minimization on the kernel graph (node cost =
    inherent collective of the chosen scheme, edge cost = layout conversion).
    Exact by exhaustive enumeration for small graphs, otherwise greedy
    topological assignment + iterated conditional modes (ICM) refinement —
    validated against brute force in tests. (The paper feeds the same
    one-hot-scheme MIP to Gurobi.)
    """
    experts = expert_region_of(graph)
    cand = [schemes_for(k, t, seq_shardable, k.name in experts)
            for k in graph.kernels]
    if not allow_sp:  # paper-faithful scheme set: no reduce-scatter epilogue
        cand = [[s for s in cs if s.name != "row_rs"] or cs for cs in cand]
    n = graph.n
    edges = [(graph.kernel_index(tn.src), graph.kernel_index(tn.dst), tn.bytes_)
             for tn in graph.tensors]

    sizes = [len(c) for c in cand]
    out_bytes = [sum(tt.bytes_ for tt in graph.out_tensors(k.name))
                 for k in graph.kernels]

    def _priced_bytes(i: int, s: Scheme) -> float:
        out_b = out_bytes[i]
        if s.price_on_full or s.out_layout == "R":
            return out_b
        return out_b / t

    # Cost tables: kernel_cost is pure in (i, scheme), edge_cost in
    # (edge, scheme, scheme) — the search loops below (exhaustive product,
    # greedy, Viterbi, ICM) revisit each entry thousands of times, so both
    # are materialized once up front.
    kc = [np.array([cand[i][si].comm(_priced_bytes(i, cand[i][si]),
                                     topo, dims)
                    for si in range(sizes[i])]) for i in range(n)]
    ec = [np.array([[conversion_cost(cand[i][si].out_layout,
                                     cand[j][sj].in_layout,
                                     b, topo, dims, t)
                     for sj in range(sizes[j])] for si in range(sizes[i])])
          for (i, j, b) in edges]
    in_edges: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    out_edges: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for ei, (i, j, _b) in enumerate(edges):
        in_edges[j].append((ei, i))
        out_edges[i].append((ei, j))

    def kernel_cost(i: int, si: int) -> float:
        return float(kc[i][si])

    def total(assign: list[int]) -> float:
        c = sum(kernel_cost(i, assign[i]) for i in range(n))
        c += sum(float(ec[ei][assign[i], assign[j]])
                 for ei, (i, j, _b) in enumerate(edges))
        return c

    space = 1
    for z in sizes:
        space *= z
        if space > 4 ** exhaustive_limit:
            break

    best: list[int]
    if space <= 4 ** exhaustive_limit and n <= exhaustive_limit:
        # tie-break toward inherent collectives over layout conversions:
        # a conversion is a serial resynchronization on the tensor's critical
        # path, while a kernel's inherent collective overlaps with its epilogue
        # (this recovers the canonical Megatron pattern among equal-cost
        # assignments — the paper's §VI.A validation).
        #
        # Enumeration is vectorized over chunks of the scheme product space
        # in itertools.product order, accumulating kernel then edge terms in
        # the same order as ``total`` so the selected assignment (including
        # first-occurrence tie-breaks) matches the scalar scan exactly.
        best, best_key = None, (float("inf"), float("inf"))
        CHUNK = 1 << 16
        for lo in range(0, space, CHUNK):
            hi = min(space, lo + CHUNK)
            combos = np.array(np.unravel_index(np.arange(lo, hi), sizes))
            ksum = np.zeros(hi - lo)
            for i in range(n):
                ksum += kc[i][combos[i]]
            esum = np.zeros(hi - lo)
            for ei, (i, j, _b) in enumerate(edges):
                esum += ec[ei][combos[i], combos[j]]
            tot = ksum + esum
            cand_idx = np.nonzero(tot == tot.min())[0]
            ci = int(cand_idx[int(np.argmin(esum[cand_idx]))])
            key = (float(tot[ci]), float(esum[ci]))
            if key < best_key:
                best_key, best = key, [int(x) for x in combos[:, ci]]
    else:
        # Viterbi DP seed over the topo chain (exact for pure chains), then
        # multi-restart ICM sweeps (handles skip edges) — DESIGN.md §5.
        def viterbi() -> list[int]:
            """Exact on chains: DP over the topo order, scoring each node's
            scheme against its first predecessor's edge only."""
            order = graph.topo_order
            prev_of: dict[int, tuple[int, tuple[int, int, float]]] = {}
            for ei, e in enumerate(edges):  # one representative in-edge/node
                prev_of.setdefault(e[1], (ei, e))
            dp: dict[int, list[float]] = {}
            back: dict[int, list[int]] = {}
            for i in order:
                dp[i] = [0.0] * sizes[i]
                back[i] = [0] * sizes[i]
                e_in = prev_of.get(i)
                for si in range(sizes[i]):
                    c = kernel_cost(i, si)
                    if e_in is not None:
                        ei, e = e_in
                        p = e[0]
                        opts = [dp[p][sp] + float(ec[ei][sp, si])
                                for sp in range(sizes[p])]
                        bp = int(min(range(len(opts)), key=opts.__getitem__))
                        c += opts[bp]
                        back[i][si] = bp
                    dp[i][si] = c
            out = [0] * n
            for i in reversed(order):
                # choose the terminal node's best; propagate back pointers
                if not out_edges[i]:
                    out[i] = int(min(range(sizes[i]),
                                     key=dp[i].__getitem__))
            for i in reversed(order):
                e_in = prev_of.get(i)
                if e_in is not None:
                    p, d = e_in[1][0], e_in[1][1]
                    out[p] = back[d][out[d]]
            return out

        def icm(start: list[int]) -> tuple[list[int], float]:
            cur = list(start)
            for _ in range(12):
                changed = False
                for i in range(n):
                    old = cur[i]
                    cbest, sbest = float("inf"), old
                    for si in range(sizes[i]):
                        c = kernel_cost(i, si)
                        c += sum(float(ec[ei][cur[src], si])
                                 for ei, src in in_edges[i])
                        c += sum(float(ec[ei][si, cur[dst]])
                                 for ei, dst in out_edges[i])
                        if c < cbest:
                            cbest, sbest = c, si
                    cur[i] = sbest
                    changed |= sbest != old
                if not changed:
                    break
            return cur, total(cur)

        greedy = [0] * n
        for i in graph.topo_order:
            opts = []
            for si in range(sizes[i]):
                greedy[i] = si
                c = kernel_cost(i, si)
                c += sum(float(ec[ei][greedy[src], si])
                         for ei, src in in_edges[i])
                opts.append(c)
            greedy[i] = int(min(range(sizes[i]), key=opts.__getitem__))

        starts = [greedy, viterbi()]
        for s0 in range(max(sizes)):
            starts.append([min(s0, z - 1) for z in sizes])
        best, best_c = None, float("inf")
        for st in starts:
            cand_assign, c = icm(st)
            if c < best_c:
                best_c, best = c, cand_assign

    schemes = [cand[i][best[i]] for i in range(n)]
    h_n = [kernel_cost(i, best[i]) for i in range(n)]
    h_m = [float(ec[ei][best[i], best[j]])
           for ei, (i, j, _b) in enumerate(edges)]
    cbytes = 0.0
    for i, s in enumerate(schemes):
        cbytes += s.comm_bytes(_priced_bytes(i, s))
    for (i, j, b), hm in zip(edges, h_m):
        cbytes += conversion_bytes(schemes[i].out_layout, schemes[j].in_layout,
                                   b, t)
    return ShardingSolution(best, schemes, h_n, h_m, cbytes, total(best))

"""LLM serving performance model (paper §VIII.A, Fig 20) and speculative
decoding model (§VIII.B, Fig 21).

Prefill resembles one training forward pass; decode is one token per step
against a KV cache. Metrics: TTFT, TPOT, and system throughput (tokens/s),
as functions of (TP, PP) on a serving system.
"""
from __future__ import annotations

import dataclasses
import math

from ..systems.system import SystemSpec
from ..systems.topology import Topology
from .graph import DataflowGraph
from .interchip import _subdivide_dims
from .intrachip import optimize_intra_chip
from .sharding import solve_sharding
from .utilization import kernel_utilization

import numpy as np


@dataclasses.dataclass
class ServingPoint:
    tp: int
    pp: int
    ttft: float                 # s (prefill latency, one request)
    tpot: float                 # s per output token (decode latency)
    prefill_throughput: float   # tokens/s across the system
    decode_throughput: float    # tokens/s across the system
    breakdown_prefill: dict[str, float]
    breakdown_decode: dict[str, float]


def _phase_time(graph: DataflowGraph, system: SystemSpec, tp: int,
                tp_topo: Topology, execution: str = "dataflow",
                p_max: int = 8,
                n_streams: int = 16,
                sram_headroom: float = 0.9) -> tuple[float, dict[str, float]]:
    """Per-layer latency of one phase on a TP group + breakdown fractions."""
    dims = list(range(len(tp_topo.dims)))
    shard = solve_sharding(graph, tp, tp_topo, dims)
    sharded = graph.scaled(flop_scale=1.0, bytes_scale=1.0)  # shapes via h_*
    # per-chip flops applied through scheme factors:
    import dataclasses as _dc
    ks = [_dc.replace(k, flops=k.flops * s.flop_factor,
                      weight_bytes=k.weight_bytes * s.weight_factor)
          for k, s in zip(graph.kernels, shard.schemes)]
    ts = [_dc.replace(t, bytes_=t.bytes_ / tp) for t in graph.tensors]
    per_chip = DataflowGraph(ks, ts, graph.name + f"_tp{tp}")
    intra = optimize_intra_chip(per_chip, system.chip, system.memory,
                                h_n=shard.h_n, h_m=shard.h_m,
                                mode=execution, p_max=p_max,
                                n_streams=n_streams,
                                sram_headroom=sram_headroom)
    total = float(intra.t_critical.sum())
    denom = intra.t_comp.sum() + intra.t_mem.sum() + intra.t_net.sum()
    frac = {
        "compute": float(intra.t_comp.sum() / denom) if denom else 0.0,
        "memory": float(intra.t_mem.sum() / denom) if denom else 0.0,
        "network": float(intra.t_net.sum() / denom) if denom else 0.0,
    }
    return total, frac


def serving_sweep(prefill_layer: DataflowGraph, decode_layer: DataflowGraph,
                  n_layers: int, system: SystemSpec,
                  batch: int = 1, execution: str = "dataflow",
                  net_latency: float = 150e-9) -> list[ServingPoint]:
    """Sweep (TP, PP) with TP·PP == n_chips (paper Fig 20)."""
    n = system.n_chips
    out: list[ServingPoint] = []
    for tp in [d for d in range(1, n + 1) if n % d == 0]:
        pp = n // tp
        if pp > n_layers:
            continue
        cand = _subdivide_dims(system.topology, (tp, pp, 1), True)
        if not cand:
            continue
        tp_topo, pp_topo, _ = cand[0]
        layers_per_stage = math.ceil(n_layers / pp)
        # all resident layers of a stage share the chip's SRAM equally
        headroom = 0.9 / layers_per_stage
        t_pre, f_pre = _phase_time(prefill_layer, system, tp, tp_topo,
                                   execution, sram_headroom=headroom)
        # decode: one token per step — spilled weights and the KV cache are
        # re-streamed every step (no cross-microbatch amortization)
        t_dec, f_dec = _phase_time(decode_layer, system, tp, tp_topo,
                                   execution, n_streams=1,
                                   sram_headroom=headroom)
        stage_pre = t_pre * layers_per_stage
        stage_dec = t_dec * layers_per_stage + (net_latency if pp > 1 else 0.0)
        # TTFT: one request flows through all pp stages
        ttft = stage_pre * pp
        # TPOT: one token must traverse the whole pipeline (autoregressive)
        tpot = stage_dec * pp
        # throughput: pipeline accepts a new microbatch every stage time
        seq = _seq_of(prefill_layer)
        prefill_tp = batch * seq / stage_pre if stage_pre else 0.0
        decode_tp = batch / stage_dec if stage_dec else 0.0
        out.append(ServingPoint(tp, pp, ttft, tpot, prefill_tp, decode_tp,
                                f_pre, f_dec))
    return out


def _seq_of(graph: DataflowGraph) -> int:
    # sequence length is carried in the graph name by the builders (s<len>)
    import re
    m = re.search(r"_s(\d+)", graph.name)
    return int(m.group(1)) if m else 1


# ---------------- speculative decoding (paper §VIII.B, Fig 21) --------------
@dataclasses.dataclass
class SpecDecodePoint:
    scheme: str            # 'sequence' | 'tree'
    window: int            # K
    acceptance: float      # per-token acceptance rate
    tokens_per_s: float


def expected_accepted(window: int, acceptance: float, scheme: str) -> float:
    """Expected tokens emitted per verify step (+1 for the bonus token).

    sequence: 1 + a + a² + ... + a^K  (geometric, Leviathan et al. [50])
    tree (SpecInfer): path diversity boosts the effective per-step acceptance;
    we model the best-of-2^K tree as acceptance a_t = 1-(1-a)^2 per level.
    """
    if scheme == "sequence":
        return sum(acceptance ** k for k in range(window + 1))
    a_t = 1.0 - (1.0 - acceptance) ** 2
    return sum(a_t ** k for k in range(window + 1))


def speculative_throughput(t_draft_token: float, t_target_verify: float,
                           window: int, acceptance: float,
                           scheme: str = "sequence") -> float:
    """tokens/s of draft-then-verify decoding.

    draft cost: K tokens sequentially (sequence) or 2^K-1 tokens in a tree —
    tree drafting batches siblings but must still expand level by level; we
    charge K sequential levels with width-driven extra compute.
    """
    if scheme == "sequence":
        t_draft = window * t_draft_token
        verify_mult = 1.0 + 0.02 * window           # K+1 tokens in one pass
    else:
        width_cost = (2 ** window - 1) / max(window, 1)
        t_draft = window * t_draft_token * max(1.0, width_cost / 4.0)
        verify_mult = 1.0 + 0.05 * (2 ** window) / 8.0  # tree attention cost
    t_step = t_draft + t_target_verify * verify_mult
    return expected_accepted(window, acceptance, scheme) / t_step

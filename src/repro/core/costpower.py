"""Cost & power models (paper §VI.C, Fig 9).

Fig 9 fits silicon power vs compute throughput with a superlinear polynomial
(Y = 3e-7·X² − 4.3e-4·X + 0.04 in the paper's axis units, which are not
stated). We re-fit the same quadratic *shape* to the paper's own Table V
chips so the superlinearity conclusion is reproducible in explicit units:

    P_watts(X_tflops) = 2.4e-4·X² + 0.5·X

    H100   993 TFLOPS → 734 W   (actual 700)
    TPUv4  275        → 156     (actual 192)
    SN30   614        → 397     (actual 350)
    WSE-2  7500       → 17.2 kW (actual ~15 kW + system)

Price follows the same trend (paper: "similar, not shown"); we scale the
quadratic so H100-class silicon lands at ~$30k.
"""
from __future__ import annotations

from ..systems.system import SystemSpec

_PA, _PB = 2.4e-4, 0.5           # power fit (W per TFLOPS², W per TFLOPS)
_CA, _CB = 1.2e-2, 20.0          # price fit (USD per TFLOPS², USD per TFLOPS)


def silicon_power_w(tflops: float) -> float:
    """Superlinear power fit (Fig 9 shape, Table-V calibration)."""
    return _PA * tflops ** 2 + _PB * tflops


def silicon_price_usd(tflops: float) -> float:
    return _CA * tflops ** 2 + _CB * tflops


def cost_efficiency(util: float, system: SystemSpec) -> float:
    """Achieved FLOP/s per USD of system price."""
    return util * system.peak_flops / system.price()


def power_efficiency(util: float, system: SystemSpec) -> float:
    """Achieved FLOP/s per watt of system power."""
    return util * system.peak_flops / system.power()


def system_efficiency_terms(system: SystemSpec) -> tuple[float, float, float]:
    """(peak FLOP/s, price USD, power W) for a system — the constants the
    plan phase folds into ``pricing.PlanVector`` so the batched price phase
    computes cost/power efficiency without SystemSpec objects in hand."""
    return system.peak_flops, system.price(), system.power()

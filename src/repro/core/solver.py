"""Optimization engines replacing Gurobi (paper §III.A).

Two problem shapes recur in DFModel:

1. **min-max contiguous partition** (inter-chip PP stages, Eq. 7 objective):
   split a topologically ordered sequence of items into exactly ``p``
   contiguous groups minimizing the max group cost. Exact interval DP.

2. **min-sum contiguous partition with capacity** (intra-chip fusion, §V
   objective): split into at most ``p_max`` groups minimizing Σ group cost
   subject to per-group feasibility (SRAM). Exact interval DP.

3. **exact branch & bound over the assignment matrix A** for small graphs —
   searches the same space as the paper's MIP (one-hot rows + precedence) and
   certifies the DP answers optimal in tests. The DP restricts partitions to
   contiguous intervals of the topological order; B&B does not, so agreement
   between the two on non-trivial DAGs is evidence the restriction is lossless
   for the pipeline-ordered semantics DFModel uses.
"""
from __future__ import annotations

import itertools
from typing import Callable, Sequence

import numpy as np

from .graph import DataflowGraph
from .matrices import assignment_matrix, matrix_B, matrix_D, matrix_L


def minmax_partition(costs: Sequence[float], p: int,
                     extra: Callable[[int, int], float] | None = None
                     ) -> tuple[list[int], float]:
    """Split ``costs`` into exactly ``p`` contiguous groups minimizing the max
    group total (+ optional ``extra(i, j)`` per group [i, j)).

    Returns (boundaries, objective) where boundaries are group start indices
    (length p, first is 0). O(n²·p).
    """
    n = len(costs)
    if p > n:
        p = n
    pref = np.concatenate([[0.0], np.cumsum(costs)])

    INF = float("inf")
    dp = np.full((p + 1, n + 1), INF)
    arg = np.full((p + 1, n + 1), -1, dtype=np.int64)
    dp[0, 0] = 0.0
    if extra is None:
        # vectorized inner minimization (hot path: PP sweeps call this for
        # hundreds of (tp, pp, dp) candidates over ~100-layer sequences)
        for k in range(1, p + 1):
            prev = dp[k - 1]
            for j in range(k, n + 1):
                lo = k - 1
                cand = np.maximum(prev[lo:j], pref[j] - pref[lo:j])
                i = int(np.argmin(cand))
                dp[k, j] = cand[i]
                arg[k, j] = lo + i
    else:
        # vectorized like the ``extra is None`` path: materialize the extra
        # term once as a dense (i, j) table — O(n²) callback invocations
        # instead of the O(n²·p) of the scalar reference — then run the same
        # numpy inner minimization. Arithmetic order matches the scalar
        # implementation exactly ((pref[j] - pref[i]) + extra), so results
        # are bit-identical (``minmax_partition_scalar`` certifies this in
        # tests/test_solver.py).
        E = np.zeros((n + 1, n + 1))
        for j in range(1, n + 1):
            for i in range(j):
                E[i, j] = extra(i, j)
        for k in range(1, p + 1):
            prev = dp[k - 1]
            for j in range(k, n + 1):
                lo = k - 1
                cand = np.maximum(prev[lo:j],
                                  (pref[j] - pref[lo:j]) + E[lo:j, j])
                i = int(np.argmin(cand))
                dp[k, j] = cand[i]
                arg[k, j] = lo + i
    bounds = []
    j = n
    for k in range(p, 0, -1):
        i = int(arg[k, j])
        bounds.append(i)
        j = i
    bounds.reverse()
    return bounds, float(dp[p, n])


def minmax_partition_scalar(costs: Sequence[float], p: int,
                            extra: Callable[[int, int], float] | None = None
                            ) -> tuple[list[int], float]:
    """Pure-Python reference implementation of :func:`minmax_partition`.

    Kept as the agreement oracle for the vectorized paths (property tests
    assert bit-identical boundaries and objectives); never used on hot paths.
    """
    n = len(costs)
    if p > n:
        p = n
    pref = np.concatenate([[0.0], np.cumsum(costs)])
    INF = float("inf")
    dp = np.full((p + 1, n + 1), INF)
    arg = np.full((p + 1, n + 1), -1, dtype=np.int64)
    dp[0, 0] = 0.0

    def group(i: int, j: int) -> float:
        g = pref[j] - pref[i]
        return g + extra(i, j) if extra is not None else g

    for k in range(1, p + 1):
        for j in range(k, n + 1):
            for i in range(k - 1, j):
                c = max(dp[k - 1, i], group(i, j))
                if c < dp[k, j]:
                    dp[k, j] = c
                    arg[k, j] = i
    bounds: list[int] = []
    j = n
    for k in range(p, 0, -1):
        i = int(arg[k, j])
        bounds.append(i)
        j = i
    bounds.reverse()
    return bounds, float(dp[p, n])


def minsum_partition(n: int, p_max: int,
                     group_cost: Callable[[int, int], float],
                     feasible: Callable[[int, int], bool]
                     ) -> tuple[list[int], float]:
    """Split [0, n) into ≤ ``p_max`` contiguous groups minimizing
    Σ group_cost(i, j) s.t. feasible(i, j) per group. O(n²·p_max).

    Returns (boundaries, objective); raises if no feasible split exists.
    """
    INF = float("inf")
    dp = np.full((p_max + 1, n + 1), INF)
    arg = np.full((p_max + 1, n + 1), -1, dtype=np.int64)
    dp[0, 0] = 0.0
    # memoize costs since group_cost may be expensive
    cost_cache: dict[tuple[int, int], float] = {}

    def gc(i: int, j: int) -> float:
        key = (i, j)
        if key not in cost_cache:
            cost_cache[key] = group_cost(i, j) if feasible(i, j) else INF
        return cost_cache[key]

    for k in range(1, p_max + 1):
        for j in range(1, n + 1):
            best = dp[k - 1, j] if k > 1 else INF  # allow fewer groups
            besti = arg[k - 1, j] if k > 1 else -2
            for i in range(j):
                if dp[k - 1, i] == INF:
                    continue
                c = dp[k - 1, i] + gc(i, j)
                if c < best:
                    best, besti = c, i
            if best < dp[k, j]:
                dp[k, j] = best
                arg[k, j] = besti
    # best over any number of groups ≤ p_max
    kbest = int(np.argmin(dp[:, n]))
    if not np.isfinite(dp[kbest, n]):
        raise ValueError("no feasible partitioning (capacity too small?)")
    bounds: list[int] = []
    j, k = n, kbest
    while j > 0:
        i = int(arg[k, j])
        if i == -2:  # came from dp[k-1, j] (unused group)
            k -= 1
            continue
        bounds.append(i)
        j, k = i, k - 1
    bounds.reverse()
    return bounds, float(dp[kbest, n])


def bounds_to_assign(bounds: list[int], n: int) -> np.ndarray:
    """Convert group start indices to a per-item partition id vector."""
    assign = np.zeros(n, dtype=np.int64)
    for g, start in enumerate(bounds):
        end = bounds[g + 1] if g + 1 < len(bounds) else n
        assign[start:end] = g
    return assign


def branch_and_bound(graph: DataflowGraph, p_max: int,
                     objective: Callable[[np.ndarray], float],
                     feasible: Callable[[np.ndarray], bool] | None = None,
                     node_limit: int = 2_000_000) -> tuple[np.ndarray, float]:
    """Exact search over all precedence-feasible assignment matrices A.

    ``objective(assign)`` maps a full partition-id vector (in graph kernel
    order) to a cost; ``feasible`` may reject assignments (capacity).
    Kernels are assigned in topological order; each kernel may go to any
    partition ≥ max(partition of its predecessors) — the monotone schedule
    constraint of a sequential/pipelined execution. Branch & bound with the
    trivial bound (objectives here are monotone in prefix assignment is NOT
    assumed — we bound only by full evaluation at leaves, pruning via the
    precedence lattice and an optional incumbent check on partial costs when
    the objective supports it).
    """
    topo = graph.topo_order
    n = graph.n
    preds: list[list[int]] = [[] for _ in range(n)]
    for t in graph.tensors:
        preds[graph.kernel_index(t.dst)].append(graph.kernel_index(t.src))

    best_assign: np.ndarray | None = None
    best_cost = float("inf")
    assign = np.zeros(n, dtype=np.int64)
    nodes = 0

    def rec(pos: int) -> None:
        nonlocal best_assign, best_cost, nodes
        nodes += 1
        if nodes > node_limit:
            raise RuntimeError("branch_and_bound node limit exceeded")
        if pos == n:
            if feasible is not None and not feasible(assign):
                return
            c = objective(assign)
            if c < best_cost:
                best_cost = c
                best_assign = assign.copy()
            return
        i = topo[pos]
        lo = max((assign[p] for p in preds[i]), default=0)
        for part in range(lo, p_max):
            assign[i] = part
            rec(pos + 1)
        assign[i] = 0

    rec(0)
    if best_assign is None:
        raise ValueError("no feasible assignment")
    return best_assign, best_cost


def enumerate_parallelism(n_chips: int,
                          max_tp: int | None = None,
                          max_pp: int | None = None
                          ) -> list[tuple[int, int, int]]:
    """All (tp, pp, dp) with tp·pp·dp == n_chips (paper's outer loop)."""
    out = []
    for tp in _divisors(n_chips):
        if max_tp and tp > max_tp:
            continue
        rest = n_chips // tp
        for pp in _divisors(rest):
            if max_pp and pp > max_pp:
                continue
            out.append((tp, pp, rest // pp))
    return out


def _divisors(x: int) -> list[int]:
    out = [d for d in range(1, int(x ** 0.5) + 1) if x % d == 0]
    return sorted(set(out + [x // d for d in out]))


def design_space_size(graph: DataflowGraph, p_max: int, n_chips: int,
                      schemes_per_kernel: int = 3) -> float:
    """Order-of-magnitude size of the joint mapping space (paper: O(10^295)).

    partitions^kernels × schemes^kernels × parallelism combos.
    """
    import math
    n = graph.n
    combos = len(enumerate_parallelism(n_chips))
    return (math.log10(p_max) * n + math.log10(schemes_per_kernel) * n
            + math.log10(max(combos, 1)))


def intra_chip_matrices_cost(graph: DataflowGraph, assign: np.ndarray,
                             p_max: int, b: np.ndarray, s_cap: float,
                             d_cap: float) -> tuple[np.ndarray, np.ndarray, bool]:
    """Evaluate SRAM/DRAM terms through the exact matrix formulation.

    Returns (sram_per_partition, dram_xfer_per_partition, feasible) using
    Bᵀb ≤ s_cap, Lᵀb ≤ d_cap (paper §V.B.2).
    """
    A = assignment_matrix(assign, p_max)
    B = matrix_B(graph, A).astype(np.float64)
    D = matrix_D(graph, A).astype(np.float64)
    L = matrix_L(graph, A).astype(np.float64)
    sram = B.T @ b
    dram = D.T @ b
    live = L.T @ b
    ok = bool((sram <= s_cap).all() and (live <= d_cap).all())
    return sram, dram, ok

"""Assignment matrices A, B, D, L, H — paper §III.B Eqs. (1)-(4).

The Gurobi MIP in the paper centers on the boolean assignment matrix
``A ∈ B^{n×p}`` (kernel → partition, one-hot rows) and matrices derived from it:

  B[j,:] = A[src,:] ∧ A[dst,:]                      (intra-partition tensors, Eq. 1)
  D[j,:] = A[src,:] ⊕ A[dst,:]                      (cross-partition tensors, Eq. 2)
  L[j,:] = (A[src]U_s ⊕ A[dst]U_t) ⊕ (A[src] ∧ A[dst])   (tensor lifetime, Eq. 3)
  H[j,:] = A[src,:]                                 (source partition, Eq. 4)

We implement them vectorized in numpy; the solver evaluates candidate
assignments through these exact formulas, and the property tests assert the
identities the paper relies on (e.g. row(B)+row(D) partitions tensors, L covers
the open-closed interval between producer and consumer partitions).
"""
from __future__ import annotations

import numpy as np

from .graph import DataflowGraph


def assignment_matrix(assign: np.ndarray, p_max: int) -> np.ndarray:
    """One-hot encode a kernel→partition vector into A ∈ B^{n×p_max}."""
    assign = np.asarray(assign, dtype=np.int64)
    if assign.ndim != 1:
        raise ValueError("assign must be 1-D")
    if (assign < 0).any() or (assign >= p_max).any():
        raise ValueError("partition index out of range")
    A = np.zeros((assign.shape[0], p_max), dtype=bool)
    A[np.arange(assign.shape[0]), assign] = True
    return A


def _edge_endpoints(graph: DataflowGraph) -> tuple[np.ndarray, np.ndarray]:
    src = np.array([graph.kernel_index(t.src) for t in graph.tensors], dtype=np.int64)
    dst = np.array([graph.kernel_index(t.dst) for t in graph.tensors], dtype=np.int64)
    return src, dst


def matrix_B(graph: DataflowGraph, A: np.ndarray) -> np.ndarray:
    """Eq. 1: tensors whose producer and consumer share a partition."""
    src, dst = _edge_endpoints(graph)
    return A[src] & A[dst]


def matrix_D(graph: DataflowGraph, A: np.ndarray) -> np.ndarray:
    """Eq. 2: XOR — marks the two endpoints of cross-partition tensors."""
    src, dst = _edge_endpoints(graph)
    return A[src] ^ A[dst]


def matrix_H(graph: DataflowGraph, A: np.ndarray) -> np.ndarray:
    """Eq. 4: tensor placed where its producer lives."""
    src, _ = _edge_endpoints(graph)
    return A[src]


def upper_triangular_masks(p_max: int) -> tuple[np.ndarray, np.ndarray]:
    """U_s[i,j] = i <= j and U_t[i,j] = i < j (paper's auxiliary constants)."""
    idx = np.arange(p_max)
    U_s = idx[:, None] <= idx[None, :]
    U_t = idx[:, None] < idx[None, :]
    return U_s, U_t


def matrix_L(graph: DataflowGraph, A: np.ndarray) -> np.ndarray:
    """Eq. 3: lifetime indicator of cross-partition tensors.

    ``A[src]U_s`` is ones from the producer partition onward (inclusive),
    ``A[dst]U_t`` is ones strictly after the consumer partition; the XOR selects
    the interval [src_partition, dst_partition], and subtracting the
    intra-partition case (A[src] ∧ A[dst]) zeroes same-partition tensors.
    For backward edges (consumer scheduled before producer — possible only for
    inter-chip cyclic schedules, which our builders do not emit) the formula
    still yields a symmetric interval.
    """
    p_max = A.shape[1]
    src, dst = _edge_endpoints(graph)
    U_s, U_t = upper_triangular_masks(p_max)
    from_src = (A[src].astype(np.int64) @ U_s.astype(np.int64)) > 0
    from_dst = (A[dst].astype(np.int64) @ U_t.astype(np.int64)) > 0
    same = A[src] & A[dst]
    return (from_src ^ from_dst) ^ same


def validate_assignment(graph: DataflowGraph, A: np.ndarray) -> None:
    """Check the MIP's hard constraints: one-hot rows, precedence feasibility."""
    if A.dtype != bool:
        raise ValueError("A must be boolean")
    if A.shape[0] != graph.n:
        raise ValueError("A has wrong number of rows")
    if not (A.sum(axis=1) == 1).all():
        raise ValueError("A rows must be one-hot (A·1 = 1)")
    part = A.argmax(axis=1)
    for t in graph.tensors:
        if part[graph.kernel_index(t.src)] > part[graph.kernel_index(t.dst)]:
            raise ValueError(
                f"precedence violated: {t.src}(p{part[graph.kernel_index(t.src)]}) -> "
                f"{t.dst}(p{part[graph.kernel_index(t.dst)]})")


def partition_summaries(graph: DataflowGraph, assign: np.ndarray, p_max: int):
    """Per-partition aggregates used by both optimization passes.

    Returns dict with:
      flops[p]        Σ kernel flops in partition p            (Aᵀ f)
      sram_bytes[p]   Σ intra-partition tensor bytes           (Bᵀ b)
      dram_xfer[p]    Σ cross-partition tensor bytes touching p (Dᵀ b)
      dram_live[p]    Σ bytes of tensors live in p             (Lᵀ b)
      weight_bytes[p] Σ kernel weight bytes in p               (Aᵀ w)
    """
    A = assignment_matrix(assign, p_max)
    f = np.array([k.flops for k in graph.kernels])
    w = np.array([k.weight_bytes for k in graph.kernels])
    b = np.array([t.bytes_ for t in graph.tensors])
    B = matrix_B(graph, A)
    D = matrix_D(graph, A)
    L = matrix_L(graph, A)
    return {
        "A": A,
        "flops": A.astype(np.float64).T @ f,
        "weight_bytes": A.astype(np.float64).T @ w,
        "sram_bytes": B.astype(np.float64).T @ b,
        "dram_xfer": D.astype(np.float64).T @ b,
        "dram_live": L.astype(np.float64).T @ b,
    }

"""Keyed memo cache for the expensive DSE inner solves.

The 80-system cartesian sweep of §VI.C re-solves identical subproblems at
almost every design point: the TP sharding of one workload layer graph is a
pure function of ``(graph, tp, topology structure)`` and is shared by every
memory variant of a system; the PP stage partition depends only on the
per-layer cost vector; the intra-chip pass on ``(layer_graph, chip, mem, tp,
mode)``; and the whole inter-chip plan is memory-independent except for its
final capacity check.  ``SolveCache`` memoizes all of them under structural
(content-derived) keys so that rebuilding an identical workload object — which
``sweep()`` does once per system — still hits.

Cache key contract
------------------
Keys must capture *every* input that influences the cached value, using
hashable structural identities (never ``id()``):

* graphs enter keys via :meth:`repro.core.graph.DataflowGraph.fingerprint`
  (a content digest over kernels + tensors);
* chip/memory/interconnect/topology specs are frozen dataclasses and enter
  keys directly;
* derived float vectors (``h_n``/``h_m``, per-stage cost items) enter as
  tuples of the exact float values.

Under that contract a cache hit returns an object computed from bitwise-
identical inputs, so cached and uncached sweeps produce identical results —
the property ``tests/test_dse_engine.py`` locks in.

Each process owns its own local cache (workers of a forked
:class:`repro.core.dse_engine.DSEEngine` pool inherit the parent's warm
entries at fork time).  A *shared* tier can be layered underneath via
:meth:`SolveCache.attach_shared`: lookups then fall back local → shared,
and computed values are written through to both, so every worker of one
sweep reuses every other worker's solves (see
:mod:`repro.core.memo_store` for the cross-process store backends).  The
shared tier is strictly an extra place to find the same
structurally-keyed values, so the bit-identical-results property is
unchanged — and any shared-store failure silently degrades to a miss.
"""
from __future__ import annotations

import contextlib
import dataclasses
import pickle
from collections import Counter
from typing import Any, Callable, Hashable


@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Hit/miss/size counters, total and per key space."""

    hits: int
    misses: int
    entries: int
    by_space: dict[str, tuple[int, int, int]]  # space -> (hits, misses, size)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def space_hit_rate(self, space: str) -> float:
        """Hit rate of one key space, with the same zero-lookup guard as
        the aggregate :attr:`hit_rate`: a space with no lookups (or one
        this snapshot has never seen) reports 0.0, never a
        ``ZeroDivisionError`` — spaces holding only entries inherited at
        fork time, or registered after a ``clear()``, legitimately show
        size > 0 with zero traffic."""
        h, m, _ = self.by_space.get(space, (0, 0, 0))
        total = h + m
        return h / total if total else 0.0

    def rows(self) -> list[dict]:
        """Per-space stats as table/JSON rows (bench_dse reporting)."""
        out = [{"space": s, "hits": h, "misses": m, "entries": e,
                "hit_rate": self.space_hit_rate(s)}
               for s, (h, m, e) in sorted(self.by_space.items())]
        out.append({"space": "TOTAL", "hits": self.hits,
                    "misses": self.misses, "entries": self.entries,
                    "hit_rate": self.hit_rate})
        return out


class SolveCache:
    """A namespaced memo cache with hit/miss accounting.

    ``space`` partitions keys by solve family ("sharding", "minmax",
    "intra", "plan", "subdiv") so stats are attributable and clearing can
    stay global and simple. Entries are evicted wholesale once ``max_entries`` is
    exceeded (the sweep working set is far below the default bound; the
    guard only protects pathological long-running processes).
    """

    def __init__(self, max_entries: int = 1 << 16) -> None:
        self.max_entries = max_entries
        self.enabled = True
        self._data: dict[tuple[str, Hashable], Any] = {}
        self._hits: Counter[str] = Counter()
        self._misses: Counter[str] = Counter()
        #: Optional cross-process tier (see ``repro.core.memo_store``):
        #: a client with ``get(space, key_bytes) -> bytes | None`` and
        #: ``put(space, key_bytes, value_bytes)``.
        self.shared = None

    def attach_shared(self, client) -> None:
        """Layer a cross-process store under the local dict (write-through)."""
        self.shared = client

    def detach_shared(self):
        """Remove and return the shared tier (local entries stay warm)."""
        client, self.shared = self.shared, None
        return client

    def get_or_compute(self, space: str, key: Hashable,
                       compute: Callable[[], Any]) -> Any:
        if not self.enabled:
            self._misses[space] += 1
            return compute()
        full = (space, key)
        if full in self._data:
            self._hits[space] += 1
            return self._data[full]
        blob_key = self._shared_key(full) if self.shared is not None else None
        if blob_key is not None:
            found = self._shared_get(space, blob_key)
            if found is not None:
                # found in another process's work: a hit for this sweep
                # (the store's own stats count it as a cross-process hit)
                (value,) = found
                self._hits[space] += 1
                if len(self._data) >= self.max_entries:
                    self._data.clear()
                self._data[full] = value
                return value
        value = compute()
        if len(self._data) >= self.max_entries:
            self._data.clear()
        self._data[full] = value
        self._misses[space] += 1
        if blob_key is not None:
            self._shared_put(space, blob_key, value)
        return value

    # -- shared tier (never allowed to break a solve) --
    def _shared_key(self, full: tuple[str, Hashable]) -> bytes | None:
        try:
            return pickle.dumps(full, pickle.HIGHEST_PROTOCOL)
        except Exception:
            return None  # unpicklable key: local-only entry

    def _shared_get(self, space: str,
                    blob_key: bytes) -> tuple[Any] | None:
        """The stored value in a 1-tuple (``None`` *values* are legitimate
        cache entries — e.g. failed plan solves) or ``None`` on a miss."""
        try:
            blob = self.shared.get(space, blob_key)
            if blob is None:
                return None
            found = pickle.loads(blob)
            if isinstance(found, tuple) and len(found) == 1:
                return found
            return None  # not our wrapping: treat as a miss, never raise
        except Exception:
            return None

    def _shared_put(self, space: str, blob_key: bytes, value: Any) -> None:
        try:
            self.shared.put(space, blob_key,
                            pickle.dumps((value,), pickle.HIGHEST_PROTOCOL))
        except Exception:
            pass  # unpicklable value / full stripe / dead store: local-only

    def harvest(self, space: str) -> list[tuple[Hashable, Any]]:
        """All ``(key, value)`` pairs cached under ``space``, local tier
        first, then any shared-tier entries not already seen locally.

        This is the training-set extraction hook for surrogate models
        (:mod:`repro.search`): after a sweep, ``harvest("candmat")``
        yields every memoised :class:`repro.core.interchip.CandidateSet`
        — including ones computed by *other* processes of the same sweep
        when a shared store is attached.  Shared entries that fail to
        unpickle (version skew, torn writes are already excluded by the
        store) are skipped, never raised — same contract as
        ``_shared_get``.  Purely observational: no stats counters move.
        """
        out = [(key, value) for (s, key), value in self._data.items()
               if s == space]
        shared_items = getattr(self.shared, "items", None)
        if shared_items is None:
            return out
        seen = {self._shared_key((space, key)) for key, _ in out}
        seen.discard(None)
        try:
            blobs = list(shared_items())
        except Exception:
            return out
        for key_blob, value_blob in blobs:
            if key_blob in seen:
                continue
            try:
                full = pickle.loads(key_blob)
                if (not isinstance(full, tuple) or len(full) != 2
                        or full[0] != space):
                    continue
                found = pickle.loads(value_blob)
                if isinstance(found, tuple) and len(found) == 1:
                    out.append((full[1], found[0]))
            except Exception:
                continue
        return out

    def stats(self) -> CacheStats:
        sizes: Counter[str] = Counter(space for space, _ in self._data)
        spaces = set(self._hits) | set(self._misses) | set(sizes)
        return CacheStats(
            hits=sum(self._hits.values()),
            misses=sum(self._misses.values()),
            entries=len(self._data),
            by_space={s: (self._hits[s], self._misses[s], sizes[s])
                      for s in spaces})

    def diff_stats(self, before: CacheStats | None) -> dict:
        """Per-space entry/traffic delta since a :meth:`stats` snapshot
        (``before=None`` ≡ an empty snapshot).  The incremental-retrain
        driver of the learned rank stage polls ``["by_space"]["candmat"]``
        growth between warm-session requests to decide when the harvest
        gained enough new candidate sets to justify refitting — the
        in-process analogue of :func:`repro.core.memo_store.diff_stats`
        for the shared tier."""
        after = self.stats()
        if before is None:
            before = CacheStats(hits=0, misses=0, entries=0, by_space={})
        by_space = {}
        for space in set(after.by_space) | set(before.by_space):
            ah, am, asz = after.by_space.get(space, (0, 0, 0))
            bh, bm, bsz = before.by_space.get(space, (0, 0, 0))
            by_space[space] = (ah - bh, am - bm, asz - bsz)
        return {"hits": after.hits - before.hits,
                "misses": after.misses - before.misses,
                "entries": after.entries - before.entries,
                "by_space": by_space}

    def clear(self) -> None:
        self._data.clear()
        self._hits.clear()
        self._misses.clear()


#: Process-global cache shared by the inter-chip, intra-chip and DSE layers.
GLOBAL_CACHE = SolveCache()


def cache_stats() -> CacheStats:
    return GLOBAL_CACHE.stats()


def clear_caches() -> None:
    GLOBAL_CACHE.clear()


@contextlib.contextmanager
def caching_disabled():
    """Force every solve to run cold (the serial-baseline mode of
    ``benchmarks/bench_dse.py``)."""
    prev = GLOBAL_CACHE.enabled
    GLOBAL_CACHE.enabled = False
    try:
        yield
    finally:
        GLOBAL_CACHE.enabled = prev

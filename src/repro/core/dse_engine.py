"""DSEEngine — process-parallel, memoised, phase-split design-space sweeps.

The engine evaluates the same design grid as the serial reference
:func:`repro.core.dse.sweep`, but

* **phase-split & columnar**: workers run only the *plan* phase (the
  discrete solves, grouped so the memory variants of each (chip, net,
  topology) system share one candidate enumeration) and ship back
  :class:`repro.core.dse.PlannedGroup` records — the candidate-level
  :class:`repro.core.pricing.PlanMatrix` plus the per-memory winners. The
  parent row-concatenates every shipped matrix, prices all candidates of
  all memory variants in ONE batched ``price_plans`` call on the
  configured backend (``jax.vmap`` / the pallas kernel) and certifies the
  batched lexicographic argmin against the workers' numpy selection —
  skipped when the backend resolves to numpy, the workers' own reference —
  then batch-prices the winners' full vectors. ``DSEEngine(phased=False)``
  keeps the original per-point path (each worker plans *and* prices one
  cell) as a baseline for ``benchmarks/bench_dse.py``.
* **in parallel**: design points are independent, so plan groups are
  evaluated by a ``concurrent.futures`` process pool. Results are reduced
  *by grid index* (a deterministic ordered reduce), so the output list —
  including every float in ``DesignPoint.row()`` — is identical to the
  serial sweep's, regardless of worker count or completion order. The pool
  transport is configurable via ``mp_context`` (fork / spawn / forkserver);
  by default fork is used when safe and forkserver once jax is loaded
  (forking a process that already started jax's threads is a deadlock
  risk; the forkserver's template process predates them).
* **cached**: the inner solves (TP sharding, PP min-max partition, the
  memory-independent inter-chip plan, dim subdivision, the intra-chip pass)
  are memoised in ``repro.core.memo`` under structural keys. Workers forked
  after a warm-up inherit the parent's cache.
* **streaming**: :meth:`DSEEngine.sweep_iter` yields grid-index-tagged
  :class:`SweepItem`\\ s in completion order with windowed submission, so an
  early-exit predicate (e.g. :func:`stop_after_feasible`) stops submitting
  new work — live heat-map rendering and "stop after N feasible frontier
  points" both fall out.
* **scenario-first**: :meth:`DSEEngine.sweep_scenario` runs the named
  sweeps over the workload families (LLM / DLRM / HPL / FFT / MoE / Mamba2
  / serving, see :mod:`repro.workloads.scenarios`) and extracts the Pareto
  frontier over ``utilization × cost_eff × power_eff`` — the decision
  surface the paper's heat maps (Figs 10-17) visualize.

``benchmarks/bench_dse.py`` measures the phased engine against both the
serial scalar baseline and the per-point parallel path, asserts
row-identical output, and writes the numbers to ``BENCH_dse.json``;
``examples/dse_scenario.py`` shows the scenario/Pareto and streaming APIs.
"""
from __future__ import annotations

import dataclasses
import math
import multiprocessing
import os
import pickle
import sys
import time
import warnings
from typing import Callable, Iterable, Iterator, Sequence

from ..systems.system import SystemSpec
from .dse import (CERTIFY_EVERY, DEFAULT_CHIPS, DEFAULT_MEM_NET,
                  DEFAULT_TOPOLOGIES, DesignPoint, GridCell, PlannedGroup,
                  PlannedPoint, _group_cells, design_grid,
                  evaluate_design_point, plan_design_cells,
                  plan_design_groups, price_planned)
from .interchip import (TrainWorkload, candidate_matrix, certify_scalar_rows,
                        certify_winner_rows, resolve_prune,
                        select_candidates, winner_rows)
from .memo import GLOBAL_CACHE, caching_disabled
from .memo_store import StoreHandle, choose_backend, create_store
from .pricing import PlanMatrix, is_approx_backend, price_plans


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Immutable description of one design-grid sweep."""

    n_chips: int = 1024
    chips: tuple[str, ...] = DEFAULT_CHIPS
    topologies: tuple[str, ...] = DEFAULT_TOPOLOGIES
    mem_net: tuple[tuple[str, str], ...] = DEFAULT_MEM_NET
    max_tp: int | None = 64
    max_pp: int | None = None
    execution: str = "auto"

    def grid(self) -> list[GridCell]:
        return design_grid(self.chips, self.mem_net, self.topologies)


@dataclasses.dataclass
class ScenarioResult:
    """Points + Pareto frontier for one named workload scenario."""

    name: str
    smoke: bool
    spec: SweepSpec
    points: list[DesignPoint]
    frontier: list[DesignPoint]

    def rows(self) -> list[dict]:
        return [{"workload": self.name, **p.row()} for p in self.points]


@dataclasses.dataclass
class SweepItem:
    """One streamed sweep result: the grid index, its cell, and the priced
    point (``None`` for undecomposable cells, which ``sweep`` would skip)."""

    index: int
    cell: GridCell
    point: DesignPoint | None


def stop_after_feasible(n: int) -> Callable[[SweepItem], bool]:
    """Early-exit predicate for :meth:`DSEEngine.sweep_iter`: stop once
    ``n`` memory-feasible points have streamed out."""
    seen = 0

    def _stop(item: SweepItem) -> bool:
        nonlocal seen
        if item.point is not None and item.point.plan.feasible:
            seen += 1
        return seen >= n

    return _stop


def pareto_frontier(points: Sequence[DesignPoint],
                    metrics: tuple[str, ...] = ("utilization", "cost_eff",
                                                "power_eff"),
                    feasible_only: bool | str = "auto"
                    ) -> list[DesignPoint]:
    """Non-dominated subset of ``points`` maximizing every metric.

    A point is dominated if some other point is ≥ on every metric and
    strictly better on at least one. ``feasible_only="auto"`` restricts to
    memory-feasible points when any exist (the paper's heat maps grey out
    infeasible systems) and falls back to the full set otherwise, so the
    frontier of a non-empty sweep is never empty.
    """
    pts = list(points)
    if feasible_only == "auto":
        feas = [p for p in pts if p.plan.feasible]
        pts = feas or pts
    elif feasible_only:
        pts = [p for p in pts if p.plan.feasible]
    vals = [tuple(getattr(p, m) for m in metrics) for p in pts]
    out = []
    for i, vi in enumerate(vals):
        dominated = any(
            vj != vi and all(vj[k] >= vi[k] for k in range(len(vi)))
            for j, vj in enumerate(vals) if j != i)
        if not dominated:
            out.append(pts[i])
    return out


# --- worker plumbing ---------------------------------------------------------
# Two transports:
#   fork        — the work_fn closure (often a lambda) cannot be pickled, so
#                 the parent parks the sweep context in a module global,
#                 forks the pool, and ships only grid *indices* to workers.
#   spawn /     — used when forking is unsafe (jax already imported: forking
#   forkserver    a multithreaded process is a documented deadlock risk) or
#                 requested via ``mp_context``. Requires a picklable work_fn
#                 (the scenario registry's builders all are); each task
#                 carries its full arguments.
_WORKER_CTX: dict = {}


def _init_worker_shared(handle: StoreHandle) -> None:
    """Pool-worker initializer: attach a fresh connection to the sweep's
    shared memo store.  Runs before any task in every worker, for every
    start method — fork children must not reuse the parent's socket or
    lock-owning fd, so inheriting the parent's attached client is never
    enough.  The exit hook flushes whatever the client still buffers
    (trailing puts, stats deltas) when the pool retires the worker; it is
    a ``multiprocessing.util.Finalize``, NOT ``atexit`` — pool children
    leave via ``os._exit``, which skips atexit handlers."""
    from multiprocessing.util import Finalize

    client = handle.connect()
    GLOBAL_CACHE.attach_shared(client)
    Finalize(None, client.close, exitpriority=10)


def _noop(_i: int) -> None:
    """Warm-up task for :meth:`DSEEngine.start` (module-level so every
    start method can pickle it)."""
    return None


def _eval_index(i: int) -> DesignPoint | None:
    ctx = _WORKER_CTX
    return evaluate_design_point(ctx["work_fn"], ctx["grid"][i],
                                 ctx["n_chips"], max_tp=ctx["max_tp"],
                                 max_pp=ctx["max_pp"],
                                 execution=ctx["execution"])


def _eval_args(args: tuple) -> DesignPoint | None:
    work_fn, cell, n_chips, max_tp, max_pp, execution = args
    return evaluate_design_point(work_fn, cell, n_chips, max_tp=max_tp,
                                 max_pp=max_pp, execution=execution)


# Workers always select on the numpy reference backend (importing jax in a
# worker would be waste). With a non-numpy parent backend they also ship
# the candidate matrix so the parent can re-price it and certify the
# argmin; a numpy parent could never disagree with them, so it asks for
# lean groups (ship_matrix=False) instead of megabytes of unused IPC.
def _remap_group(group: PlannedGroup,
                 idxs: tuple[int, ...]) -> PlannedGroup:
    """Re-key a group's cell positions to the parent's grid indices."""
    return dataclasses.replace(
        group, indices=tuple(idxs[p] for p in group.indices))


def _plan_group_index(task: tuple) -> list[PlannedGroup]:
    idxs, certify = task
    ctx = _WORKER_CTX
    cells = [ctx["grid"][i] for i in idxs]
    groups = plan_design_groups(ctx["work_fn"], cells, ctx["n_chips"],
                                max_tp=ctx["max_tp"], max_pp=ctx["max_pp"],
                                execution=ctx["execution"],
                                ship_matrix=ctx["ship_matrix"],
                                prune=ctx["prune"], certify=certify,
                                ranker=ctx.get("ranker"),
                                rank_keep_frac=ctx.get("rank_keep_frac"))
    return [_remap_group(g, idxs) for g in groups]


def _plan_group_args(args: tuple) -> list[PlannedGroup]:
    (work_fn, cells, idxs, n_chips, max_tp, max_pp, execution, ship,
     prune, ranker, rank_keep_frac, certify) = args
    groups = plan_design_groups(work_fn, cells, n_chips, max_tp=max_tp,
                                max_pp=max_pp, execution=execution,
                                ship_matrix=ship, prune=prune,
                                certify=certify, ranker=ranker,
                                rank_keep_frac=rank_keep_frac)
    return [_remap_group(g, idxs) for g in groups]


def _group_indices(grid: Sequence[GridCell]) -> list[tuple[int, ...]]:
    """Grid indices grouped by (chip, net, topology): the memory variants
    of one system, which share a plan-phase candidate enumeration."""
    groups: dict[tuple, list[int]] = {}
    for i, (chip, _mem, net, topo) in enumerate(grid):
        groups.setdefault((chip, net, topo), []).append(i)
    return [tuple(v) for v in groups.values()]


def _chunk_groups(groups: Sequence, chunk_rows: int):
    """Split groups into consecutive batches of at most ~``chunk_rows``
    candidate rows (batches hold whole groups; one oversized group is its
    own batch). This is what bounds the whole-grid re-pricing pass's peak
    memory: a 10⁶-row candidate matrix never materializes at once —
    fixed-size blocks stream through the kernel instead."""
    batch: list = []
    rows = 0
    for g in groups:
        n = len(g.matrix)
        if batch and rows + n > chunk_rows:
            yield batch
            batch, rows = [], 0
        batch.append(g)
        rows += n
    if batch:
        yield batch


@dataclasses.dataclass
class _RepriceGroup:
    """One name-group of :meth:`DSEEngine.reprice_grid`: the (pruned)
    candidate matrix, the group's memory-variant capacities, and the
    numpy reference winners it must reproduce. Shape-compatible with
    ``PlannedGroup`` where ``_verify_group_winners`` reads it."""

    matrix: PlanMatrix
    capacities: tuple[float, ...]
    winner_rows: tuple[int, ...]
    survivors: object                  # np.ndarray | None


#: Infrastructure failures that justify a silent-ish serial fallback (the
#: fallback is warned about). Anything else — e.g. a work_fn bug — must
#: propagate with its real traceback, not be retried serially.
def _pool_infra_errors() -> tuple[type[BaseException], ...]:
    from concurrent.futures.process import BrokenProcessPool

    return (OSError, BrokenProcessPool, pickle.PicklingError)


def _require_picklable(work_fn) -> None:
    """Probe work_fn for non-fork transports. Pickle reports unpicklable
    callables inconsistently (PicklingError, AttributeError for local
    closures, TypeError) — normalize to PicklingError so the probe always
    lands in the infra-error fallback, never masquerades as a work_fn bug."""
    try:
        pickle.dumps(work_fn)
    except Exception as exc:
        raise pickle.PicklingError(
            f"work_fn {work_fn!r} is not picklable, which the non-fork "
            f"pool transport requires: {exc}") from exc


class DSEEngine:
    """Parallel + cached + phase-split design-space sweep engine.

    Parameters
    ----------
    max_workers:
        Process count for the parallel path (default: CPU count).
    parallel:
        ``"auto"`` (parallel when >1 CPU and the grid is big enough),
        ``True`` (force), or ``False`` (serial in-process, still cached).
    use_cache:
        ``False`` runs every solve cold — the serial-baseline mode of
        ``benchmarks/bench_dse.py``. (Fork workers inherit the disabled
        flag; spawn workers start fresh either way.)
    mp_context:
        Explicit multiprocessing start method (``"fork"``, ``"spawn"``,
        ``"forkserver"``) or a ``multiprocessing`` context object. Default
        ``None`` keeps the auto-detection: fork when available and jax has
        not been imported, spawn otherwise. Non-fork transports ship full
        task arguments, so ``work_fn`` must be picklable.
    phased:
        ``True`` (default) splits evaluation into a parallel plan phase +
        one batched pricing call; ``False`` keeps the per-point path where
        each worker plans and prices a single cell.
    pricing_backend:
        ``"numpy"``, ``"jax"``, ``"pallas"`` (the interpret-mode Pallas
        pricing kernel, :mod:`repro.kernels.pricing`),
        ``"pallas-compiled"`` (the compiled f32 lowering — approximate
        columns settled through the drift-budget contract of
        :mod:`repro.kernels.pricing.drift`; ``last_drift_stats`` reports
        the band accounting), or ``"auto"`` (env var
        ``DFMODEL_PRICING_BACKEND``, else numpy) — used for the parent's
        batched candidate-selection and final pricing calls
        (:func:`repro.core.pricing.price_plans`). Workers always select on
        the numpy reference; the parent certifies its backend against
        them. Final winner pricing on an approximate backend resolves to
        the exact reference (``pricing.exact_backend``), so sweep rows
        stay bit-identical across every backend.
    price_chunk_rows:
        Upper bound (approximate — whole groups only) on candidate rows
        per batched re-pricing call in the parent's whole-grid pass and
        :meth:`reprice_grid`. Bounds peak memory when the grid carries
        10⁵–10⁶ candidate rows; the default (65536) keeps one f32 block
        comfortably cache-sized while amortizing dispatch.
    shared_cache:
        ``False`` (default) keeps worker memo caches process-private.
        ``True``/``"auto"`` layers a cross-process shared memo store
        (:mod:`repro.core.memo_store`) under every worker's cache for the
        duration of each parallel sweep, so workers reuse each other's
        plan/sharding/minmax/subdiv/candmat solves; the backend follows
        the pool transport (mmap table for fork/forkserver, unix-socket
        server for spawn). ``"mmap"``/``"server"`` force a backend. The
        store lives for one sweep: it is created next to the pool and torn
        down — even on pool failure — before the sweep returns, leaving
        its aggregated cross-process stats in ``last_shared_stats``.
    prune:
        Candidate-pruning policy for the phased plan phase: ``"on"``,
        ``"off"``, a bool, or ``"auto"`` (env var ``DFMODEL_PRUNE``, else
        on). With pruning on, workers apply the hard feasibility mask +
        dominance filter (``interchip.prune_matrix``) before pricing and
        ship the compacted matrix plus its survivor index map; the
        parent's batched re-pricing (including the pallas kernel path)
        then covers only surviving rows, and every sampled group's
        winners are re-certified against the full scalar scan on the
        parent's side of the IPC boundary. Winners are certified
        bit-identical to the unpruned reference either way; pruning only
        shrinks how many rows get priced (``last_plan_stats`` reports
        enumerated / survived / priced).
    rank:
        Learned rank-stage policy (:mod:`repro.learned`): ``"on"``,
        ``"off"``, a bool, or ``"auto"`` (env var ``DFMODEL_RANK``, else
        **off** — the learned stage is opt-in). With rank on (and pruning
        on — the rank stage refines the dominance survivors, so prune off
        implies rank off), the engine fits a ridge ranker on the memo
        cache's ``candmat`` harvest once per sweep (warm sessions refit
        incrementally when :meth:`repro.core.memo.SolveCache.diff_stats`
        shows the harvest grew) and ships it to the workers; each group
        then prices only the model's calibrated top fraction union the
        staircase safety set (:func:`repro.learned.rank.rank_keep`).
        When the harvest is below the staleness guard
        (:data:`repro.learned.model.MIN_TRAIN_ROWS`) the engine degrades
        to rank-off for that sweep. Winners stay certified identical to
        the unranked pipeline (same sampled scalar certification);
        ``last_plan_stats`` reports ``rank`` / ``rank_survived``.
    rank_keep_frac:
        Override for the model's calibrated keep fraction, a float in
        (0, 1] (default ``None`` → ``$DFMODEL_RANK_KEEP_FRAC``, else the
        calibrated fraction).
    rank_model_path:
        Optional persistence path for the trained
        :class:`repro.learned.model.LearnedModel`: loaded when the
        in-process harvest is too small to fit (a cold service process
        reusing the previous session's model), saved after every
        successful fit.
    """

    def __init__(self, max_workers: int | None = None,
                 parallel: bool | str = "auto",
                 use_cache: bool = True,
                 mp_context: str | multiprocessing.context.BaseContext | None
                 = None,
                 phased: bool = True,
                 pricing_backend: str = "auto",
                 shared_cache: bool | str = False,
                 prune: str | bool = "auto",
                 price_chunk_rows: int = 65536,
                 rank: str | bool = "auto",
                 rank_keep_frac: float | None = None,
                 rank_model_path: str | None = None) -> None:
        self.max_workers = max_workers or (os.cpu_count() or 1)
        self.parallel = parallel
        self.use_cache = use_cache
        if isinstance(mp_context, str):
            if mp_context not in multiprocessing.get_all_start_methods():
                raise ValueError(
                    f"mp_context {mp_context!r} not available on this "
                    f"platform; have {multiprocessing.get_all_start_methods()}")
        self.mp_context = mp_context
        self.phased = phased
        self.pricing_backend = pricing_backend
        if shared_cache not in (False, True, "auto", "mmap", "server"):
            raise ValueError(
                f"shared_cache {shared_cache!r}; expected False, True, "
                f"'auto', 'mmap' or 'server'")
        self.shared_cache = shared_cache
        resolve_prune(prune)  # reject unknown policies at construction
        self.prune = prune
        if not isinstance(price_chunk_rows, int) or price_chunk_rows < 1:
            raise ValueError(f"price_chunk_rows must be a positive int, "
                             f"got {price_chunk_rows!r}")
        self.price_chunk_rows = price_chunk_rows
        from ..learned.rank import resolve_rank

        resolve_rank(rank)  # reject unknown policies at construction
        self.rank = rank
        if rank_keep_frac is not None and not 0.0 < rank_keep_frac <= 1.0:
            raise ValueError(f"rank_keep_frac must lie in (0, 1], "
                             f"got {rank_keep_frac!r}")
        self.rank_keep_frac = rank_keep_frac
        self.rank_model_path = rank_model_path
        # learned rank-stage session state: the current fitted model and
        # the cache-stats snapshot its harvest was taken at (warm-session
        # incremental retrain compares against it; see _ranker_for_run)
        self._ranker = None
        self._rank_snapshot = None
        #: Plan-phase accounting of the last parallel phased sweep:
        #: {"groups", "candidates", "cells", "backend"} — the exactly-once
        #: candidate-matrix shipping contract tests/test_dse_engine.py
        #: asserts. ``None`` until a parallel phased sweep completes.
        self.last_plan_stats: dict | None = None
        #: Aggregated cross-process stats of the last parallel sweep's
        #: shared memo store ({"backend", "hits", "misses", "inserts",
        #: "dropped", "entries", "by_space"}), or ``None`` when no shared
        #: store ran. ``hits`` counts lookups served by *another*
        #: process's solve — the cross-worker reuse ``BENCH_dse.json``'s
        #: ``cold_parallel_shared`` row certifies.
        self.last_shared_stats: dict | None = None
        #: Aggregated drift-band accounting of the last sweep's banded
        #: certifications on an approximate backend ({"backend", "band",
        #: "groups", "rows", "caps", "repriced", "ambiguous_mem",
        #: "band_hits", "fallback_caps", "max_iter_drift",
        #: "max_mem_drift"}), or ``None`` when no banded selection ran.
        self.last_drift_stats: dict | None = None
        # warm-session state (:meth:`start` / :meth:`shutdown`): one
        # process pool + one shared memo store reused across calls
        self._session = False
        self._session_pool = None
        self._session_store = None

    # -- core sweep ----------------------------------------------------------
    def sweep(self, work_fn: Callable[[SystemSpec], TrainWorkload],
              spec: SweepSpec = SweepSpec()) -> list[DesignPoint]:
        """Price every grid cell of ``spec``; skip infeasible cells.

        Output order and values are identical to
        ``repro.core.dse.sweep(work_fn, **spec fields, phased=False)``.
        """
        grid = spec.grid()
        self.last_plan_stats = None
        self.last_shared_stats = None
        self.last_drift_stats = None
        if not self.phased:
            return self._sweep_perpoint(work_fn, spec, grid)
        planned: list[PlannedPoint | None] | None = None
        if self._should_parallelize(len(grid)):
            try:
                planned = self._parallel_plan(work_fn, spec, grid)
            except _pool_infra_errors() as exc:
                warnings.warn(f"parallel sweep unavailable ({exc!r}); "
                              f"falling back to serial", RuntimeWarning,
                              stacklevel=2)
        if planned is None:
            with self._cache_mode():
                # the serial phased path goes through the same group
                # reduce as the pool path, so ``last_plan_stats`` (incl.
                # the pruning accounting) is populated either way; the
                # matrices are not shipped anywhere — backend and sampled
                # scalar certification already ran inside the call
                ranker, rkf = self._ranker_for_run()
                groups = plan_design_groups(
                    work_fn, grid, spec.n_chips, max_tp=spec.max_tp,
                    max_pp=spec.max_pp, execution=spec.execution,
                    pricing_backend=self.pricing_backend,
                    ship_matrix=False, prune=self.prune,
                    ranker=ranker, rank_keep_frac=rkf)
                planned = self._finish_plan_groups(groups, len(grid))
        return price_planned(planned, backend=self.pricing_backend)

    def sweep_iter(self, work_fn: Callable[[SystemSpec], TrainWorkload],
                   spec: SweepSpec = SweepSpec(),
                   stop: Callable[[SweepItem], bool] | None = None
                   ) -> Iterator[SweepItem]:
        """Stream :class:`SweepItem`\\ s as plan groups finish.

        Items carry their grid index so consumers can re-order; every index
        of the grid is delivered exactly once (unless ``stop`` ends the
        sweep early). ``stop`` is called after each yield; a truthy return
        cancels all not-yet-running work and ends the iteration. Work is
        submitted in a bounded window (≈2 tasks per worker), so an early
        stop genuinely avoids planning the rest of the grid.

        Points are priced through the same batched backend as :meth:`sweep`
        (one batch per plan group) — pricing is elementwise over the batch
        axis, so streamed values are bit-identical to a full sweep's.
        """
        return self._iter_cells(work_fn, spec, spec.grid(), stop)

    def sweep_cells_iter(self, work_fn: Callable[[SystemSpec], TrainWorkload],
                         cells: Sequence[GridCell],
                         spec: SweepSpec = SweepSpec(),
                         stop: Callable[[SweepItem], bool] | None = None
                         ) -> Iterator[SweepItem]:
        """Stream :class:`SweepItem`\\ s for an explicit list of grid cells.

        Identical machinery (and therefore bit-identical points) to
        :meth:`sweep_iter`, but over ``cells`` instead of ``spec``'s own
        cartesian grid — ``spec`` contributes only the non-grid sweep
        parameters (``n_chips``, ``max_tp``, ``max_pp``, ``execution``).
        Item indices are positions in ``cells``; every position is
        delivered exactly once (unless ``stop`` fires).

        This is the warm-service entry point: the service scheduler
        (:mod:`repro.service`) batches deduplicated cells from many
        concurrent requests and streams each batch through the same
        certified plan → price pipeline, usually on a warm session pool
        (:meth:`start`).
        """
        return self._iter_cells(work_fn, spec, list(cells), stop)

    def _iter_cells(self, work_fn, spec: SweepSpec, grid, stop
                    ) -> Iterator[SweepItem]:
        self.last_shared_stats = None
        self.last_drift_stats = None
        delivered: set[int] = set()
        if self._should_parallelize(len(grid)):
            gen = self._parallel_iter(work_fn, spec, grid, stop)
            while True:
                try:
                    item = next(gen)
                except StopIteration:
                    # the parallel stream completed (or stop() fired in it)
                    return
                except _pool_infra_errors() as exc:
                    # mid-stream pool failure: fall through to the serial
                    # path for the *undelivered* indices only, preserving
                    # the exactly-once contract (and any state the stop
                    # predicate accumulated so far)
                    warnings.warn(f"parallel sweep unavailable ({exc!r}); "
                                  f"streaming serially", RuntimeWarning,
                                  stacklevel=2)
                    break
                delivered.add(item.index)
                yield item
        pending = [(i, cell) for i, cell in enumerate(grid)
                   if i not in delivered]
        yield from self._serial_iter(work_fn, spec, pending, stop)

    def sweep_scenario(self, name: str, smoke: bool = False
                       ) -> ScenarioResult:
        """Run a named workload-family sweep + Pareto extraction."""
        from ..workloads.scenarios import get_scenario

        sc = get_scenario(name, smoke=smoke)
        points = self.sweep(sc.work_fn, sc.spec)
        return ScenarioResult(name=sc.name, smoke=smoke, spec=sc.spec,
                              points=points,
                              frontier=pareto_frontier(points))

    def sweep_all_scenarios(self, smoke: bool = False,
                            names: Iterable[str] | None = None
                            ) -> dict[str, ScenarioResult]:
        from ..workloads.scenarios import scenario_names

        return {n: self.sweep_scenario(n, smoke=smoke)
                for n in (names or scenario_names())}

    # -- warm-session lifecycle ----------------------------------------------
    @property
    def session_active(self) -> bool:
        """True between :meth:`start` and :meth:`shutdown`."""
        return self._session

    def start(self) -> "DSEEngine":
        """Switch the engine into *warm-session* mode.

        One process pool and (with ``shared_cache``) one cross-process
        memo store are created now — workers forked/spawned up front,
        store attached to the parent's cache — and reused by every
        subsequent ``sweep`` / ``sweep_iter`` / ``sweep_cells_iter`` /
        ``search`` / ``reprice_grid`` call until :meth:`shutdown`,
        instead of being built and torn down per sweep. This is what the
        DSE service daemon (:mod:`repro.service`) runs on: request
        latency stops paying pool spin-up, and solves harvested by one
        request seed every later one through the persistent store.

        Two session-mode consequences:

        * all workers predate later calls, so even the fork transport
          ships full task arguments — ``work_fn`` must be picklable
          (the scenario registry's builders all are);
        * calls must not run concurrently from multiple threads — the
          engine serializes nothing internally (the service scheduler
          owns exactly one engine thread for this reason).

        Idempotent; returns ``self`` so it nests in ``with``:
        ``with DSEEngine(...) as engine: ...``. If the pool cannot be
        built (or ``parallel=False`` / one worker), the session still
        starts — sweeps run serially against the warm store.
        """
        if self._session:
            return self
        store = self._open_shared_store()
        self._session_store = store
        self._session = True
        pool = None
        if self.parallel is not False and self.max_workers > 1:
            import concurrent.futures as cf

            try:
                pool = cf.ProcessPoolExecutor(
                    max_workers=self.max_workers,
                    mp_context=self._mp_context(),
                    **self._pool_kwargs(store))
                # force every worker into existence NOW: the daemon
                # starts its accept/scheduler threads after this, and
                # forking a multithreaded process later is the exact
                # hazard the transport auto-pick exists to avoid
                list(pool.map(_noop, range(self.max_workers * 4),
                              chunksize=1))
            except _pool_infra_errors() as exc:
                warnings.warn(
                    f"warm session pool unavailable ({exc!r}); session "
                    f"continues serially", RuntimeWarning, stacklevel=2)
                if pool is not None:
                    pool.shutdown(wait=False, cancel_futures=True)
                pool = None
        self._session_pool = pool
        return self

    def shutdown(self) -> None:
        """End the warm session: drain + close the session pool, detach
        and tear down the session store (its aggregated cross-process
        stats land in ``last_shared_stats``). Idempotent."""
        pool, self._session_pool = self._session_pool, None
        store, self._session_store = self._session_store, None
        self._session = False
        if pool is not None:
            pool.shutdown(wait=True, cancel_futures=True)
        if store is not None:
            self._close_shared_store(store)

    def __enter__(self) -> "DSEEngine":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.shutdown()
        return False

    # -- budgeted search -----------------------------------------------------
    def search(self, work_fn: Callable[[SystemSpec], TrainWorkload],
               spec: SweepSpec = SweepSpec(), *,
               policy, budget: int,
               certify: bool = True,
               progress: Callable[[dict], None] | None = None):
        """Budgeted adaptive exploration of ``spec``'s design grid.

        ``policy`` (a :class:`repro.search.SearchPolicy`) proposes
        batches of grid indices; each batch is planned + priced through
        the same columnar pipeline as :meth:`sweep` (one batched
        ``plan_design_cells`` + ``price_planned`` call per batch on the
        configured pricing backend) and the priced observations feed
        back into the policy.  The loop ends when the policy stops
        asking or ``budget`` full evaluations are spent.

        The proposal contract is enforced strictly — an index out of
        range, proposed twice, or past the budget raises RuntimeError
        (exactly-once evaluation accounting is part of the result's
        meaning, not a best-effort hint).  Per-round progress records
        (evals, elapsed, ETA) accumulate in the result and stream
        through ``progress`` when given.

        ``certify=True`` (default, the house rule) evaluates the FULL
        grid through the identical machinery afterwards and requires the
        search winner to be the exhaustive argmin of the lexicographic
        ``(infeasible, iter_time, index)`` objective — a policy that
        misses the true winner raises rather than returning silently
        wrong results.  All values are bit-identical between search and
        oracle (same certified planning/pricing path), so the
        comparison is exact, not tolerance-based.
        """
        from ..search.policy import SearchContext, SearchResult
        from ..search.surrogate import cell_features

        grid = spec.grid()
        n = len(grid)
        if n == 0:
            raise ValueError("search needs a non-empty design grid")
        if int(budget) < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        granted = min(int(budget), n)
        t0 = time.perf_counter()
        cheap_evals = 0

        def cheap_bound(indices: Sequence[int]) -> list[tuple[bool, float]]:
            nonlocal cheap_evals
            idx = [int(i) for i in indices]
            bad = [i for i in idx if not 0 <= i < n]
            if bad:
                raise IndexError(f"cheap_bound indices out of range "
                                 f"(grid size {n}): {bad[:5]}")
            out: list = [None] * len(idx)
            cells = [grid[i] for i in idx]
            with self._cache_mode():
                for pos_list, work, systems in _group_cells(
                        work_fn, cells, spec.n_chips, spec.execution):
                    caps = [s.memory.capacity for s in systems]
                    cands = candidate_matrix(
                        work, systems[0], max_tp=spec.max_tp,
                        max_pp=spec.max_pp, execution=spec.execution,
                        prune=self.prune)
                    if not len(cands):
                        for pos in pos_list:
                            out[pos] = (True, math.inf)
                        continue
                    sel = cands.selection()
                    rows = winner_rows(sel["iter_time"],
                                       sel["per_chip_mem_bytes"], caps)
                    for pos, cap, r in zip(pos_list, caps, rows):
                        out[pos] = (
                            bool(sel["per_chip_mem_bytes"][r] > cap),
                            float(sel["iter_time"][r]))
            cheap_evals += len(idx)
            return out

        topo_vocab = {t: k for k, t in enumerate(spec.topologies)}

        def features(index: int):
            return cell_features(grid[int(index)], spec.n_chips, topo_vocab)

        policy.reset(SearchContext(n_points=n, budget=granted,
                                   cheap_bound=cheap_bound,
                                   features=features))
        evaluated: dict = {}
        rounds: list[dict] = []
        round_no = 0
        while len(evaluated) < granted:
            asked = [int(i) for i in policy.ask()]
            if not asked:
                break
            self._check_proposals(policy, asked, evaluated, granted, n)
            obs = self._search_eval(work_fn, spec, grid, asked,
                                    certify=round_no % CERTIFY_EVERY == 0)
            for o in obs:
                evaluated[o.index] = o
            policy.tell(obs)
            round_no += 1
            elapsed = time.perf_counter() - t0
            done = len(evaluated)
            best = min(evaluated.values(), key=lambda o: o.objective)
            record = {"round": round_no, "asked": len(asked),
                      "evals": done, "budget": granted,
                      "elapsed_s": elapsed,
                      "eta_s": elapsed / done * (granted - done),
                      "best_index": best.index,
                      "best_iter_time": best.iter_time,
                      "best_feasible": best.feasible}
            rounds.append(record)
            if progress is not None:
                progress(record)
        best = (min(evaluated.values(), key=lambda o: o.objective)
                if evaluated else None)
        oracle_index = None
        if certify:
            oracle = min(
                self._search_eval(work_fn, spec, grid, list(range(n)),
                                  certify="sample"),
                key=lambda o: o.objective)
            oracle_index = oracle.index
            if best is None or best.index != oracle.index:
                raise RuntimeError(
                    f"search policy {policy.name!r} missed the true argmin: "
                    f"policy best "
                    f"{(best.index, best.objective[:2]) if best else None} "
                    f"vs exhaustive argmin "
                    f"{(oracle.index, oracle.objective[:2])} "
                    f"(budget {granted}/{n}, evals {len(evaluated)})")
        return SearchResult(
            policy=policy.name, budget=granted, evals_used=len(evaluated),
            cheap_evals=cheap_evals, rounds=rounds,
            best_index=best.index if best else -1,
            best_point=best.point if best else None,
            best_objective=((best.feasible, best.iter_time)
                            if best else None),
            evaluated=evaluated, certified=certify,
            oracle_index=oracle_index,
            seconds=time.perf_counter() - t0)

    @staticmethod
    def _check_proposals(policy, asked, evaluated, budget: int,
                         n: int) -> None:
        """Exactly-once/bounded proposal contract (violations raise)."""
        seen: set[int] = set()
        for i in asked:
            if not 0 <= i < n:
                raise RuntimeError(
                    f"search policy {policy.name!r} proposed out-of-range "
                    f"index {i} (grid size {n})")
            if i in seen or i in evaluated:
                raise RuntimeError(
                    f"search policy {policy.name!r} proposed index {i} "
                    f"more than once")
            seen.add(i)
        if len(evaluated) + len(asked) > budget:
            raise RuntimeError(
                f"search policy {policy.name!r} exceeded the evaluation "
                f"budget: {len(evaluated)} evaluated + {len(asked)} "
                f"proposed > {budget}")

    def _search_eval(self, work_fn, spec: SweepSpec, grid, indices,
                     certify: bool | str):
        """Plan + price one proposed batch; one Observation per index.

        The same columnar path as :meth:`sweep` — memory variants in the
        batch share candidate enumerations, the backend prices one
        batch, and ``certify`` (the engine's sampled cadence) runs the
        scalar-scan check inside the planning call."""
        from ..search.policy import Observation

        cells = [grid[i] for i in indices]
        ranker, rkf = self._ranker_for_run()
        with self._cache_mode():
            planned = plan_design_cells(
                work_fn, cells, spec.n_chips, max_tp=spec.max_tp,
                max_pp=spec.max_pp, execution=spec.execution,
                pricing_backend=self.pricing_backend, prune=self.prune,
                ranker=ranker, rank_keep_frac=rkf, certify=certify)
            pts = price_planned(planned, backend=self.pricing_backend)
        live = [i for i, p in zip(indices, planned) if p is not None]
        by_index = dict(zip(live, pts))
        out = []
        for i in indices:
            pt = by_index.get(i)
            if pt is None:
                out.append(Observation(index=i, cell=grid[i], feasible=False,
                                       iter_time=math.inf, utilization=0.0,
                                       point=None))
            else:
                out.append(Observation(
                    index=i, cell=grid[i],
                    feasible=bool(pt.plan.feasible),
                    iter_time=float(pt.plan.iter_time),
                    utilization=float(pt.utilization), point=pt))
        return out

    # -- internals -----------------------------------------------------------
    def _should_parallelize(self, grid_size: int) -> bool:
        if self.parallel is False:
            return False
        if self._session_pool is not None:
            # the warm session pool is already paid for — even a small
            # service batch routes through it
            return True
        if self.parallel is True:
            return self.max_workers > 1
        return self.max_workers > 1 and grid_size >= 4

    def _start_method(self) -> str:
        """Pick the pool transport.

        An explicit ``mp_context`` wins. Otherwise: forking a multithreaded
        process is a documented deadlock risk, and importing jax starts
        worker threads — so once jax is loaded (the kernel test suite, a
        training session) we prefer forkserver: its server process was
        forked at first use, before jax's threads existed, so children are
        clean while task submission still needs only picklable work_fns
        (same contract as spawn, but without re-importing the world per
        worker). When jax was never imported fork stays the default — it
        supports closures and is ~4× faster cold.
        """
        if isinstance(self.mp_context, str):
            return self.mp_context
        if self.mp_context is not None:
            return self.mp_context.get_start_method()
        methods = multiprocessing.get_all_start_methods()
        if "jax" not in sys.modules and "fork" in methods:
            return "fork"
        if "forkserver" in methods:
            return "forkserver"
        return "spawn"

    def _mp_context(self) -> multiprocessing.context.BaseContext:
        if (self.mp_context is not None
                and not isinstance(self.mp_context, str)):
            return self.mp_context
        return multiprocessing.get_context(self._start_method())

    # -- shared memo store (one per parallel sweep) --------------------------
    def _open_shared_store(self):
        """Create the sweep's cross-process memo store and attach it to
        the parent's cache too (the parent's own misses then seed the
        workers).  ``None`` when disabled — or when caching is off, which
        must stay genuinely cold.

        In warm-session mode the session's persistent store is returned
        (re-attached if something detached it) instead of creating a new
        one — the store is shared across *requests*, not per-sweep."""
        if self._session_store is not None:
            if GLOBAL_CACHE.shared is not self._session_store:
                GLOBAL_CACHE.attach_shared(self._session_store)
            return self._session_store
        if not self.shared_cache or not self.use_cache:
            return None
        try:
            backend = (self.shared_cache
                       if self.shared_cache in ("mmap", "server")
                       else choose_backend(self._start_method()))
            store = create_store(backend, mp_context=self._mp_context())
        except (RuntimeError, OSError) as exc:
            # no usable backend on this platform (no fcntl, no AF_UNIX) or
            # the store could not materialize (unwritable TMPDIR, socket
            # bind failure — an OSError escaping here would otherwise land
            # in the callers' pool-infra fallback and needlessly serialize
            # the sweep): the cache tier must never take the sweep down —
            # keep the parallel pool, just with process-private caches
            warnings.warn(f"shared memo store unavailable ({exc}); "
                          f"sweeping with private caches", RuntimeWarning,
                          stacklevel=3)
            return None
        GLOBAL_CACHE.attach_shared(store)
        return store

    def _close_shared_store(self, store) -> None:
        """Detach + tear down the sweep's store, keeping its aggregated
        cross-process stats.  Runs in ``finally`` blocks so a pool failure
        (and the serial fallback after it) never leaks a store, a server
        process, or a stale attachment.

        The session store is NOT torn down here — it outlives individual
        sweeps by design; only its running stats are snapshotted.
        :meth:`shutdown` (which clears ``_session_store`` first) owns its
        teardown."""
        if store is None:
            return
        if store is self._session_store:
            try:
                self.last_shared_stats = store.stats()
            except Exception:
                self.last_shared_stats = None
            return
        if GLOBAL_CACHE.shared is store:
            GLOBAL_CACHE.detach_shared()
        try:
            self.last_shared_stats = store.stats()
        except Exception:
            self.last_shared_stats = None
        store.close()

    def _pool_kwargs(self, store) -> dict:
        """Extra ``ProcessPoolExecutor`` kwargs wiring workers to ``store``."""
        if store is None:
            return {}
        return {"initializer": _init_worker_shared,
                "initargs": (store.handle(),)}

    def _pool(self, workers: int, store):
        """Pool acquisition: the warm session pool when one is live
        (kept open on exit; rebuilt first if a dead worker poisoned it),
        else a fresh per-sweep pool torn down on exit."""
        import concurrent.futures as cf
        import contextlib

        if self._session_pool is not None:
            if getattr(self._session_pool, "_broken", False):
                # a BrokenProcessPool is permanent for its executor —
                # rebuild on the same session store so the warm session
                # (and the daemon on top of it) survives a worker death
                self._session_pool.shutdown(wait=False, cancel_futures=True)
                self._session_pool = None
                self._session = False
                self.start()
            if self._session_pool is not None:
                return contextlib.nullcontext(self._session_pool)
        pool = cf.ProcessPoolExecutor(max_workers=workers,
                                      mp_context=self._mp_context(),
                                      **self._pool_kwargs(store))

        @contextlib.contextmanager
        def owned():
            try:
                yield pool
            finally:
                pool.shutdown(wait=True, cancel_futures=True)

        return owned()

    # -- per-point path (PR 1 baseline) --------------------------------------
    def _sweep_perpoint(self, work_fn, spec: SweepSpec, grid):
        results = None
        if self._should_parallelize(len(grid)):
            try:
                results = self._parallel_eval(work_fn, spec, grid)
            except _pool_infra_errors() as exc:
                # pool infrastructure failed (no start method, worker died,
                # unpicklable work_fn under spawn) — the sweep itself is
                # still fine serially. work_fn errors are NOT caught: they
                # propagate with their real traceback.
                warnings.warn(f"parallel sweep unavailable ({exc!r}); "
                              f"falling back to serial", RuntimeWarning,
                              stacklevel=2)
        if results is None:
            results = self._serial_eval(work_fn, spec, grid)
        return [p for p in results if p is not None]

    def _serial_eval(self, work_fn, spec: SweepSpec, grid):
        with self._cache_mode():
            return [evaluate_design_point(work_fn, cell, spec.n_chips,
                                          max_tp=spec.max_tp,
                                          max_pp=spec.max_pp,
                                          execution=spec.execution)
                    for cell in grid]

    def _parallel_eval(self, work_fn, spec: SweepSpec, grid):
        # Submission order: group the memory variants of each
        # (chip, net, topology) so they land in one worker chunk and share
        # the memory-independent plan solve. The reduce below restores grid
        # order exactly, so submission order never affects the result.
        order = sorted(range(len(grid)),
                       key=lambda i: (grid[i][0], grid[i][2], grid[i][3],
                                      grid[i][1]))
        group = max(1, len(grid) //
                    max(1, len({(c, n, t) for c, _m, n, t in grid})))
        workers = min(self.max_workers, len(grid))
        per_worker = math.ceil(len(grid) / workers)
        # keep chunks small enough that every worker gets work
        chunk = min(max(group, 1), max(1, per_worker))
        method = self._start_method()
        store = self._open_shared_store()
        try:
            if method != "fork" or self._session_pool is not None:
                # spawn/forkserver ship full task args — requires a
                # picklable work_fn; an unpicklable one is an infra error
                # → serial fallback. A warm session pool's workers were
                # forked at start(), before this call could park anything
                # in _WORKER_CTX, so the session always ships args too.
                _require_picklable(work_fn)
                tasks = [(work_fn, grid[i], spec.n_chips, spec.max_tp,
                          spec.max_pp, spec.execution) for i in order]
                fn, payload = _eval_args, tasks
            else:
                _WORKER_CTX.update(work_fn=work_fn, grid=grid,
                                   n_chips=spec.n_chips, max_tp=spec.max_tp,
                                   max_pp=spec.max_pp,
                                   execution=spec.execution)
                fn, payload = _eval_index, order
            with self._cache_mode():
                with self._pool(workers, store) as pool:
                    mapped = pool.map(fn, payload, chunksize=chunk)
                    out: list[DesignPoint | None] = [None] * len(grid)
                    for j, point in zip(order, mapped):
                        out[j] = point
                    return out
        finally:
            _WORKER_CTX.clear()
            self._close_shared_store(store)

    # -- phased path ---------------------------------------------------------
    def _plan_tasks(self, work_fn, spec: SweepSpec, grid):
        """(worker fn, payload per group, cleanup-needed) for the pool."""
        groups = _group_indices(grid)
        ship = self._resolved_backend() != "numpy"
        # sampled prune certification: every CERTIFY_EVERY-th task's
        # worker runs the in-call scalar-scan check AND attaches the
        # unpruned matrix so the parent can re-price and re-run the scan
        # independently across the IPC boundary. The sample is chosen
        # HERE per task (tasks are one system group each, so a call-local
        # cadence would degenerate to all-or-nothing) and is
        # deterministic in grid order.
        prune_on = self._resolved_prune()
        certify = [prune_on and ti % CERTIFY_EVERY == 0
                   for ti in range(len(groups))]
        # the parent trains (or refits) the ranker ONCE per sweep and
        # ships the frozen model with the tasks — every worker of every
        # transport ranks with the identical model, so results stay
        # deterministic across fork/spawn/forkserver and worker count
        ranker, rkf = self._ranker_for_run()
        method = self._start_method()
        if method != "fork" or self._session_pool is not None:
            # non-fork transports — and the warm session pool, whose
            # workers were forked at start() before this call existed —
            # ship full task arguments instead of _WORKER_CTX
            _require_picklable(work_fn)
            payload = [(work_fn, [grid[i] for i in idxs], idxs, spec.n_chips,
                        spec.max_tp, spec.max_pp, spec.execution, ship,
                        self.prune, ranker, rkf, cert)
                       for idxs, cert in zip(groups, certify)]
            return _plan_group_args, payload, False
        _WORKER_CTX.update(work_fn=work_fn, grid=grid, n_chips=spec.n_chips,
                           max_tp=spec.max_tp, max_pp=spec.max_pp,
                           execution=spec.execution, ship_matrix=ship,
                           prune=self.prune, ranker=ranker,
                           rank_keep_frac=rkf)
        return _plan_group_index, list(zip(groups, certify)), True

    def _parallel_plan(self, work_fn, spec: SweepSpec, grid
                       ) -> list[PlannedPoint | None]:
        workers = min(self.max_workers, max(1, len(grid) // 2))
        store = self._open_shared_store()
        used_ctx = False
        try:
            fn, payload, used_ctx = self._plan_tasks(work_fn, spec, grid)
            with self._cache_mode():
                with self._pool(workers, store) as pool:
                    groups = [g for result in pool.map(fn, payload)
                              for g in result]
        finally:
            if used_ctx:
                _WORKER_CTX.clear()
            self._close_shared_store(store)
        return self._finish_plan_groups(groups, len(grid))

    def _finish_plan_groups(self, groups: list[PlannedGroup], n_cells: int
                            ) -> list[PlannedPoint | None]:
        """Reduce worker-shipped plan groups into a grid-aligned list.

        With a non-numpy backend, the shipped candidate matrices —
        PRUNED to the surviving rows when pruning ran — are
        row-concatenated and priced in ONE batched ``price_plans`` call,
        and the resulting per-group argmins (remapped through each
        group's survivor index map) are certified against the workers'
        numpy selection before the winners are accepted. When the backend
        resolves to numpy (the workers' own reference), re-pricing the
        identical deterministic formula could never disagree, so the
        duplicate whole-grid pass is skipped.

        Independently of the backend, every sampled group that shipped
        its unpruned matrix is re-priced on the numpy reference and its
        winners re-certified against the literal full scalar scan — the
        parent-side proof that the pruning filters dropped no winner.
        """
        backend = self._resolved_backend()
        live = [g for g in groups if len(g.matrix)]
        if live and backend != "numpy":
            # stream fixed-size candidate blocks (price_chunk_rows) instead
            # of concatenating the whole grid: peak memory stays bounded
            # no matter how many candidate rows the grid carries
            for batch in _chunk_groups(live, self.price_chunk_rows):
                big = PlanMatrix.concat([g.matrix for g in batch])
                priced = price_plans(big.cols, backend=backend)
                off = 0
                for g in batch:
                    n = len(g.matrix)
                    self._verify_group_winners(
                        priced["iter_time"][off:off + n],
                        priced["per_chip_mem_bytes"][off:off + n], g)
                    off += n
        # serial phased path: the banded certification ran inside
        # plan_design_groups (matrices never shipped) and left its stats
        # on the group — fold them in so last_drift_stats is populated
        # on both sides of the IPC boundary
        for g in groups:
            in_call = (g.prune_stats or {}).get("drift")
            if in_call:
                self._note_drift(in_call)
        parent_certified = sum(self._certify_group_prune(g) for g in groups)
        out: list[PlannedPoint | None] = [None] * n_cells
        for g in groups:
            for i, planned in zip(g.indices, g.planned):
                out[i] = planned
        prune_on = self._resolved_prune()
        pstats = [g.prune_stats for g in groups if g.prune_stats]
        self.last_plan_stats = {
            "groups": len(groups),
            "candidates": sum(g.n_candidates for g in groups),
            "cells": sum(len(g.indices) for g in groups),
            "backend": backend,
            "verified": backend != "numpy",
            "prune": prune_on,
            "enumerated": sum(s["enumerated"] for s in pstats),
            "survived": sum(s["survived"] for s in pstats),
            "priced": sum(s["priced"] for s in pstats),
            # groups whose winners were certified against the full scalar
            # scan anywhere (in the planning call, serial or worker), and
            # the subset the parent independently re-priced + re-certified
            # from a shipped unpruned matrix
            "scalar_certified_groups": sum(
                1 for s in pstats if s.get("scalar_certified")),
            "parent_certified_groups": parent_certified,
            # learned rank stage: ``survived`` keeps its meaning
            # (dominance survivors); ``rank_survived`` is what actually
            # got priced when the rank stage ran (== survived otherwise)
            "rank": any(s.get("ranked") for s in pstats),
            "rank_survived": sum(s.get("rank_survived", s["survived"])
                                 for s in pstats),
        }
        return out

    def _certify_group_prune(self, group: PlannedGroup) -> bool:
        """Parent-side sampled pruning certification: re-price the
        group's unpruned matrix on the numpy reference and require the
        shipped winners to reproduce the full scalar scan bit-for-bit."""
        if group.full_matrix is None or not len(group.full_matrix):
            return False
        priced = price_plans(group.full_matrix.cols, backend="numpy")
        certify_scalar_rows(priced["iter_time"].tolist(),
                            priced["per_chip_mem_bytes"].tolist(),
                            group.capacities, group.winner_rows,
                            context=f"parent certify, cells {group.indices}")
        return True

    def _resolved_backend(self) -> str:
        from .pricing import default_backend

        return (default_backend() if self.pricing_backend == "auto"
                else self.pricing_backend)

    def _resolved_prune(self) -> bool:
        return resolve_prune(self.prune)

    def _resolved_rank(self) -> bool:
        from ..learned.rank import resolve_rank

        return resolve_rank(self.rank)

    def _ranker_for_run(self):
        """``(ranker, keep_frac)`` for the sweep about to run, or
        ``(None, None)`` when the rank stage is off / must degrade.

        The model is fitted from the memo cache's ``candmat`` harvest
        (:func:`repro.learned.model.fit_ranker`) the first time a ranked
        sweep runs and REFITTED only when
        :meth:`repro.core.memo.SolveCache.diff_stats` shows the harvest
        gained entries since the last fit — warm service sessions retrain
        incrementally across requests instead of once per sweep.  When
        the in-process harvest is below the staleness guard, a persisted
        model at ``rank_model_path`` (if any) is loaded instead; with
        neither, the sweep degrades to rank-off — correctness never
        depends on the model, so degrading is always safe."""
        if not (self._resolved_rank() and self._resolved_prune()):
            return None, None
        from ..learned.model import LearnedModel, fit_ranker
        from ..learned.rank import rank_keep_frac as _env_keep_frac

        delta = GLOBAL_CACHE.diff_stats(self._rank_snapshot)
        grew = delta["by_space"].get("candmat", (0, 0, 0))[2] > 0
        if self._ranker is None or grew:
            self._rank_snapshot = GLOBAL_CACHE.stats()
            model = fit_ranker()
            if model is not None:
                self._ranker = model
                if self.rank_model_path:
                    try:
                        model.save(self.rank_model_path)
                    except OSError:
                        pass  # unwritable path never takes the sweep down
            elif self._ranker is None and self.rank_model_path:
                try:
                    self._ranker = LearnedModel.load(self.rank_model_path)
                except (OSError, ValueError):
                    pass  # absent/stale file: degrade, don't die
        if self._ranker is None:
            return None, None
        frac = (self.rank_keep_frac if self.rank_keep_frac is not None
                else _env_keep_frac())
        return self._ranker, frac

    def _verify_group_winners(self, iter_time, mem,
                              group: PlannedGroup) -> None:
        backend = self._resolved_backend()
        if is_approx_backend(backend):
            # approximate columns: certify winner identity under the
            # drift-budget contract (exact re-pricing of the banded
            # slivers from the group's shipped candidate matrix)
            from ..kernels.pricing.drift import certify_banded_rows

            sel = certify_banded_rows(
                group.matrix.cols,
                {"iter_time": iter_time, "per_chip_mem_bytes": mem},
                group.capacities, group.winner_rows, backend,
                survivors=group.survivors)
            self._note_drift(sel.stats)
            return
        certify_winner_rows(iter_time, mem, group.capacities,
                            group.winner_rows, backend,
                            survivors=group.survivors)

    def _note_drift(self, stats: dict) -> None:
        """Fold one banded selection's stats into ``last_drift_stats``."""
        agg = self.last_drift_stats
        if agg is None:
            agg = self.last_drift_stats = {
                "backend": self._resolved_backend(), "band": stats["band"],
                "groups": 0, "rows": 0, "caps": 0, "repriced": 0,
                "ambiguous_mem": 0, "band_hits": 0, "fallback_caps": 0,
                "max_iter_drift": 0.0, "max_mem_drift": 0.0}
        agg["groups"] += 1
        for key in ("rows", "caps", "repriced", "ambiguous_mem",
                    "band_hits", "fallback_caps"):
            agg[key] += stats[key]
        agg["max_iter_drift"] = max(agg["max_iter_drift"],
                                    stats["max_iter_drift"])
        agg["max_mem_drift"] = max(agg["max_mem_drift"],
                                   stats["max_mem_drift"])

    # -- whole-grid re-pricing at scale --------------------------------------
    def reprice_grid(self, work_fn: Callable[[SystemSpec], TrainWorkload],
                     spec: SweepSpec = SweepSpec(),
                     chunk_rows: int | None = None) -> dict:
        """Price-and-certify an entire design grid's candidate space in
        fixed-size streamed blocks — the 10⁵–10⁶-cell scaling harness for
        the batched pricing backends.

        Each (chip, net, topology) name-group of ``spec``'s grid is
        planned ONCE: one representative :class:`SystemSpec`, one columnar
        candidate enumeration shared by every memory variant, and the
        numpy reference selection over the group's capacity column (memory
        capacities resolve per *name*, so a million-cell grid never builds
        a million systems or plan vectors — the memory axis is just
        numbers). The groups' candidate matrices then stream through the
        engine's pricing backend in blocks of ≤ ``chunk_rows`` rows
        (default ``price_chunk_rows``; peak re-pricing memory is bounded
        by the block, not the grid), and every group's winners are
        certified against the reference — under the drift-budget contract
        on an approximate backend (``pallas-compiled``; accounting lands
        in ``last_drift_stats``), bit-identically otherwise.

        ``work_fn`` must not depend on the memory variant of the system
        it receives (the standard workload factories don't) — each
        name-group sees only its representative system.

        Returns a report dict: cell/group/row counts, chunk accounting,
        phase timings + throughput (``cells_per_s``, ``rows_per_s``),
        ``winners_identical`` (certify-or-die — the call raises rather
        than return ``False``), and the drift-band block on approximate
        backends.
        """
        backend = self._resolved_backend()
        chunk = self.price_chunk_rows if chunk_rows is None else chunk_rows
        if not isinstance(chunk, int) or chunk < 1:
            raise ValueError(f"chunk_rows must be a positive int, "
                             f"got {chunk!r}")
        from ..systems.chips import resolve_memory
        from .dse import build_system

        grid = spec.grid()
        self.last_drift_stats = None
        prune_on = self._resolved_prune()
        cap_by_name: dict[str, float] = {}

        def capacity(mem_name: str) -> float:
            cap = cap_by_name.get(mem_name)
            if cap is None:
                cap = cap_by_name[mem_name] = float(
                    resolve_memory(mem_name).capacity)
            return cap

        t0 = time.perf_counter()
        ranker, rkf = self._ranker_for_run()
        groups: list[_RepriceGroup] = []
        enumerated = 0
        empty_groups = 0
        dom_survived = 0
        rank_survived = 0
        with self._cache_mode():
            for idxs in _group_indices(grid):
                system = build_system(grid[idxs[0]], spec.n_chips)
                work = work_fn(system)
                cands = candidate_matrix(work, system, max_tp=spec.max_tp,
                                         max_pp=spec.max_pp,
                                         execution=spec.execution,
                                         prune=self.prune)
                enumerated += len(cands)
                if not len(cands):
                    empty_groups += 1
                    continue
                caps = tuple(capacity(grid[i][1]) for i in idxs)
                rank_ctx = None
                if ranker is not None:
                    from ..learned.features import system_features

                    rank_ctx = system_features(system.chip, system.n_chips,
                                               system.topology.name)
                sel = select_candidates(cands, caps, prune=self.prune,
                                        ranker=ranker, rank_keep_frac=rkf,
                                        rank_context=rank_ctx)
                dom_survived += sel.stats["survived"]
                rank_survived += sel.stats["rank_survived"]
                matrix = (cands.pruned(max(caps), ranker=ranker,
                                       keep_frac=rkf,
                                       rank_context=rank_ctx,
                                       rank_capacities=caps).matrix
                          if prune_on else cands.matrix)
                groups.append(_RepriceGroup(matrix, caps, tuple(sel.rows),
                                            sel.survivors))
        plan_s = time.perf_counter() - t0

        t1 = time.perf_counter()
        priced_rows = 0
        chunks = 0
        with self._cache_mode():
            for batch in _chunk_groups(groups, chunk):
                big = PlanMatrix.concat([g.matrix for g in batch])
                priced = price_plans(big.cols, backend=backend)
                off = 0
                for g in batch:
                    n = len(g.matrix)
                    self._verify_group_winners(
                        priced["iter_time"][off:off + n],
                        priced["per_chip_mem_bytes"][off:off + n], g)
                    off += n
                priced_rows += len(big)
                chunks += 1
        price_s = time.perf_counter() - t1
        total_s = time.perf_counter() - t0

        drift = self.last_drift_stats
        return {
            "backend": backend,
            "cells": len(grid),
            "groups": len(groups),
            "empty_groups": empty_groups,
            "enumerated": enumerated,
            "rank": ranker is not None,
            "survived": dom_survived,
            "rank_survived": rank_survived,
            "priced_rows": priced_rows,
            "chunk_rows": chunk,
            "chunks": chunks,
            "plan_s": plan_s,
            "price_s": price_s,
            "total_s": total_s,
            "cells_per_s": len(grid) / total_s if total_s > 0 else 0.0,
            "rows_per_s": priced_rows / price_s if price_s > 0 else 0.0,
            # certify-or-die: a winner mismatch raised inside
            # _verify_group_winners, so reaching here proves identity
            "winners_identical": True,
            "drift": drift,
            "repriced_frac": (drift["repriced"] / max(1, drift["rows"])
                              if drift else 0.0),
        }

    def _serial_iter(self, work_fn, spec: SweepSpec, cells, stop):
        """Lazily stream (index, cell) pairs in order."""
        ranker, rkf = self._ranker_for_run()
        with self._cache_mode():
            for j, (i, cell) in enumerate(cells):
                # one cell per planning call: pick the scalar-certify
                # sample here (the call-local "sample" cadence would
                # certify every single-group call)
                planned = plan_design_cells(
                    work_fn, [cell], spec.n_chips, max_tp=spec.max_tp,
                    max_pp=spec.max_pp, execution=spec.execution,
                    pricing_backend=self.pricing_backend,
                    prune=self.prune, ranker=ranker, rank_keep_frac=rkf,
                    certify=j % CERTIFY_EVERY == 0)
                pts = price_planned(planned, backend=self.pricing_backend)
                item = SweepItem(i, cell, pts[0] if pts else None)
                yield item
                if stop is not None and stop(item):
                    return

    def _parallel_iter(self, work_fn, spec: SweepSpec, grid, stop):
        import concurrent.futures as cf

        workers = min(self.max_workers, max(1, len(grid) // 2))
        window = max(2 * workers, workers + 1)
        store = self._open_shared_store()
        used_ctx = False
        try:
            fn, payload, used_ctx = self._plan_tasks(work_fn, spec, grid)
            with self._pool(workers, store) as pool:
                with self._cache_mode():
                    queue = iter(payload)
                    pending: set = set()
                    for task in queue:
                        pending.add(pool.submit(fn, task))
                        if len(pending) >= window:
                            break
                    try:
                        while pending:
                            done, pending = cf.wait(
                                pending, return_when=cf.FIRST_COMPLETED)
                            for fut in done:
                                for group in fut.result():
                                    for item in self._stream_group(grid,
                                                                   group):
                                        yield item
                                        if stop is not None and stop(item):
                                            return
                                for task in queue:
                                    pending.add(pool.submit(fn, task))
                                    if len(pending) >= window:
                                        break
                    finally:
                        # early stop / abandoned generator: cancel what
                        # never started (matters on the session pool,
                        # which outlives this call)
                        for f in pending:
                            f.cancel()
        finally:
            if used_ctx:
                _WORKER_CTX.clear()
            self._close_shared_store(store)

    def _stream_group(self, grid, group: PlannedGroup) -> list[SweepItem]:
        # certify the worker's candidate argmin on a non-numpy parent
        # backend (over the pruned rows, remapped through the survivor
        # map) and the sampled pruning certification, then price the
        # group's winners (one batch per group — elementwise over the
        # batch axis, so streamed values match a full sweep's bits)
        if len(group.matrix) and self._resolved_backend() != "numpy":
            priced = price_plans(group.matrix.cols,
                                 backend=self.pricing_backend)
            self._verify_group_winners(priced["iter_time"],
                                       priced["per_chip_mem_bytes"], group)
        self._certify_group_prune(group)
        pairs = list(zip(group.indices, group.planned))
        live = [(i, p) for i, p in pairs if p is not None]
        pts = price_planned([p for _, p in live],
                            backend=self.pricing_backend)
        by_index = {i: pt for (i, _), pt in zip(live, pts)}
        return [SweepItem(i, grid[i], by_index.get(i)) for i, _ in pairs]

    def _cache_mode(self):
        if self.use_cache:
            import contextlib

            return contextlib.nullcontext()
        return caching_disabled()

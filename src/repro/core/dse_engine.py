"""DSEEngine — process-parallel, memoised design-space sweeps (§VI.C at scale).

The engine evaluates the same design grid as the serial reference
:func:`repro.core.dse.sweep`, but

* **in parallel**: design points are independent, so they are priced by a
  ``concurrent.futures`` process pool. Results are reduced *by grid index*
  (a deterministic ordered reduce), so the output list — including every
  float in ``DesignPoint.row()`` — is identical to the serial sweep's,
  regardless of worker count or completion order.
* **cached**: the inner solves (TP sharding, PP min-max partition, the
  memory-independent inter-chip plan, the intra-chip pass) are memoised in
  ``repro.core.memo`` under structural keys. Submission order groups the
  memory variants of each (chip, net, topology) into the same worker chunk
  so the plan-level cache hits inside each worker; workers forked after a
  warm-up also inherit the parent's cache.
* **scenario-first**: :meth:`DSEEngine.sweep_scenario` runs the named
  sweeps over the four workload families (LLM / DLRM / HPL / FFT, see
  :mod:`repro.workloads.scenarios`) and extracts the Pareto frontier over
  ``utilization × cost_eff × power_eff`` — the decision surface the paper's
  heat maps (Figs 10-17) visualize.

``benchmarks/bench_dse.py`` measures the engine against the serial uncached
baseline and asserts row-identical output; ``examples/dse_scenario.py``
shows the scenario/Pareto API.
"""
from __future__ import annotations

import dataclasses
import math
import multiprocessing
import os
import pickle
import sys
import warnings
from typing import Callable, Iterable, Sequence

from ..systems.system import SystemSpec
from .dse import (DEFAULT_CHIPS, DEFAULT_MEM_NET, DEFAULT_TOPOLOGIES,
                  DesignPoint, design_grid, evaluate_design_point)
from .interchip import TrainWorkload
from .memo import GLOBAL_CACHE, caching_disabled


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Immutable description of one design-grid sweep."""

    n_chips: int = 1024
    chips: tuple[str, ...] = DEFAULT_CHIPS
    topologies: tuple[str, ...] = DEFAULT_TOPOLOGIES
    mem_net: tuple[tuple[str, str], ...] = DEFAULT_MEM_NET
    max_tp: int | None = 64
    max_pp: int | None = None
    execution: str = "auto"

    def grid(self) -> list[tuple[str, str, str, str]]:
        return design_grid(self.chips, self.mem_net, self.topologies)


@dataclasses.dataclass
class ScenarioResult:
    """Points + Pareto frontier for one named workload scenario."""

    name: str
    smoke: bool
    spec: SweepSpec
    points: list[DesignPoint]
    frontier: list[DesignPoint]

    def rows(self) -> list[dict]:
        return [{"workload": self.name, **p.row()} for p in self.points]


def pareto_frontier(points: Sequence[DesignPoint],
                    metrics: tuple[str, ...] = ("utilization", "cost_eff",
                                                "power_eff"),
                    feasible_only: bool | str = "auto"
                    ) -> list[DesignPoint]:
    """Non-dominated subset of ``points`` maximizing every metric.

    A point is dominated if some other point is ≥ on every metric and
    strictly better on at least one. ``feasible_only="auto"`` restricts to
    memory-feasible points when any exist (the paper's heat maps grey out
    infeasible systems) and falls back to the full set otherwise, so the
    frontier of a non-empty sweep is never empty.
    """
    pts = list(points)
    if feasible_only == "auto":
        feas = [p for p in pts if p.plan.feasible]
        pts = feas or pts
    elif feasible_only:
        pts = [p for p in pts if p.plan.feasible]
    vals = [tuple(getattr(p, m) for m in metrics) for p in pts]
    out = []
    for i, vi in enumerate(vals):
        dominated = any(
            vj != vi and all(vj[k] >= vi[k] for k in range(len(vi)))
            for j, vj in enumerate(vals) if j != i)
        if not dominated:
            out.append(pts[i])
    return out


# --- worker plumbing ---------------------------------------------------------
# Two transports:
#   fork  — the work_fn closure (often a lambda) cannot be pickled, so the
#           parent parks the sweep context in a module global, forks the
#           pool, and ships only grid *indices* to workers.
#   spawn — used when forking is unsafe (jax already imported: forking a
#           multithreaded process is a documented deadlock risk). Requires a
#           picklable work_fn (the scenario registry's builders all are);
#           each task carries its full arguments.
_WORKER_CTX: dict = {}


def _eval_index(i: int) -> DesignPoint | None:
    ctx = _WORKER_CTX
    return evaluate_design_point(ctx["work_fn"], ctx["grid"][i],
                                 ctx["n_chips"], max_tp=ctx["max_tp"],
                                 max_pp=ctx["max_pp"],
                                 execution=ctx["execution"])


def _eval_args(args: tuple) -> DesignPoint | None:
    work_fn, cell, n_chips, max_tp, max_pp, execution = args
    return evaluate_design_point(work_fn, cell, n_chips, max_tp=max_tp,
                                 max_pp=max_pp, execution=execution)


#: Infrastructure failures that justify a silent-ish serial fallback (the
#: fallback is warned about). Anything else — e.g. a work_fn bug — must
#: propagate with its real traceback, not be retried serially.
def _pool_infra_errors() -> tuple[type[BaseException], ...]:
    from concurrent.futures.process import BrokenProcessPool

    return (OSError, BrokenProcessPool, pickle.PicklingError)


class DSEEngine:
    """Parallel + cached design-space sweep engine.

    Parameters
    ----------
    max_workers:
        Process count for the parallel path (default: CPU count).
    parallel:
        ``"auto"`` (parallel when >1 CPU and the grid is big enough),
        ``True`` (force), or ``False`` (serial in-process, still cached).
    use_cache:
        ``False`` runs every solve cold — the serial-baseline mode of
        ``benchmarks/bench_dse.py``. (Fork workers inherit the disabled
        flag; spawn workers start fresh either way.)
    """

    def __init__(self, max_workers: int | None = None,
                 parallel: bool | str = "auto",
                 use_cache: bool = True) -> None:
        self.max_workers = max_workers or (os.cpu_count() or 1)
        self.parallel = parallel
        self.use_cache = use_cache

    # -- core sweep ----------------------------------------------------------
    def sweep(self, work_fn: Callable[[SystemSpec], TrainWorkload],
              spec: SweepSpec = SweepSpec()) -> list[DesignPoint]:
        """Price every grid cell of ``spec``; skip infeasible cells.

        Output order and values are identical to
        ``repro.core.dse.sweep(work_fn, **spec fields)``.
        """
        grid = spec.grid()
        results = None
        if self._should_parallelize(len(grid)):
            try:
                results = self._parallel_eval(work_fn, spec, grid)
            except _pool_infra_errors() as exc:
                # pool infrastructure failed (no start method, worker died,
                # unpicklable work_fn under spawn) — the sweep itself is
                # still fine serially. work_fn errors are NOT caught: they
                # propagate with their real traceback.
                warnings.warn(f"parallel sweep unavailable ({exc!r}); "
                              f"falling back to serial", RuntimeWarning,
                              stacklevel=2)
        if results is None:
            results = self._serial_eval(work_fn, spec, grid)
        return [p for p in results if p is not None]

    def sweep_scenario(self, name: str, smoke: bool = False
                       ) -> ScenarioResult:
        """Run a named workload-family sweep + Pareto extraction."""
        from ..workloads.scenarios import get_scenario

        sc = get_scenario(name, smoke=smoke)
        points = self.sweep(sc.work_fn, sc.spec)
        return ScenarioResult(name=sc.name, smoke=smoke, spec=sc.spec,
                              points=points,
                              frontier=pareto_frontier(points))

    def sweep_all_scenarios(self, smoke: bool = False,
                            names: Iterable[str] | None = None
                            ) -> dict[str, ScenarioResult]:
        from ..workloads.scenarios import scenario_names

        return {n: self.sweep_scenario(n, smoke=smoke)
                for n in (names or scenario_names())}

    # -- internals -----------------------------------------------------------
    def _should_parallelize(self, grid_size: int) -> bool:
        if self.parallel is False:
            return False
        if self.parallel is True:
            return self.max_workers > 1
        return self.max_workers > 1 and grid_size >= 4

    @staticmethod
    def _start_method() -> str:
        """Pick the pool transport.

        Forking a multithreaded process is a documented deadlock risk, and
        importing jax starts worker threads — so once jax is loaded (the
        kernel test suite, a training session) we use spawn, which needs a
        picklable work_fn. Otherwise fork, which supports closures.
        """
        methods = multiprocessing.get_all_start_methods()
        if "fork" in methods and "jax" not in sys.modules:
            return "fork"
        return "spawn"

    def _serial_eval(self, work_fn, spec: SweepSpec, grid):
        with self._cache_mode():
            return [evaluate_design_point(work_fn, cell, spec.n_chips,
                                          max_tp=spec.max_tp,
                                          max_pp=spec.max_pp,
                                          execution=spec.execution)
                    for cell in grid]

    def _parallel_eval(self, work_fn, spec: SweepSpec, grid):
        import concurrent.futures as cf

        # Submission order: group the memory variants of each
        # (chip, net, topology) so they land in one worker chunk and share
        # the memory-independent plan solve. The reduce below restores grid
        # order exactly, so submission order never affects the result.
        order = sorted(range(len(grid)),
                       key=lambda i: (grid[i][0], grid[i][2], grid[i][3],
                                      grid[i][1]))
        group = max(1, len(grid) //
                    max(1, len({(c, n, t) for c, _m, n, t in grid})))
        workers = min(self.max_workers, len(grid))
        per_worker = math.ceil(len(grid) / workers)
        # keep chunks small enough that every worker gets work
        chunk = min(max(group, 1), max(1, per_worker))
        method = self._start_method()
        ctx = multiprocessing.get_context(method)

        if method == "spawn":
            # spawn ships full task args — requires a picklable work_fn;
            # an unpicklable one is an infra error → serial fallback
            pickle.dumps(work_fn)
            tasks = [(work_fn, grid[i], spec.n_chips, spec.max_tp,
                      spec.max_pp, spec.execution) for i in order]
            fn, payload = _eval_args, tasks
        else:
            _WORKER_CTX.update(work_fn=work_fn, grid=grid,
                               n_chips=spec.n_chips, max_tp=spec.max_tp,
                               max_pp=spec.max_pp, execution=spec.execution)
            fn, payload = _eval_index, order
        try:
            with self._cache_mode():
                with cf.ProcessPoolExecutor(max_workers=workers,
                                            mp_context=ctx) as pool:
                    mapped = pool.map(fn, payload, chunksize=chunk)
                    out: list[DesignPoint | None] = [None] * len(grid)
                    for j, point in zip(order, mapped):
                        out[j] = point
                    return out
        finally:
            _WORKER_CTX.clear()

    def _cache_mode(self):
        if self.use_cache:
            import contextlib

            return contextlib.nullcontext()
        return caching_disabled()

"""Per-kernel compute-utilization model u_c (paper §V.B.1, following
SCALE-sim-style empirical equations [73]).

On a systolic/MXU-style tile of ``tile_dim × tile_dim`` MACs, a GEMM of
(M, K, N) achieves utilization ≈ alignment efficiency of M and N against the
tile edge, with a pipeline-fill penalty when K is small. Non-GEMM kernels get
kind-specific ceilings (they are vector-unit / memory-bound in practice).
"""
from __future__ import annotations

from .graph import Kernel, KernelKind

TILE_DIM = 128  # MXU / systolic array edge


def _align_eff(d: int, tile: int = TILE_DIM) -> float:
    if d <= 0:
        return 1.0
    full = (d // tile) * tile
    rem = d - full
    padded = full + (tile if rem else 0)
    return d / padded


def gemm_utilization(m: int, k: int, n: int) -> float:
    eff = _align_eff(m) * _align_eff(n)
    fill = k / (k + TILE_DIM)  # pipeline fill/drain along the reduction dim
    return max(0.05, eff * fill)


_KIND_CEILING = {
    KernelKind.GEMM: 0.95,
    KernelKind.ATTENTION: 0.70,   # softmax interleave + masked work
    KernelKind.SOFTMAX: 0.15,
    KernelKind.NORM: 0.12,
    KernelKind.ELEMENTWISE: 0.10,
    KernelKind.EMBEDDING: 0.25,
    KernelKind.SCAN: 0.45,        # chunked SSD: GEMM-rich but stateful
    KernelKind.FFT: 0.50,
    KernelKind.COMM: 1.0,
    KernelKind.ROUTER: 0.10,
}


def kernel_utilization(kernel: Kernel) -> float:
    """u_c for one kernel (dimension-aware for GEMMs)."""
    ceil = _KIND_CEILING.get(kernel.kind, 0.5)
    if kernel.kind in (KernelKind.GEMM, KernelKind.ATTENTION) and kernel.gemm_dims:
        m, k, n = kernel.gemm_dims
        return max(0.05, min(ceil, gemm_utilization(m, k, n) * ceil / 0.95))
    return ceil


def kernel_utilizations(kernels) -> "np.ndarray":
    """Vectorized u_c over a kernel sequence — the form the plan phase's
    per-layer compute model consumes (one array op instead of a Python
    loop per candidate plan)."""
    import numpy as np

    return np.array([kernel_utilization(k) for k in kernels])

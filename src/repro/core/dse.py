"""Design space exploration driver (paper §VI.C, Figs 10-17).

Sweeps accelerator × topology × memory × interconnect for a workload,
running the full two-level optimization per design point and reporting
utilization, cost efficiency, power efficiency, and the compute/memory/
network latency breakdown.

Plan / price phases
-------------------
Evaluating a design point splits into two phases:

* **plan** (:func:`plan_design_cells` / :func:`plan_design_groups`) — the
  discrete solves: TP sharding, PP min-max partition and the intra-chip
  fusion DP. The (tp, pp, dp) × dim-assignment argmin itself is *columnar*:
  ``interchip.candidate_matrix`` stacks every candidate into a
  :class:`repro.core.pricing.PlanMatrix` and ``interchip.select_plans``
  runs one batched ``price_plans`` call + lexicographic argmin covering
  every memory variant of the system. All solves memo-cache in
  ``repro.core.memo``; the phase emits one compact
  :class:`repro.core.pricing.PlanVector` per grid cell.
* **price** (:func:`price_planned` → :func:`repro.core.pricing.price_plans`)
  — all closed-form roofline/latency/utilization/cost/power arithmetic,
  batched over the stacked plan vectors (numpy by default, ``jax.vmap``
  when requested), so one call prices an entire grid.

:func:`sweep` walks the grid through the phased path by default;
``sweep(..., phased=False)`` is the serial scalar reference — one
:func:`evaluate_design_point` per cell, pricing inline in Python — which
the batched path is certified against *element-identically* (every float
in ``DesignPoint.row()``) by ``tests/test_pricing.py``.

Engine API
----------
The production engine lives in :mod:`repro.core.dse_engine`:

* ``DSEEngine.sweep(work_fn, spec)`` — process-parallel planning of the
  same grid (plan groups shipped to a worker pool) + one batched pricing
  call, with a deterministic ordered reduce: the returned list is
  element-for-element identical to this module's sweep.
* ``DSEEngine.sweep_iter(work_fn, spec)`` — streaming variant yielding
  grid-index-tagged points in completion order, with early-exit.
* ``DSEEngine.sweep_scenario(name, smoke=...)`` — named sweeps over the
  workload families (``repro.workloads.scenarios``) plus Pareto-frontier
  extraction over utilization × cost_eff × power_eff.

Cache key contract
------------------
The expensive inner solves are memoised in ``repro.core.memo.GLOBAL_CACHE``
under structural keys (see that module's docstring for the full contract):

* ``"sharding"``: ``(layer_graph.fingerprint(), tp, tp_topo.dims, dims)``
* ``"minmax"``  : ``(tuple(stage cost items), pp)``
* ``"plan"``    : ``(work key, chip, n_chips, tp, pp, dp, dim structures,
  execution)`` — memory-independent; the capacity check is re-applied per
  memory variant.
* ``"intra"``   : ``(scaled layer fingerprint, chip, mem, tuple(h_n),
  tuple(h_m), mode)``
* ``"subdiv"``  : ``(topology, degrees, allow_subdivision)``

Keys never involve object identity, so the cache hits across design points
even though ``work_fn`` rebuilds the workload graph for every system, and a
cached value is always computed from bit-identical inputs — cached and
uncached sweeps return identical results.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Sequence

from ..systems.chips import resolve_chip, resolve_interconnect, resolve_memory
from ..systems.system import SystemSpec
from ..systems.topology import TOPOLOGIES
from .costpower import (cost_efficiency, power_efficiency,
                        system_efficiency_terms)
from .interchip import (InterChipPlan, TrainWorkload, _work_key,
                        candidate_matrix, certify_scalar_rows,
                        certify_winner_rows, optimize_inter_chip,
                        resolve_prune, select_candidates)
from .intrachip import IntraChipResult, optimize_intra_chip
from .memo import GLOBAL_CACHE
from .pricing import (PlanMatrix, PlanVector, default_backend,
                      exact_backend, is_approx_backend, price_plans)


@dataclasses.dataclass
class DesignPoint:
    system: SystemSpec
    plan: InterChipPlan
    utilization: float
    cost_eff: float                 # FLOP/s per USD
    power_eff: float                # FLOP/s per W
    latency_breakdown: dict[str, float]

    def row(self) -> dict:
        return {
            "chip": self.system.chip.name,
            "memory": self.system.memory.name,
            "topology": self.system.topology.name,
            "link": self.system.topology.dims[0].link.name,
            "tp": self.plan.tp, "pp": self.plan.pp, "dp": self.plan.dp,
            "feasible": self.plan.feasible,
            "utilization": self.utilization,
            "cost_eff_gflops_per_usd": self.cost_eff / 1e9,
            "power_eff_gflops_per_w": self.power_eff / 1e9,
            **{f"t_{k}": v for k, v in self.latency_breakdown.items()},
        }


DEFAULT_CHIPS = ("H100", "TPUv4", "SN30", "WSE2")
DEFAULT_TOPOLOGIES = ("torus2d", "torus3d", "dragonfly", "dgx1", "dgx2")
DEFAULT_MEM_NET = (("DDR", "PCIe"), ("DDR", "NVLink"),
                   ("HBM", "PCIe"), ("HBM", "NVLink"))

#: One cell of the design grid: (chip, memory, interconnect, topology) names.
GridCell = tuple[str, str, str, str]


def design_grid(chips: Iterable[str] = DEFAULT_CHIPS,
                mem_net: Iterable[tuple[str, str]] = DEFAULT_MEM_NET,
                topologies: Iterable[str] = DEFAULT_TOPOLOGIES
                ) -> list[GridCell]:
    """The cartesian design grid in canonical (serial-sweep) order."""
    return [(chip, mem, net, topo)
            for chip in chips
            for mem, net in mem_net
            for topo in topologies]


def build_system(cell: GridCell, n_chips: int) -> SystemSpec:
    chip_name, mem_name, net_name, topo_name = cell
    chip, mem = resolve_chip(chip_name), resolve_memory(mem_name)
    net = resolve_interconnect(net_name)
    topo = TOPOLOGIES[topo_name](n_chips, net)
    return SystemSpec(f"{chip_name}-{mem_name}-{net_name}-{topo_name}",
                      chip, mem, topo)


# --- scalar reference path ---------------------------------------------------
def evaluate_design_point(work_fn: Callable[[SystemSpec], TrainWorkload],
                          cell: GridCell, n_chips: int,
                          max_tp: int | None = 64, max_pp: int | None = None,
                          execution: str = "auto") -> DesignPoint | None:
    """Plan *and* price one grid cell, scalar-by-scalar (the reference);
    ``None`` marks an infeasible/undecomposable cell (the sweep *skips*
    those rather than crashing)."""
    system = build_system(cell, n_chips)
    work = work_fn(system)
    try:
        plan = optimize_inter_chip(work, system, max_tp=max_tp,
                                   max_pp=max_pp, execution=execution)
    except ValueError:
        return None
    return _to_point(work, system, plan, execution)


def plan_vector_for(work: TrainWorkload, system: SystemSpec,
                    plan: InterChipPlan,
                    execution: str = "auto") -> PlanVector:
    """The full pricing row for one already-solved plan: runs the intra-chip
    pass on the plan's per-chip shard and assembles the same
    :class:`~repro.core.pricing.PlanVector` the phased sweep prices. Public
    entry point for consumers that hold a single (workload, system, plan)
    triple — the validation loop feeds the result to
    :func:`repro.core.pricing.decompose_iter_time` for the per-term
    modeled-vs-measured comparison."""
    intra = _intra_refine(work, system, plan, execution)
    return _plan_vector(work, system, plan, intra)


def sweep(work_fn: Callable[[SystemSpec], TrainWorkload],
          n_chips: int = 1024,
          chips: Iterable[str] = DEFAULT_CHIPS,
          topologies: Iterable[str] = DEFAULT_TOPOLOGIES,
          mem_net: Iterable[tuple[str, str]] = DEFAULT_MEM_NET,
          max_tp: int | None = 64, max_pp: int | None = None,
          execution: str = "auto", phased: bool = True,
          pricing_backend: str = "auto",
          prune: str | bool = "auto") -> list[DesignPoint]:
    """The 80-system cartesian sweep (4 chips × 5 topologies × 4 mem/net),
    evaluated in grid order.

    ``phased=True`` (default) runs the plan phase over the grid and prices
    everything in one batched call; ``phased=False`` is the serial scalar
    reference (one ``evaluate_design_point`` per cell). Both return
    element-identical ``DesignPoint`` lists — the property
    ``tests/test_pricing.py`` certifies. ``prune`` (phased path only)
    controls the candidate-pruning stage: ``"auto"`` (default; env
    ``DFMODEL_PRUNE``, else on) masks memory-infeasible and dominated
    candidates before pricing — certified winner-preserving, so the
    output is identical either way.
    """
    cells = design_grid(chips, mem_net, topologies)
    if phased:
        planned = plan_design_cells(work_fn, cells, n_chips, max_tp=max_tp,
                                    max_pp=max_pp, execution=execution,
                                    pricing_backend=pricing_backend,
                                    prune=prune)
        return price_planned(planned, backend=pricing_backend)
    points: list[DesignPoint] = []
    for cell in cells:
        point = evaluate_design_point(work_fn, cell, n_chips,
                                      max_tp=max_tp, max_pp=max_pp,
                                      execution=execution)
        if point is not None:
            points.append(point)
    return points


def _resolve_mode(system: SystemSpec, execution: str) -> str:
    # execution='auto' follows the chip's native model: spatial-dataflow
    # chips (RDU/WSE) fuse on-chip, instruction chips (GPU/TPU) run
    # kernel-by-kernel — the paper's §VI.C setting.
    if execution == "auto":
        return "dataflow" if system.chip.dataflow else "kbk"
    return execution


def _intra_refine(work: TrainWorkload, system: SystemSpec,
                  plan: InterChipPlan, execution: str) -> IntraChipResult:
    """The intra-chip pass on the winning plan's per-chip shard (memoised)."""
    mode = _resolve_mode(system, execution)
    layer = work.layer_graph.scaled(
        flop_scale=1.0 / plan.tp, bytes_scale=1.0 / plan.tp)
    key = (layer.fingerprint(), system.chip, system.memory,
           tuple(plan.sharding.h_n), tuple(plan.sharding.h_m), mode)
    return GLOBAL_CACHE.get_or_compute(
        "intra", key,
        lambda: optimize_intra_chip(layer, system.chip, system.memory,
                                    h_n=plan.sharding.h_n,
                                    h_m=plan.sharding.h_m, mode=mode))


def _to_point(work: TrainWorkload, system: SystemSpec, plan: InterChipPlan,
              execution: str) -> DesignPoint:
    # refine the critical stage with the intra-chip pass for the breakdown.
    intra = _intra_refine(work, system, plan, execution)
    tc, tm, tn = intra.sums()
    total = tc + tm + tn
    util = plan.utilization
    # memory-bound refinement: if intra-chip memory time dominates the
    # inter-chip estimate, derate utilization accordingly
    if intra.total_time > 0 and plan.t_stage_fwd > 0:
        per_layer_inter = max(plan.t_comp_stage, plan.t_net_stage) / max(
            1, _stage_layers(plan, work))
        derate = min(1.0, per_layer_inter / intra.total_time)
        util = plan.utilization * derate
    breakdown = {
        "compute": tc / total if total else 0.0,
        "memory": tm / total if total else 0.0,
        "network": tn / total if total else 0.0,
    }
    return DesignPoint(system, plan, util,
                       cost_efficiency(util, system),
                       power_efficiency(util, system), breakdown)


def _stage_layers(plan: InterChipPlan, work: TrainWorkload) -> int:
    return math.ceil(work.n_layers / plan.pp)


# --- plan phase --------------------------------------------------------------
@dataclasses.dataclass
class PlannedPoint:
    """Output of the plan phase for one grid cell: the winning discrete
    plan plus the flat numeric record the price phase consumes."""

    cell: GridCell
    system: SystemSpec
    plan: InterChipPlan
    vector: PlanVector


def _plan_vector(work: TrainWorkload, system: SystemSpec,
                 plan: InterChipPlan, intra: IntraChipResult) -> PlanVector:
    tc, tm, tn = intra.sums()
    peak, price, power = system_efficiency_terms(system)
    layers_per_stage = math.ceil(work.n_layers / plan.pp)
    return PlanVector(
        t_comp_stage=plan.t_comp_stage,
        t_net_stage=plan.t_net_stage,
        t_p2p=plan.t_p2p_stage,
        t_dp=plan.breakdown["dp_comm"],
        n_micro=float(plan.n_micro),
        tp=float(plan.tp),
        pp=float(plan.pp),
        bwd_flop_mult=work.bwd_flop_mult,
        bwd_comm_mult=work.bwd_comm_mult,
        opt_mult=work.optimizer_bytes_per_param_byte,
        model_flops=(work.total_fwd_flops_per_seq()
                     * (1.0 + work.bwd_flop_mult) * work.global_batch),
        weight_bytes=work.total_weight_bytes(),
        act_bytes_layer=sum(t.bytes_ for t in work.layer_graph.tensors),
        layers_per_stage=float(layers_per_stage),
        stage_layers=float(max(1, layers_per_stage)),
        n_chips=float(system.n_chips),
        chip_peak=system.chip.peak_flops,
        mem_capacity=system.memory.capacity,
        sys_peak_flops=peak,
        sys_price=price,
        sys_power=power,
        intra_comp=tc,
        intra_mem=tm,
        intra_net=tn,
        intra_total=intra.total_time)


@dataclasses.dataclass
class PlannedGroup:
    """The plan-phase output for one (chip, net, topology) system group:
    the columnar candidate space plus the per-memory-variant winners.

    This is the record ``DSEEngine`` workers ship to the parent: the
    candidate :class:`~repro.core.pricing.PlanMatrix` travels alongside the
    selected :class:`PlannedPoint`\\ s so the parent can re-price every
    candidate × memory variant in one batched call on its configured
    backend and certify the workers' numpy argmin against it. When the
    parent's backend *is* the numpy reference that re-pricing could never
    disagree, so the engine asks workers not to ship the matrix
    (``ship_matrix=False`` → an empty matrix travels; ``n_candidates``
    still records the enumeration size).
    """

    indices: tuple[int, ...]            # positions into the caller's cells
    capacities: tuple[float, ...]       # memory capacity per cell
    matrix: PlanMatrix                  # candidate pricing columns — the
                                        # PRUNED (surviving-row) matrix when
                                        # pruning ran (may be empty when not
                                        # shipped)
    n_candidates: int                   # size of the candidate enumeration
    winner_rows: tuple[int, ...]        # candidate row per cell (-1: none),
                                        # ORIGINAL-enumeration indexing
    planned: list[PlannedPoint | None]  # aligned with ``indices``
    #: Original-enumeration index of each shipped matrix row (``None``
    #: when the matrix rows ARE the enumeration, i.e. pruning off).
    survivors: tuple[int, ...] | None = None
    #: Per-group pruning accounting (enumerated/survived/priced/...).
    prune_stats: dict | None = None
    #: The UNPRUNED matrix, shipped only for the sampled certification
    #: subset: the parent re-prices it and certifies the shipped winners
    #: against the full scalar scan.
    full_matrix: PlanMatrix | None = None


def _group_cells(work_fn, cells: Sequence[GridCell], n_chips: int,
                 execution: str):
    """Group cell positions by shared candidate space (the memory variants
    of one system); yields (cell positions, work, system-per-position)."""
    systems = [build_system(cell, n_chips) for cell in cells]
    works = [work_fn(system) for system in systems]
    groups: dict[tuple, list[int]] = {}
    for i, (work, system) in enumerate(zip(works, systems)):
        gkey = (_work_key(work), system.chip, system.n_chips,
                system.topology, execution)
        groups.setdefault(gkey, []).append(i)
    return [(idxs, works[idxs[0]], [systems[i] for i in idxs])
            for idxs in groups.values()]


#: Sampled-certification cadence: every ``CERTIFY_EVERY``-th system group
#: of a :func:`plan_design_groups` call has its pruned selection checked
#: against the full scalar scan (and ships its unpruned matrix to the
#: engine parent for an independent re-priced check). Group order is
#: deterministic, so the sample is too.
CERTIFY_EVERY = 4


def plan_design_groups(work_fn: Callable[[SystemSpec], TrainWorkload],
                       cells: Sequence[GridCell], n_chips: int,
                       max_tp: int | None = 64, max_pp: int | None = None,
                       execution: str = "auto",
                       pricing_backend: str = "numpy",
                       ship_matrix: bool = True,
                       prune: str | bool = "auto",
                       certify: bool | str = "sample",
                       ranker=None,
                       rank_keep_frac: float | None = None
                       ) -> list[PlannedGroup]:
    """Plan phase emitting one :class:`PlannedGroup` per system group.

    Per group: one columnar candidate enumeration
    (``interchip.candidate_matrix``), the pruning stage (hard feasibility
    mask + dominance filter over the cheap selection prepass, per
    ``prune``), then one batched selection covering every memory variant
    (``interchip.select_candidates`` — a single ``price_plans`` call over
    the SURVIVING rows + lexicographic argmin per capacity), then the
    intra-chip pass and full :class:`~repro.core.pricing.PlanVector` for
    each winner only.

    Winners are always selected on the **numpy reference** columns. A
    non-numpy ``pricing_backend`` prices the same (pruned) candidate rows
    a second time and must reproduce the reference argmin row-for-row
    (:func:`interchip.certify_winner_rows`) — so a drifting backend can
    never silently change a winner. With pruning on, a *sampled* subset
    of groups has its winners additionally certified against the literal
    scalar scan over the FULL enumeration — so a filter bug can never
    silently drop a winner either. ``certify`` picks the sample:
    ``"sample"`` (the default, for direct multi-group calls) certifies
    every :data:`CERTIFY_EVERY`-th group of this call; ``True``/``False``
    certify all/none of the call's groups — the engine passes these
    per-task, since its tasks hold one group each and a call-local
    cadence would degenerate to all-or-nothing.

    ``ship_matrix=False`` replaces the matrix in the emitted groups with
    an empty one (the engine's numpy-parent path, which would never read
    it); certified groups of a ``certify=True`` call also carry the
    unpruned matrix so the engine parent can repeat the scalar-scan
    certification on its side of the IPC boundary.

    ``ranker`` (a :class:`repro.learned.model.LearnedModel`, pruning on
    only) inserts the learned rank stage between the dominance filter
    and pricing: every ``pruned(...)`` view this call takes — the
    selection, the backend-certification re-pricing and the shipped
    matrix — is the SAME rank-filtered view, so the survivor maps stay
    consistent across the IPC boundary.  The sampled scalar
    certification above runs against the full enumeration and therefore
    re-proves the rank union guarantee on every sampled group.
    """
    backend = (default_backend() if pricing_backend == "auto"
               else pricing_backend)
    pruning = resolve_prune(prune)
    if ranker is not None and not pruning:
        ranker = None  # the rank stage is a refinement of the prune stage
    out: list[PlannedGroup] = []
    for gi, (idxs, work, systems) in enumerate(_group_cells(
            work_fn, cells, n_chips, execution)):
        cands = candidate_matrix(work, systems[0], max_tp=max_tp,
                                 max_pp=max_pp, execution=execution,
                                 prune=prune)
        caps = tuple(s.memory.capacity for s in systems)
        rank_ctx = None
        if ranker is not None:
            from ..learned.features import system_features

            rank_ctx = system_features(systems[0].chip, systems[0].n_chips,
                                       systems[0].topology.name)
        sel = select_candidates(cands, caps, prune=prune, ranker=ranker,
                                rank_keep_frac=rank_keep_frac,
                                rank_context=rank_ctx)  # numpy winners
        sampled = pruning and (gi % CERTIFY_EVERY == 0
                               if certify == "sample" else bool(certify))
        if sampled and len(cands):
            certify_scalar_rows([p.iter_time for p in cands.plans],
                                [p.per_chip_mem_bytes for p in cands.plans],
                                caps, sel.rows, context=f"group {gi}")
        drift_stats: dict | None = None
        if len(cands) and backend != "numpy":
            src = (cands.pruned(max(caps), ranker=ranker,
                                keep_frac=rank_keep_frac,
                                rank_context=rank_ctx,
                                rank_capacities=caps)
                   if pruning else cands)
            check = src.priced(backend)
            if is_approx_backend(backend):
                # approximate columns: winner identity is certified under
                # the drift-budget contract, not bit-identity
                from ..kernels.pricing.drift import certify_banded_rows

                drift_stats = certify_banded_rows(
                    src.matrix.cols, check, caps, sel.rows, backend,
                    survivors=sel.survivors).stats
            else:
                certify_winner_rows(check["iter_time"],
                                    check["per_chip_mem_bytes"], caps,
                                    sel.rows, backend,
                                    survivors=sel.survivors)
        planned: list[PlannedPoint | None] = []
        for pos, system, cap, row, lrow in zip(idxs, systems, caps,
                                               sel.rows, sel.local_rows):
            if row < 0:
                planned.append(None)
                continue
            plan = dataclasses.replace(
                cands.plans[row],
                feasible=bool(sel.priced["per_chip_mem_bytes"][lrow] <= cap))
            intra = _intra_refine(work, system, plan, execution)
            planned.append(PlannedPoint(cells[pos], system, plan,
                                        _plan_vector(work, system, plan,
                                                     intra)))
        if ship_matrix:
            matrix = (cands.pruned(max(caps), ranker=ranker,
                                   keep_frac=rank_keep_frac,
                                   rank_context=rank_ctx,
                                   rank_capacities=caps).matrix
                      if pruning and len(cands) else cands.matrix)
        else:
            matrix = PlanMatrix.concat([])
        out.append(PlannedGroup(
            indices=tuple(idxs), capacities=caps, matrix=matrix,
            n_candidates=len(cands),
            winner_rows=tuple(sel.rows), planned=planned,
            survivors=(tuple(int(s) for s in sel.survivors)
                       if ship_matrix and sel.survivors is not None
                       else None),
            prune_stats=dict(sel.stats,
                             scalar_certified=bool(sampled and len(cands)),
                             **({"drift": drift_stats} if drift_stats
                                else {})),
            full_matrix=(cands.matrix if certify is True and sampled
                         and len(cands) else None)))
    return out


def plan_design_cells(work_fn: Callable[[SystemSpec], TrainWorkload],
                      cells: Sequence[GridCell], n_chips: int,
                      max_tp: int | None = 64, max_pp: int | None = None,
                      execution: str = "auto",
                      pricing_backend: str = "numpy",
                      prune: str | bool = "auto",
                      certify: bool | str = "sample",
                      ranker=None,
                      rank_keep_frac: float | None = None
                      ) -> list[PlannedPoint | None]:
    """Plan phase over a list of grid cells (output aligned to ``cells``).

    Cells whose (workload, chip, n_chips, topology) coincide — the memory
    variants of one system — share a single columnar candidate enumeration
    and one batched selection call (:func:`plan_design_groups`); only the
    capacity check and intra-chip pass run per cell. ``None`` marks an
    undecomposable cell, mirroring :func:`evaluate_design_point`.
    ``certify`` passes straight through — callers streaming one cell per
    call must pick the sample themselves (the call-local ``"sample"``
    cadence would certify every single-group call).
    """
    out: list[PlannedPoint | None] = [None] * len(cells)
    for group in plan_design_groups(work_fn, cells, n_chips, max_tp=max_tp,
                                    max_pp=max_pp, execution=execution,
                                    pricing_backend=pricing_backend,
                                    prune=prune, certify=certify,
                                    ranker=ranker,
                                    rank_keep_frac=rank_keep_frac):
        for pos, planned in zip(group.indices, group.planned):
            out[pos] = planned
    return out


# --- price phase -------------------------------------------------------------
def price_planned(planned: Sequence[PlannedPoint | None],
                  backend: str = "auto") -> list[DesignPoint]:
    """Batch-price planned points (``None`` entries are skipped, matching
    the scalar sweep's infeasible-cell skip).

    Approximate backends resolve to their exact reference here
    (:func:`exact_backend`): the compiled f32 path earns its keep on the
    candidate mass during selection; the handful of *winners* that land
    in sweep output are always priced bit-identically."""
    live = [p for p in planned if p is not None]
    if not live:
        return []
    priced = price_plans([p.vector for p in live],
                         backend=exact_backend(backend))
    return [_assemble(p, priced, i) for i, p in enumerate(live)]


def _assemble(planned: PlannedPoint, priced: dict, i: int) -> DesignPoint:
    plan = dataclasses.replace(planned.plan,
                               feasible=bool(priced["feasible"][i]))
    return DesignPoint(
        planned.system, plan,
        float(priced["utilization"][i]),
        float(priced["cost_eff"][i]),
        float(priced["power_eff"][i]),
        {"compute": float(priced["frac_compute"][i]),
         "memory": float(priced["frac_memory"][i]),
         "network": float(priced["frac_network"][i])})

"""Design space exploration driver (paper §VI.C, Figs 10-17).

Sweeps accelerator × topology × memory × interconnect for a workload,
running the full two-level optimization per design point and reporting
utilization, cost efficiency, power efficiency, and the compute/memory/
network latency breakdown.

Engine API
----------
This module is the *serial reference path*: :func:`sweep` walks the design
grid in order and prices one point at a time. The production engine lives in
:mod:`repro.core.dse_engine`:

* ``DSEEngine.sweep(work_fn, spec)`` — process-parallel evaluation of the
  same grid with a deterministic ordered reduce: results are collected by
  grid index, so the returned list is element-for-element identical
  (including every float in ``DesignPoint.row()``) to this module's serial
  sweep.
* ``DSEEngine.sweep_scenario(name, smoke=...)`` — named sweeps over the four
  workload families (``repro.workloads.scenarios``) plus Pareto-frontier
  extraction over utilization × cost_eff × power_eff.

Both paths share :func:`design_grid` / :func:`evaluate_design_point` below,
which is what makes the parallel reduce deterministic by construction.

Cache key contract
------------------
The expensive inner solves are memoised in ``repro.core.memo.GLOBAL_CACHE``
under structural keys (see that module's docstring for the full contract):

* ``"sharding"``: ``(layer_graph.fingerprint(), tp, tp_topo.dims, dims)``
* ``"minmax"``  : ``(tuple(stage cost items), pp)``
* ``"plan"``    : ``(work key, chip, n_chips, tp, pp, dp, dim structures,
  execution)`` — memory-independent; the capacity check is re-applied per
  memory variant.
* ``"intra"``   : ``(scaled layer fingerprint, chip, mem, tuple(h_n),
  tuple(h_m), mode)``

Keys never involve object identity, so the cache hits across design points
even though ``work_fn`` rebuilds the workload graph for every system, and a
cached value is always computed from bit-identical inputs — cached and cold
sweeps return identical results.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

from ..systems.chips import (CHIPS, INTERCONNECTS, MEMORIES, ChipSpec,
                             InterconnectSpec, MemorySpec)
from ..systems.system import SystemSpec
from ..systems.topology import TOPOLOGIES
from .costpower import cost_efficiency, power_efficiency
from .interchip import InterChipPlan, TrainWorkload, optimize_inter_chip
from .intrachip import optimize_intra_chip
from .memo import GLOBAL_CACHE


@dataclasses.dataclass
class DesignPoint:
    system: SystemSpec
    plan: InterChipPlan
    utilization: float
    cost_eff: float                 # FLOP/s per USD
    power_eff: float                # FLOP/s per W
    latency_breakdown: dict[str, float]

    def row(self) -> dict:
        return {
            "chip": self.system.chip.name,
            "memory": self.system.memory.name,
            "topology": self.system.topology.name,
            "link": self.system.topology.dims[0].link.name,
            "tp": self.plan.tp, "pp": self.plan.pp, "dp": self.plan.dp,
            "feasible": self.plan.feasible,
            "utilization": self.utilization,
            "cost_eff_gflops_per_usd": self.cost_eff / 1e9,
            "power_eff_gflops_per_w": self.power_eff / 1e9,
            **{f"t_{k}": v for k, v in self.latency_breakdown.items()},
        }


DEFAULT_CHIPS = ("H100", "TPUv4", "SN30", "WSE2")
DEFAULT_TOPOLOGIES = ("torus2d", "torus3d", "dragonfly", "dgx1", "dgx2")
DEFAULT_MEM_NET = (("DDR", "PCIe"), ("DDR", "NVLink"),
                   ("HBM", "PCIe"), ("HBM", "NVLink"))

#: One cell of the design grid: (chip, memory, interconnect, topology) names.
GridCell = tuple[str, str, str, str]


def design_grid(chips: Iterable[str] = DEFAULT_CHIPS,
                mem_net: Iterable[tuple[str, str]] = DEFAULT_MEM_NET,
                topologies: Iterable[str] = DEFAULT_TOPOLOGIES
                ) -> list[GridCell]:
    """The cartesian design grid in canonical (serial-sweep) order."""
    return [(chip, mem, net, topo)
            for chip in chips
            for mem, net in mem_net
            for topo in topologies]


def build_system(cell: GridCell, n_chips: int) -> SystemSpec:
    chip_name, mem_name, net_name, topo_name = cell
    chip, mem = CHIPS[chip_name], MEMORIES[mem_name]
    net = INTERCONNECTS[net_name]
    topo = TOPOLOGIES[topo_name](n_chips, net)
    return SystemSpec(f"{chip_name}-{mem_name}-{net_name}-{topo_name}",
                      chip, mem, topo)


def evaluate_design_point(work_fn: Callable[[SystemSpec], TrainWorkload],
                          cell: GridCell, n_chips: int,
                          max_tp: int | None = 64, max_pp: int | None = None,
                          execution: str = "auto") -> DesignPoint | None:
    """Price one grid cell; ``None`` marks an infeasible/undecomposable cell
    (the sweep *skips* those rather than crashing)."""
    system = build_system(cell, n_chips)
    work = work_fn(system)
    try:
        plan = optimize_inter_chip(work, system, max_tp=max_tp,
                                   max_pp=max_pp, execution=execution)
    except ValueError:
        return None
    return _to_point(work, system, plan, execution)


def sweep(work_fn: Callable[[SystemSpec], TrainWorkload],
          n_chips: int = 1024,
          chips: Iterable[str] = DEFAULT_CHIPS,
          topologies: Iterable[str] = DEFAULT_TOPOLOGIES,
          mem_net: Iterable[tuple[str, str]] = DEFAULT_MEM_NET,
          max_tp: int | None = 64, max_pp: int | None = None,
          execution: str = "auto") -> list[DesignPoint]:
    """The 80-system cartesian sweep (4 chips × 5 topologies × 4 mem/net),
    evaluated serially in grid order (the reference for ``DSEEngine``)."""
    points: list[DesignPoint] = []
    for cell in design_grid(chips, mem_net, topologies):
        point = evaluate_design_point(work_fn, cell, n_chips,
                                      max_tp=max_tp, max_pp=max_pp,
                                      execution=execution)
        if point is not None:
            points.append(point)
    return points


def _to_point(work: TrainWorkload, system: SystemSpec, plan: InterChipPlan,
              execution: str) -> DesignPoint:
    # refine the critical stage with the intra-chip pass for the breakdown.
    # execution='auto' follows the chip's native model: spatial-dataflow
    # chips (RDU/WSE) fuse on-chip, instruction chips (GPU/TPU) run
    # kernel-by-kernel — the paper's §VI.C setting.
    if execution == "auto":
        mode = "dataflow" if system.chip.dataflow else "kbk"
    else:
        mode = execution
    layer = work.layer_graph.scaled(
        flop_scale=1.0 / plan.tp, bytes_scale=1.0 / plan.tp)
    key = (layer.fingerprint(), system.chip, system.memory,
           tuple(plan.sharding.h_n), tuple(plan.sharding.h_m), mode)
    intra = GLOBAL_CACHE.get_or_compute(
        "intra", key,
        lambda: optimize_intra_chip(layer, system.chip, system.memory,
                                    h_n=plan.sharding.h_n,
                                    h_m=plan.sharding.h_m, mode=mode))
    total = intra.t_comp.sum() + intra.t_mem.sum() + intra.t_net.sum()
    util = plan.utilization
    # memory-bound refinement: if intra-chip memory time dominates the
    # inter-chip estimate, derate utilization accordingly
    if intra.total_time > 0 and plan.t_stage_fwd > 0:
        per_layer_inter = max(plan.t_comp_stage, plan.t_net_stage) / max(
            1, _stage_layers(plan, work))
        derate = min(1.0, per_layer_inter / intra.total_time)
        util = plan.utilization * derate
    breakdown = {
        "compute": float(intra.t_comp.sum() / total) if total else 0.0,
        "memory": float(intra.t_mem.sum() / total) if total else 0.0,
        "network": float(intra.t_net.sum() / total) if total else 0.0,
    }
    return DesignPoint(system, plan, util,
                       cost_efficiency(util, system),
                       power_efficiency(util, system), breakdown)


def _stage_layers(plan: InterChipPlan, work: TrainWorkload) -> int:
    import math
    return math.ceil(work.n_layers / plan.pp)

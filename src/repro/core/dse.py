"""Design space exploration driver (paper §VI.C, Figs 10-17).

Sweeps accelerator × topology × memory × interconnect for a workload,
running the full two-level optimization per design point and reporting
utilization, cost efficiency, power efficiency, and the compute/memory/
network latency breakdown.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

from ..systems.chips import (CHIPS, INTERCONNECTS, MEMORIES, ChipSpec,
                             InterconnectSpec, MemorySpec)
from ..systems.system import SystemSpec
from ..systems.topology import TOPOLOGIES
from .costpower import cost_efficiency, power_efficiency
from .interchip import InterChipPlan, TrainWorkload, optimize_inter_chip
from .intrachip import optimize_intra_chip


@dataclasses.dataclass
class DesignPoint:
    system: SystemSpec
    plan: InterChipPlan
    utilization: float
    cost_eff: float                 # FLOP/s per USD
    power_eff: float                # FLOP/s per W
    latency_breakdown: dict[str, float]

    def row(self) -> dict:
        return {
            "chip": self.system.chip.name,
            "memory": self.system.memory.name,
            "topology": self.system.topology.name,
            "link": self.system.topology.dims[0].link.name,
            "tp": self.plan.tp, "pp": self.plan.pp, "dp": self.plan.dp,
            "feasible": self.plan.feasible,
            "utilization": self.utilization,
            "cost_eff_gflops_per_usd": self.cost_eff / 1e9,
            "power_eff_gflops_per_w": self.power_eff / 1e9,
            **{f"t_{k}": v for k, v in self.latency_breakdown.items()},
        }


DEFAULT_CHIPS = ("H100", "TPUv4", "SN30", "WSE2")
DEFAULT_TOPOLOGIES = ("torus2d", "torus3d", "dragonfly", "dgx1", "dgx2")
DEFAULT_MEM_NET = (("DDR", "PCIe"), ("DDR", "NVLink"),
                   ("HBM", "PCIe"), ("HBM", "NVLink"))


def sweep(work_fn: Callable[[SystemSpec], TrainWorkload],
          n_chips: int = 1024,
          chips: Iterable[str] = DEFAULT_CHIPS,
          topologies: Iterable[str] = DEFAULT_TOPOLOGIES,
          mem_net: Iterable[tuple[str, str]] = DEFAULT_MEM_NET,
          max_tp: int | None = 64, max_pp: int | None = None,
          execution: str = "auto") -> list[DesignPoint]:
    """The 80-system cartesian sweep (4 chips × 5 topologies × 4 mem/net)."""
    points: list[DesignPoint] = []
    for chip_name in chips:
        chip = CHIPS[chip_name]
        for mem_name, net_name in mem_net:
            mem, net = MEMORIES[mem_name], INTERCONNECTS[net_name]
            for topo_name in topologies:
                topo = TOPOLOGIES[topo_name](n_chips, net)
                system = SystemSpec(
                    f"{chip_name}-{mem_name}-{net_name}-{topo_name}",
                    chip, mem, topo)
                work = work_fn(system)
                try:
                    plan = optimize_inter_chip(work, system, max_tp=max_tp,
                                               max_pp=max_pp,
                                               execution=execution)
                except ValueError:
                    continue
                points.append(_to_point(work, system, plan, execution))
    return points


def _to_point(work: TrainWorkload, system: SystemSpec, plan: InterChipPlan,
              execution: str) -> DesignPoint:
    # refine the critical stage with the intra-chip pass for the breakdown.
    # execution='auto' follows the chip's native model: spatial-dataflow
    # chips (RDU/WSE) fuse on-chip, instruction chips (GPU/TPU) run
    # kernel-by-kernel — the paper's §VI.C setting.
    if execution == "auto":
        mode = "dataflow" if system.chip.dataflow else "kbk"
    else:
        mode = execution
    layer = work.layer_graph.scaled(
        flop_scale=1.0 / plan.tp, bytes_scale=1.0 / plan.tp)
    intra = optimize_intra_chip(layer, system.chip, system.memory,
                                h_n=plan.sharding.h_n, h_m=plan.sharding.h_m,
                                mode=mode)
    total = intra.t_comp.sum() + intra.t_mem.sum() + intra.t_net.sum()
    util = plan.utilization
    # memory-bound refinement: if intra-chip memory time dominates the
    # inter-chip estimate, derate utilization accordingly
    if intra.total_time > 0 and plan.t_stage_fwd > 0:
        per_layer_inter = max(plan.t_comp_stage, plan.t_net_stage) / max(
            1, _stage_layers(plan, work))
        derate = min(1.0, per_layer_inter / intra.total_time)
        util = plan.utilization * derate
    breakdown = {
        "compute": float(intra.t_comp.sum() / total) if total else 0.0,
        "memory": float(intra.t_mem.sum() / total) if total else 0.0,
        "network": float(intra.t_net.sum() / total) if total else 0.0,
    }
    return DesignPoint(system, plan, util,
                       cost_efficiency(util, system),
                       power_efficiency(util, system), breakdown)


def _stage_layers(plan: InterChipPlan, work: TrainWorkload) -> int:
    import math
    return math.ceil(work.n_layers / plan.pp)

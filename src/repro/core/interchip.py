"""Inter-chip optimization pass (paper §IV).

Searches (TP, PP, DP) degrees × network-dimension assignments × per-kernel
sharding schemes × PP stage partitions, minimizing the critical per-stage time

    t_cri_inter[i] = max(t_comp[i], t_net[i], t_p2p[i])        (Eq. 7)

and, for training, composes the stages into a 1F1B pipelined iteration with a
DP gradient all-reduce (the Calculon-comparable iteration model used in the
paper's Fig 8 validation and the DSE of §VI).

Deviation from the paper noted in DESIGN.md: the paper forbids subdividing a
network dimension across strategies; its own Fig 8 sweep (TP=2..64 on fixed
systems) requires it, so we allow contiguous subdivision (a ring splits into
smaller rings, fc into fc, switch into switch) behind ``allow_subdivision``.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import os
from typing import Sequence

import numpy as np

from ..systems.chips import ChipSpec
from ..systems.system import SystemSpec
from ..systems.topology import Topology, TopologyDim
from .graph import DataflowGraph
from .memo import GLOBAL_CACHE
from .pricing import (PlanMatrix, PlanVector, is_approx_backend,
                      price_plans, selection_columns)
from .sharding import ShardingSolution, solve_sharding
from .solver import enumerate_parallelism, minmax_partition
from .utilization import kernel_utilizations


@dataclasses.dataclass(frozen=True)
class TrainWorkload:
    """A training workload at microbatch granularity.

    ``layer_graph`` describes ONE repeated layer for ONE microbatch
    (unsharded); ``pre_graph``/``post_graph`` are the embedding / LM-head
    blocks. FLOPs are forward-pass; backward is modeled as 2× forward compute
    and 2× the TP collective volume (dgrad + wgrad all-reduces — the paper's
    "four all-reduces per layer per iteration").
    """

    name: str
    layer_graph: DataflowGraph
    n_layers: int
    global_batch: int            # sequences per iteration
    microbatch: int = 1          # sequences per pipeline microbatch
    pre_graph: DataflowGraph | None = None
    post_graph: DataflowGraph | None = None
    bwd_flop_mult: float = 2.0
    bwd_comm_mult: float = 1.0   # bwd TP comm ≈ fwd TP comm
    optimizer_bytes_per_param_byte: float = 8.0  # bf16 w+g, fp32 master+m+v
    dp_allreduce: bool = True    # False for serving: DP replicas sync nothing

    def total_weight_bytes(self) -> float:
        w = self.layer_graph.total_weight_bytes() * self.n_layers
        for g in (self.pre_graph, self.post_graph):
            if g is not None:
                w += g.total_weight_bytes()
        return w

    def total_fwd_flops_per_seq(self) -> float:
        f = self.layer_graph.total_flops() * self.n_layers / self.microbatch
        for g in (self.pre_graph, self.post_graph):
            if g is not None:
                f += g.total_flops() / self.microbatch
        return f


@dataclasses.dataclass
class InterChipPlan:
    tp: int
    pp: int
    dp: int
    sharding: ShardingSolution
    stage_bounds: list[int]          # layer-block start indices per stage
    t_stage_fwd: float               # critical stage time (Eq. 7), seconds
    t_comp_stage: float
    t_net_stage: float
    t_p2p_stage: float
    n_micro: int
    iter_time: float
    breakdown: dict[str, float]      # fwd/bwd/bubble/tp_comm/pp_comm/dp_comm
    utilization: float               # model FLOPs / (T · chips · peak)
    per_chip_mem_bytes: float
    feasible: bool
    tp_topology: Topology | None = None
    dp_topology: Topology | None = None

    def summary(self) -> str:
        return (f"TP={self.tp} PP={self.pp} DP={self.dp} "
                f"iter={self.iter_time * 1e3:.2f}ms util={self.utilization:.3f}"
                f" mem/chip={self.per_chip_mem_bytes / 1e9:.1f}GB"
                f"{'' if self.feasible else ' INFEASIBLE'}")


def _subdivide_dims(topology: Topology, degrees: tuple[int, int, int],
                    allow_subdivision: bool) -> list[tuple[Topology, ...]] :
    """Assign topology dims to (tp, pp, dp), innermost dims to TP first.

    Returns a list of candidate (tp_topo, pp_topo, dp_topo) tuples (possibly
    several orderings); empty if infeasible.
    """
    out = []
    for perm in set(itertools.permutations(range(3))):
        need = [degrees[i] for i in perm]  # consume in this strategy order
        pieces: list[list[TopologyDim]] = [[], [], []]
        ok = True
        di = 0
        dims = list(topology.dims)
        remaining = dims[di].size if dims else 1
        for s_pos, s in enumerate(perm):
            want = need[s_pos]
            while want > 1:
                if di >= len(dims):
                    ok = False
                    break
                d = dims[di]
                g = math.gcd(want, remaining)
                if g == 1:
                    if remaining == 1:
                        di += 1
                        remaining = dims[di].size if di < len(dims) else 0
                        continue
                    ok = False
                    break
                take = g if allow_subdivision else remaining
                if not allow_subdivision and remaining != g:
                    ok = False
                    break
                if want % take:
                    ok = False
                    break
                pieces[s].append(TopologyDim(take, d.kind, d.link))
                want //= take
                remaining //= take
                if remaining == 1:
                    di += 1
                    remaining = dims[di].size if di < len(dims) else 0
            if not ok:
                break
        if ok:
            topos = tuple(
                Topology(f"{topology.name}/{'tpd'[i]}", tuple(pieces[i]) or
                         (TopologyDim(1, "ring", topology.dims[0].link),))
                for i in range(3))
            out.append(topos)
    # dedupe by structure
    seen, uniq = set(), []
    for t3 in out:
        key = tuple(tuple((d.size, d.kind) for d in t.dims) for t in t3)
        if key not in seen:
            seen.add(key)
            uniq.append(t3)
    return uniq


def _cached_subdivide(topology: Topology, degrees: tuple[int, int, int],
                      allow_subdivision: bool) -> list[tuple[Topology, ...]]:
    """Memoised ``_subdivide_dims`` — a pure function of the (frozen)
    topology and degrees, and the hottest per-candidate Python loop of a
    warm sweep (profiling: ~60% of a fully-cached design-point solve)."""
    key = (topology, degrees, allow_subdivision)
    return GLOBAL_CACHE.get_or_compute(
        "subdiv", key,
        lambda: _subdivide_dims(topology, degrees, allow_subdivision))


# sharding solutions are pure functions of (graph content, tp,
# topo-structure); the (tp, pp, dp) sweep revisits the same key hundreds of
# times, and the DSE sweep rebuilds identical graphs once per system — the
# structural fingerprint key hits across both.
def _cached_sharding(graph: DataflowGraph, tp: int, topo: Topology,
                     dims) -> ShardingSolution:
    key = (graph.fingerprint(), tp, topo.dims, tuple(dims))
    return GLOBAL_CACHE.get_or_compute(
        "sharding", key, lambda: solve_sharding(graph, tp, topo, dims))


def _cached_minmax(items: list[float], p: int) -> list[int]:
    """PP stage partition, memoised on the exact cost vector."""
    key = (tuple(items), p)
    return list(GLOBAL_CACHE.get_or_compute(
        "minmax", key, lambda: tuple(minmax_partition(items, p)[0])))


def _work_key(work: TrainWorkload) -> tuple:
    """Structural identity of a workload (cache-key component)."""
    return (work.layer_graph.fingerprint(),
            work.pre_graph.fingerprint() if work.pre_graph else None,
            work.post_graph.fingerprint() if work.post_graph else None,
            work.n_layers, work.global_batch, work.microbatch,
            work.bwd_flop_mult, work.bwd_comm_mult,
            work.optimizer_bytes_per_param_byte, work.dp_allreduce)


def memo_plan(work: TrainWorkload, chip: ChipSpec, n_chips: int,
              tp: int, pp: int, dp: int,
              tp_topo: Topology, pp_topo: Topology, dp_topo: Topology,
              execution: str = "dataflow") -> InterChipPlan | None:
    """The memory-independent plan solve for one (tp, pp, dp,
    dim-assignment) point, memoised on (workload, chip, n_chips, degrees,
    dim structures). The returned plan's ``feasible`` flag is a placeholder
    (``False``); callers apply the per-memory capacity check."""
    key = (_work_key(work), chip, n_chips, tp, pp, dp,
           tp_topo.dims, pp_topo.dims, dp_topo.dims, execution)
    return GLOBAL_CACHE.get_or_compute(
        "plan", key,
        lambda: _price_plan(work, chip, n_chips, tp, pp, dp,
                            tp_topo, pp_topo, dp_topo))


def evaluate_plan(work: TrainWorkload, system: SystemSpec,
                  tp: int, pp: int, dp: int,
                  tp_topo: Topology, pp_topo: Topology, dp_topo: Topology,
                  execution: str = "dataflow") -> InterChipPlan | None:
    """Price one (tp, pp, dp, dim-assignment) point.

    Everything except the final memory-capacity check is independent of the
    system's memory part, so the priced plan is memoised on
    (workload, chip, n_chips, degrees, dim structures) and only the
    ``feasible`` flag is recomputed per memory variant — the DSE grid pairs
    each (chip, net, topology) with several memories, all of which share one
    solve.
    """
    plan = memo_plan(work, system.chip, system.n_chips, tp, pp, dp,
                     tp_topo, pp_topo, dp_topo, execution)
    if plan is None:
        return None
    return dataclasses.replace(
        plan, feasible=plan.per_chip_mem_bytes <= system.memory.capacity)


def _price_plan(work: TrainWorkload, chip: ChipSpec, n_chips: int,
                tp: int, pp: int, dp: int,
                tp_topo: Topology, pp_topo: Topology,
                dp_topo: Topology) -> InterChipPlan | None:
    peak = chip.peak_flops
    tdims = list(range(len(tp_topo.dims)))

    # --- TP sharding of the layer graph (Eq. 5/6 costs) ---------------------
    shard = _cached_sharding(work.layer_graph, tp, tp_topo, tdims)

    # per-layer fwd times on the TP group
    f = np.array([k.flops for k in work.layer_graph.kernels])
    u = kernel_utilizations(work.layer_graph.kernels)
    ff = np.array([s.flop_factor for s in shard.schemes])
    t_comp_layer = float(((f * ff) / u).sum() / peak)
    t_net_layer = float(sum(shard.h_n) + sum(shard.h_m))

    def block(graph: DataflowGraph | None) -> tuple[float, float, float]:
        if graph is None:
            return 0.0, 0.0, 0.0
        s = _cached_sharding(graph, tp, tp_topo, tdims)
        fb = np.array([k.flops for k in graph.kernels])
        ub = kernel_utilizations(graph.kernels)
        ffb = np.array([x.flop_factor for x in s.schemes])
        return (float(((fb * ffb) / ub).sum() / peak),
                float(sum(s.h_n) + sum(s.h_m)),
                graph.total_weight_bytes())

    pre = block(work.pre_graph)
    post = block(work.post_graph)

    # --- PP stage partition over [pre] + layers + [post] (minmax DP) --------
    items_comp = [pre[0]] + [t_comp_layer] * work.n_layers + [post[0]]
    items_net = [pre[1]] + [t_net_layer] * work.n_layers + [post[1]]
    items = [max(c, nn) for c, nn in zip(items_comp, items_net)]
    bounds = _cached_minmax(items, pp)

    # boundary activation bytes (largest tensor leaving a layer), sharded by tp
    boundary_b = max((t.bytes_ for t in work.layer_graph.tensors),
                     default=0.0) / tp
    t_p2p = pp_topo.p2p(boundary_b, list(range(len(pp_topo.dims)))) if pp > 1 else 0.0

    stage_comp = np.zeros(len(bounds))
    stage_net = np.zeros(len(bounds))
    nitems = len(items)
    for g, start in enumerate(bounds):
        end = bounds[g + 1] if g + 1 < len(bounds) else nitems
        stage_comp[g] = sum(items_comp[start:end])
        stage_net[g] = sum(items_net[start:end])
    t_comp_stage = float(stage_comp.max())
    t_net_stage = float(stage_net.max())
    t_stage = max(t_comp_stage, t_net_stage, t_p2p)        # Eq. 7

    # --- training iteration (1F1B) ------------------------------------------
    if work.global_batch % (dp * work.microbatch):
        return None
    n_micro = work.global_batch // (dp * work.microbatch)
    if n_micro < 1:
        return None
    t_fwd = t_stage
    t_bwd_comp = t_comp_stage * work.bwd_flop_mult
    t_bwd_net = t_net_stage * (work.bwd_flop_mult * work.bwd_comm_mult)
    t_bwd = max(t_bwd_comp, t_bwd_net, t_p2p)
    t_pipe = (n_micro + pp - 1) * (t_fwd + t_bwd)
    bubble = (pp - 1) * (t_fwd + t_bwd)

    # DP gradient all-reduce on the per-chip weight shard, overlapped with
    # bwd (skipped entirely for serving workloads: replicas sync nothing)
    w_chip = work.total_weight_bytes() / (tp * pp)
    t_dp = (dp_topo.all_reduce(w_chip, list(range(len(dp_topo.dims))))
            if dp > 1 and work.dp_allreduce else 0.0)
    exposed_dp = max(0.0, t_dp - n_micro * t_bwd_comp * 0.5)
    iter_time = t_pipe + exposed_dp

    model_flops = (work.total_fwd_flops_per_seq()
                   * (1.0 + work.bwd_flop_mult) * work.global_batch)
    util = model_flops / (iter_time * n_chips * peak)

    # --- per-chip memory -----------------------------------------------------
    w_bytes = work.total_weight_bytes() / (tp * pp)
    opt_bytes = w_bytes * work.optimizer_bytes_per_param_byte
    act_per_layer = sum(t.bytes_ for t in work.layer_graph.tensors) / tp
    layers_per_stage = math.ceil(work.n_layers / pp)
    act_bytes = act_per_layer * layers_per_stage * min(n_micro, pp)
    mem = w_bytes + opt_bytes + act_bytes
    # the capacity check is the caller's job (evaluate_plan replaces this
    # flag per memory variant); the cached plan itself is memory-agnostic
    feasible = False

    return InterChipPlan(
        tp=tp, pp=pp, dp=dp, sharding=shard, stage_bounds=bounds,
        t_stage_fwd=t_fwd, t_comp_stage=t_comp_stage, t_net_stage=t_net_stage,
        t_p2p_stage=t_p2p, n_micro=n_micro, iter_time=iter_time,
        breakdown={
            "fwd": n_micro * t_comp_stage,
            "bwd": n_micro * t_bwd_comp,
            "bubble": bubble,
            "tp_comm": n_micro * (t_net_stage + t_bwd_net),
            "pp_comm": n_micro * t_p2p,
            "dp_comm": t_dp,
            "dp_exposed": exposed_dp,
        },
        utilization=util, per_chip_mem_bytes=mem, feasible=feasible,
        tp_topology=tp_topo, dp_topology=dp_topo)


def _enumerate_candidates(work: TrainWorkload, system: SystemSpec,
                          max_tp: int | None, max_pp: int | None,
                          allow_subdivision: bool,
                          fixed: tuple[int, int, int] | None,
                          execution: str
                          ) -> list[tuple[tuple[int, int, int, int],
                                          InterChipPlan]]:
    """((tp, pp, dp, assignment-index), plan) pairs in canonical order."""
    n_chips = system.n_chips
    combos = ([fixed] if fixed is not None
              else enumerate_parallelism(n_chips, max_tp, max_pp))
    out: list[tuple[tuple[int, int, int, int], InterChipPlan]] = []
    for tp, pp, dp in combos:
        if pp > work.n_layers + 2:
            continue
        for a, (tp_topo, pp_topo, dp_topo) in enumerate(_cached_subdivide(
                system.topology, (tp, pp, dp), allow_subdivision)):
            plan = memo_plan(work, system.chip, n_chips, tp, pp, dp,
                             tp_topo, pp_topo, dp_topo, execution)
            if plan is not None:
                out.append(((tp, pp, dp, a), plan))
    return out


def candidate_plans(work: TrainWorkload, system: SystemSpec,
                    max_tp: int | None = None,
                    max_pp: int | None = None,
                    allow_subdivision: bool = True,
                    fixed: tuple[int, int, int] | None = None,
                    execution: str = "dataflow") -> list[InterChipPlan]:
    """Every memory-independent candidate plan of the (TP, PP, DP) ×
    dim-assignment search, in canonical enumeration order.

    This is the *plan phase* of the search: all discrete solves run (and
    memo-cache) here, while the memory part of the system only enters in
    :func:`select_plan`. The DSE grid pairs each (chip, net, topology) with
    several memory variants — all of them share one candidate enumeration.
    :func:`candidate_matrix` is the columnar form of the same enumeration.
    """
    return [plan for _, plan in _enumerate_candidates(
        work, system, max_tp, max_pp, allow_subdivision, fixed, execution)]


def _candidate_vector(work: TrainWorkload, plan: InterChipPlan) -> PlanVector:
    """The candidate-level pricing row: exactly the fields the selection
    argmin consumes (``iter_time`` + ``per_chip_mem_bytes`` inputs, fed to
    the same certified formula the winner's full vector goes through).
    Fields the argmin never reads — the intra-chip terms, the system
    cost/power constants — are neutral (0 / 1 / ∞) placeholders; the full
    :class:`PlanVector` for the *winner* is built by ``dse._plan_vector``
    after the intra-chip pass runs."""
    layers_per_stage = math.ceil(work.n_layers / plan.pp)
    return PlanVector(
        t_comp_stage=plan.t_comp_stage,
        t_net_stage=plan.t_net_stage,
        t_p2p=plan.t_p2p_stage,
        t_dp=plan.breakdown["dp_comm"],
        n_micro=float(plan.n_micro),
        tp=float(plan.tp),
        pp=float(plan.pp),
        bwd_flop_mult=work.bwd_flop_mult,
        bwd_comm_mult=work.bwd_comm_mult,
        opt_mult=work.optimizer_bytes_per_param_byte,
        model_flops=1.0,
        weight_bytes=work.total_weight_bytes(),
        act_bytes_layer=sum(t.bytes_ for t in work.layer_graph.tensors),
        layers_per_stage=float(layers_per_stage),
        stage_layers=float(max(1, layers_per_stage)),
        n_chips=1.0, chip_peak=1.0, mem_capacity=math.inf,
        sys_peak_flops=1.0, sys_price=1.0, sys_power=1.0,
        intra_comp=0.0, intra_mem=0.0, intra_net=0.0, intra_total=0.0)


# --- candidate pruning -------------------------------------------------------
#: Environment override consumed by ``default_prune()`` (and therefore by
#: every ``prune="auto"`` default in this module, ``repro.core.dse`` and
#: ``DSEEngine``).
PRUNE_ENV_VAR = "DFMODEL_PRUNE"

PRUNE_MODES = ("on", "off", "auto")

#: Accepted spellings for the ``DFMODEL_PRUNE`` environment variable.
#: Anything else raises — silently mapping ``false`` to "on" (the
#: pre-PR-6 behavior) meant users who thought they disabled pruning
#: got it enabled.
_PRUNE_SPELLINGS = {
    "on": "on", "1": "on", "true": "on", "yes": "on",
    "off": "off", "0": "off", "false": "off", "no": "off",
}


def default_prune() -> str:
    env = os.environ.get(PRUNE_ENV_VAR, "").strip().lower()
    if not env:
        return "on"
    try:
        return _PRUNE_SPELLINGS[env]
    except KeyError:
        raise ValueError(
            f"unknown {PRUNE_ENV_VAR} value {env!r}; expected one of "
            f"{sorted(_PRUNE_SPELLINGS)}") from None


def resolve_prune(policy: str | bool) -> bool:
    """Normalize a ``prune=`` policy to a bool (``"auto"`` → env → on)."""
    if isinstance(policy, bool):
        return policy
    if policy not in PRUNE_MODES:
        raise ValueError(f"unknown prune policy {policy!r}; "
                         f"expected a bool or one of {PRUNE_MODES}")
    if policy == "auto":
        policy = default_prune()
    return policy == "on"


def dominance_keep(iter_time: np.ndarray, iter_lb: np.ndarray,
                   mem: np.ndarray, chunk: int = 512) -> np.ndarray:
    """Boolean keep-mask of the prefix-dominance filter.

    Row ``s`` is pruned iff some EARLIER row ``r`` has
    ``iter_time[r] <= iter_lb[s]`` and ``mem[r] <= mem[s]``. Such an
    ``r`` is present in every pool ``s`` could appear in (its memory
    footprint is no larger, so it is feasible whenever ``s`` is, and the
    no-feasible fallback pool contains everything) and always beats ``s``
    in the lexicographic argmin: its exact iteration time is no larger
    than ``s``'s *lower bound*, and on exact ties ``np.argmin`` resolves
    to the lower row — ``r``'s side. Pruned rows therefore can never be
    selected for ANY capacity, which is the winner-preservation property
    ``tests/test_interchip.py`` certifies against the scalar scan.

    Checking all earlier rows (not just surviving ones) is sound: if the
    dominating ``r`` was itself pruned by an even earlier ``r'``, then
    ``iter_time[r'] <= iter_lb[r] <= iter_time[r] <= iter_lb[s]`` and the
    memory chain ``mem[r'] <= mem[r] <= mem[s]`` make ``r'`` dominate
    ``s`` too, down to a kept row by induction.

    ``iter_lb`` on the dominated side (instead of the exact iter_time)
    keeps the rule valid for any true lower bound — today the pipeline
    term of ``pricing.selection_columns``, whose communication component
    grows monotonically with TP (that monotonicity is what makes the
    filter bite along the TP axis). The quadratic row-pair scan is
    tiled ``chunk`` × ``chunk``: a block of candidate rows is compared
    against earlier full blocks (all earlier by construction — no index
    broadcast needed) and against its own strict lower triangle, so
    peak temporary memory is O(chunk²) regardless of the enumeration
    size.
    """
    n = len(iter_time)
    keep = np.ones(n, dtype=bool)
    if n <= 1:
        return keep
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        blk_lb = iter_lb[lo:hi][:, None]
        blk_mem = mem[lo:hi][:, None]
        dominated = np.zeros(hi - lo, dtype=bool)
        for plo in range(0, lo, chunk):
            phi = min(plo + chunk, lo)
            dom = ((iter_time[plo:phi][None, :] <= blk_lb)
                   & (mem[plo:phi][None, :] <= blk_mem))
            dominated |= dom.any(axis=1)
        m = hi - lo
        tri = np.arange(m)[None, :] < np.arange(m)[:, None]
        dom = (tri & (iter_time[lo:hi][None, :] <= blk_lb)
               & (mem[lo:hi][None, :] <= blk_mem))
        dominated |= dom.any(axis=1)
        keep[lo:hi] = ~dominated
    return keep


def capacity_keep(iter_time: np.ndarray, mem: np.ndarray,
                  max_capacity: float) -> np.ndarray:
    """Boolean keep-mask of the hard memory-feasibility filter.

    Rows whose footprint exceeds every memory variant's capacity can
    never be selected *feasibly*; the one exception is the no-feasible
    fallback, where the serial scan returns the first row of globally
    minimal iteration time — that row is always kept, so the fallback
    winner survives bit-for-bit. (Topology-subdivision validity, the
    other hard mask, is applied at enumeration time: invalid subdivisions
    and undecomposable (tp, pp, dp) combos never enter the matrix.)
    """
    keep = mem <= max_capacity
    if not keep.all() and len(iter_time):
        keep[int(np.argmin(iter_time))] = True
    return keep


@dataclasses.dataclass
class PrunedCandidates:
    """A pruned view of one candidate matrix: the surviving rows, their
    compacted :class:`~repro.core.pricing.PlanMatrix`, and the pruning
    accounting. ``survivors`` maps pruned row ``i`` back to original
    candidate row ``survivors[i]`` (ascending, so relative enumeration
    order — and therefore argmin tie-breaking — is preserved)."""

    survivors: np.ndarray              # int64 original rows, ascending
    matrix: PlanMatrix                 # compacted candidate columns
    stats: dict                        # enumerated / mem_pruned /
                                       # dominance_pruned / survived
    _priced: dict = dataclasses.field(default_factory=dict, repr=False)

    def __len__(self) -> int:
        return int(self.survivors.shape[0])

    def priced(self, backend: str = "numpy") -> dict[str, np.ndarray]:
        """``price_plans`` over the surviving rows only (cached per
        backend) — the compacted batch every backend, including the
        pallas kernel path, prices instead of the full enumeration."""
        out = self._priced.get(backend)
        if out is None:
            out = price_plans(self.matrix.cols, backend=backend)
            self._priced[backend] = out
        return out


def prune_matrix(matrix: PlanMatrix, max_capacity: float,
                 selection: dict[str, np.ndarray] | None = None,
                 ranker=None, keep_frac: float | None = None,
                 rank_context: np.ndarray | None = None,
                 rank_capacities: Sequence[float] | None = None
                 ) -> PrunedCandidates:
    """Apply the hard feasibility mask + the dominance filter to a
    candidate matrix, columnar, before any full pricing runs.

    With a ``ranker`` (a :class:`repro.learned.model.LearnedModel`) the
    learned **rank stage** runs as a third filter over the dominance
    survivors: only the model's top ``keep_frac`` fraction (default: the
    model's calibrated fraction) union the rows the dominance lower
    bound cannot exclude at ``rank_capacities`` (the group's actual
    per-variant capacities; default: just ``max_capacity``) stay —
    winner-preserving by construction, see
    :func:`repro.learned.rank.rank_keep`.  ``rank_context`` is the
    per-group system feature block
    (:func:`repro.learned.features.system_features`)."""
    sel = selection if selection is not None else selection_columns(
        matrix.cols)
    n = len(matrix)
    cap_keep = capacity_keep(sel["iter_time"], sel["per_chip_mem_bytes"],
                             max_capacity)
    dom_keep = dominance_keep(sel["iter_time"], sel["iter_lb"],
                              sel["per_chip_mem_bytes"])
    keep = cap_keep & dom_keep
    survivors = np.flatnonzero(keep).astype(np.int64)
    stats = {"enumerated": int(n),
             "mem_pruned": int((~cap_keep).sum()),
             "dominance_pruned": int((cap_keep & ~dom_keep).sum()),
             "survived": int(survivors.shape[0]),
             "ranked": False,
             "rank_survived": int(survivors.shape[0])}
    pruned = matrix.take(survivors)
    if ranker is not None and len(survivors) > 1:
        from ..learned.features import (SYSTEM_FEATURE_NAMES,
                                        candidate_features)
        from ..learned.rank import rank_keep

        if rank_context is None:
            # featurizable without a system: the block is constant per
            # group, so zeros never reorder rows within the group
            rank_context = np.zeros(len(SYSTEM_FEATURE_NAMES))
        frac = keep_frac if keep_frac is not None else ranker.keep_frac
        caps = (rank_capacities if rank_capacities is not None
                else (max_capacity,))
        scores = ranker.score(candidate_features(pruned.cols, rank_context))
        rk = rank_keep(scores, sel["iter_time"][survivors],
                       sel["iter_lb"][survivors],
                       sel["per_chip_mem_bytes"][survivors], caps, frac)
        survivors = survivors[rk]
        pruned = pruned.take(np.flatnonzero(rk).astype(np.int64))
        stats["ranked"] = True
        stats["rank_survived"] = int(survivors.shape[0])
        stats["rank_keep_frac"] = float(frac)
    return PrunedCandidates(survivors=survivors, matrix=pruned, stats=stats)


@dataclasses.dataclass
class CandidateSet:
    """The columnar candidate space of one (workload, chip, n_chips,
    topology) search: the plan objects in canonical enumeration order plus
    their stacked :class:`~repro.core.pricing.PlanMatrix`. Priced columns
    are cached per backend so the memory variants of a system share one
    batched pricing call; pruned views are cached per capacity ceiling so
    they share one mask computation too."""

    plans: list[InterChipPlan]
    matrix: PlanMatrix
    _priced: dict = dataclasses.field(default_factory=dict, repr=False)
    _selection: dict | None = dataclasses.field(default=None, repr=False)
    _pruned: dict = dataclasses.field(default_factory=dict, repr=False)

    def __len__(self) -> int:
        return len(self.plans)

    def priced(self, backend: str = "numpy") -> dict[str, np.ndarray]:
        """``price_plans`` over the candidate matrix (cached per backend)."""
        out = self._priced.get(backend)
        if out is None:
            out = price_plans(self.matrix.cols, backend=backend)
            self._priced[backend] = out
        return out

    def selection(self) -> dict[str, np.ndarray]:
        """The numpy selection prepass over the full matrix (cached):
        exact ``iter_time``/``per_chip_mem_bytes`` plus dominance bounds,
        without the full pricing formula."""
        if self._selection is None:
            self._selection = selection_columns(self.matrix.cols)
        return self._selection

    def pruned(self, max_capacity: float, ranker=None,
               keep_frac: float | None = None,
               rank_context: np.ndarray | None = None,
               rank_capacities: Sequence[float] | None = None
               ) -> PrunedCandidates:
        """The pruned candidate view for a capacity ceiling (cached per
        ceiling — the memory variants of one system share the pruning
        pass through their common ``max(capacities)``).  With a
        ``ranker`` the view is additionally rank-filtered and cached per
        (ceiling, model fingerprint, keep fraction, capacity set) — all
        consumers of one ranked group (selection, backend certification,
        the shipped matrix) see the SAME filtered view."""
        key = (max_capacity if ranker is None
               else (max_capacity, ranker.fingerprint, keep_frac,
                     None if rank_capacities is None
                     else tuple(sorted(set(map(float, rank_capacities))))))
        out = self._pruned.get(key)
        if out is None:
            out = prune_matrix(self.matrix, max_capacity, self.selection(),
                               ranker=ranker, keep_frac=keep_frac,
                               rank_context=rank_context,
                               rank_capacities=rank_capacities)
            self._pruned[key] = out
        return out


def candidate_matrix(work: TrainWorkload, system: SystemSpec,
                     max_tp: int | None = None,
                     max_pp: int | None = None,
                     allow_subdivision: bool = True,
                     fixed: tuple[int, int, int] | None = None,
                     execution: str = "dataflow",
                     prune: str | bool = "auto") -> CandidateSet:
    """Columnar :func:`candidate_plans`: the same enumeration, emitted as a
    :class:`CandidateSet` whose matrix rows are tagged with their
    (tp, pp, dp, dim-assignment) coordinates. Memoised (space ``candmat``)
    on the same structural key as the underlying plan solves, so a warm
    re-sweep skips straight to the batched argmin.

    ``prune`` does not change the enumeration (pruning is a per-capacity
    view, see :meth:`CandidateSet.pruned`); when it resolves on, the
    selection prepass is computed eagerly so the memoised set carries its
    warm dominance bounds into every re-sweep."""
    key = (_work_key(work), system.chip, system.n_chips,
           system.topology, max_tp, max_pp, allow_subdivision, fixed,
           execution)
    cands = GLOBAL_CACHE.get_or_compute(
        "candmat", key,
        lambda: _build_candidate_set(work, system, max_tp, max_pp,
                                     allow_subdivision, fixed, execution))
    if resolve_prune(prune):
        cands.selection()
    return cands


def _build_candidate_set(work, system, max_tp, max_pp, allow_subdivision,
                         fixed, execution) -> CandidateSet:
    tagged = _enumerate_candidates(work, system, max_tp, max_pp,
                                   allow_subdivision, fixed, execution)
    return CandidateSet(
        plans=[plan for _, plan in tagged],
        matrix=PlanMatrix.from_vectors(
            [_candidate_vector(work, plan) for _, plan in tagged],
            [tag for tag, _ in tagged]))


def winner_rows(iter_time: np.ndarray, mem: np.ndarray,
                capacities: Sequence[float]) -> list[int]:
    """The batched lexicographic argmin: per capacity, the first row
    minimizing (per_chip_mem_bytes > capacity, iter_time).

    ``np.argmin`` returns the *first* minimum, so ties resolve to the
    lowest row — exactly the serial scan's first-strictly-smaller
    acceptance order. Returns -1 per capacity when there are no rows.
    """
    n = len(iter_time)
    out: list[int] = []
    for cap in capacities:
        if n == 0:
            out.append(-1)
            continue
        feasible = np.nonzero(mem <= cap)[0]
        pool = feasible if feasible.size else np.arange(n)
        out.append(int(pool[np.argmin(iter_time[pool])]))
    return out


def select_plan(cands: "CandidateSet | Sequence[InterChipPlan]",
                capacity: float,
                backend: str = "numpy",
                prune: str | bool = "auto") -> InterChipPlan | None:
    """Pick the winner for one memory variant: the candidate minimizing
    (infeasible, iter_time) lexicographically — exactly the serial search's
    first-strictly-smaller acceptance order.

    Given a :class:`CandidateSet` this is a batched argmin over
    :func:`~repro.core.pricing.price_plans` output on ``backend`` (the
    columnar hot path, pruned per ``prune``); given a plain plan sequence
    it is the scalar reference scan over the plans' own priced fields,
    which the columnar path — pruned or not — is certified bit-identical
    to (``tests/test_interchip.py``).
    """
    if isinstance(cands, CandidateSet):
        return select_plans(cands, [capacity], backend=backend,
                            prune=prune)[0]
    best: InterChipPlan | None = None
    bkey: tuple[bool, float] | None = None
    for plan in cands:
        key = (plan.per_chip_mem_bytes > capacity, plan.iter_time)
        if best is None or key < bkey:
            best, bkey = plan, key
    if best is None:
        return None
    return dataclasses.replace(best, feasible=not bkey[0])


def select_rows(cands: CandidateSet, capacities: Sequence[float],
                backend: str = "numpy"
                ) -> tuple[list[int], dict | None]:
    """UNPRUNED winner candidate-row per capacity plus the priced columns
    used (``None`` priced for an empty candidate set, rows all -1) — the
    full-enumeration reference the pruned path is certified against."""
    if not len(cands):
        return [-1] * len(capacities), None
    priced = cands.priced(backend)
    return winner_rows(priced["iter_time"], priced["per_chip_mem_bytes"],
                       capacities), priced


@dataclasses.dataclass
class SelectionResult:
    """One batched selection over a candidate set, with the pruning
    bookkeeping the engine ships across processes.

    ``rows`` are winner indices in the ORIGINAL (unpruned) enumeration —
    so certification against the full scalar scan compares like with
    like; ``local_rows`` index the priced arrays, which cover only the
    ``survivors`` rows when pruning ran (``survivors is None`` means the
    full enumeration was priced)."""

    rows: list[int]                    # original-row winner per capacity
    local_rows: list[int]              # same winners, priced-array indexing
    priced: dict | None                # priced columns over the priced rows
    survivors: np.ndarray | None       # original indices of priced rows
    stats: dict                        # enumerated / survived / priced
    #: EXACT f64 per-chip memory of each winner, set by the drift-banded
    #: route (approximate backends) so downstream feasibility flags never
    #: read an f32 column; ``None`` on exact backends (read ``priced``).
    winner_mem: list[float] | None = None
    #: drift-band statistics of the banded selection (approx backends)
    drift: dict | None = None


def select_candidates(cands: CandidateSet, capacities: Sequence[float],
                      backend: str = "numpy",
                      prune: str | bool = "auto",
                      ranker=None, rank_keep_frac: float | None = None,
                      rank_context: np.ndarray | None = None
                      ) -> SelectionResult:
    """The per-memory-variant argmin for *every* capacity at once.

    With pruning on (the default policy), the hard feasibility mask and
    the dominance filter run first on the cheap selection prepass, and
    only the surviving rows go through the full batched ``price_plans``
    call on ``backend`` — strictly fewer rows priced, identical winners
    (the pruning filters are winner-preserving by construction, and the
    property is separately certified against the scalar scan).

    A ``ranker`` (requires pruning on) inserts the learned rank stage
    between the dominance filter and pricing — see
    :func:`prune_matrix`; winners stay identical by the
    :func:`repro.learned.rank.rank_keep` union guarantee.

    On an *approximate* backend (``pallas-compiled``) the argmin is the
    drift-banded selection (``repro.kernels.pricing.drift``): the f32
    columns rank the candidate mass, the ambiguous slivers are re-priced
    exactly, and the returned winners — plus their ``winner_mem`` — are
    exact f64 values identical to the numpy reference selection."""
    n = len(cands)
    empty_stats = {"enumerated": n, "survived": n, "priced": 0,
                   "mem_pruned": 0, "dominance_pruned": 0,
                   "ranked": False, "rank_survived": n}
    if n == 0 or not len(capacities):
        return SelectionResult([-1] * len(capacities),
                               [-1] * len(capacities), None, None,
                               empty_stats)
    approx = is_approx_backend(backend)
    if approx:
        from ..kernels.pricing.drift import banded_winner_rows
    if not resolve_prune(prune):
        priced = cands.priced(backend)
        if approx:
            bsel = banded_winner_rows(cands.matrix.cols, priced, capacities)
            return SelectionResult(bsel.rows, list(bsel.rows), priced, None,
                                   {**empty_stats, "priced": n},
                                   winner_mem=bsel.winner_mem,
                                   drift=bsel.stats)
        rows = winner_rows(priced["iter_time"],
                           priced["per_chip_mem_bytes"], capacities)
        return SelectionResult(rows, list(rows), priced, None,
                               {**empty_stats, "priced": n})
    pc = cands.pruned(max(capacities), ranker=ranker,
                      keep_frac=rank_keep_frac, rank_context=rank_context,
                      rank_capacities=tuple(capacities))
    priced = pc.priced(backend)
    if approx:
        bsel = banded_winner_rows(pc.matrix.cols, priced, capacities)
        rows = [int(pc.survivors[r]) if r >= 0 else -1 for r in bsel.rows]
        return SelectionResult(rows, list(bsel.rows), priced, pc.survivors,
                               {**pc.stats, "priced": len(pc)},
                               winner_mem=bsel.winner_mem, drift=bsel.stats)
    local = winner_rows(priced["iter_time"], priced["per_chip_mem_bytes"],
                        capacities)
    rows = [int(pc.survivors[r]) if r >= 0 else -1 for r in local]
    return SelectionResult(rows, local, priced, pc.survivors,
                           {**pc.stats, "priced": len(pc)})


def certify_winner_rows(iter_time: np.ndarray, mem: np.ndarray,
                        capacities: Sequence[float],
                        expected: Sequence[int], backend: str,
                        survivors: np.ndarray | None = None) -> None:
    """The certify-or-die contract shared by the serial plan phase and
    ``DSEEngine``: a non-reference backend's batched argmin must
    reproduce the numpy reference's winner rows exactly. When the priced
    arrays cover only pruned ``survivors``, the local argmin is remapped
    through the survivor index map before comparing — ``expected`` is
    always in original-enumeration indexing."""
    rows = winner_rows(iter_time, mem, capacities)
    if survivors is not None:
        rows = [int(survivors[r]) if r >= 0 else -1 for r in rows]
    if list(rows) != list(expected):
        raise RuntimeError(
            f"pricing backend {backend!r} selected different candidates "
            f"than the numpy reference ({rows} != {list(expected)}); "
            f"the backend is not bit-identical")


def scalar_winner_rows(iter_time: Sequence[float], mem: Sequence[float],
                       capacities: Sequence[float]) -> list[int]:
    """The literal serial reference scan, as a Python loop over scalar
    rows: per capacity, the first row strictly improving the
    (infeasible, iter_time) key. This is the ground truth the pruned
    columnar selection is certified against (sampled in production,
    exhaustively in tests)."""
    out: list[int] = []
    for cap in capacities:
        bkey, bi = None, -1
        for i, (it, m) in enumerate(zip(iter_time, mem)):
            key = (m > cap, it)
            if bkey is None or key < bkey:
                bkey, bi = key, i
        out.append(bi)
    return out


def certify_scalar_rows(iter_time: Sequence[float], mem: Sequence[float],
                        capacities: Sequence[float],
                        expected: Sequence[int], context: str) -> None:
    """Certify-or-die for the pruning stage itself: the winners selected
    over the pruned matrix must reproduce the full scalar scan exactly."""
    rows = scalar_winner_rows(iter_time, mem, capacities)
    if list(rows) != list(expected):
        raise RuntimeError(
            f"pruned candidate selection diverged from the full scalar "
            f"scan ({context}): {list(expected)} != scalar {rows}; "
            f"the pruning filters are not winner-preserving")


def select_plans(cands: CandidateSet, capacities: Sequence[float],
                 backend: str = "numpy",
                 prune: str | bool = "auto") -> list[InterChipPlan | None]:
    """The per-memory-variant argmin for *every* capacity at once: one
    batched ``price_plans`` call over the candidate matrix, then a
    vectorized lexicographic argmin per capacity — the memory variants
    of a system never price a candidate twice.

    ``prune`` (``"auto"`` → ``$DFMODEL_PRUNE``, else on) applies the
    winner-preserving dominance/memory filters of :func:`prune_candidates`
    before pricing, so only surviving rows hit the backend; selection is
    certified against the full scalar scan on sampled groups
    (:func:`certify_scalar_selection` — certify-or-die).

    On an *approximate* backend (``pallas-compiled`` f32) the selection
    is drift-banded: every candidate within the declared band of the f32
    argmin is re-priced exactly in f64 and the winner (and its memory
    feasibility bit, below) comes from those exact values — the returned
    plans are bit-identical to a numpy-backend run."""
    sel = select_candidates(cands, capacities, backend, prune)
    if sel.priced is None:
        return [None] * len(capacities)
    if sel.winner_mem is not None:
        # drift-banded route: winners' memory is already exact f64 —
        # never derive a feasibility bit from an f32 column
        return [dataclasses.replace(cands.plans[r],
                                    feasible=bool(wm <= cap))
                for r, wm, cap in zip(sel.rows, sel.winner_mem, capacities)]
    return [dataclasses.replace(
                cands.plans[r],
                feasible=bool(sel.priced["per_chip_mem_bytes"][lr] <= cap))
            for r, lr, cap in zip(sel.rows, sel.local_rows, capacities)]


def optimize_inter_chip(work: TrainWorkload, system: SystemSpec,
                        max_tp: int | None = None,
                        max_pp: int | None = None,
                        allow_subdivision: bool = True,
                        fixed: tuple[int, int, int] | None = None,
                        execution: str = "dataflow",
                        prune: str | bool = "off") -> InterChipPlan:
    """Search the (TP, PP, DP) × dim-assignment space; return the best
    *feasible* plan by iteration time (ties → first in enumeration order).

    With ``prune="off"`` (the default HERE, unlike the engine's
    ``"auto"``) this composes :func:`candidate_plans` (the
    memory-independent plan phase) + the scalar :func:`select_plan` scan
    — the serial *reference* path, deliberately untouched by both the
    pruning stage (PR 6) and the batched/drift-banded pricing backends
    (PRs 5/7), so certification against it stays meaningful: pricing is
    always scalar f64 here. Passing ``prune="on"``/``"auto"`` (``"auto"``
    reads ``$DFMODEL_PRUNE``) routes through the pruned columnar
    selection instead (:func:`candidate_matrix` + :func:`select_plan` on
    the pruned matrix), which is certified winner-preserving against the
    scalar scan. Batched sweeps do not call this function per point —
    they go through :func:`candidate_matrix` / :func:`select_plans` so
    a system's memory variants share one enumeration and one pricing
    call.
    """
    if resolve_prune(prune):
        best = select_plan(
            candidate_matrix(work, system, max_tp=max_tp, max_pp=max_pp,
                             allow_subdivision=allow_subdivision,
                             fixed=fixed, execution=execution),
            system.memory.capacity, prune=prune)
    else:
        best = select_plan(
            candidate_plans(work, system, max_tp=max_tp, max_pp=max_pp,
                            allow_subdivision=allow_subdivision, fixed=fixed,
                            execution=execution),
            system.memory.capacity)
    if best is None:
        raise ValueError(f"no (tp,pp,dp) decomposition of {system.n_chips} "
                         f"chips fits {work.name}")
    return best

"""DFModel core — the paper's contribution as a library.

Public surface:
  graph IR            : DataflowGraph, Kernel, Tensor, KernelKind
  matrices (Eq. 1-4)  : assignment_matrix, matrix_B/D/L/H
  sharding (Fig 4)    : solve_sharding, Scheme
  inter-chip (§IV)    : TrainWorkload, optimize_inter_chip, InterChipPlan
  intra-chip (§V)     : optimize_intra_chip, IntraChipResult
  solver              : minmax_partition, minsum_partition, branch_and_bound
  roofline (Fig 18)   : HierPoint, RooflineTerms
  DSE (§VI.C)         : sweep, DesignPoint, DSEEngine, SweepSpec,
                        pareto_frontier (parallel+cached: dse_engine.py);
                        plan phase: plan_design_cells → PlannedPoint,
                        plan_design_groups → PlannedGroup (candidate
                        matrices shipped worker → parent);
                        streaming: DSEEngine.sweep_iter → SweepItem
  candidates (columnar): CandidateSet, candidate_matrix, select_plans —
                        the batched (tp, pp, dp) × dim-assignment argmin
  pruning             : PrunedCandidates, prune_matrix, select_candidates —
                        hard feasibility mask + dominance filter applied
                        columnar before pricing (prune= policy on
                        candidate_matrix / select_plan(s) / sweep /
                        DSEEngine; winners certified identical to the
                        unpruned scalar scan)
  pricing (batched)   : PlanVector, PlanMatrix, price_plans,
                        price_plan_scalar, stack_plans, batched_roofline
                        (numpy | jax.vmap | pallas interpret kernel)
  memo cache          : cache_stats, clear_caches, caching_disabled;
                        cross-process tier (memo_store.py): create_store,
                        StoreHandle — mmap table / socket server shared by
                        sweep workers, DSEEngine(shared_cache=...)
  serving (§VIII)     : serving_sweep, speculative_throughput
  plan (runtime glue) : plan_for → MappingPlan consumed by repro.launch
"""
from .graph import DataflowGraph, Kernel, KernelKind, Tensor, chain_graph
from .matrices import (assignment_matrix, matrix_B, matrix_D, matrix_H,
                       matrix_L, partition_summaries, validate_assignment)
from .sharding import Scheme, ShardingSolution, solve_sharding
from .solver import (branch_and_bound, bounds_to_assign, design_space_size,
                     enumerate_parallelism, minmax_partition, minsum_partition)
from .utilization import gemm_utilization, kernel_utilization
from .interchip import (CandidateSet, InterChipPlan, PrunedCandidates,
                        SelectionResult, TrainWorkload, candidate_matrix,
                        candidate_plans, default_prune, optimize_inter_chip,
                        prune_matrix, resolve_prune, select_candidates,
                        select_plan, select_plans)
from .intrachip import IntraChipResult, optimize_intra_chip
from .roofline import (HierPoint, RooflineTerms, V5E_HBM_BW, V5E_ICI_BW,
                       V5E_PEAK_FLOPS)
from .costpower import (cost_efficiency, power_efficiency, silicon_power_w,
                        silicon_price_usd)
from .dse import (DesignPoint, PlannedGroup, PlannedPoint, design_grid,
                  plan_design_cells, plan_design_groups, price_planned,
                  sweep)
from .dse_engine import (DSEEngine, ScenarioResult, SweepItem, SweepSpec,
                         pareto_frontier, stop_after_feasible)
from .pricing import (PlanMatrix, PlanVector, batched_roofline,
                      price_plan_scalar, price_plans, stack_plans)
from .memo import (CacheStats, SolveCache, cache_stats, caching_disabled,
                   clear_caches)
from .memo_store import (MmapStore, ServerStore, StoreHandle, choose_backend,
                         create_store)
from .serving import (ServingPoint, SpecDecodePoint, expected_accepted,
                      serving_sweep, speculative_throughput)

__all__ = [
    "DataflowGraph", "Kernel", "KernelKind", "Tensor", "chain_graph",
    "assignment_matrix", "matrix_B", "matrix_D", "matrix_H", "matrix_L",
    "partition_summaries", "validate_assignment",
    "Scheme", "ShardingSolution", "solve_sharding",
    "branch_and_bound", "bounds_to_assign", "design_space_size",
    "enumerate_parallelism", "minmax_partition", "minsum_partition",
    "gemm_utilization", "kernel_utilization",
    "CandidateSet", "InterChipPlan", "PrunedCandidates", "SelectionResult",
    "TrainWorkload", "candidate_matrix", "candidate_plans", "default_prune",
    "optimize_inter_chip", "prune_matrix", "resolve_prune",
    "select_candidates", "select_plan", "select_plans",
    "IntraChipResult", "optimize_intra_chip",
    "HierPoint", "RooflineTerms", "V5E_HBM_BW", "V5E_ICI_BW",
    "V5E_PEAK_FLOPS",
    "cost_efficiency", "power_efficiency", "silicon_power_w",
    "silicon_price_usd",
    "DesignPoint", "PlannedGroup", "PlannedPoint", "design_grid",
    "plan_design_cells", "plan_design_groups", "price_planned", "sweep",
    "DSEEngine", "ScenarioResult", "SweepItem", "SweepSpec",
    "pareto_frontier", "stop_after_feasible",
    "PlanMatrix", "PlanVector", "batched_roofline", "price_plan_scalar",
    "price_plans", "stack_plans",
    "CacheStats", "SolveCache", "cache_stats", "caching_disabled",
    "clear_caches",
    "MmapStore", "ServerStore", "StoreHandle", "choose_backend",
    "create_store",
    "ServingPoint", "SpecDecodePoint", "expected_accepted", "serving_sweep",
    "speculative_throughput",
]

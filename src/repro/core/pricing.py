"""Batched design-point pricing — the *price* phase of the DSE pipeline.

The evaluation of one design point splits into two phases (see
:mod:`repro.core.dse` for the pipeline view):

* **plan** — the discrete solves (TP sharding, PP min-max partition,
  intra-chip fusion DP, the (tp, pp, dp) × dim-assignment argmin). These are
  combinatorial, memo-cached in :mod:`repro.core.memo`, and emit one
  :class:`PlanVector` per design point: a flat record of every numeric
  parameter the closed-form cost model needs.
* **price** — this module. All roofline / latency / utilization / cost /
  power terms (the Eq. 7 per-stage timing, the 1F1B iteration composition,
  the intra-chip derate and compute/memory/network breakdown, the §VI.C
  cost- and power-efficiency metrics) are *pure arithmetic* over stacked
  ``PlanVector`` columns, so one :func:`price_plans` call prices an entire
  design grid as array ops instead of Python scalar-by-scalar.

Backends
--------
``numpy``
    The default. Stacked float64 columns, elementwise ops.
``jax``
    ``jax.vmap`` of the same formula over the batch axis, run under
    ``jax.experimental.enable_x64`` so every op is IEEE double. Eager vmap
    on CPU is **bit-identical** to the numpy backend (and hence to the
    scalar reference); pass ``jit=True`` for an XLA-compiled variant that
    may fuse multiplies into FMAs and differ in the last ulp — fast, but
    not certified element-identical.
``pallas``
    The same formula lowered as a Pallas kernel tiled over the batch
    (candidate) axis — :mod:`repro.kernels.pricing`. Runs in interpret
    mode on CPU (float64, bit-identical to numpy; the kernel package's
    ``certify()`` harness proves it row by row) and is the lowering path
    for pricing 10⁵-point candidate grids on an accelerator.
``pallas-compiled``
    The compiled f32 lowering of the same kernel ((8, 128)
    sublane × lane candidate tiles, masked ragged tail, no bit-identity
    pinning) — the 10⁵–10⁶-candidate scaling path. Outputs are float32
    with bounded relative drift, NOT bit-identical: this is the repo's
    only *approximate* backend, and every decision made from its columns
    goes through the drift-budget contract
    (:mod:`repro.kernels.pricing.drift`) — winners are re-priced exactly
    in f64 within the declared band, so selected candidates are provably
    identical to the scalar reference even though the mass pricing is
    approximate. Final winner pricing resolves to the exact reference
    backend (:func:`exact_backend`), so sweep outputs stay bit-identical
    end to end. On CPU it runs as an interpret-mode f32 twin (same
    tiling/masking/dtype).
``auto``
    ``$DFMODEL_PRICING_BACKEND`` if set (unknown spellings raise), else
    ``numpy``.

Because every formula is elementwise over the batch axis, pricing a batch
of one is bit-identical to pricing the point inside a batch of 80 — which
is what lets the streaming sweep (:meth:`DSEEngine.sweep_iter`) price
groups incrementally while staying certified against the serial path.

The certification itself lives in ``tests/test_pricing.py``: batched numpy
and jax pricing reproduce :func:`price_plan_scalar` — a literal
transcription of the serial path's arithmetic in
``interchip._price_plan`` / ``dse._to_point`` / ``costpower`` — bit for
bit, and the phased sweep reproduces ``dse.sweep(phased=False)`` row for
row.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterable, Mapping, Sequence

import numpy as np

BACKENDS = ("numpy", "jax", "pallas", "pallas-compiled")

#: Backends whose priced columns are approximate (bounded relative drift
#: instead of bit-identity). Decisions over these columns must go through
#: the drift-budget contract (``repro.kernels.pricing.drift``), and final
#: winner pricing resolves to :func:`exact_backend`.
APPROX_BACKENDS = ("pallas-compiled",)

#: Environment override consumed by ``default_backend()`` (and therefore by
#: ``DSEEngine(pricing_backend="auto")`` and ``tools/ci.sh``).
BACKEND_ENV_VAR = "DFMODEL_PRICING_BACKEND"


@dataclasses.dataclass(frozen=True)
class PlanVector:
    """Numeric parameters of one planned design point (array-of-structs row).

    Emitted by the plan phase (``dse.plan_design_cells``); consumed in
    stacked column form by :func:`price_plans`. Every field is a float so
    the whole record stacks into a dense float64 matrix; integer quantities
    (tp, pp, n_micro, …) are exact in float64 far beyond any realistic
    system size.
    """

    # Eq. 7 critical-stage terms of the winning inter-chip plan
    t_comp_stage: float
    t_net_stage: float
    t_p2p: float
    t_dp: float                  # DP gradient all-reduce time (0 if dp == 1)
    n_micro: float
    tp: float
    pp: float
    # workload multipliers
    bwd_flop_mult: float
    bwd_comm_mult: float
    opt_mult: float              # optimizer bytes per parameter byte
    model_flops: float           # useful FLOPs per iteration
    weight_bytes: float          # total model weight bytes (unsharded)
    act_bytes_layer: float       # Σ tensor bytes of one unsharded layer
    layers_per_stage: float      # ceil(n_layers / pp)
    stage_layers: float          # max(1, ceil(n_layers / pp)) — derate denom
    # system constants
    n_chips: float
    chip_peak: float             # per-chip peak FLOP/s
    mem_capacity: float
    sys_peak_flops: float        # n_chips × chip_peak (system property)
    sys_price: float
    sys_power: float
    # intra-chip pass reductions (partition-summed, canonical np order)
    intra_comp: float
    intra_mem: float
    intra_net: float
    intra_total: float           # Σ per-partition critical time


FIELDS: tuple[str, ...] = tuple(
    f.name for f in dataclasses.fields(PlanVector))


def stack_plans(vectors: Sequence[PlanVector]) -> dict[str, np.ndarray]:
    """Array-of-structs → struct-of-arrays: one float64 column per field."""
    return {name: np.array([getattr(v, name) for v in vectors],
                           dtype=np.float64)
            for name in FIELDS}


#: Column order of :attr:`PlanMatrix.tags` rows.
TAG_FIELDS: tuple[str, ...] = ("tp", "pp", "dp", "assignment")


@dataclasses.dataclass(frozen=True)
class PlanMatrix:
    """Stacked *candidate-level* plan vectors (struct-of-arrays).

    One row per (tp, pp, dp) × dim-assignment candidate of an inter-chip
    search, emitted by ``interchip.candidate_matrix``. ``cols`` holds one
    float64 column per :class:`PlanVector` field; ``tags`` is an
    ``(n, 4)`` int64 array of the search coordinates (:data:`TAG_FIELDS`
    order — the dim-assignment entry indexes the candidate's position in
    the subdivision list of its (tp, pp, dp) combo). Feed ``cols``
    straight to :func:`price_plans`; the batched lexicographic argmin in
    ``interchip.select_plan`` consumes the resulting ``iter_time`` /
    ``per_chip_mem_bytes`` columns.
    """

    cols: Mapping[str, np.ndarray]
    tags: np.ndarray

    def __len__(self) -> int:
        return int(self.tags.shape[0])

    @classmethod
    def from_vectors(cls, vectors: Sequence[PlanVector],
                     tags: Sequence[tuple[int, int, int, int]]
                     ) -> "PlanMatrix":
        if len(vectors) != len(tags):
            raise ValueError(f"{len(vectors)} vectors vs {len(tags)} tags")
        return cls(stack_plans(vectors),
                   np.asarray(tags, dtype=np.int64).reshape(len(tags), 4))

    @staticmethod
    def concat(matrices: Sequence["PlanMatrix"]) -> "PlanMatrix":
        """Row-concatenate matrices (the engine's whole-grid pricing call)."""
        if not matrices:
            return PlanMatrix({name: np.empty(0) for name in FIELDS},
                              np.empty((0, 4), dtype=np.int64))
        return PlanMatrix(
            {name: np.concatenate([m.cols[name] for m in matrices])
             for name in FIELDS},
            np.concatenate([m.tags for m in matrices], axis=0))

    def take(self, rows: Sequence[int] | np.ndarray) -> "PlanMatrix":
        """Row-subset view (the pruning compaction: survivors only).

        ``rows`` are row indices into this matrix; the result's row ``i``
        is this matrix's row ``rows[i]``, tags included, so a pruned
        matrix stays a valid :class:`PlanMatrix` for every consumer
        (``price_plans``, the pallas kernel path, IPC shipping).
        """
        idx = np.asarray(rows, dtype=np.int64)
        return PlanMatrix({name: col[idx] for name, col in self.cols.items()},
                          self.tags[idx])


def random_plan_vectors(n: int, seed: int = 0) -> list[PlanVector]:
    """Seeded random-but-plausible plan vectors, with every degenerate
    branch (no DP comm, no p2p, empty intra pass, inference-only
    multipliers) exercised at random.

    The single source of certification inputs: the seeded property tests
    (``tests/test_pricing.py``) and the pallas kernel harness
    (``repro.kernels.pricing.certify``) both draw from here, so every
    backend is certified against the same input distribution.
    """
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        tp = float(2 ** rng.integers(0, 7))
        pp = float(2 ** rng.integers(0, 5))
        n_layers = int(rng.integers(1, 130))
        lps = -(-n_layers // int(pp))  # ceil
        out.append(PlanVector(
            t_comp_stage=float(rng.uniform(1e-6, 1.0)),
            t_net_stage=float(rng.uniform(0.0, 1.0)),
            t_p2p=float(rng.choice([0.0, rng.uniform(0.0, 0.1)])),
            t_dp=float(rng.choice([0.0, rng.uniform(0.0, 0.5)])),
            n_micro=float(rng.integers(1, 1025)),
            tp=tp, pp=pp,
            bwd_flop_mult=float(rng.choice([0.0, 2.0])),
            bwd_comm_mult=float(rng.choice([0.0, 1.0])),
            opt_mult=float(rng.choice([0.0, 8.0])),
            model_flops=float(rng.uniform(1e12, 1e21)),
            weight_bytes=float(rng.uniform(1e6, 1e13)),
            act_bytes_layer=float(rng.uniform(1e3, 1e10)),
            layers_per_stage=float(lps),
            stage_layers=float(max(1, lps)),
            n_chips=float(2 ** rng.integers(0, 11)),
            chip_peak=float(rng.uniform(1e13, 1e16)),
            mem_capacity=float(rng.uniform(1e9, 1e12)),
            sys_peak_flops=float(rng.uniform(1e15, 1e19)),
            sys_price=float(rng.uniform(1e5, 1e9)),
            sys_power=float(rng.uniform(1e3, 1e7)),
            intra_comp=float(rng.choice([0.0, rng.uniform(0.0, 1.0)])),
            intra_mem=float(rng.choice([0.0, rng.uniform(0.0, 1.0)])),
            intra_net=float(rng.choice([0.0, rng.uniform(0.0, 1.0)])),
            intra_total=float(rng.choice([0.0, rng.uniform(1e-9, 1.0)]))))
    return out


def default_backend() -> str:
    env = os.environ.get(BACKEND_ENV_VAR, "").strip().lower()
    if not env:
        return "numpy"
    if env not in BACKENDS:
        raise ValueError(
            f"unknown {BACKEND_ENV_VAR} value {env!r}; expected one of "
            f"{BACKENDS}")
    return env


def resolve_backend(backend: str) -> str:
    """Resolve ``"auto"`` to the concrete backend; validate the spelling."""
    if backend == "auto":
        return default_backend()
    if backend not in BACKENDS:
        raise ValueError(f"unknown pricing backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    return backend


def is_approx_backend(backend: str) -> bool:
    """True when the backend's priced columns carry bounded drift rather
    than bit-identity — selections over them must be drift-banded."""
    return resolve_backend(backend) in APPROX_BACKENDS


def exact_backend(backend: str) -> str:
    """The backend to price *final winners* on: approximate backends map
    to the numpy reference (so sweep outputs stay bit-identical end to
    end); exact backends price on themselves."""
    resolved = resolve_backend(backend)
    return "numpy" if resolved in APPROX_BACKENDS else resolved


def available_backends() -> list[str]:
    out = ["numpy"]
    try:
        import jax  # noqa: F401

        # interpret-mode pallas (and the compiled backend's interpret-f32
        # twin on CPU) need only jax
        out.extend(["jax", "pallas", "pallas-compiled"])
    except Exception:
        pass
    return out


# --- the pricing formula (generic over the array namespace) ------------------
# Operation ORDER here is load-bearing: it mirrors the serial scalar path
# (interchip._price_plan → dse._to_point → costpower.*_efficiency) expression
# by expression, which is what makes the batched result bit-identical to the
# reference. Don't re-associate products or fold constants.
def _price(xp, v: Mapping[str, object]) -> dict[str, object]:
    # Eq. 7 forward stage time + 1F1B backward composition
    t_fwd = xp.maximum(xp.maximum(v["t_comp_stage"], v["t_net_stage"]),
                       v["t_p2p"])
    t_bwd_comp = v["t_comp_stage"] * v["bwd_flop_mult"]
    t_bwd_net = v["t_net_stage"] * (v["bwd_flop_mult"] * v["bwd_comm_mult"])
    t_bwd = xp.maximum(xp.maximum(t_bwd_comp, t_bwd_net), v["t_p2p"])
    t_pipe = (v["n_micro"] + v["pp"] - 1.0) * (t_fwd + t_bwd)
    exposed_dp = xp.maximum(0.0, v["t_dp"] - v["n_micro"] * t_bwd_comp * 0.5)
    iter_time = t_pipe + exposed_dp
    util_inter = v["model_flops"] / (iter_time * v["n_chips"] * v["chip_peak"])

    # per-chip memory footprint + capacity check
    w_bytes = v["weight_bytes"] / (v["tp"] * v["pp"])
    opt_bytes = w_bytes * v["opt_mult"]
    act_per_layer = v["act_bytes_layer"] / v["tp"]
    act_bytes = (act_per_layer * v["layers_per_stage"]
                 * xp.minimum(v["n_micro"], v["pp"]))
    mem = w_bytes + opt_bytes + act_bytes
    feasible = mem <= v["mem_capacity"]

    # memory-bound derate from the intra-chip pass (dse._to_point)
    derate_on = (v["intra_total"] > 0) & (t_fwd > 0)
    safe_intra = xp.where(derate_on, v["intra_total"], 1.0)
    per_layer_inter = (xp.maximum(v["t_comp_stage"], v["t_net_stage"])
                       / v["stage_layers"])
    derate = xp.minimum(1.0, per_layer_inter / safe_intra)
    utilization = xp.where(derate_on, util_inter * derate, util_inter)

    # compute/memory/network latency breakdown
    total = v["intra_comp"] + v["intra_mem"] + v["intra_net"]
    nz = total != 0.0
    safe_total = xp.where(nz, total, 1.0)
    zero = total * 0.0
    frac_compute = xp.where(nz, v["intra_comp"] / safe_total, zero)
    frac_memory = xp.where(nz, v["intra_mem"] / safe_total, zero)
    frac_network = xp.where(nz, v["intra_net"] / safe_total, zero)

    # §VI.C efficiency metrics
    cost_eff = utilization * v["sys_peak_flops"] / v["sys_price"]
    power_eff = utilization * v["sys_peak_flops"] / v["sys_power"]

    return {
        "utilization": utilization,
        "cost_eff": cost_eff,
        "power_eff": power_eff,
        "frac_compute": frac_compute,
        "frac_memory": frac_memory,
        "frac_network": frac_network,
        "iter_time": iter_time,
        "util_inter": util_inter,
        "per_chip_mem_bytes": mem,
        "feasible": feasible,
    }


# --- the selection prepass (candidate pruning inputs) ------------------------
def _selection(xp, v: Mapping[str, object]) -> dict[str, object]:
    """The two columns the candidate argmin consumes — ``iter_time`` and
    ``per_chip_mem_bytes`` — plus the lower bounds the dominance filter
    uses, at a fraction of :func:`_price`'s work (no utilization, derate,
    breakdown or efficiency terms).

    The ``iter_time``/``per_chip_mem_bytes`` expressions are copied from
    :func:`_price` operation for operation, so prepass values are
    BIT-IDENTICAL to the priced columns — that is what lets the pruning
    stage reason about rows it will never fully price.

    ``iter_lb`` is the full pipeline term ``t_pipe`` (compute, network
    and p2p composed exactly as priced), dropping only the non-negative
    exposed-DP term: ``iter_lb ≤ iter_time`` always, with equality
    whenever the DP all-reduce hides. Because ``t_pipe`` is bounded
    below by its communication component
    ``(n_micro + pp - 1) · (t_net_fwd + t_net_bwd)`` — TP collective
    seconds, which grow monotonically with the TP degree (same payload,
    more chips in the group, fewer FLOPs to hide it) — the bound rises
    along the TP axis of the candidate enumeration, which is what lets
    the dominance filter sink whole swaths of high-TP candidates once
    any cheaper candidate is known.
    """
    t_fwd = xp.maximum(xp.maximum(v["t_comp_stage"], v["t_net_stage"]),
                       v["t_p2p"])
    t_bwd_comp = v["t_comp_stage"] * v["bwd_flop_mult"]
    t_bwd_net = v["t_net_stage"] * (v["bwd_flop_mult"] * v["bwd_comm_mult"])
    t_bwd = xp.maximum(xp.maximum(t_bwd_comp, t_bwd_net), v["t_p2p"])
    t_pipe = (v["n_micro"] + v["pp"] - 1.0) * (t_fwd + t_bwd)
    exposed_dp = xp.maximum(0.0, v["t_dp"] - v["n_micro"] * t_bwd_comp * 0.5)
    iter_time = t_pipe + exposed_dp

    w_bytes = v["weight_bytes"] / (v["tp"] * v["pp"])
    opt_bytes = w_bytes * v["opt_mult"]
    act_per_layer = v["act_bytes_layer"] / v["tp"]
    act_bytes = (act_per_layer * v["layers_per_stage"]
                 * xp.minimum(v["n_micro"], v["pp"]))
    mem = w_bytes + opt_bytes + act_bytes
    return {
        "iter_time": iter_time,
        "per_chip_mem_bytes": mem,
        "iter_lb": t_pipe,
    }


def selection_columns(cols: Mapping[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Numpy selection prepass over stacked candidate columns.

    Always runs on the numpy reference: pruning is part of the *reference
    semantics* (which rows exist to be priced), so its decision procedure
    never floats with the pricing backend. Returns ``iter_time`` and
    ``per_chip_mem_bytes`` bit-identical to :func:`price_plans` output,
    plus the ``iter_lb`` dominance bound.
    """
    return {k: np.asarray(a) for k, a in _selection(np, cols).items()}


def _dispatch(formula, cols: Mapping[str, np.ndarray], backend: str,
              jit: bool) -> dict[str, np.ndarray]:
    """Run an elementwise batch formula on the chosen backend.

    ``formula(xp, row_or_cols)`` must be pure elementwise arithmetic over
    the batch axis — that is what makes the jax path (``vmap`` under
    ``enable_x64``) bit-identical to numpy, and a batch of one identical
    to the same point inside a batch of 80.
    """
    backend = resolve_backend(backend)
    n = len(next(iter(cols.values()))) if cols else 0
    if n == 0 or backend == "numpy":
        out = formula(np, cols)
    elif backend == "pallas":
        from ..kernels.pricing.ops import pallas_columns

        out = pallas_columns(formula, cols)
    elif backend == "pallas-compiled":
        from ..kernels.pricing.ops import pallas_columns_f32

        out = pallas_columns_f32(formula, cols)
    else:
        import jax
        from jax.experimental import enable_x64

        with enable_x64():
            import jax.numpy as jnp

            fn = jax.vmap(lambda row: formula(jnp, row))
            if jit:
                fn = jax.jit(fn)
            # materialize inside the x64 scope
            out = {k: np.asarray(a) for k, a in fn(
                {k: jnp.asarray(a, dtype=jnp.float64)
                 for k, a in cols.items()}).items()}
    return {k: np.asarray(a) for k, a in out.items()}


def price_plans(plans: Sequence[PlanVector] | Mapping[str, np.ndarray],
                backend: str = "auto",
                jit: bool = False) -> dict[str, np.ndarray]:
    """Price a batch of plan vectors; returns a dict of per-point columns.

    ``plans`` is either a sequence of :class:`PlanVector` or pre-stacked
    columns from :func:`stack_plans`. Output keys: ``utilization``,
    ``cost_eff``, ``power_eff``, ``frac_compute|memory|network``,
    ``iter_time``, ``util_inter``, ``per_chip_mem_bytes``, ``feasible``.
    """
    cols = plans if isinstance(plans, Mapping) else stack_plans(plans)
    return _dispatch(_price, cols, backend, jit)


def price_plan_scalar(v: PlanVector) -> dict[str, float]:
    """Reference scalar pricing — a literal transcription of the serial
    path's arithmetic (``interchip._price_plan`` + ``dse._to_point`` +
    ``costpower``). The batched backends are certified bit-identical to
    this in ``tests/test_pricing.py``."""
    t_fwd = max(v.t_comp_stage, v.t_net_stage, v.t_p2p)
    t_bwd_comp = v.t_comp_stage * v.bwd_flop_mult
    t_bwd_net = v.t_net_stage * (v.bwd_flop_mult * v.bwd_comm_mult)
    t_bwd = max(t_bwd_comp, t_bwd_net, v.t_p2p)
    t_pipe = (v.n_micro + v.pp - 1.0) * (t_fwd + t_bwd)
    exposed_dp = max(0.0, v.t_dp - v.n_micro * t_bwd_comp * 0.5)
    iter_time = t_pipe + exposed_dp
    util_inter = v.model_flops / (iter_time * v.n_chips * v.chip_peak)

    w_bytes = v.weight_bytes / (v.tp * v.pp)
    opt_bytes = w_bytes * v.opt_mult
    act_per_layer = v.act_bytes_layer / v.tp
    act_bytes = act_per_layer * v.layers_per_stage * min(v.n_micro, v.pp)
    mem = w_bytes + opt_bytes + act_bytes

    util = util_inter
    if v.intra_total > 0 and t_fwd > 0:
        per_layer_inter = max(v.t_comp_stage, v.t_net_stage) / v.stage_layers
        derate = min(1.0, per_layer_inter / v.intra_total)
        util = util_inter * derate

    total = v.intra_comp + v.intra_mem + v.intra_net
    return {
        "utilization": util,
        "cost_eff": util * v.sys_peak_flops / v.sys_price,
        "power_eff": util * v.sys_peak_flops / v.sys_power,
        "frac_compute": v.intra_comp / total if total else 0.0,
        "frac_memory": v.intra_mem / total if total else 0.0,
        "frac_network": v.intra_net / total if total else 0.0,
        "iter_time": iter_time,
        "util_inter": util_inter,
        "per_chip_mem_bytes": mem,
        "feasible": mem <= v.mem_capacity,
    }


def decompose_iter_time(v: PlanVector) -> dict[str, float]:
    """Per-term decomposition of one plan's iteration time (seconds).

    Splits the :func:`price_plan_scalar` ``iter_time`` into additive terms —
    the validation loop compares each against its measured counterpart
    rather than only the end-to-end number:

    ``t_compute``
        arithmetic on the critical stage (steady pipeline rounds), scaled by
        the intra-chip pass's compute share;
    ``t_memory``
        the memory-bound share of the same busy time (0 when no intra-chip
        pass ran — the inter-chip model alone cannot see memory);
    ``t_collective``
        exposed communication: stage network/P2P time that the compute of a
        round cannot hide, the exposed DP all-reduce, and the intra-chip
        network share;
    ``t_bubble``
        the (pp − 1) pipeline fill/drain rounds.

    The decomposition is exact by construction and certified at runtime:
    the terms are attributed so that they sum to ``iter_time`` bit-for-bit
    up to float addition order, and this function raises if they drift
    beyond 1 part in 10⁹ — the decomposition can never silently disagree
    with the priced scalar.
    """
    t_fwd = max(v.t_comp_stage, v.t_net_stage, v.t_p2p)
    t_bwd_comp = v.t_comp_stage * v.bwd_flop_mult
    t_bwd_net = v.t_net_stage * (v.bwd_flop_mult * v.bwd_comm_mult)
    t_bwd = max(t_bwd_comp, t_bwd_net, v.t_p2p)
    exposed_dp = max(0.0, v.t_dp - v.n_micro * t_bwd_comp * 0.5)
    iter_time = (v.n_micro + v.pp - 1.0) * (t_fwd + t_bwd) + exposed_dp

    # steady rounds: compute is attributed first; whatever of the round it
    # cannot cover is exposed communication
    comp_round = min(v.t_comp_stage, t_fwd) + min(t_bwd_comp, t_bwd)
    net_round = (t_fwd + t_bwd) - comp_round
    busy = v.n_micro * comp_round
    t_bubble = (v.pp - 1.0) * (t_fwd + t_bwd)

    total_intra = v.intra_comp + v.intra_mem + v.intra_net
    if total_intra > 0.0:
        t_compute = busy * (v.intra_comp / total_intra)
        t_memory = busy * (v.intra_mem / total_intra)
        intra_net = busy * (v.intra_net / total_intra)
    else:
        t_compute, t_memory, intra_net = busy, 0.0, 0.0
    t_collective = v.n_micro * net_round + exposed_dp + intra_net

    out = {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_collective,
        "t_bubble": t_bubble,
        "iter_time": iter_time,
    }
    resum = t_compute + t_memory + t_collective + t_bubble
    if abs(resum - iter_time) > 1e-9 * max(iter_time, 1e-300):
        raise AssertionError(
            f"iter-time decomposition drifted: terms sum to {resum!r}, "
            f"priced iter_time is {iter_time!r}")
    return out


# --- batched roofline (Fig 18 / dry-run terms over many cells) ---------------
def _roofline(xp, c: Mapping[str, object]) -> dict[str, object]:
    t_compute = c["hlo_flops"] / (c["chips"] * c["peak_flops"])
    t_memory = c["hlo_bytes"] / (c["chips"] * c["hbm_bw"])
    t_collective = c["collective_bytes"] / (c["chips"] * c["link_bw"])
    t_bound = xp.maximum(xp.maximum(t_compute, t_memory), t_collective)
    zero = t_bound * 0.0
    denom = t_bound * c["chips"] * c["peak_flops"]
    safe_denom = xp.where(denom != 0.0, denom, 1.0)
    frac = xp.where(denom != 0.0, c["model_flops"] / safe_denom, zero)
    nz_flops = c["hlo_flops"] != 0.0
    safe_flops = xp.where(nz_flops, c["hlo_flops"], 1.0)
    useful = xp.where(nz_flops, c["model_flops"] / safe_flops, zero)
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_collective, "t_bound": t_bound,
            "roofline_fraction": frac, "useful_flop_ratio": useful}


def batched_roofline(cols: Mapping[str, np.ndarray],
                     backend: str = "auto",
                     jit: bool = False) -> dict[str, np.ndarray]:
    """Batched :class:`repro.core.roofline.RooflineTerms` evaluation.

    ``cols`` holds stacked float64 columns ``hlo_flops``, ``hlo_bytes``,
    ``collective_bytes``, ``chips``, ``model_flops``, ``peak_flops``,
    ``hbm_bw``, ``link_bw`` (see ``roofline.stack_terms``). Returns the
    per-cell time terms, bound, roofline fraction and useful-FLOP ratio —
    element-identical to the scalar ``RooflineTerms`` properties.
    """
    return _dispatch(_roofline, cols, backend, jit)

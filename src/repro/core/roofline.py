"""Roofline analysis — two flavors.

1. The paper's hierarchical roofline (Fig 18): an execution point has two
   operational intensities (FLOP/byte vs DRAM and vs network) and its achieved
   throughput is the min of the compute roof and the two bandwidth roofs.

2. The deliverable's dry-run roofline: given HLO FLOPs / bytes / collective
   bytes from a compiled ``jax.jit`` artifact, derive the three time terms

      compute    = HLO_FLOPs / (chips × peak)
      memory     = HLO_bytes / (chips × HBM_bw)
      collective = collective_bytes / (chips × link_bw)

   against the TPU v5e constants (197 bf16 TFLOP/s, 819 GB/s, 50 GB/s/link).
"""
from __future__ import annotations

import dataclasses

GB = 1e9
TFLOPS = 1e12

# TPU v5e hardware constants (per chip) — prompt-specified
V5E_PEAK_FLOPS = 197 * TFLOPS
V5E_HBM_BW = 819 * GB
V5E_ICI_BW = 50 * GB   # per link; we price aggregate collective bytes per chip


@dataclasses.dataclass(frozen=True)
class HierPoint:
    """A point on the hierarchical roofline plot (paper Fig 18)."""

    name: str
    flops: float            # useful FLOPs of the mapping (per microbatch)
    dram_bytes: float       # DRAM traffic (per microbatch)
    net_bytes: float        # network traffic (per microbatch)
    peak_flops: float
    dram_bw: float
    net_bw: float

    @property
    def oi_mem(self) -> float:
        return self.flops / self.dram_bytes if self.dram_bytes else float("inf")

    @property
    def oi_net(self) -> float:
        return self.flops / self.net_bytes if self.net_bytes else float("inf")

    @property
    def achieved_flops(self) -> float:
        roofs = [self.peak_flops]
        if self.dram_bytes:
            roofs.append(self.oi_mem * self.dram_bw)
        if self.net_bytes:
            roofs.append(self.oi_net * self.net_bw)
        return min(roofs)

    @property
    def bound(self) -> str:
        a = self.achieved_flops
        if self.dram_bytes and abs(a - self.oi_mem * self.dram_bw) < 1e-6 * a:
            return "memory"
        if self.net_bytes and abs(a - self.oi_net * self.net_bw) < 1e-6 * a:
            return "network"
        return "compute"


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Three-term dry-run roofline for an (arch × shape × mesh) cell."""

    name: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float                  # 6·N·D (dense) / 6·N_active·D (MoE)
    peak_flops: float = V5E_PEAK_FLOPS
    hbm_bw: float = V5E_HBM_BW
    link_bw: float = V5E_ICI_BW

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * self.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * self.link_bw)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundant compute."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roof attained if the dominant term were the
        only cost: model_flops / (t_bound · chips · peak)."""
        denom = self.t_bound * self.chips * self.peak_flops
        return self.model_flops / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "cell": self.name, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def stack_terms(terms: "list[RooflineTerms] | tuple[RooflineTerms, ...]"
                ) -> dict:
    """Stack RooflineTerms into the float64 columns consumed by
    ``repro.core.pricing.batched_roofline`` (one array op prices every
    (arch × shape × mesh) cell instead of a property call per cell)."""
    import numpy as np

    cols = ("hlo_flops", "hlo_bytes", "collective_bytes", "chips",
            "model_flops", "peak_flops", "hbm_bw", "link_bw")
    return {c: np.array([getattr(t, c) for t in terms], dtype=np.float64)
            for c in cols}

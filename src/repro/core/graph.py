"""Dataflow graph IR — the workload representation of DFModel (paper §III.B).

Vertices are compute kernels (FLOP counts + kind + sharding metadata); edges are
tensors (byte sizes). The graph is a DAG; tensors have a single producer and a
single consumer (paper §IV.C) — multi-consumer tensors are replicated by the
builders in ``repro.workloads``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Sequence


class KernelKind(enum.Enum):
    """Coarse kernel taxonomy used by the utilization + sharding models."""

    GEMM = "gemm"                # dense matmul (QKV, Proj, FFN, MLP, LU-update)
    ATTENTION = "attention"      # score/softmax/AV fused region
    SOFTMAX = "softmax"
    NORM = "norm"                # layernorm / rmsnorm
    ELEMENTWISE = "elementwise"  # add, mul, activation
    EMBEDDING = "embedding"      # gather from a (possibly huge) table
    SCAN = "scan"                # recurrence (SSM / Mamba chunk scan)
    FFT = "fft"
    COMM = "comm"                # explicit communication kernel (e.g. DLRM a2a)
    ROUTER = "router"            # MoE top-k routing


@dataclasses.dataclass(frozen=True)
class Kernel:
    """A compute vertex.

    ``flops``       total FLOP for one logical execution (unsharded).
    ``weight_bytes``parameter bytes resident for this kernel (unsharded).
    ``kind``        drives the utilization model u_c and sharding scheme set.
    ``gemm_dims``   optional (M, K, N) for GEMM-like kernels — used by the
                    sharding model to derive collective sizes (paper Fig 4).
    """

    name: str
    flops: float
    kind: KernelKind = KernelKind.GEMM
    weight_bytes: float = 0.0
    gemm_dims: tuple[int, int, int] | None = None

    def __post_init__(self) -> None:
        if self.flops < 0 or self.weight_bytes < 0:
            raise ValueError(f"kernel {self.name}: negative flops/bytes")


@dataclasses.dataclass(frozen=True)
class Tensor:
    """A directed edge ``src -> dst`` carrying ``bytes_`` bytes (unsharded)."""

    name: str
    src: str
    dst: str
    bytes_: float

    def __post_init__(self) -> None:
        if self.bytes_ < 0:
            raise ValueError(f"tensor {self.name}: negative bytes")


class DataflowGraph:
    """A DAG of kernels and tensors with a cached topological order."""

    def __init__(self, kernels: Sequence[Kernel], tensors: Sequence[Tensor],
                 name: str = "graph") -> None:
        self.name = name
        self.kernels: list[Kernel] = list(kernels)
        self.tensors: list[Tensor] = list(tensors)
        self._index = {k.name: i for i, k in enumerate(self.kernels)}
        if len(self._index) != len(self.kernels):
            raise ValueError("duplicate kernel names")
        for t in self.tensors:
            if t.src not in self._index or t.dst not in self._index:
                raise ValueError(f"tensor {t.name}: unknown endpoint {t.src}->{t.dst}")
            if t.src == t.dst:
                raise ValueError(f"tensor {t.name}: self-loop")
        self._topo = self._toposort()  # raises on cycles
        self._fingerprint: str | None = None

    # -- structure ---------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.kernels)

    @property
    def m(self) -> int:
        return len(self.tensors)

    def kernel_index(self, name: str) -> int:
        return self._index[name]

    def kernel(self, name: str) -> Kernel:
        return self.kernels[self._index[name]]

    def successors(self, name: str) -> list[str]:
        return [t.dst for t in self.tensors if t.src == name]

    def predecessors(self, name: str) -> list[str]:
        return [t.src for t in self.tensors if t.dst == name]

    def in_tensors(self, name: str) -> list[Tensor]:
        return [t for t in self.tensors if t.dst == name]

    def out_tensors(self, name: str) -> list[Tensor]:
        return [t for t in self.tensors if t.src == name]

    def _toposort(self) -> list[int]:
        indeg = [0] * self.n
        adj: list[list[int]] = [[] for _ in range(self.n)]
        for t in self.tensors:
            s, d = self._index[t.src], self._index[t.dst]
            adj[s].append(d)
            indeg[d] += 1
        queue = sorted(i for i in range(self.n) if indeg[i] == 0)
        order: list[int] = []
        import heapq

        heap = list(queue)
        heapq.heapify(heap)
        while heap:
            i = heapq.heappop(heap)
            order.append(i)
            for j in adj[i]:
                indeg[j] -= 1
                if indeg[j] == 0:
                    heapq.heappush(heap, j)
        if len(order) != self.n:
            raise ValueError("dataflow graph has a cycle")
        return order

    @property
    def topo_order(self) -> list[int]:
        """Kernel indices in (deterministic, lexicographic-tiebreak) topo order."""
        return list(self._topo)

    def topo_names(self) -> list[str]:
        return [self.kernels[i].name for i in self._topo]

    def fingerprint(self) -> str:
        """Structural content digest (kernels + tensors, order-sensitive).

        Two graphs with equal fingerprints are byte-for-byte the same
        workload, so solver results computed on one are valid for the other.
        This is the graph identity used by the ``repro.core.memo`` cache keys
        — unlike ``id()``, it survives rebuilding the graph object, which the
        DSE sweep does once per design point.
        """
        if self._fingerprint is None:
            import hashlib

            h = hashlib.blake2b(digest_size=16)
            for k in self.kernels:
                h.update(repr((k.name, k.flops, k.kind.value, k.weight_bytes,
                               k.gemm_dims)).encode())
            for t in self.tensors:
                h.update(repr((t.name, t.src, t.dst, t.bytes_)).encode())
            self._fingerprint = h.hexdigest()
        return self._fingerprint

    # -- aggregate quantities ------------------------------------------------
    def total_flops(self) -> float:
        return sum(k.flops for k in self.kernels)

    def total_weight_bytes(self) -> float:
        return sum(k.weight_bytes for k in self.kernels)

    def total_tensor_bytes(self) -> float:
        return sum(t.bytes_ for t in self.tensors)

    # -- transforms ----------------------------------------------------------
    def scaled(self, flop_scale: float = 1.0, bytes_scale: float = 1.0,
               name: str | None = None) -> "DataflowGraph":
        """A copy with FLOPs and tensor/weight bytes scaled (e.g. per-shard)."""
        ks = [dataclasses.replace(k, flops=k.flops * flop_scale,
                                  weight_bytes=k.weight_bytes * bytes_scale)
              for k in self.kernels]
        ts = [dataclasses.replace(t, bytes_=t.bytes_ * bytes_scale)
              for t in self.tensors]
        return DataflowGraph(ks, ts, name or self.name)

    def __repr__(self) -> str:
        return (f"DataflowGraph({self.name!r}, n={self.n}, m={self.m}, "
                f"flops={self.total_flops():.3e})")


def chain_graph(kernels: Sequence[Kernel],
                tensor_bytes: Iterable[float],
                name: str = "chain") -> DataflowGraph:
    """Convenience: a linear chain k0 -> k1 -> ... with the given edge sizes."""
    kernels = list(kernels)
    sizes = list(tensor_bytes)
    if len(sizes) != len(kernels) - 1:
        raise ValueError("need exactly n-1 edge sizes for a chain")
    tensors = [Tensor(f"t{i}", kernels[i].name, kernels[i + 1].name, b)
               for i, b in enumerate(sizes)]
    return DataflowGraph(kernels, tensors, name)

"""Cross-process shared memo store for DSE sweeps.

The §VI.C sweep re-solves identical subproblems at almost every design
point, and :mod:`repro.core.memo` already memoises them *per process* —
but pool workers of a parallel :class:`~repro.core.dse_engine.DSEEngine`
sweep fork with a cold (or frozen) cache and cannot reuse each other's
solves.  This module adds the missing shared tier: a store that every
worker of one sweep reads and writes, layered *under* the local memo dict
(write-through, local-first) so call sites never change.

Two backends, selected per pool transport by
``DSEEngine(shared_cache=...)``:

``MmapStore``
    A lock-striped hash table in a plain mmap'd file.  Each stripe is an
    append-only log of pickled ``(key, value)`` entries guarded by an
    ``fcntl`` byte-range lock, so any process that can open the file path
    can share it — fork and forkserver workers attach by path via the
    pool initializer.  Readers take the stripe lock shared, writers
    exclusive; a racing writer of an already-present key discards its
    value (first writer wins), which keeps entries exactly-once.

``ServerStore``
    A tiny unix-domain-socket server owned by a daemon child process —
    the portable (spawn-safe) fallback.  Clients speak a batched
    length-prefixed pickle protocol: pending puts are buffered and
    piggybacked onto the next get, so the common miss→solve→put→next-get
    cycle costs one round trip.  The server survives client crashes
    (one thread per connection) and tears down on a ``shutdown`` message
    or when its owner exits (daemonized).

Both present the same client surface — ``get``/``put``/``flush``/
``stats``/``close`` plus a picklable :class:`StoreHandle` that workers
``connect()`` — and both aggregate per-space hit/miss/insert counters in
the shared medium itself, so the parent reads one cross-process total
after the pool drains (``DSEEngine.last_shared_stats`` →
``BENCH_dse.json``'s ``shared_cache`` block).

Keys arrive as opaque bytes (the memo layer pickles its structural
``(space, key)`` tuples).  Pickle bytes for structurally-equal keys built
independently in two processes are identical in practice for the frozen
dataclass / tuple / float keys the memo uses; any divergence merely costs
a cache miss, never a wrong value.  Every store error degrades the same
way — the memo layer treats a failing shared tier as a miss.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import mmap
import multiprocessing
import os
import pickle
import shutil
import socket
import struct
import tempfile
import threading
import time
from typing import Any

try:  # byte-range locks for the mmap backend; absent on Windows
    import fcntl
except ImportError:  # pragma: no cover - exercised only off-Linux
    fcntl = None  # type: ignore[assignment]

PICKLE_PROTO = pickle.HIGHEST_PROTOCOL

_MAGIC = b"DFMEMO01"
_U64 = struct.Struct("<Q")
_STRIPE_HDR = struct.Struct("<QQ")      # used bytes (past header), entries
_ENTRY_HDR = struct.Struct("<II")       # key length, value length
_SLOT_NAME = 48                          # max space-name bytes per stats slot
_SLOT = struct.Struct(f"<{_SLOT_NAME}sQQQQ")  # name, hits, misses, ins, drop
_N_SLOTS = 16


def _empty_stats(backend: str) -> dict:
    return {"backend": backend, "hits": 0, "misses": 0, "inserts": 0,
            "dropped": 0, "entries": 0, "by_space": {}}


def _merge_space(stats: dict, space: str, hits: int, misses: int,
                 inserts: int, dropped: int) -> None:
    stats["by_space"][space] = {"hits": hits, "misses": misses,
                                "inserts": inserts, "dropped": dropped}
    stats["hits"] += hits
    stats["misses"] += misses
    stats["inserts"] += inserts
    stats["dropped"] += dropped


@dataclasses.dataclass(frozen=True)
class StoreHandle:
    """Picklable pointer to a live shared store.

    Shipped to pool workers (via the executor initializer) so each worker
    opens its own connection — fork children must not reuse the parent's
    socket or lock-owning file descriptor.
    """

    kind: str  # "mmap" | "server"
    path: str

    def connect(self):
        if self.kind == "mmap":
            return MmapStore(path=self.path, create=False)
        if self.kind == "server":
            # short connect timeout: the owner proved the server up before
            # shipping handles, so a refused connect here means it died —
            # degrade to misses quickly instead of stalling the worker
            return ServerClient(self.path, connect_timeout=2.0)
        raise ValueError(f"unknown store kind {self.kind!r}")


# --------------------------- mmap backend ------------------------------------
class MmapStore:
    """Lock-striped shared hash table in an mmap'd file.

    Layout: ``magic | n_stripes | stripe_bytes`` header, a stats region of
    ``_N_SLOTS`` fixed per-space counter slots, then ``n_stripes`` stripes
    of ``stripe_bytes`` each.  A stripe is ``(used, count)`` followed by an
    append-only log of ``(klen, vlen, key, value)`` entries.  Keys hash to
    a stripe with BLAKE2b (deterministic across processes, unlike
    ``hash()``); lookups scan the stripe under a shared ``fcntl`` range
    lock, inserts re-scan under the exclusive lock so racing writers of
    one key keep a single entry.  A full stripe drops further inserts —
    dropping is always safe for a memo cache and is counted in stats.
    """

    backend = "mmap"

    def __init__(self, path: str | None = None, n_stripes: int = 64,
                 stripe_bytes: int = 1 << 20, create: bool | None = None):
        if fcntl is None:
            raise RuntimeError("MmapStore needs fcntl byte-range locks "
                               "(unavailable on this platform)")
        if create is None:
            create = path is None
        self._owner = create
        if path is None:
            fd, path = tempfile.mkstemp(prefix="dfmodel-memo-",
                                        suffix=".mmap")
            os.close(fd)
        self.path = path
        if create:
            self._format(path, n_stripes, stripe_bytes)
        self._open()

    # -- file plumbing --
    def _format(self, path: str, n_stripes: int, stripe_bytes: int) -> None:
        head = _MAGIC + _U64.pack(n_stripes) + _U64.pack(stripe_bytes)
        stats_len = _N_SLOTS * _SLOT.size
        total = len(head) + stats_len + n_stripes * stripe_bytes
        with open(path, "wb") as f:
            f.write(head)
            f.truncate(total)  # sparse: pages materialize only when used

    def _open(self) -> None:
        self._fd = os.open(self.path, os.O_RDWR)
        head = os.pread(self._fd, len(_MAGIC) + 16, 0)
        if head[:len(_MAGIC)] != _MAGIC:
            os.close(self._fd)
            raise ValueError(f"{self.path} is not a DFModel memo store")
        self.n_stripes = _U64.unpack_from(head, len(_MAGIC))[0]
        self.stripe_bytes = _U64.unpack_from(head, len(_MAGIC) + 8)[0]
        self._stats_off = len(_MAGIC) + 16
        self._data_off = self._stats_off + _N_SLOTS * _SLOT.size
        size = self._data_off + self.n_stripes * self.stripe_bytes
        self._mm = mmap.mmap(self._fd, size)
        self._pid = os.getpid()
        # per-space [hits, misses, inserts, dropped] deltas not yet folded
        # into the shared stats region (one fcntl lock per op is the
        # dominant overhead otherwise)
        self._pending: dict[str, list[int]] = {}
        self._pending_ops = 0

    def _ensure_process(self) -> None:
        # A fork child inheriting this object must not reuse the parent's
        # fd: fcntl locks are per (process, inode) but closing ANY fd to
        # the file drops the process's locks, and lock state would be
        # confusing at best. Reopen on first use in a new process.
        if self._pid != os.getpid():
            with contextlib.suppress(OSError, ValueError):
                self._mm.close()
            with contextlib.suppress(OSError):
                os.close(self._fd)
            self._open()

    @contextlib.contextmanager
    def _locked(self, start: int, length: int, exclusive: bool):
        op = fcntl.LOCK_EX if exclusive else fcntl.LOCK_SH
        fcntl.lockf(self._fd, op, length, start)
        try:
            yield
        finally:
            fcntl.lockf(self._fd, fcntl.LOCK_UN, length, start)

    def _stripe_of(self, key: bytes) -> int:
        digest = hashlib.blake2b(key, digest_size=8).digest()
        return _U64.unpack(digest)[0] % self.n_stripes

    def _scan(self, off: int, used: int, key: bytes) -> bytes | None:
        pos, end = off + _STRIPE_HDR.size, off + _STRIPE_HDR.size + used
        mm = self._mm
        while pos < end:
            klen, vlen = _ENTRY_HDR.unpack_from(mm, pos)
            pos += _ENTRY_HDR.size
            if klen == len(key) and mm[pos:pos + klen] == key:
                return bytes(mm[pos + klen:pos + klen + vlen])
            pos += klen + vlen
        return None

    # -- client surface --
    def get(self, space: str, key: bytes) -> bytes | None:
        self._ensure_process()
        off = self._data_off + self._stripe_of(key) * self.stripe_bytes
        with self._locked(off, self.stripe_bytes, exclusive=False):
            used, _ = _STRIPE_HDR.unpack_from(self._mm, off)
            value = self._scan(off, used, key)
        self._bump(space, hits=value is not None, misses=value is None)
        return value

    def put(self, space: str, key: bytes, value: bytes) -> None:
        self._ensure_process()
        need = _ENTRY_HDR.size + len(key) + len(value)
        capacity = self.stripe_bytes - _STRIPE_HDR.size
        if need > capacity:
            self._bump(space, dropped=True)
            return
        off = self._data_off + self._stripe_of(key) * self.stripe_bytes
        with self._locked(off, self.stripe_bytes, exclusive=True):
            used, count = _STRIPE_HDR.unpack_from(self._mm, off)
            if self._scan(off, used, key) is not None:
                return  # racing writer already inserted: first one wins
            if used + need > capacity:
                self._bump(space, dropped=True)
                return
            pos = off + _STRIPE_HDR.size + used
            _ENTRY_HDR.pack_into(self._mm, pos, len(key), len(value))
            pos += _ENTRY_HDR.size
            self._mm[pos:pos + len(key)] = key
            self._mm[pos + len(key):pos + len(key) + len(value)] = value
            _STRIPE_HDR.pack_into(self._mm, off, used + need, count + 1)
        self._bump(space, inserts=True)

    def flush(self) -> None:
        """Fold pending stats deltas into the shared region (entries are
        never buffered — the data stripes are always current)."""
        self._flush_stats()

    def items(self) -> list[tuple[bytes, bytes]]:
        """Every stored ``(key, value)`` entry, stripe by stripe.

        The harvest surface for surrogate training
        (:meth:`repro.core.memo.SolveCache.harvest`): one shared lock per
        stripe, so concurrent writers are never blocked for long and each
        stripe snapshot is internally consistent (entries are append-only,
        a later put only grows the log past the ``used`` mark we read).
        Stats counters do not move — harvesting is observational.
        """
        self._ensure_process()
        out: list[tuple[bytes, bytes]] = []
        for stripe in range(self.n_stripes):
            off = self._data_off + stripe * self.stripe_bytes
            with self._locked(off, self.stripe_bytes, exclusive=False):
                used, _ = _STRIPE_HDR.unpack_from(self._mm, off)
                pos = off + _STRIPE_HDR.size
                end = pos + used
                mm = self._mm
                while pos < end:
                    klen, vlen = _ENTRY_HDR.unpack_from(mm, pos)
                    pos += _ENTRY_HDR.size
                    out.append((bytes(mm[pos:pos + klen]),
                                bytes(mm[pos + klen:pos + klen + vlen])))
                    pos += klen + vlen
        return out

    # -- shared stats --
    def _bump(self, space: str, hits: bool = False, misses: bool = False,
              inserts: bool = False, dropped: bool = False) -> None:
        delta = self._pending.setdefault(space, [0, 0, 0, 0])
        delta[0] += hits
        delta[1] += misses
        delta[2] += inserts
        delta[3] += dropped
        self._pending_ops += 1
        if self._pending_ops >= 64:
            self._flush_stats()

    def _flush_stats(self) -> None:
        pending, self._pending = self._pending, {}
        self._pending_ops = 0
        if not any(any(d) for d in pending.values()):
            return
        region = _N_SLOTS * _SLOT.size
        with self._locked(self._stats_off, region, exclusive=True):
            for space, (dh, dm, di, dd) in pending.items():
                name = space.encode()[:_SLOT_NAME - 1]
                for slot in range(_N_SLOTS):
                    pos = self._stats_off + slot * _SLOT.size
                    raw, h, m, i, d = _SLOT.unpack_from(self._mm, pos)
                    cur = raw.rstrip(b"\0")
                    if cur and cur != name:
                        continue
                    _SLOT.pack_into(self._mm, pos, name, h + dh, m + dm,
                                    i + di, d + dd)
                    break
                # (no break: all slots taken by other spaces — this
                # space's stats are lost; the store itself still works)

    def stats(self) -> dict:
        self._ensure_process()
        self._flush_stats()
        out = _empty_stats(self.backend)
        region = _N_SLOTS * _SLOT.size
        with self._locked(self._stats_off, region, exclusive=False):
            for slot in range(_N_SLOTS):
                pos = self._stats_off + slot * _SLOT.size
                raw, h, m, i, d = _SLOT.unpack_from(self._mm, pos)
                name = raw.rstrip(b"\0")
                if name:
                    _merge_space(out, name.decode(), h, m, i, d)
        out["entries"] = out["inserts"]  # the shared tier never evicts
        return out

    # -- lifecycle --
    def handle(self) -> StoreHandle:
        return StoreHandle("mmap", self.path)

    def close(self) -> None:
        with contextlib.suppress(OSError, ValueError):
            self._flush_stats()
        with contextlib.suppress(OSError, ValueError):
            self._mm.close()
        with contextlib.suppress(OSError):
            os.close(self._fd)
        if self._owner and self._pid == os.getpid():
            with contextlib.suppress(OSError):
                os.unlink(self.path)


def diff_stats(before: dict | None, after: dict | None) -> dict | None:
    """Per-request delta between two :meth:`stats` snapshots of one
    long-lived store (the DSE service daemon keeps a single store across
    requests — :mod:`repro.service` reports each request's share of the
    cross-process reuse with this). Counter keys subtract; ``entries``
    reports the *new* entries; ``by_space`` carries per-space deltas for
    the spaces that moved."""
    if after is None:
        return None
    if before is None:
        return after
    out = _empty_stats(after.get("backend", "?"))
    for key in ("hits", "misses", "inserts", "dropped"):
        out[key] = after.get(key, 0) - before.get(key, 0)
    out["entries"] = after.get("entries", 0) - before.get("entries", 0)
    spaces = set(after.get("by_space", {})) | set(before.get("by_space", {}))
    for space in sorted(spaces):
        a = after.get("by_space", {}).get(space, {})
        b = before.get("by_space", {}).get(space, {})
        delta = {k: a.get(k, 0) - b.get(k, 0)
                 for k in ("hits", "misses", "inserts", "dropped")}
        if any(delta.values()):
            out["by_space"][space] = delta
    return out


# --------------------------- server backend ----------------------------------
def send_msg(sock: socket.socket, obj: Any) -> None:
    """One length-prefixed pickled message (the wire framing shared by
    the store server and the DSE service daemon, :mod:`repro.service`)."""
    payload = pickle.dumps(obj, PICKLE_PROTO)
    sock.sendall(_U64.pack(len(payload)) + payload)


def recv_msg(sock: socket.socket) -> Any | None:
    """One length-prefixed message; ``None`` on a cleanly closed peer."""
    head = b""
    while len(head) < _U64.size:
        chunk = sock.recv(_U64.size - len(head))
        if not chunk:
            return None
        head += chunk
    (n,) = _U64.unpack(head)
    parts, got = [], 0
    while got < n:
        chunk = sock.recv(min(1 << 20, n - got))
        if not chunk:
            return None
        parts.append(chunk)
        got += len(chunk)
    return pickle.loads(b"".join(parts))


# legacy private names (pre-service-layer call sites)
_send_msg = send_msg
_recv_msg = recv_msg


def serve(path: str) -> None:
    """Store-server main loop (runs in the daemon child process).

    One thread per client connection; a client crash (EOF / reset on its
    socket) kills only that thread.  The loop exits on a ``shutdown``
    message and removes its socket file.
    """
    data: dict[bytes, bytes] = {}
    counters: dict[str, list[int]] = {}  # space -> [hits, misses, ins, drop]
    lock = threading.Lock()
    stop = threading.Event()

    def bump(space: str) -> list[int]:
        return counters.setdefault(space, [0, 0, 0, 0])

    def handle(conn: socket.socket) -> None:
        try:
            while not stop.is_set():
                msg = _recv_msg(conn)
                if msg is None:
                    return
                op = msg[0]
                if op == "batch":
                    _, puts, gets = msg
                    with lock:
                        for space, key, value in puts:
                            if key not in data:  # racing writers: first wins
                                data[key] = value
                                bump(space)[2] += 1
                        values = []
                        for space, key in gets:
                            value = data.get(key)
                            bump(space)[0 if value is not None else 1] += 1
                            values.append(value)
                    _send_msg(conn, values)
                elif op == "items":
                    with lock:
                        snapshot = list(data.items())
                    _send_msg(conn, snapshot)
                elif op == "stats":
                    with lock:
                        out = _empty_stats("server")
                        for space, (h, m, i, d) in sorted(counters.items()):
                            _merge_space(out, space, h, m, i, d)
                        out["entries"] = len(data)
                    _send_msg(conn, out)
                elif op == "shutdown":
                    _send_msg(conn, True)
                    stop.set()
                    return
                else:
                    _send_msg(conn, None)
        except OSError:
            return  # client died mid-message; server stays up
        finally:
            with contextlib.suppress(OSError):
                conn.close()

    srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    try:
        srv.bind(path)
        srv.listen(128)
        srv.settimeout(0.1)  # poll the stop flag between accepts
        while not stop.is_set():
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=handle, args=(conn,), daemon=True).start()
    finally:
        with contextlib.suppress(OSError):
            srv.close()
        with contextlib.suppress(OSError):
            os.unlink(path)


class ServerClient:
    """Batching client for :func:`serve`.

    ``put`` buffers locally; the buffer rides along with the next ``get``
    (or a size-triggered / explicit ``flush``), so the memo layer's
    miss→solve→put→next-get cycle costs one round trip per lookup.  A dead
    server turns every operation into a cheap no-op miss — a sweep never
    fails because its cache fell over.
    """

    backend = "server"

    def __init__(self, path: str, flush_every: int = 8,
                 connect_timeout: float = 20.0, alive_check=None):
        self.path = path
        self.flush_every = flush_every
        self.connect_timeout = connect_timeout
        self._alive_check = alive_check  # fail fast on a dead server proc
        self._sock: socket.socket | None = None
        self._puts: list[tuple[str, bytes, bytes]] = []
        self._dead = False
        self._pid = os.getpid()

    def _connection(self) -> socket.socket:
        if self._pid != os.getpid():
            # fork child: the inherited socket belongs to the parent's
            # protocol stream; abandon it (close would not disturb the
            # parent, but reconnecting is the only safe option) and any
            # inherited put buffer (re-putting is harmless, first wins).
            self._sock, self._puts, self._dead = None, [], False
            self._pid = os.getpid()
        if self._sock is None:
            deadline = time.monotonic() + self.connect_timeout
            while True:
                sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                try:
                    sock.connect(self.path)
                    self._sock = sock
                    break
                except OSError:
                    sock.close()
                    if self._alive_check is not None \
                            and not self._alive_check():
                        raise OSError("memo server process is gone")
                    if time.monotonic() >= deadline:
                        raise
                    time.sleep(0.02)
        return self._sock

    def _rpc(self, msg: tuple) -> Any:
        try:
            sock = self._connection()
            _send_msg(sock, msg)
            reply = _recv_msg(sock)
            if reply is None:
                raise OSError("memo server closed the connection")
            return reply
        except OSError:
            self._dead = True
            if self._sock is not None:
                with contextlib.suppress(OSError):
                    self._sock.close()
                self._sock = None
            raise

    def get(self, space: str, key: bytes) -> bytes | None:
        if self._dead and self._pid == os.getpid():
            return None
        puts, self._puts = self._puts, []
        try:
            return self._rpc(("batch", puts, [(space, key)]))[0]
        except OSError:
            return None

    def put(self, space: str, key: bytes, value: bytes) -> None:
        if self._dead and self._pid == os.getpid():
            return
        self._puts.append((space, key, value))
        if len(self._puts) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        if not self._puts:
            return
        puts, self._puts = self._puts, []
        with contextlib.suppress(OSError):
            self._rpc(("batch", puts, []))

    def stats(self) -> dict:
        self.flush()
        return self._rpc(("stats",))

    def items(self) -> list[tuple[bytes, bytes]]:
        """Every stored ``(key, value)`` entry (harvest surface; a dead
        server yields the empty list, matching the degrade-to-miss
        contract of ``get``)."""
        if self._dead and self._pid == os.getpid():
            return []
        try:
            self.flush()
            return self._rpc(("items",)) or []
        except OSError:
            return []

    def shutdown_server(self) -> None:
        self.flush()
        self._rpc(("shutdown",))

    def handle(self) -> StoreHandle:
        return StoreHandle("server", self.path)

    def close(self) -> None:
        with contextlib.suppress(OSError):
            self.flush()
        if self._sock is not None:
            with contextlib.suppress(OSError):
                self._sock.close()
            self._sock = None


class ServerStore:
    """Parent-side owner of a store-server process + its local client.

    Spawn-safe: the server is a daemon child started via an explicitly
    chosen multiprocessing context (default ``spawn``, matching the pools
    it serves — forking a jax-threaded parent is the hazard the server
    backend exists to avoid), and workers connect by socket path.  The
    daemon flag guarantees teardown even if ``close()`` is never reached.
    """

    backend = "server"

    def __init__(self, mp_context: multiprocessing.context.BaseContext
                 | str | None = None):
        if not hasattr(socket, "AF_UNIX"):
            raise RuntimeError("ServerStore needs unix-domain sockets")
        if mp_context is None or isinstance(mp_context, str):
            mp_context = multiprocessing.get_context(mp_context or "spawn")
        self._dir = tempfile.mkdtemp(prefix="dfmodel-memo-")
        self.path = os.path.join(self._dir, "memo.sock")
        self._proc = mp_context.Process(target=serve, args=(self.path,),
                                        daemon=True, name="dfmodel-memo-srv")
        self._proc.start()
        self._client = ServerClient(self.path,
                                    alive_check=self._proc.is_alive)
        # fail fast if the server never comes up (the first RPC retries
        # connect until connect_timeout or the server process dies) —
        # and never leak the daemon + temp dir when the probe gives up
        try:
            self._client.stats()
        except BaseException:
            self.close()
            raise

    def get(self, space: str, key: bytes) -> bytes | None:
        return self._client.get(space, key)

    def put(self, space: str, key: bytes, value: bytes) -> None:
        self._client.put(space, key, value)

    def flush(self) -> None:
        self._client.flush()

    def stats(self) -> dict:
        return self._client.stats()

    def items(self) -> list[tuple[bytes, bytes]]:
        return self._client.items()

    def handle(self) -> StoreHandle:
        return StoreHandle("server", self.path)

    def close(self) -> None:
        with contextlib.suppress(Exception):
            self._client.shutdown_server()
        self._client.close()
        self._proc.join(timeout=5.0)
        if self._proc.is_alive():  # pragma: no cover - shutdown always acks
            self._proc.terminate()
            self._proc.join(timeout=1.0)
        shutil.rmtree(self._dir, ignore_errors=True)


# ------------------------------ selection ------------------------------------
def choose_backend(start_method: str) -> str:
    """Backend for a pool transport: the mmap table for fork/forkserver
    (workers share the file by path with zero per-op IPC), the socket
    server as the portable fallback for spawn (and for platforms without
    ``fcntl`` range locks)."""
    if fcntl is not None and start_method in ("fork", "forkserver"):
        return "mmap"
    if hasattr(socket, "AF_UNIX"):
        return "server"
    if fcntl is not None:  # pragma: no cover - no-AF_UNIX platforms
        return "mmap"
    raise RuntimeError("no shared memo-store backend available "
                       "(need fcntl or AF_UNIX)")


def create_store(backend: str = "auto",
                 mp_context: multiprocessing.context.BaseContext | str |
                 None = None):
    """Build a parent-side shared store.

    ``backend="auto"`` picks per the pool's start method
    (:func:`choose_backend`); ``"mmap"`` / ``"server"`` force one.
    """
    if backend in ("auto", True):
        method = (mp_context if isinstance(mp_context, str)
                  else mp_context.get_start_method() if mp_context is not None
                  else multiprocessing.get_start_method(allow_none=False))
        backend = choose_backend(method)
    if backend == "mmap":
        return MmapStore()
    if backend == "server":
        return ServerStore(mp_context=mp_context)
    raise ValueError(f"unknown shared-cache backend {backend!r}; "
                     f"expected 'auto', 'mmap' or 'server'")

"""Intra-chip optimization pass (paper §V).

Given the per-chip subgraph (kernels with sharded FLOPs f', tensors with
sharded bytes b' for one streaming microbatch), partition it into sequential
*dataflow partitions*. Within a partition, kernels are fused and pipelined:
compute, DRAM transfer and network fully overlap, so the partition latency is

    t_cri = max(t_comp, t_mem, t_net)                       (§V.B.4)

with
    t_comp = Σ_k (f'_k / u_k) / (t_lim · t_flop)   — optimal tile allocation:
             minimizing max_k f'_k/(t_k·t_flop·u_k) s.t. Σ t_k ≤ t_lim gives
             t_k ∝ f'_k/u_k, hence the sum form (closed form of §V.B.1's max).
    t_mem  = (Dᵀb' cross-partition traffic + streamed weights/n_streams) / d_bw
    t_net  = Σ_k∈p h_n[k] + Σ_j∈p h_m[j]            (inherited from inter-chip)

Constraints: buffer_factor·Bᵀb' + pinned weights ≤ s_cap (SRAM; the streaming
pipeline double-buffers inter-kernel tensors), Lᵀb' ≤ d_cap (DRAM).

Weight handling (TPU adaptation; DESIGN.md §3): as much of a partition's
weights as fits in leftover SRAM is pinned; the remainder streams from DRAM.
A resident partition processes ``n_streams`` microbatches before the chip
reconfigures to the next partition, so streamed-weight traffic is amortized
by 1/n_streams — this is the "less memory traffic" advantage of dataflow
execution (paper §II.B) and what drives Fig 19's SRAM sweep.

The objective min Σ_p max(...) is solved exactly by interval DP over the
topological order (``solver.minsum_partition``); branch & bound over the full
assignment-matrix space certifies optimality for small graphs in tests.

Non-dataflow (kernel-by-kernel, Fig 2D) mode: every kernel is its own
partition and *nothing overlaps*: t_k = t_comp_k + t_mem_k + t_net_k with all
inputs/outputs/weights hitting DRAM every microbatch — the Calculon-style
baseline the paper compares against.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..systems.chips import ChipSpec, MemorySpec
from .graph import DataflowGraph
from .solver import bounds_to_assign, minsum_partition
from .utilization import kernel_utilizations


@dataclasses.dataclass
class IntraChipResult:
    assign: np.ndarray              # kernel -> partition id (graph order)
    n_partitions: int
    t_comp: np.ndarray              # per-partition seconds (per microbatch)
    t_mem: np.ndarray
    t_net: np.ndarray
    t_critical: np.ndarray          # max of the three per partition
    total_time: float               # Σ t_critical  (§V objective)
    sram_used: np.ndarray           # per-partition bytes (incl. pinned weights)
    dram_traffic: float             # bytes per microbatch
    mode: str                       # 'dataflow' | 'kbk'

    @property
    def bottleneck(self) -> str:
        tot = {"compute": self.t_comp.sum(), "memory": self.t_mem.sum(),
               "network": self.t_net.sum()}
        return max(tot, key=tot.get)

    def sums(self) -> tuple[float, float, float]:
        """(Σt_comp, Σt_mem, Σt_net) over partitions, as Python floats.

        This is the canonical reduction (``np.ndarray.sum`` pairwise order)
        the plan phase stores in ``pricing.PlanVector`` — the price phase
        never re-reduces the ragged per-partition arrays, so batched and
        scalar breakdowns are bit-identical by construction.
        """
        return (float(self.t_comp.sum()), float(self.t_mem.sum()),
                float(self.t_net.sum()))


@dataclasses.dataclass
class _Env:
    """Shared per-call context for group evaluation."""

    f: np.ndarray
    w: np.ndarray
    u: np.ndarray
    hn: np.ndarray
    edges: list[tuple[int, int, float, float]]
    peak: float
    s_cap: float
    mem_bw: float
    weights: str
    buffer_factor: float
    n_streams: int
    kbk_efficiency: float


def _make_env(graph: DataflowGraph, chip: ChipSpec, mem: MemorySpec,
              h_n, h_m, sram_headroom: float, weights: str,
              buffer_factor: float, n_streams: int,
              kbk_efficiency: float) -> tuple[_Env, list[int]]:
    n = graph.n
    order = graph.topo_order
    kernels = [graph.kernels[i] for i in order]
    f = np.array([k.flops for k in kernels])
    w = np.array([k.weight_bytes for k in kernels])
    u = kernel_utilizations(kernels)
    hn_full = np.zeros(n) if h_n is None else np.asarray(h_n, dtype=float)
    hn = hn_full[order]
    pos = {ki: p for p, ki in enumerate(order)}
    hm_arr = (np.zeros(graph.m) if h_m is None
              else np.asarray(h_m, dtype=float))
    edges = [(pos[graph.kernel_index(t.src)], pos[graph.kernel_index(t.dst)],
              t.bytes_, hm_arr[j]) for j, t in enumerate(graph.tensors)]
    env = _Env(f, w, u, hn, edges, chip.tiles * chip.tile_flops,
               chip.sram_capacity * sram_headroom, mem.bandwidth,
               weights, buffer_factor, max(1, n_streams), kbk_efficiency)
    return env, order


def _group_terms(env: _Env, members: set[int]
                 ) -> tuple[float, float, float, float]:
    """(t_comp, t_mem, t_net, sram) for fusing the given topo positions."""
    idx = np.fromiter(members, dtype=np.int64)
    gcomp = float((env.f[idx] / env.u[idx]).sum() / env.peak)
    intra = sum(b for s, d, b, _ in env.edges
                if s in members and d in members)
    cross = sum(b for s, d, b, _ in env.edges
                if (s in members) != (d in members))
    wsum = float(env.w[idx].sum())
    sram = intra * env.buffer_factor
    if env.weights == "stream":
        pinned = 0.0
    elif env.weights == "resident":
        pinned = wsum
    else:  # auto: pin as much as fits
        pinned = min(wsum, max(0.0, env.s_cap - sram))
    wstream = (wsum - pinned) / env.n_streams
    sram += pinned
    gmem = (cross + wstream) / env.mem_bw
    gnet = float(env.hn[idx].sum())
    gnet += sum(hm for s, d, _, hm in env.edges if s in members)
    return gcomp, gmem, gnet, sram


def optimize_intra_chip(graph: DataflowGraph, chip: ChipSpec, mem: MemorySpec,
                        h_n: Sequence[float] | None = None,
                        h_m: Sequence[float] | None = None,
                        p_max: int = 8, mode: str = "dataflow",
                        sram_headroom: float = 0.9,
                        weights: str = "auto",
                        buffer_factor: float = 2.0,
                        n_streams: int = 16,
                        kbk_efficiency: float = 0.75) -> IntraChipResult:
    """Run the §V pass (see module docstring for the model).

    ``weights``: 'resident' (RDU spatial mapping: weights count fully against
    SRAM; infeasible if they do not fit), 'auto' (pin what fits, stream the
    rest — TPU/VMEM semantics, default), 'stream'.
    ``n_streams``: microbatches streamed per partition residency (weight
    traffic amortization). ``kbk_efficiency`` derates unfused kernels.
    """
    env, order = _make_env(graph, chip, mem, h_n, h_m, sram_headroom,
                           weights, buffer_factor, n_streams, kbk_efficiency)
    n = graph.n

    if mode == "kbk":
        return _run_kbk(graph, env, order)

    def group_cost(i: int, j: int) -> float:
        c, m_, t_, _ = _group_terms(env, set(range(i, j)))
        return max(c, m_, t_)

    def feasible(i: int, j: int) -> bool:
        return _group_terms(env, set(range(i, j)))[3] <= env.s_cap

    try:
        bounds, _ = minsum_partition(n, p_max, group_cost, feasible)
    except ValueError:
        # p_max forces groups whose fused buffers exceed SRAM (large graphs /
        # long sequences); allow up to one partition per kernel — singleton
        # partitions are always feasible under 'auto'/'stream' weights.
        bounds, _ = minsum_partition(n, n, group_cost, feasible)
    assign_topo = bounds_to_assign(bounds, n)
    return _finalize(graph, env, order, assign_topo, "dataflow")


def evaluate_intra_assignment(graph: DataflowGraph, assign: Sequence[int],
                              chip: ChipSpec, mem: MemorySpec,
                              h_n: Sequence[float] | None = None,
                              h_m: Sequence[float] | None = None,
                              sram_headroom: float = 0.9,
                              weights: str = "auto",
                              buffer_factor: float = 2.0,
                              n_streams: int = 16) -> IntraChipResult:
    """Price a *given* kernel→partition assignment (e.g. the vendor mapping
    of §VII.B) under the same performance model as the optimizer."""
    assign = np.asarray(assign, dtype=np.int64)
    env, order = _make_env(graph, chip, mem, h_n, h_m, sram_headroom,
                           weights, buffer_factor, n_streams, 1.0)
    assign_topo = assign[order]
    return _finalize(graph, env, order, assign_topo, "dataflow")


def _finalize(graph: DataflowGraph, env: _Env, order: list[int],
              assign_topo: np.ndarray, mode: str) -> IntraChipResult:
    parts = sorted(set(int(p) for p in assign_topo))
    remap = {p: i for i, p in enumerate(parts)}
    assign_topo = np.array([remap[int(p)] for p in assign_topo])
    npart = len(parts)
    t_comp = np.zeros(npart)
    t_mem = np.zeros(npart)
    t_net = np.zeros(npart)
    sram = np.zeros(npart)
    dram = 0.0
    for g in range(npart):
        members = {i for i in range(len(assign_topo)) if assign_topo[i] == g}
        t_comp[g], t_mem[g], t_net[g], sram[g] = _group_terms(env, members)
        dram += t_mem[g] * env.mem_bw
    t_cri = np.maximum(np.maximum(t_comp, t_mem), t_net)
    out_assign = np.empty(len(assign_topo), dtype=np.int64)
    out_assign[order] = assign_topo
    return IntraChipResult(out_assign, npart, t_comp, t_mem, t_net, t_cri,
                           float(t_cri.sum()), sram, dram, mode=mode)


def _run_kbk(graph: DataflowGraph, env: _Env, order: list[int]
             ) -> IntraChipResult:
    n = graph.n
    assign = np.arange(n, dtype=np.int64)
    t_comp = env.f / (env.u * env.peak * env.kbk_efficiency)
    io_bytes = np.zeros(n)
    t_net_extra = np.zeros(n)
    for s, d, b, hm in env.edges:
        io_bytes[s] += b          # producer stores to DRAM
        io_bytes[d] += b          # consumer loads from DRAM
        t_net_extra[s] += hm
    t_mem = (io_bytes + env.w) / env.mem_bw
    t_net = env.hn + t_net_extra
    t_cri = t_comp + t_mem + t_net      # sequential: no overlap
    out_assign = np.empty(n, dtype=np.int64)
    out_assign[order] = assign
    return IntraChipResult(out_assign, n, t_comp, t_mem, t_net, t_cri,
                           float(t_cri.sum()), sram_used=np.zeros(n),
                           dram_traffic=float(io_bytes.sum() + env.w.sum()),
                           mode="kbk")

"""Gradient compression for the DP all-reduce (distributed-optimization
trick): block-wise int8 quantization with error feedback.

The DP gradient all-reduce is the collective DFModel charges at
``all_reduce(grad_bytes)`` (core/interchip.py); int8 halves-to-quarters the
payload at equal convergence when error feedback accumulates the
quantization residual locally (1-bit Adam / EF-SGD lineage).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array, block: int = 256):
    """Per-block symmetric int8. Returns (q int8, scales f32, orig_shape)."""
    flat = g.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), g.shape


def dequantize_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for d in shape:
        size *= d
    return flat[:size].reshape(shape)


def compress_tree(grads, errors=None, block: int = 256):
    """Quantize a gradient pytree with error feedback.

    Returns (compressed pytree of (q, scale, shape), new_errors)."""
    if errors is None:
        errors = jax.tree.map(jnp.zeros_like, grads)
    corrected = jax.tree.map(lambda g, e: g + e, grads, errors)
    comp = jax.tree.map(lambda g: quantize_int8(g, block), corrected,
                        is_leaf=lambda x: isinstance(x, jax.Array))
    recon = jax.tree.map(lambda c: dequantize_int8(*c), comp,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda g, r: g - r, corrected, recon)
    return comp, new_err


def decompress_tree(comp):
    return jax.tree.map(lambda c: dequantize_int8(*c), comp,
                        is_leaf=lambda x: isinstance(x, tuple))

"""Context-parallel decode attention: KV cache sharded along the sequence
dimension across the 'model' axis, combined with a distributed log-sum-exp.

This is the hand-fused alternative to letting GSPMD auto-partition the decode
softmax (which all-gathers score rows). Each chip runs the split-KV Pallas
kernel (or its jnp twin) over its local KV shard, exporting (o_local, lse);
the exact global attention is

    w_i = exp(lse_i - max_j lse_j);   o = Σ_i w_i·o_i / Σ_i w_i

— two tiny psums of (B, H) + (B, H, hd) instead of a (B, H, S) all-gather.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..kernels.decode_attention.ref import decode_attention_ref


def _local_decode(q, k, v, kv_len, use_kernel: bool):
    if use_kernel:
        from ..kernels.decode_attention.ops import decode_attention
        return decode_attention(q, k, v, kv_len, return_lse=True)
    return decode_attention_ref(q, k, v, kv_len, return_lse=True)


def lse_combine(o: jax.Array, lse: jax.Array, axis: str):
    """Merge per-shard partial attentions along ``axis``.

    o: (B, H, hd) local numerator/denominator-normalized output;
    lse: (B, H) local log-sum-exp. Exact for disjoint KV shards."""
    m = jax.lax.pmax(lse, axis)
    w = jnp.exp(lse - m)
    num = jax.lax.psum(o.astype(jnp.float32) * w[..., None], axis)
    den = jax.lax.psum(w, axis)
    return (num / den[..., None]).astype(o.dtype)


def decode_attention_cache_layout(mesh: Mesh, q, cache_k, cache_v, kv_len,
                                  batch_axes=("data",), axis: str = "model"):
    """Context-parallel decode over the model's cache layout.

    q: (B, H, hd) — replicated over ``axis`` inside the map (tiny);
    cache_{k,v}: (B, Smax, Hkv, hd) with Smax sharded on ``axis`` and B on
    the data axes; kv_len: global valid length (pos + 1).

    Collective: one psum of (B, H, hd) + (B, H) instead of GSPMD's
    all-gather of the KV cache — O(B·H·hd) vs O(Smax·Hkv·hd) per step.
    """
    ba = batch_axes if isinstance(batch_axes, (tuple, list)) else (batch_axes,)
    ba = tuple(a for a in ba if a in mesh.axis_names)
    bspec = ba if len(ba) > 1 else (ba[0] if ba else None)

    @partial(shard_map, mesh=mesh,
             in_specs=(P(bspec, None, None),
                       P(bspec, axis, None, None),
                       P(bspec, axis, None, None), P()),
             out_specs=P(bspec, None, None), check_rep=False)
    def fn(q_l, k_shard, v_shard, kv_len):
        idx = jax.lax.axis_index(axis)
        s_local = k_shard.shape[1]
        local_start = idx * s_local
        local_len = jnp.clip(kv_len - local_start, 0, s_local)
        # (B, S, Hkv, hd) -> (B, Hkv, S, hd) for the split-KV layout
        ks = k_shard.transpose(0, 2, 1, 3)
        vs = v_shard.transpose(0, 2, 1, 3)
        o, lse = decode_attention_ref(q_l, ks, vs, local_len,
                                      return_lse=True)
        lse = jnp.where(local_len > 0, lse, -jnp.inf)
        o = jnp.where(local_len > 0, o, 0.0)
        return lse_combine(o, lse, axis)

    return fn(q, cache_k, cache_v, kv_len)


def context_parallel_decode(mesh: Mesh, axis: str = "model",
                            use_kernel: bool = False):
    """Returns fn(q (B,H,hd), k/v (B,Hkv,S,hd) seq-sharded, kv_len) -> o.

    ``kv_len`` is the *global* valid length; each shard masks its local
    window using its axis index.
    """
    n_shards = mesh.shape[axis]

    @partial(shard_map, mesh=mesh,
             in_specs=(P(), P(None, None, axis, None),
                       P(None, None, axis, None), P()),
             out_specs=P(), check_rep=False)
    def fn(q, k_shard, v_shard, kv_len):
        idx = jax.lax.axis_index(axis)
        s_local = k_shard.shape[2]
        local_start = idx * s_local
        local_len = jnp.clip(kv_len - local_start, 0, s_local)
        o, lse = _local_decode(q, k_shard, v_shard, local_len, use_kernel)
        # shards past the valid prefix contribute nothing
        lse = jnp.where(local_len > 0, lse, -jnp.inf)
        o = jnp.where(local_len > 0, o, 0.0)
        return lse_combine(o, lse, axis)

    return fn

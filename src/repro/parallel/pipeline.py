"""Pipeline parallelism via shard_map + collective_permute (GPipe schedule).

DFModel's inter-chip pass emits PP stage boundaries (paper §IV); this module
executes them: each device along the 'stage' mesh axis owns one stage's
layer stack and microbatches flow through a collective_permute ring.

The schedule is the classic GPipe fill-steady-drain loop: T = n_micro +
n_stages - 1 ticks; at tick t, stage s processes microbatch t - s. The
bubble fraction (n_stages-1)/T is exactly the term DFModel's iteration model
charges (core/interchip.py).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(mesh: Mesh, stage_fn: Callable, n_stages: int,
                     axis: str = "stage"):
    """Build fn(stage_params, x_micro) -> y_micro running the GPipe schedule.

    stage_params: pytree with leading (n_stages, ...) dims, sharded one
    stage per device along ``axis``.
    x_micro: (n_micro, mb, ...) microbatched input (replicated along axis).
    stage_fn(params_slice, x) -> y must be shape-preserving (d_model in/out),
    as in a transformer trunk.
    """

    @partial(shard_map, mesh=mesh,
             in_specs=(P(axis), P()),
             out_specs=P(), check_rep=False)
    def run(params, xs):
        params = jax.tree.map(lambda a: a[0], params)  # this stage's slice
        sidx = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        total = n_micro + n_stages - 1
        state = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            state, outs = carry
            mb_idx = t - sidx
            # stage 0 ingests microbatch t (if valid); others use the
            # permuted activation from the previous stage
            feed = jnp.where(
                (mb_idx >= 0) & (mb_idx < n_micro),
                jax.lax.dynamic_index_in_dim(
                    xs, jnp.clip(mb_idx, 0, n_micro - 1), 0, keepdims=False),
                jnp.zeros_like(xs[0]))
            x_in = jnp.where(sidx == 0, feed, state)
            y = stage_fn(params, x_in)
            # last stage records its finished microbatch
            outs = jnp.where(
                (sidx == n_stages - 1) & (mb_idx >= 0) & (mb_idx < n_micro),
                jax.lax.dynamic_update_index_in_dim(
                    outs, y, jnp.clip(mb_idx, 0, n_micro - 1), 0),
                outs)
            # pass activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            state = jax.lax.ppermute(y, axis, perm)
            return (state, outs), None

        (_, outs), _ = jax.lax.scan(tick, (state, outs), jnp.arange(total))
        # every device now holds only its own writes; the last stage owns the
        # real outputs — broadcast them
        outs = jax.lax.psum(
            jnp.where(sidx == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    return run

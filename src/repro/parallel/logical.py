"""Logical-axis sharding annotations (MaxText-style, minimal).

Models annotate activations with *logical* axis names; the launcher installs
an ``AxisRules`` mapping logical names → mesh axes. Outside any rules context
(unit tests, single device) the annotations are no-ops, so model code is
mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Sequence

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """logical axis -> mesh axis (or tuple of mesh axes, or None)."""

    rules: dict

    def spec(self, *logical: str | None) -> P:
        return P(*(self.rules.get(a) if a is not None else None
                   for a in logical))


_current: contextvars.ContextVar[AxisRules | None] = contextvars.ContextVar(
    "axis_rules", default=None)
_current_mesh: contextvars.ContextVar = contextvars.ContextVar(
    "axis_mesh", default=None)


def current_rules() -> AxisRules | None:
    return _current.get()


def current_mesh():
    """The mesh installed alongside the rules (None outside the launcher).
    Layers use it to opt into hand-written shard_map collectives (e.g. the
    expert-parallel MoE dispatch) instead of GSPMD auto-partitioning."""
    return _current_mesh.get()


@contextlib.contextmanager
def use_rules(rules: AxisRules | None, mesh=None):
    token = _current.set(rules)
    mtoken = _current_mesh.set(mesh)
    try:
        yield
    finally:
        _current.reset(token)
        _current_mesh.reset(mtoken)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate ``x`` with the mesh sharding implied by logical axis names.

    No-op when no rules are installed. Logical names not present in the
    rules map to replicated dims.
    """
    rules = _current.get()
    if rules is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"shard(): {len(logical)} axes for rank-{x.ndim}")
    return jax.lax.with_sharding_constraint(x, rules.spec(*logical))


def param_spec(path: Sequence[str], shape: tuple[int, ...],
               rules: AxisRules, mesh_axis_sizes: dict) -> P:
    """PartitionSpec for a parameter leaf by naming convention.

    Conventions (leaf name — see models/layers.py init functions):
      embed (V, d)        -> ('vocab', None)
      wq/wk/wv (d, H*hd)  -> (None, 'heads')   [kv replicated if indivisible]
      wo (H*hd, d)        -> ('heads', None)
      mlp wi/wg (d, F)    -> (None, 'ff'); wo (F, d) -> ('ff', None)
      moe wi/wg (E, d, F) -> ('experts', None, None); router replicated
      ssm in_proj (d, X)  -> (None, 'ff'); out_proj (X, d) -> ('ff', None)
      norms / scalars     -> replicated
    Stacked-layer leaves carry a leading (n_blocks,) dim -> None prepended.
    """
    name = path[-1]
    stacked = len(path) > 1 and path[0] == "stack"

    def ok(logical: str, dim: int) -> bool:
        ax = rules.rules.get(logical)
        if ax is None:
            return False
        size = mesh_axis_sizes.get(ax, 1) if isinstance(ax, str) else 1
        if isinstance(ax, tuple):
            size = 1
            for a in ax:
                size *= mesh_axis_sizes.get(a, 1)
        return dim % max(size, 1) == 0

    base: tuple = ()
    d = shape[1:] if stacked else shape
    if name == "embed":
        base = (rules.rules.get("vocab") if ok("vocab", d[0]) else None, None)
    elif name in ("wq",):
        base = (None, rules.rules.get("heads") if ok("heads", d[1]) else None)
    elif name in ("wk", "wv"):
        base = (None, rules.rules.get("kv_heads") if ok("kv_heads", d[1]) else None)
    elif name == "wo" and len(d) == 2:
        base = (rules.rules.get("heads") if ok("heads", d[0]) else None, None)
    elif name in ("wi", "wg") and len(d) == 2:
        base = (None, rules.rules.get("ff") if ok("ff", d[1]) else None)
    elif name in ("wi", "wg", "wo", "router") and len(d) == 3:
        base = (rules.rules.get("experts") if ok("experts", d[0]) else None,
                None, None)
    elif name == "in_proj":
        base = (None, rules.rules.get("ff") if ok("ff", d[1]) else None)
    elif name == "out_proj":
        base = (rules.rules.get("ff") if ok("ff", d[0]) else None, None)
    elif name == "lm_head":
        base = (None, rules.rules.get("vocab") if ok("vocab", d[1]) else None)
    else:
        base = tuple(None for _ in d)
    if stacked:
        base = (None,) + base
    return P(*base)

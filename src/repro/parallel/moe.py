"""Hand-scheduled expert-parallel MoE dispatch (beyond-paper optimization).

Under pure GSPMD, the capacity-buffer scatter in ``models.layers.moe`` —
``buf.at[expert, slot].add(token)`` into an expert-sharded (E, cap, d)
buffer — partitions poorly: the compiler materializes and all-reduces the
*full* capacity buffer (E·cap·d bytes per MoE layer), which makes MoE
training collective-bound (see EXPERIMENTS.md §Perf, olmoe baseline).

This module replaces it with an explicit shard_map schedule:

  · tokens are replicated across the 'model' axis (they already are after
    the attention block's output all-reduce);
  · every shard runs the identical router math, then builds ONLY its local
    experts' capacity buffer (a local scatter, no communication);
  · local experts compute their FFN;
  · each shard gathers its experts' outputs back to token order and the
    partial token outputs are combined with one psum of (T, d) — the only
    collective in the layer.

Collective payload per MoE layer drops from O(E·cap·d) to O(T·d).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def moe_shard_map(p: dict, x: jax.Array, cfg, mesh: Mesh,
                  capacity_factor: float | None = None) -> jax.Array:
    """Drop-in replacement for layers.moe under an active mesh.

    x: (B, S, d) with B sharded over the data axes and replicated over
    'model'; expert weights (E, d, f) sharded over 'model' on dim 0.
    """
    e = cfg.moe_experts
    k = cfg.moe_top_k
    m_size = mesh.shape["model"]
    assert e % m_size == 0
    e_local = e // m_size
    cf = cfg.moe_capacity_factor if capacity_factor is None else capacity_factor
    ba = _batch_axes(mesh)
    has_gate = "wg" in p

    wspec = P("model", None, None)
    in_specs = [P(ba, None, None), P(None, None), wspec, wspec]
    if has_gate:
        in_specs.insert(3, wspec)

    @partial(shard_map, mesh=mesh, in_specs=tuple(in_specs),
             out_specs=P(ba, None, None), check_rep=False)
    def fn(x_l, router, wi, *rest):
        if has_gate:
            wg, wo = rest
        else:
            (wo,) = rest
        b, s, d = x_l.shape
        t = b * s
        xt = x_l.reshape(t, d)
        # --- routing: identical on every 'model' shard (replicated) --------
        logits = (xt @ router.astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, k)                     # (T, k)
        gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
        cap = int(max(1, math.ceil(t * k / e * cf)))
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)         # (T, k, E)
        flat = onehot.reshape(t * k, e)
        rank = jnp.cumsum(flat, axis=0) - 1
        rank = (rank * flat).sum(-1).reshape(t, k)               # (T, k)
        keep = rank < cap
        # --- local dispatch: only this shard's experts ---------------------
        lo = jax.lax.axis_index("model") * e_local
        local = keep & (idx >= lo) & (idx < lo + e_local)
        ei = jnp.where(local, idx - lo, 0).reshape(-1)
        ri = jnp.where(local, rank, 0).reshape(-1)
        w_keep = (gates * local).reshape(-1)                     # (T·k,)
        tok = jnp.broadcast_to(xt[:, None, :], (t, k, d)).reshape(t * k, d)
        buf = jnp.zeros((e_local, cap, d), x_l.dtype)
        buf = buf.at[ei, ri].add(tok * (w_keep > 0)[:, None].astype(x_l.dtype))
        # --- local expert FFN ----------------------------------------------
        h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(x_l.dtype))
        if has_gate:
            g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(x_l.dtype))
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h)
        out = jnp.einsum("ecf,efd->ecd", h, wo.astype(x_l.dtype))
        # --- combine: gather local contributions, one psum over 'model' ----
        y = out[ei, ri].reshape(t, k, d)
        y = (y * w_keep.reshape(t, k, 1).astype(x_l.dtype)).sum(axis=1)
        y = jax.lax.psum(y, "model")
        return y.reshape(b, s, d)

    args = [x, p["router"], p["wi"]]
    if has_gate:
        args.append(p["wg"])
    args.append(p["wo"])
    return fn(*args)

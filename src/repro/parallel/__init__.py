from .logical import AxisRules, shard, use_rules, current_rules, param_spec

__all__ = ["AxisRules", "shard", "use_rules", "current_rules", "param_spec"]

"""DFModel planning for the production cells — the paper's optimizer driving
the real system (DESIGN.md §2).

``plan_cell`` builds the architecture's dataflow graph, runs the two-level
optimization against the TPU v5e production system, and returns the
prediction (iteration time / utilization / bottleneck / fusion partitions).
The dry-run stores this next to the compiled-HLO roofline so model and
system can be compared cell by cell (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses

from ..configs import SHAPES, get_config
from ..core.graph import DataflowGraph, Kernel, Tensor
from ..core.interchip import TrainWorkload, evaluate_plan, _subdivide_dims
from ..core.intrachip import optimize_intra_chip
from ..core.sharding import solve_sharding
from ..models.config import ModelConfig
from ..systems.chips import HBM_V5E, ICI, TPU_V5E
from ..systems.system import SystemSpec
from ..systems.topology import Topology, TopologyDim
from ..workloads.llm import (LLMShape, decode_layer_graph, embedding_graph,
                             gpt_layer_graph, lm_head_graph,
                             mamba_layer_graph)


def v5e_system(multi_pod: bool = False) -> SystemSpec:
    dims = [TopologyDim(16, "ring", ICI), TopologyDim(16, "ring", ICI)]
    if multi_pod:
        dims.append(TopologyDim(2, "ring", ICI))
    topo = Topology("v5e_pod" + ("2" if multi_pod else "1"), tuple(dims))
    return SystemSpec(topo.name, TPU_V5E, HBM_V5E, topo)


def _llm_shape(cfg: ModelConfig, seq: int, batch: int) -> LLMShape:
    return LLMShape(
        name=cfg.name, n_layers=cfg.n_layers, d_model=cfg.d_model,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_ff=cfg.d_ff or 1, vocab=cfg.vocab, seq=seq, batch=batch,
        moe_experts=cfg.moe_experts, moe_top_k=cfg.moe_top_k,
        d_head=cfg.head_dim, gated=cfg.gated)


def _concat(graphs: list[DataflowGraph], name: str) -> DataflowGraph:
    """Sequentially chain per-layer graphs into one block graph."""
    ks, ts = [], []
    prev_last = None
    for li, g in enumerate(graphs):
        ren = {k.name: f"L{li}_{k.name}" for k in g.kernels}
        ks += [dataclasses.replace(k, name=ren[k.name]) for k in g.kernels]
        ts += [Tensor(f"L{li}_{t.name}", ren[t.src], ren[t.dst], t.bytes_)
               for t in g.tensors]
        first = ren[g.kernels[g.topo_order[0]].name]
        if prev_last is not None:
            ts.append(Tensor(f"chain{li}", prev_last, first,
                             g.tensors[0].bytes_ if g.tensors else 0.0))
        prev_last = ren[g.kernels[g.topo_order[-1]].name]
    return DataflowGraph(ks, ts, name)


def block_graph(cfg: ModelConfig, seq: int, batch: int) -> DataflowGraph:
    """One repeated block (cfg.block_size layers) as a dataflow graph."""
    s = _llm_shape(cfg, seq, batch)
    per_layer = []
    for i in range(cfg.block_size):
        moe = cfg.layer_is_moe(i)
        ls = dataclasses.replace(
            s, moe_experts=cfg.moe_experts if moe else 0,
            moe_top_k=cfg.moe_top_k if moe else 0,
            d_ff=cfg.d_ff if cfg.d_ff else 1)
        if cfg.layer_kind(i) == "ssm":
            g = mamba_layer_graph(ls, d_state=cfg.ssm_state,
                                  expand=cfg.ssm_expand)
            if cfg.d_ff:
                g = _concat([g, gpt_layer_graph(
                    dataclasses.replace(ls, n_layers=1))], f"ssm_ffn{i}")
        else:
            g = gpt_layer_graph(ls, cross_attention=cfg.layer_is_cross(i))
        per_layer.append(g)
    if len(per_layer) == 1:
        return per_layer[0]
    return _concat(per_layer, f"{cfg.name}_block")


def plan_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    system = v5e_system(multi_pod)
    n_chips = system.n_chips
    tp = 16
    dp = n_chips // tp

    if shape.phase == "train":
        micro = max(1, shape.global_batch // dp)
        work = TrainWorkload(
            name=f"{arch}_{shape_name}",
            layer_graph=block_graph(cfg, shape.seq_len, micro),
            n_layers=cfg.n_blocks,
            global_batch=shape.global_batch,
            microbatch=micro,
            pre_graph=embedding_graph(_llm_shape(cfg, shape.seq_len, micro)),
            post_graph=lm_head_graph(_llm_shape(cfg, shape.seq_len, micro)))
        cands = _subdivide_dims(system.topology, (tp, 1, dp), True)
        tp_topo, pp_topo, dp_topo = cands[0]
        plan = evaluate_plan(work, system, tp, 1, dp, tp_topo, pp_topo,
                             dp_topo)
        if plan is None:
            return {"error": "no feasible plan"}
        return {
            "tp": plan.tp, "pp": plan.pp, "dp": plan.dp,
            "iter_time_s": plan.iter_time,
            "utilization": plan.utilization,
            "breakdown": plan.breakdown,
            "per_chip_mem_gb": plan.per_chip_mem_bytes / 1e9,
            "feasible": plan.feasible,
        }

    # serving cells: intra-chip view of one layer/block on the TP group
    s = _llm_shape(cfg, shape.seq_len,
                   max(1, shape.global_batch // dp))
    if shape.phase == "prefill":
        graph = block_graph(cfg, shape.seq_len,
                            max(1, shape.global_batch // dp))
    else:
        graph = decode_layer_graph(s, kv_len=shape.seq_len)
    cands = _subdivide_dims(system.topology, (tp, 1, dp), True)
    tp_topo = cands[0][0]
    sol = solve_sharding(graph, tp, tp_topo, list(range(len(tp_topo.dims))))
    sharded = DataflowGraph(
        [dataclasses.replace(k, flops=k.flops * sch.flop_factor,
                             weight_bytes=k.weight_bytes * sch.weight_factor)
         for k, sch in zip(graph.kernels, sol.schemes)],
        [dataclasses.replace(t, bytes_=t.bytes_ / tp) for t in graph.tensors],
        graph.name + f"_tp{tp}")
    res = optimize_intra_chip(sharded, system.chip, system.memory,
                              h_n=sol.h_n, h_m=sol.h_m, mode="dataflow")
    kbk = optimize_intra_chip(sharded, system.chip, system.memory,
                              h_n=sol.h_n, h_m=sol.h_m, mode="kbk")
    reps = cfg.n_blocks if shape.phase == "prefill" else cfg.n_layers
    return {
        "tp": tp, "dp": dp,
        "per_block_time_s": res.total_time,
        "total_time_s": res.total_time * reps,
        "bottleneck": res.bottleneck,
        "n_partitions": res.n_partitions,
        "kbk_time_s": kbk.total_time * reps,
        "dataflow_speedup_vs_kbk": kbk.total_time / max(res.total_time, 1e-12),
    }

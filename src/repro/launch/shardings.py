"""Sharding assembly: params / optimizer / batch / cache PartitionSpecs for
a given (config, mesh). Used by the dry-run, the trainer and the server.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import init_params, init_cache
from ..models.config import ModelConfig
from ..parallel.logical import AxisRules, param_spec
from .mesh import batch_axes, make_axis_rules, safe_spec


def _path_strs(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return out


def _fsdp_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO/FSDP: additionally shard each parameter over the data axes.

    Picks the largest dim not already sharded whose size divides the data
    axis product; params/optimizer state then live fully sharded and GSPMD
    inserts the all-gather (fwd/bwd) + reduce-scatter (grads) — the ZeRO-3
    schedule. Leaves too small to split stay replicated.
    """
    ba = batch_axes(mesh)
    axes = ba if isinstance(ba, tuple) else (ba,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fsdp_size = 1
    for a in axes:
        fsdp_size *= sizes[a]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    cands = [i for i, (dim, ax) in enumerate(zip(shape, entries))
             if ax is None and dim % fsdp_size == 0]
    if not cands:
        return spec
    best = max(cands, key=lambda i: shape[i])
    entries[best] = ba
    return P(*entries)


def param_shardings(cfg: ModelConfig, mesh: Mesh, fsdp: bool = False):
    """Pytree of NamedShardings matching init_params(cfg, key)."""
    rules = make_axis_rules(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))

    def one(path, leaf):
        parts = _path_strs(path)
        if parts[0] in ("stack", "enc_stack"):
            parts = ["stack"] + parts[1:]
        spec = param_spec(parts, leaf.shape, rules, sizes)
        if fsdp:
            spec = _fsdp_spec(spec, leaf.shape, mesh)
        return NamedSharding(mesh, safe_spec(leaf.shape, spec, mesh))

    return jax.tree_util.tree_map_with_path(one, shapes)


def opt_shardings(cfg: ModelConfig, mesh: Mesh, fsdp: bool = False,
                  master: bool = False):
    ps = param_shardings(cfg, mesh, fsdp=fsdp)
    out = {"m": ps, "v": ps,
           "step": NamedSharding(mesh, P())}
    if master:   # mixed precision: fp32 master weights, sharded like params
        out["master"] = ps
    return out


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch: int):
    ba = batch_axes(mesh)
    bspec = safe_spec((batch, 1), P(ba, None), mesh)
    out = {"tokens": NamedSharding(mesh, bspec),
           "labels": NamedSharding(mesh, bspec)}
    if cfg.family == "vlm":
        out["image_embeds"] = NamedSharding(
            mesh, safe_spec((batch, 1, 1), P(ba, None, None), mesh))
    if cfg.is_enc_dec:
        out["audio_frames"] = NamedSharding(
            mesh, safe_spec((batch, 1, 1), P(ba, None, None), mesh))
    return out


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    """Decode cache: KV sequence dim on 'model' (context parallelism),
    batch on the data axes; SSM state heads on 'model'."""
    ba = batch_axes(mesh)
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    specs = {}
    if "k" in shapes:
        s = shapes["k"].shape
        spec = safe_spec(s, P(None, None, ba, "model", None, None), mesh)
        specs["k"] = NamedSharding(mesh, spec)
        specs["v"] = NamedSharding(mesh, spec)
    if "ssm" in shapes:
        s = shapes["ssm"].shape
        specs["ssm"] = NamedSharding(
            mesh, safe_spec(s, P(None, None, ba, "model", None, None), mesh))
        c = shapes["conv"].shape
        specs["conv"] = NamedSharding(
            mesh, safe_spec(c, P(None, None, ba, None, "model"), mesh))
    return specs


def decode_input_shardings(cfg: ModelConfig, mesh: Mesh, batch: int,
                           max_len: int):
    ba = batch_axes(mesh)
    out = {
        "token": NamedSharding(mesh, safe_spec((batch,), P(ba), mesh)),
        "pos": NamedSharding(mesh, P()),
        "cache": cache_shardings(cfg, mesh, batch, max_len),
    }
    if cfg.family == "vlm" or cfg.is_enc_dec:
        out["memory"] = NamedSharding(
            mesh, safe_spec((batch, 1, 1), P(ba, None, None), mesh))
    return out

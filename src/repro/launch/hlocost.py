"""Trip-count-aware HLO cost model for the dry-run roofline.

``jax.stages.Compiled.cost_analysis()`` counts each ``while`` body exactly
once, regardless of trip count — for scan-over-layers models (all of ours)
this undercounts FLOPs/bytes by ~n_layers× and makes the roofline terms
meaningless. XLA's optimized HLO, however, annotates every while op with
``backend_config={"known_trip_count":{"n":...}}``; this module re-derives the
HloCostAnalysis quantities from the HLO text with loop bodies scaled by their
trip counts (nesting multiplies):

  flops             dot: 2·|out|·|contracted|; elementwise: |out|; reduce: |in|
  bytes accessed    Σ per instruction (operands + output), fusion computations
                    priced at their boundary only (interior tensors are fused)
  collective bytes  Σ operand payloads of all-gather / all-reduce /
                    reduce-scatter / all-to-all / collective-permute, by kind,
                    each scaled by its enclosing trip multiplier

The parser handles the post-SPMD per-device module (``compiled.as_text()``),
so totals are per-device; callers multiply by the chip count where the
roofline formula wants global quantities.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Iterable

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
    r"pred|c64|c128)\[([0-9,]*)\]")

# ops that cost ~1 flop per output element
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "cbrt", "tanh", "negate", "abs", "cosine", "sine", "tan",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "atan2", "remainder", "logistic", "erf", "clamp", "select",
    "shift-left", "shift-right-arithmetic", "shift-right-logical",
    "and", "or", "xor", "not", "is-finite",
}

# ops that move bytes but do no arithmetic
_ZERO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "add-dependency", "partition-id",
               "replica-id", "opt-barrier"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    shape: str                     # raw result type text (may be a tuple)
    operands: list[str]
    attrs: str                     # raw attribute tail

    def result_bytes(self) -> float:
        return sum(_type_bytes(m) for m in _SHAPE_RE.finditer(self.shape))

    def result_elems(self) -> float:
        tot = 0
        for m in _SHAPE_RE.finditer(self.shape):
            tot += _shape_elems(m)
        return tot


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list[Instruction]
    by_name: dict


@dataclasses.dataclass
class CollectiveRecord:
    kind: str
    bytes_: float                 # payload per execution (operand bytes)
    trips: float                  # total executions (loop multiplier)
    shape: str
    participants: int = 1         # group size S (from replica_groups)

    @property
    def total_bytes(self) -> float:
        return self.bytes_ * self.trips

    @property
    def link_bytes(self) -> float:
        """Per-chip link traffic of one execution (ring-algorithm terms):
        AG: s·(S-1)   RS/A2A: n·(S-1)/S   AR: 2n·(S-1)/S   permute: n."""
        s = max(self.participants, 1)
        if s == 1:
            return 0.0 if self.kind != "collective-permute" else self.bytes_
        if self.kind == "all-gather":
            return self.bytes_ * (s - 1)
        if self.kind == "all-reduce":
            return 2.0 * self.bytes_ * (s - 1) / s
        if self.kind in ("reduce-scatter", "all-to-all"):
            return self.bytes_ * (s - 1) / s
        return self.bytes_  # collective-permute

    @property
    def total_link_bytes(self) -> float:
        return self.link_bytes * self.trips


@dataclasses.dataclass
class CostSummary:
    flops: float
    bytes_accessed: float
    collective_bytes: dict               # kind -> total bytes
    collectives: list[CollectiveRecord]  # the collective schedule
    while_trip_counts: list[int]
    bytes_by_opcode: dict = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def link_traffic_bytes(self) -> float:
        """Per-chip link traffic across all collectives (ring terms)."""
        return sum(r.total_link_bytes for r in self.collectives)

    def row(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": dict(self.collective_bytes),
            "n_collectives": len(self.collectives),
            "while_trip_counts": self.while_trip_counts,
        }


def _type_bytes(m: re.Match) -> float:
    return _shape_elems(m) * _DTYPE_BYTES[m.group(1)]


def _shape_elems(m: re.Match) -> float:
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


# ------------------------------ parsing --------------------------------------
_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INST = re.compile(
    r"^(ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\]{},\s/*]+?)\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> tuple[dict, str]:
    """Parse optimized HLO text → ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line[0].isspace():
            m = _COMP_HEAD.match(line)
            if m:
                cur = Computation(m.group(2), [], {})
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            elif line.startswith("}"):
                cur = None
            continue
        s = line.strip()
        if s.startswith("}") or cur is None:
            if s.startswith("}"):
                cur = None
            continue
        mi = _INST.match(s)
        if not mi:
            continue
        _, name, rtype, opcode, rest = mi.groups()
        # split operand list from the attribute tail at the closing paren
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = rest[:end]
        attrs = rest[end + 1:]
        operands = _OPERAND.findall(operand_str)
        inst = Instruction(name, opcode, rtype.strip(), operands, attrs)
        cur.instructions.append(inst)
        cur.by_name[name] = inst
    if entry is None:  # fall back: the computation named like the module
        entry = next(iter(comps))
    return comps, entry


# ------------------------------ cost model -----------------------------------
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# fusions annotate their body as ``calls=%comp``; plain call instructions
# use ``to_apply=%comp`` on some XLA versions (e.g. the CPU backend's
# parallel-task wrapper in the jax 0.4.x line) and ``calls=`` on others —
# resolve both, or every call body prices as zero
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _participants(attrs: str) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:  # [num_groups, group_size]<=[...]
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        return m.group(1).count(",") + 1
    return 1


def _operand_shape(comp: Computation, comps: dict, name: str) -> str:
    inst = comp.by_name.get(name)
    return inst.shape if inst is not None else ""


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    out_elems = inst.result_elems()
    mc = _LHS_CONTRACT_RE.search(inst.attrs)
    contracted = 1
    if mc and inst.operands:
        lhs_shape = _shape_dims(_operand_shape(comp, {}, inst.operands[0]))
        dims = [int(x) for x in mc.group(1).split(",") if x]
        for d in dims:
            if d < len(lhs_shape):
                contracted *= lhs_shape[d]
    return 2.0 * out_elems * contracted


def _conv_flops(inst: Instruction, comp: Computation) -> float:
    # flops ≈ 2 · |out| · (kernel elems / out_channels)
    out = inst.result_elems()
    if len(inst.operands) >= 2:
        k = _shape_dims(_operand_shape(comp, {}, inst.operands[1]))
        if k:
            import numpy as _np
            kelems = 1
            for d in k:
                kelems *= d
            return 2.0 * out * kelems / max(k[-1], 1)
    return 2.0 * out


class HloCost:
    """Walks the computation graph, scaling loop bodies by trip count."""

    def __init__(self, comps: dict, entry: str):
        self.comps = comps
        self.entry = entry
        self._memo: dict[str, tuple[float, float]] = {}
        self.collectives: list[CollectiveRecord] = []
        self.trip_counts: list[int] = []
        self.bytes_by_opcode: dict[str, float] = {}

    def run(self) -> CostSummary:
        flops, bytes_ = self._comp_cost(self.entry, 1.0, count_bytes=True)
        coll: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
        for rec in self.collectives:
            coll[rec.kind] += rec.total_link_bytes  # per-chip link traffic
        top = dict(sorted(self.bytes_by_opcode.items(),
                          key=lambda kv: -kv[1])[:12])
        return CostSummary(flops, bytes_, coll, self.collectives,
                           self.trip_counts, top)

    # NOTE: collectives are recorded with their multiplier at visit time, so
    # computations reached under different multipliers must not be memoized
    # when they contain collectives / nested loops. We memoize only pure
    # fusion computations (no calls, no collectives).
    def _comp_cost(self, name: str, mult: float,
                   count_bytes: bool) -> tuple[float, float]:
        comp = self.comps.get(name)
        if comp is None:
            return 0.0, 0.0
        flops = 0.0
        bytes_ = 0.0
        for inst in comp.instructions:
            f, b = self._inst_cost(inst, comp, mult, count_bytes)
            flops += f
            bytes_ += b
        return flops, bytes_

    def _pure_key(self, name: str) -> str | None:
        comp = self.comps.get(name)
        if comp is None:
            return None
        for inst in comp.instructions:
            if inst.opcode in ("while", "fusion", "call", "conditional",
                               "custom-call") or inst.opcode.startswith(
                                   tuple(_COLLECTIVES)):
                return None
        return name

    def _inst_cost(self, inst: Instruction, comp: Computation, mult: float,
                   count_bytes: bool) -> tuple[float, float]:
        op = inst.opcode
        # ---- control flow ----------------------------------------------------
        if op == "while":
            trip = 1
            mt = _TRIP_RE.search(inst.attrs)
            if mt:
                trip = int(mt.group(1))
            self.trip_counts.append(trip)
            body = _BODY_RE.search(inst.attrs)
            cond = _COND_RE.search(inst.attrs)
            f = b = 0.0
            if body:
                fb, bb = self._comp_cost(body.group(1), mult * trip,
                                         count_bytes)
                f, b = f + fb, b + bb
            if cond:
                fc, bc = self._comp_cost(cond.group(1), mult * trip,
                                         count_bytes)
                f, b = f + fc, b + bc
            return f, b
        if op == "fusion":
            called = _CALLS_RE.search(inst.attrs)
            f = 0.0
            if called:
                key = self._pure_key(called.group(1))
                if key is not None and key in self._memo:
                    f = self._memo[key][0] * mult
                else:
                    f, _ = self._comp_cost(called.group(1), mult,
                                           count_bytes=False)
                    if key is not None and mult:
                        self._memo[key] = (f / mult, 0.0)
            b = self._io_bytes(inst, comp) * mult if count_bytes else 0.0
            if b:
                self.bytes_by_opcode["fusion"] = (
                    self.bytes_by_opcode.get("fusion", 0.0) + b)
            return f, b
        if op in ("call", "async-start"):
            called = _CALLS_RE.search(inst.attrs)
            if called:
                return self._comp_cost(called.group(1), mult, count_bytes)
            return 0.0, 0.0
        if op == "conditional":
            mb = _BRANCHES_RE.search(inst.attrs)
            if mb:
                branches = _OPERAND.findall(mb.group(1))
                costs = [self._comp_cost(br, mult, count_bytes)
                         for br in branches]
                if costs:  # charge the most expensive branch
                    return max(costs, key=lambda fb: fb[0] + fb[1])
            return 0.0, 0.0
        # ---- collectives -----------------------------------------------------
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            payload = sum(
                sum(_type_bytes(m) for m in _SHAPE_RE.finditer(
                    _operand_shape(comp, self.comps, o)))
                for o in inst.operands)
            if payload == 0.0:  # operands unresolvable → use result size
                payload = inst.result_bytes()
            self.collectives.append(
                CollectiveRecord(base, payload, mult, inst.shape,
                                 _participants(inst.attrs)))
            b = payload * mult if count_bytes else 0.0
            return 0.0, b
        if op.endswith("-done"):
            return 0.0, 0.0
        # ---- arithmetic ------------------------------------------------------
        flops = 0.0
        if op == "dot":
            flops = _dot_flops(inst, comp)
        elif op == "convolution":
            flops = _conv_flops(inst, comp)
        elif op in _ELEMENTWISE:
            flops = inst.result_elems()
        elif op in ("reduce", "reduce-window"):
            if inst.operands:
                in_b = _operand_shape(comp, self.comps, inst.operands[0])
                flops = sum(_shape_elems(m) for m in _SHAPE_RE.finditer(in_b))
            else:
                flops = inst.result_elems()
        elif op == "convert":
            flops = 0.0
        # ---- bytes -----------------------------------------------------------
        b = 0.0
        if count_bytes and op not in _ZERO_BYTES:
            b = self._io_bytes(inst, comp) * mult
            self.bytes_by_opcode[op] = self.bytes_by_opcode.get(op, 0.0) + b
        return flops * mult, b

    def _io_bytes(self, inst: Instruction, comp: Computation) -> float:
        total = inst.result_bytes()
        for o in inst.operands:
            s = _operand_shape(comp, self.comps, o)
            total += sum(_type_bytes(m) for m in _SHAPE_RE.finditer(s))
        return total


def analyze(hlo_text: str) -> CostSummary:
    comps, entry = parse_hlo(hlo_text)
    return HloCost(comps, entry).run()


def collective_schedule(summary: CostSummary, top: int = 20) -> list[dict]:
    """The dominant collectives, largest total payload first."""
    recs = sorted(summary.collectives, key=lambda r: -r.total_link_bytes)[:top]
    return [{"kind": r.kind, "payload_bytes": r.bytes_, "trips": r.trips,
             "participants": r.participants,
             "total_link_bytes": r.total_link_bytes, "shape": r.shape[:80]}
            for r in recs]

"""Production training launcher: mesh + shardings + trainer on real devices.

Builds a (data, model) mesh from whatever devices exist (host CPUs, one TPU
pod slice, ...), applies the production sharding rules (optionally FSDP),
and runs the synthetic-data training loop with checkpointing.

  # 8 host devices:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python -m repro.launch.train --arch olmo_1b --smoke --steps 20 \\
      --mesh 2x4 --fsdp
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..models import init_params, param_count, synth_batch
from ..parallel.logical import use_rules
from ..train.checkpoint import CheckpointManager
from ..train.fault import StragglerMonitor
from ..train.optimizer import AdamWConfig, adamw_init
from ..train.trainer import make_train_step
from .mesh import make_axis_rules
from .shardings import batch_shardings, opt_shardings, param_shardings


def parse_mesh(spec: str | None):
    devs = jax.devices()
    if spec:
        shape = tuple(int(x) for x in spec.split("x"))
    else:
        shape = (max(1, len(devs) // 2), min(2, len(devs)))
    axes = ("data", "model") if len(shape) == 2 else ("pod", "data", "model")
    return jax.make_mesh(shape, axes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo_1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--mesh", help="e.g. 2x4 (data x model)")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--bf16-params", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    import dataclasses
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.bf16_params:
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    mesh = parse_mesh(args.mesh)
    rules = make_axis_rules(mesh, cfg)
    print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"on {len(jax.devices())} {jax.devices()[0].platform} devices")

    with mesh, use_rules(rules, mesh):
        ps = param_shardings(cfg, mesh, fsdp=args.fsdp)
        os_ = opt_shardings(cfg, mesh, fsdp=args.fsdp,
                            master=args.bf16_params)
        bs = batch_shardings(cfg, mesh, args.batch)
        params = jax.device_put(init_params(cfg, jax.random.PRNGKey(0)), ps)
        opt = jax.device_put(
            adamw_init(params, master=args.bf16_params), os_)
        print(f"{cfg.name}: {param_count(params):,} params "
              f"({'fsdp' if args.fsdp else 'replicated over data'})")
        step_fn = jax.jit(
            make_train_step(cfg, AdamWConfig(lr=3e-4), accum=args.accum),
            in_shardings=(ps, os_, bs), out_shardings=(ps, os_, None),
            donate_argnums=(0, 1))

        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_every else None
        mon = StragglerMonitor()
        for step in range(args.steps):
            batch = synth_batch(cfg, args.batch, args.seq, seed=step)
            batch = {k: jax.device_put(v, bs[k]) for k, v in batch.items()}
            t0 = time.perf_counter()
            params, opt, metrics = step_fn(params, opt, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            mon.record(step, dt)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {loss:7.4f}  "
                      f"{dt * 1e3:8.1f} ms")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save_async(step + 1, {"params": params, "opt": opt})
        if mgr:
            mgr.wait()
    print("done")


if __name__ == "__main__":
    main()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces (no device allocation — ShapeDtypeStruct inputs):
  · compiled.memory_analysis()  — proves the cell fits per-chip HBM
  · compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  · collective payload bytes    — parsed from the post-SPMD HLO text
  · the three roofline terms against TPU v5e constants
  · DFModel's own prediction for the cell (core/ planner) side by side

Results are cached as JSON under results/dryrun/ so the sweep is resumable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo_1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]

The production meshes need 512 placeholder CPU devices; ``main`` installs
the XLA flag before jax initializes its backend. In-process callers of
:func:`run_dryrun` / :func:`run_cell` must do the same *before anything
touches jax* (the flag is inert once the backend exists) — importing this
module deliberately no longer mutates the environment, so importers that
never lower a production mesh keep their real device count.
"""
from __future__ import annotations

import argparse
import os
import gzip
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, SHAPES, cells, get_config
from ..core.roofline import RooflineTerms
from ..models import (decode_step, init_params, input_specs, loss_fn)
from ..models.config import ModelConfig
from ..parallel.logical import use_rules
from ..train.optimizer import AdamWConfig, adamw_update
from . import hlocost
from .mesh import make_axis_rules, make_production_mesh, batch_axes
from .shardings import (batch_shardings, decode_input_shardings,
                        opt_shardings, param_shardings)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ------------------------------ step builders --------------------------------
def build_train_step(cfg: ModelConfig, accum: int = 1):
    """The production train step (trainer.make_train_step): AdamW + global-
    norm clipping, with optional gradient accumulation over ``accum``
    microbatches (bounds live activation memory — §Perf knob)."""
    from ..train.trainer import make_train_step
    return make_train_step(cfg, AdamWConfig(), accum=accum)


def build_prefill_step(cfg: ModelConfig):
    from ..models import forward
    from ..models.transformer import _memory_from_batch

    def prefill_step(params, batch):
        memory = _memory_from_batch(cfg, params, batch)
        return forward(cfg, params, batch["tokens"], memory=memory)

    return prefill_step


def build_serve_step(cfg: ModelConfig):
    def serve_step(params, inputs):
        return decode_step(cfg, params, inputs["cache"], inputs["token"],
                           inputs["pos"], memory=inputs.get("memory"))

    return serve_step


# ------------------------------ one cell -------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False, extra_tag: str = "",
             planner: bool = True,
             fsdp: bool = False, remat: str | None = None,
             moe_dispatch: str | None = None, accum: int = 1,
             kv_replicate: bool = False, bf16_params: bool = False,
             bf16_ar: bool = False, cp_decode: bool = False) -> dict:
    """Lower + compile one (arch × shape × mesh) cell.

    ``fsdp`` / ``remat`` / ``moe_dispatch`` are the §Perf hillclimb knobs;
    when any is set the result is tagged separately so baseline (paper-
    faithful) and optimized artifacts coexist under results/dryrun/.
    """
    import dataclasses as _dc
    opt_tag = ""
    if fsdp:
        opt_tag += "__fsdp"
    if remat:
        opt_tag += f"__remat-{remat}"
    if moe_dispatch:
        opt_tag += f"__moe-{moe_dispatch}"
    if accum > 1:
        opt_tag += f"__accum{accum}"
    if kv_replicate:
        opt_tag += "__kvrep"
    if bf16_params:
        opt_tag += "__bf16"
    if bf16_ar:
        opt_tag += "__bf16ar"
    if cp_decode:
        opt_tag += "__cpdec"
    tag = (f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
           f"{opt_tag}{extra_tag}")
    RESULTS.mkdir(parents=True, exist_ok=True)
    out_path = RESULTS / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if remat:
        cfg = _dc.replace(cfg, remat=remat)
    if moe_dispatch:
        cfg = _dc.replace(cfg, moe_dispatch=moe_dispatch)
    if bf16_params:
        cfg = _dc.replace(cfg, param_dtype="bfloat16")
    if bf16_ar:
        cfg = _dc.replace(cfg, matmul_out="bf16")
    if cp_decode:
        cfg = _dc.replace(cfg, decode_attn="context_parallel")
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_axis_rules(mesh, cfg, kv_replicate=kv_replicate)
    specs = input_specs(cfg, shape)
    t0 = time.time()

    with mesh, use_rules(rules, mesh):
        pshard = param_shardings(cfg, mesh, fsdp=fsdp)
        if shape.phase == "train":
            from ..train.optimizer import adamw_init
            fn = build_train_step(cfg, accum=accum)
            oshard = opt_shardings(cfg, mesh, fsdp=fsdp, master=bf16_params)
            bshard = batch_shardings(cfg, mesh, shape.global_batch)
            pspec = jax.eval_shape(
                lambda k: init_params(cfg, k),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            ospec = jax.eval_shape(
                lambda pp: adamw_init(pp, master=bf16_params), pspec)
            jitted = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                             out_shardings=(pshard, oshard, None))
            lowered = jitted.lower(pspec, ospec, specs)
            tokens = shape.global_batch * shape.seq_len
            model_flops = cfg.model_flops(tokens, training=True)
        elif shape.phase == "prefill":
            fn = build_prefill_step(cfg)
            bshard = batch_shardings(cfg, mesh, shape.global_batch)
            bshard.pop("labels", None)
            pspec = jax.eval_shape(
                lambda k: init_params(cfg, k),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            jitted = jax.jit(fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(pspec, specs)
            tokens = shape.global_batch * shape.seq_len
            model_flops = cfg.model_flops(tokens, training=False)
        else:  # decode
            fn = build_serve_step(cfg)
            ishard = decode_input_shardings(cfg, mesh, shape.global_batch,
                                            shape.seq_len)
            pspec = jax.eval_shape(
                lambda k: init_params(cfg, k),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            jitted = jax.jit(fn, in_shardings=(pshard, ishard),
                             out_shardings=(None, ishard["cache"]))
            lowered = jitted.lower(pspec, specs)
            tokens = shape.global_batch  # one token per request
            model_flops = cfg.model_flops(tokens, training=False,
                                          decode_kv=shape.seq_len)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    raw_cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    summary = hlocost.analyze(hlo)          # trip-count-aware (see hlocost.py)
    n_chips = mesh.devices.size

    # hlocost quantities are per-device (post-SPMD module); the roofline
    # terms want global sums, which RooflineTerms divides back per chip.
    terms = RooflineTerms(
        name=tag, chips=n_chips,
        hlo_flops=summary.flops * n_chips,
        hlo_bytes=summary.bytes_accessed * n_chips,
        collective_bytes=summary.link_traffic_bytes * n_chips,
        model_flops=model_flops)

    hlo_path = RESULTS / f"{tag}.hlo.gz"
    with gzip.open(hlo_path, "wt", compresslevel=6) as fh:
        fh.write(hlo)

    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "n_chips": n_chips,
        "opts": {"fsdp": fsdp, "remat": remat or cfg.remat,
                 "moe_dispatch": moe_dispatch or cfg.moe_dispatch,
                 "accum": accum, "kv_replicate": kv_replicate,
                 "bf16_params": bf16_params, "bf16_ar": bf16_ar,
                 "cp_decode": cp_decode},
        "lower_s": t_lower, "compile_s": t_compile,
        "memory": {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        },
        "cost_per_device": summary.row(),
        "bytes_by_opcode": summary.bytes_by_opcode,
        "cost_raw_xla": {k: float(v) for k, v in raw_cost.items()
                         if isinstance(v, (int, float))
                         and not k.endswith("}")},
        "collective_schedule": hlocost.collective_schedule(summary),
        "roofline": terms.row(),
        "hlo": hlo_path.name,
    }
    if planner:
        try:
            from .plan import plan_cell
            result["dfmodel_plan"] = plan_cell(arch, shape_name, multi_pod)
        except Exception as e:  # planner issues must not fail the dry-run
            result["dfmodel_plan"] = {"error": str(e)}

    out_path.write_text(json.dumps(result, indent=1))
    return result


def run_dryrun(targets: list[tuple[str, str]], pods: list[bool] | None = None,
               force: bool = False, **cell_opts) -> list[dict]:
    """Importable sweep body: run every (arch, shape) target across the
    requested pod settings, collecting per-cell results (a failing cell
    records its error and the sweep continues — same contract as the CLI).
    ``cell_opts`` forward to :func:`run_cell` (fsdp/remat/accum/...)."""
    results: list[dict] = []
    for mp in (pods if pods is not None else [False]):
        for arch, shp in targets:
            try:
                r = run_cell(arch, shp, mp, force=force, **cell_opts)
                rf = r["roofline"]
                print(f"[OK ] {arch:22s} {shp:12s} pod{2 if mp else 1} "
                      f"compile={r['compile_s']:.1f}s "
                      f"dom={rf['dominant']:10s} "
                      f"tbound={max(rf['t_compute_s'], rf['t_memory_s'], rf['t_collective_s']):.4f}s "
                      f"frac={rf['roofline_fraction']:.3f}", flush=True)
            except Exception as e:
                print(f"[FAIL] {arch} {shp} pod{2 if mp else 1}: {e}",
                      flush=True)
                r = {"arch": arch, "shape": shp, "multi_pod": mp,
                     "error": str(e)}
            results.append(r)
    return results


def main():
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    # §Perf hillclimb knobs (baseline when unset)
    ap.add_argument("--fsdp", action="store_true",
                    help="ZeRO-3: shard params+optimizer over the data axes")
    ap.add_argument("--remat", choices=["full", "dots", "none"])
    ap.add_argument("--moe-dispatch", choices=["gspmd", "shard_map"])
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--kv-replicate", action="store_true",
                    help="replicate GQA K/V instead of sharding on 'model'")
    ap.add_argument("--bf16-params", action="store_true",
                    help="mixed precision: bf16 live params + fp32 master")
    ap.add_argument("--bf16-ar", action="store_true",
                    help="emit bf16 dots so row-parallel partial-sum "
                         "all-reduces move bf16 instead of f32")
    ap.add_argument("--cp-decode", action="store_true",
                    help="context-parallel decode attention (shard_map "
                         "LSE-combine over the seq-sharded KV cache)")
    args = ap.parse_args()

    pods = []
    if args.single_pod or not args.multi_pod:
        pods.append(False)
    if args.multi_pod or args.all:
        pods.append(True)

    targets = []
    if args.all:
        for arch in ARCH_IDS:
            if arch == "gpt3_175b":
                continue  # paper workload exercised via benchmarks
            for shp in cells(arch):
                targets.append((arch, shp))
    else:
        targets.append((args.arch, args.shape))

    run_dryrun(targets, pods=pods, force=args.force,
               fsdp=args.fsdp, remat=args.remat,
               moe_dispatch=args.moe_dispatch, accum=args.accum,
               kv_replicate=args.kv_replicate, bf16_params=args.bf16_params,
               bf16_ar=args.bf16_ar, cp_decode=args.cp_decode)


if __name__ == "__main__":
    main()

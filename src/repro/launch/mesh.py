"""Production mesh + axis rules.

Single pod: 16×16 = 256 chips, axes (data, model) — data parallelism over
rows, tensor/expert/context parallelism over columns (the TPU v5e 2-D torus
maps one torus dim per mesh axis, matching DFModel's one-network-dim-per-
strategy assumption). Multi-pod: 2×16×16, the 'pod' axis is outer data
parallelism over the inter-pod DCN/ICI links.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..parallel.logical import AxisRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def make_axis_rules(mesh: Mesh, cfg=None,
                    kv_replicate: bool = False) -> AxisRules:
    """Logical→mesh axis mapping for the production layout.

    'seq' is unsharded for training (per-device full sequences);
    'kv_seq' (decode KV cache) shards on 'model' — context parallelism.

    ``kv_replicate`` (§Perf knob): when GQA kv heads do not divide the
    model axis (e.g. kv=8 on a 16-wide axis), GSPMD's 8→16 resharding
    forces involuntary full rematerializations of K/V; replicating the
    (small) K/V projections instead removes those copies.
    """
    ba = batch_axes(mesh)
    kv = "model"
    if kv_replicate:
        kv = None
    return AxisRules({
        "batch": ba,
        "seq": None,
        "heads": "model",
        "kv_heads": kv,
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "kv_seq": "model",
    })


def safe_spec(shape: tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim (e.g. batch=1
    long-context cells can't shard batch) — GSPMD would reject them."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axis_size(ax):
        if ax is None:
            return 1
        if isinstance(ax, (tuple, list)):
            out = 1
            for a in ax:
                out *= sizes[a]
            return out
        return sizes[ax]

    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        fixed.append(ax if ax is not None and dim % axis_size(ax) == 0
                     else None)
    return P(*fixed)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)

"""Serving launcher: mesh + cache shardings + batched generation.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python -m repro.launch.serve --arch olmo_1b --smoke --requests 4

:func:`run_serve` is the importable body — validation and tests call it
in-process (no argv, no subprocess); ``main`` is the argparse shell.
"""
from __future__ import annotations

import argparse

import jax

from ..configs import ARCH_IDS, get_config
from ..models import init_params
from ..models.config import ModelConfig
from ..parallel.logical import use_rules
from ..serve.engine import GenerationResult, ServeEngine
from .mesh import make_axis_rules
from .train import parse_mesh


def run_serve(cfg: ModelConfig, requests: int = 4, prompt_len: int = 16,
              tokens: int = 16, mesh_spec: str | None = None,
              seed: int = 0) -> GenerationResult:
    """Initialize params on the mesh, serve one batched generation, return
    its timings. Deterministic in ``seed`` (params and prompts)."""
    mesh = parse_mesh(mesh_spec)
    rules = make_axis_rules(mesh, cfg)
    with mesh, use_rules(rules, mesh):
        params = init_params(cfg, jax.random.PRNGKey(seed))
        engine = ServeEngine(cfg, params, max_batch=requests,
                             max_len=prompt_len + tokens + 1)
        prompts = jax.random.randint(
            jax.random.PRNGKey(seed + 1), (requests, prompt_len),
            0, cfg.vocab)
        res = engine.generate(prompts, n_tokens=tokens)
    print(f"{cfg.name} on mesh "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")
    print(f"TTFT {res.ttft * 1e3:.1f} ms  TPOT {res.tpot * 1e3:.2f} ms "
          f" throughput {res.tokens_per_s:.1f} tok/s")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--mesh")
    args = ap.parse_args()
    run_serve(get_config(args.arch, smoke=args.smoke),
              requests=args.requests, prompt_len=args.prompt_len,
              tokens=args.tokens, mesh_spec=args.mesh)


if __name__ == "__main__":
    main()

"""Public jit'd wrapper for the SSD chunk-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import ssd_chunk_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
              dA: jax.Array, chunk: int = 128,
              interpret: bool | None = None):
    """x: (BH, S, P); dt/dA: (BH, S); B/C: (BH, S, N) -> (y, h_final)."""
    if interpret is None:
        interpret = not _on_tpu()
    return ssd_chunk_fwd(x, dt, B, C, dA, chunk=chunk, interpret=interpret)

"""Mamba2 SSD intra-chunk Pallas TPU kernel.

The SSD dual form makes the intra-chunk work three MXU matmuls
(C Bᵀ, scores·X, C·h_in) plus elementwise decay — a natural fused dataflow
partition: scores, L, and the chunk state live in VMEM only.

Grid = (B·H, n_chunks); the chunk dimension is sequential ("arbitrary") and
carries the running inter-chunk state h in VMEM scratch, so the *entire*
recurrence runs inside one kernel launch: HBM sees x/dt/B/C tiles in and
y tiles out — no materialized (Q,Q) scores, no per-chunk state round-trips.

TPU adaptation notes: chunk size Q and state N are 128-multiples (MXU edge);
dt/dA are precomputed outside (cheap, elementwise) to keep the kernel purely
matmul+exp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, da_ref, y_ref, hout_ref,
                h_ref, *, num_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)         # (Q,)
    B = b_ref[0].astype(jnp.float32)           # (Q, N)
    C = c_ref[0].astype(jnp.float32)           # (Q, N)
    dA = da_ref[0].astype(jnp.float32)         # (Q,)

    qn = x.shape[0]
    csum = jnp.cumsum(dA)                      # (Q,)
    diff = csum[:, None] - csum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (qn, qn), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (qn, qn), 1)
    L = jnp.where(row >= col, jnp.exp(diff), 0.0)

    xdt = x * dt[:, None]
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    h = h_ref[...]                             # (N, P)
    y = y + jax.lax.dot_general(C, h, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        * jnp.exp(csum)[:, None]

    decay_out = jnp.exp(csum[-1] - csum)[:, None]
    h_new = h * jnp.exp(csum[-1]) + jax.lax.dot_general(
        B, xdt * decay_out, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    h_ref[...] = h_new
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == num_chunks - 1)
    def _final():
        hout_ref[0] = h_new.astype(hout_ref.dtype)


def ssd_chunk_fwd(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
                  dA: jax.Array, chunk: int = 128,
                  interpret: bool = False):
    """x: (BH, S, P); dt/dA: (BH, S); B/C: (BH, S, N).

    Returns (y (BH, S, P), h_final (BH, N, P)). The inter-chunk recurrence is
    carried *inside* the kernel across the sequential chunk grid dimension.
    """
    bh, s, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, num_chunks=nc)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda ih, ic: (ih, ic, 0)),
            pl.BlockSpec((1, chunk), lambda ih, ic: (ih, ic)),
            pl.BlockSpec((1, chunk, n), lambda ih, ic: (ih, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ih, ic: (ih, ic, 0)),
            pl.BlockSpec((1, chunk), lambda ih, ic: (ih, ic)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda ih, ic: (ih, ic, 0)),
            pl.BlockSpec((1, n, p), lambda ih, ic: (ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, B, C, dA)
    return y, h_final

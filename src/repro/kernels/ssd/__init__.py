from .ops import ssd_chunk

__all__ = ["ssd_chunk"]

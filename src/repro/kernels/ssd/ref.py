"""Pure-jnp oracle for the SSD intra-chunk kernel (Mamba2, arXiv:2405.21060).

Per (batch·head, chunk): given the chunk's inputs it computes
  y_intra = (C Bᵀ ⊙ L) (x·dt)      — the "attention-like" dual form
  state   = Σ_j exp(csum_Q - csum_j) B_j (x_j dt_j)   — the chunk state
  y_inter = C h_in · exp(csum)     — contribution of the incoming state
where L[i,j] = exp(csum_i − csum_j) for i ≥ j. The inter-chunk recurrence
over chunk states stays outside the kernel (tiny, sequential).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
                  dA: jax.Array, h_in: jax.Array):
    """Single chunk, single (batch·head):
    x (Q, P), dt (Q,), B (Q, N), C (Q, N), dA (Q,), h_in (N, P).
    Returns (y (Q, P), h_out (N, P)). fp32 math.
    """
    q = x.shape[0]
    csum = jnp.cumsum(dA)                                  # (Q,)
    diff = csum[:, None] - csum[None, :]                   # (Q, Q)
    L = jnp.where(jnp.tril(jnp.ones((q, q), bool)), jnp.exp(diff), 0.0)
    xdt = x * dt[:, None]                                  # (Q, P)
    scores = (C @ B.T) * L                                 # (Q, Q)
    y_intra = scores @ xdt
    decay_in = jnp.exp(csum)[:, None]                      # (Q, 1)
    y_inter = (C @ h_in) * decay_in
    decay_out = jnp.exp(csum[-1] - csum)[:, None]          # (Q, 1)
    h_out = h_in * jnp.exp(csum[-1]) + B.T @ (xdt * decay_out)
    return y_intra + y_inter, h_out

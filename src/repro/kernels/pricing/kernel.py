"""Pallas-lowered batched design-point pricing (candidate-axis tiling).

The DSE price phase (:mod:`repro.core.pricing`) is pure elementwise
arithmetic over stacked float64 plan columns — exactly the shape Pallas
tiles well: every column is blocked along the batch (candidate) axis and
one grid step prices one tile of candidates entirely on-core. The kernel
body *is* the shared pricing formula (``pricing._price`` — or any other
elementwise column formula, e.g. ``pricing._roofline``), so the operation
order that makes the batched backends bit-identical to the scalar
reference is preserved by construction.

Bit-exactness story
-------------------
The kernel runs in **interpret mode on CPU under ``enable_x64``** — every
op is the IEEE-double XLA op the certified ``jax`` backend uses. Two
compiled-path hazards remain, each pinned off separately:

* LLVM contracts ``a*b + c`` into an FMA inside a fused computation (the
  documented last-ulp drift of the ``jit=True`` pricing path; an
  ``optimization_barrier`` alone does *not* stop it). The call is
  AOT-compiled with ``xla_backend_optimization_level=0`` — a
  *per-computation* compiler option, no process-global ``XLA_FLAGS``.
* XLA's HLO algebraic simplifier re-rounds multi-op patterns, e.g.
  ``div(div(a, b), c) → div(a, b·c)`` in the derate term. Inside the
  kernel every value is a ``_StrictArray`` whose op results each pass
  through an ``optimization_barrier``, so no cross-op pattern is visible
  to the simplifier.

With both in place the kernel is bit-identical to numpy and hence to
``price_plan_scalar``. ``ops.certify()`` proves this row by row, and
``tools/check_pricing_backend.py`` (``DFMODEL_PRICING_BACKEND=pallas``)
enforces it end-to-end against the serial sweep in CI.

A compiled TPU lowering would drop to float32 tiles of (8, 128) and leave
the certified envelope — a deliberate non-goal here; interpret mode is
the contract, the lowering is the scaling path for 10⁵-candidate grids.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.experimental import pallas as pl

#: Candidates per grid step. Large enough to amortize interpret-mode
#: dispatch, small enough that a tile of ~26 float64 columns stays resident.
DEFAULT_TILE = 512


def _unwrap(x):
    return x.a if isinstance(x, _StrictArray) else x


def _wrap(x):
    return _StrictArray(jax.lax.optimization_barrier(x))


class _StrictArray:
    """An array whose every op result passes through an optimization
    barrier, so XLA's algebraic simplifier cannot pattern-match across ops
    (e.g. the div(div(a, b), c) → div(a, b·c) rewrite that would re-round
    the derate term). Together with the level-0 backend compile this pins
    the kernel to the exact per-op IEEE sequence of the numpy reference."""

    __slots__ = ("a",)

    def __init__(self, a):
        self.a = a

    def astype(self, dtype):
        return _StrictArray(self.a.astype(dtype))


def _defop(name):
    def op(self, other):
        return _wrap(getattr(self.a, name)(_unwrap(other)))
    return op


for _name in ("__add__", "__radd__", "__sub__", "__rsub__", "__mul__",
              "__rmul__", "__truediv__", "__rtruediv__", "__pow__",
              "__lt__", "__le__", "__gt__", "__ge__", "__eq__", "__ne__",
              "__and__", "__rand__", "__or__", "__ror__"):
    setattr(_StrictArray, _name, _defop(_name))


class _StrictNamespace:
    """The ``xp`` shim handed to the formula inside the kernel: jnp ops on
    unwrapped values, every result barrier-wrapped."""

    @staticmethod
    def maximum(a, b):
        return _wrap(jnp.maximum(_unwrap(a), _unwrap(b)))

    @staticmethod
    def minimum(a, b):
        return _wrap(jnp.minimum(_unwrap(a), _unwrap(b)))

    @staticmethod
    def where(cond, x, y):
        return _wrap(jnp.where(_unwrap(cond), _unwrap(x), _unwrap(y)))


def _columns_kernel(*refs, formula, in_names, out_names):
    """One grid step: price a tile of candidates with the shared formula."""
    cols = {name: _StrictArray(ref[...])
            for name, ref in zip(in_names, refs)}
    out = formula(_StrictNamespace, cols)
    for name, ref in zip(out_names, refs[len(in_names):]):
        # bool outputs (the capacity check) travel as 0.0/1.0 float64; the
        # ops wrapper restores the dtype outside the kernel
        ref[...] = _unwrap(out[name]).astype(ref.dtype)


@functools.lru_cache(maxsize=64)
def _compiled_call(formula, in_names: tuple[str, ...],
                   out_names: tuple[str, ...], padded: int, tile: int,
                   interpret: bool):
    """AOT-compile the tiled pallas call at optimization level 0 (see the
    module docstring — this is what pins FMA contraction off). Cached per
    (formula, column layout, padded length) so warm sweeps reuse the
    executable."""
    kernel = functools.partial(_columns_kernel, formula=formula,
                               in_names=in_names, out_names=out_names)
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    call = jax.jit(pl.pallas_call(
        kernel,
        grid=(padded // tile,),
        in_specs=[spec] * len(in_names),
        out_specs=[spec] * len(out_names),
        out_shape=[jax.ShapeDtypeStruct((padded,), jnp.float64)
                   for _ in out_names],
        interpret=interpret,
    ))
    args = [jax.ShapeDtypeStruct((padded,), jnp.float64) for _ in in_names]
    return call.lower(*args).compile(
        compiler_options={"xla_backend_optimization_level": "0"})


def run_columns(formula, cols, out_names, tile: int = DEFAULT_TILE,
                interpret: bool = True) -> dict[str, np.ndarray]:
    """Run an elementwise column formula as a Pallas kernel.

    ``formula(xp, cols) -> dict`` must be pure elementwise arithmetic over
    the batch axis (the :mod:`repro.core.pricing` contract). Columns are
    padded to a tile multiple with neutral 1.0 rows (every pricing
    denominator stays non-zero) and the pad is sliced off the outputs.
    The tile is *not* shrunk to the batch: every batch ≤ ``tile`` pads to
    one tile and shares a single cached executable instead of triggering
    a per-length recompile.
    """
    in_names = tuple(cols)
    n = len(next(iter(cols.values())))
    padded = math.ceil(n / tile) * tile
    with enable_x64():
        compiled = _compiled_call(formula, in_names, tuple(out_names),
                                  padded, tile, interpret)
        ins = [jnp.asarray(np.pad(np.asarray(cols[name], dtype=np.float64),
                                  (0, padded - n), constant_values=1.0))
               for name in in_names]
        outs = compiled(*ins)
        return {name: np.asarray(out)[:n]
                for name, out in zip(out_names, outs)}

"""Pallas-lowered batched design-point pricing (candidate-axis tiling).

The DSE price phase (:mod:`repro.core.pricing`) is pure elementwise
arithmetic over stacked float64 plan columns — exactly the shape Pallas
tiles well: every column is blocked along the batch (candidate) axis and
one grid step prices one tile of candidates entirely on-core. The kernel
body *is* the shared pricing formula (``pricing._price`` — or any other
elementwise column formula, e.g. ``pricing._roofline``), so the operation
order that makes the batched backends bit-identical to the scalar
reference is preserved by construction.

Bit-exactness story
-------------------
The kernel runs in **interpret mode on CPU under ``enable_x64``** — every
op is the IEEE-double XLA op the certified ``jax`` backend uses. Two
compiled-path hazards remain, each pinned off separately:

* LLVM contracts ``a*b + c`` into an FMA inside a fused computation (the
  documented last-ulp drift of the ``jit=True`` pricing path; an
  ``optimization_barrier`` alone does *not* stop it). The call is
  AOT-compiled with ``xla_backend_optimization_level=0`` — a
  *per-computation* compiler option, no process-global ``XLA_FLAGS``.
* XLA's HLO algebraic simplifier re-rounds multi-op patterns, e.g.
  ``div(div(a, b), c) → div(a, b·c)`` in the derate term. Inside the
  kernel every value is a ``_StrictArray`` whose op results each pass
  through an ``optimization_barrier``, so no cross-op pattern is visible
  to the simplifier.

With both in place the kernel is bit-identical to numpy and hence to
``price_plan_scalar``. ``ops.certify()`` proves this row by row, and
``tools/check_pricing_backend.py`` (``DFMODEL_PRICING_BACKEND=pallas``)
enforces it end-to-end against the serial sweep in CI.

Numerics contract (the compiled f32 lowering)
---------------------------------------------
The compiled path (``run_columns_f32`` / the ``pallas-compiled`` backend)
deliberately leaves the certified envelope: float32 tiles of
(8, 128) — the flat candidate axis reshaped into sublane × lane blocks —
with the ragged tail masked to zero through a shipped validity column
instead of neutral-row padding, and NO opt-level-0 / barrier pinning (the
whole point is letting the compiler fuse). Its outputs carry bounded
relative drift vs the f64 envelope instead of bit-identity, and every
consumer must route *decisions* through the drift-budget contract in
:mod:`repro.kernels.pricing.drift`: winners are selected by exactly
re-pricing (f64, numpy-reference arithmetic) every candidate whose f32
iter-time lands within the declared band of the f32 argmin — plus every
feasibility-ambiguous candidate at the capacity boundary — so compiled
winners are provably identical to the scalar reference, and any observed
drift beyond the declared band raises. ``drift.py`` holds the band
(``DFMODEL_DRIFT_BAND``, default ``1e-5``), the banded selection, and the
certification helpers; ``ops.certify_f32`` proves the drift bound on
seeded random vectors. On CPU (no compiled pallas lowering in this jax
version) the kernel runs as an interpret-mode f32 twin — same tiling,
same masking, same dtype — so the numerics are testable anywhere;
``interpret="auto"`` switches to real compilation on an accelerator.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.experimental import pallas as pl

#: Candidates per grid step. Large enough to amortize interpret-mode
#: dispatch, small enough that a tile of ~26 float64 columns stays resident.
DEFAULT_TILE = 512

#: The compiled f32 tile: 8 sublanes × 128 lanes — the native float32
#: vreg tiling — so one grid step prices 1024 candidates.
F32_SUBLANES = 8
F32_LANES = 128
F32_BLOCK = F32_SUBLANES * F32_LANES


def padded_length(n: int, tile: int = DEFAULT_TILE) -> int:
    """Pad ``n`` to a tile multiple, then bucket to a power-of-two tile
    count, so a sweep of ragged batch sizes shares O(log) cached
    executables instead of minting one per distinct padded length.
    Every batch ≤ ``tile`` lands in one tile; beyond that the pad never
    exceeds 2× the batch."""
    tiles = max(1, math.ceil(n / tile))
    return tile * (1 << (tiles - 1).bit_length())


def _unwrap(x):
    return x.a if isinstance(x, _StrictArray) else x


def _wrap(x):
    return _StrictArray(jax.lax.optimization_barrier(x))


class _StrictArray:
    """An array whose every op result passes through an optimization
    barrier, so XLA's algebraic simplifier cannot pattern-match across ops
    (e.g. the div(div(a, b), c) → div(a, b·c) rewrite that would re-round
    the derate term). Together with the level-0 backend compile this pins
    the kernel to the exact per-op IEEE sequence of the numpy reference."""

    __slots__ = ("a",)

    def __init__(self, a):
        self.a = a

    def astype(self, dtype):
        return _StrictArray(self.a.astype(dtype))


def _defop(name):
    def op(self, other):
        return _wrap(getattr(self.a, name)(_unwrap(other)))
    return op


for _name in ("__add__", "__radd__", "__sub__", "__rsub__", "__mul__",
              "__rmul__", "__truediv__", "__rtruediv__", "__pow__",
              "__lt__", "__le__", "__gt__", "__ge__", "__eq__", "__ne__",
              "__and__", "__rand__", "__or__", "__ror__"):
    setattr(_StrictArray, _name, _defop(_name))


class _StrictNamespace:
    """The ``xp`` shim handed to the formula inside the kernel: jnp ops on
    unwrapped values, every result barrier-wrapped."""

    @staticmethod
    def maximum(a, b):
        return _wrap(jnp.maximum(_unwrap(a), _unwrap(b)))

    @staticmethod
    def minimum(a, b):
        return _wrap(jnp.minimum(_unwrap(a), _unwrap(b)))

    @staticmethod
    def where(cond, x, y):
        return _wrap(jnp.where(_unwrap(cond), _unwrap(x), _unwrap(y)))


def _columns_kernel(*refs, formula, in_names, out_names):
    """One grid step: price a tile of candidates with the shared formula."""
    cols = {name: _StrictArray(ref[...])
            for name, ref in zip(in_names, refs)}
    out = formula(_StrictNamespace, cols)
    for name, ref in zip(out_names, refs[len(in_names):]):
        # bool outputs (the capacity check) travel as 0.0/1.0 float64; the
        # ops wrapper restores the dtype outside the kernel
        ref[...] = _unwrap(out[name]).astype(ref.dtype)


@functools.lru_cache(maxsize=64)
def _compiled_call(formula, in_names: tuple[str, ...],
                   out_names: tuple[str, ...], padded: int, tile: int,
                   interpret: bool):
    """AOT-compile the tiled pallas call at optimization level 0 (see the
    module docstring — this is what pins FMA contraction off). Cached per
    (formula, column layout, padded length) so warm sweeps reuse the
    executable."""
    kernel = functools.partial(_columns_kernel, formula=formula,
                               in_names=in_names, out_names=out_names)
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    call = jax.jit(pl.pallas_call(
        kernel,
        grid=(padded // tile,),
        in_specs=[spec] * len(in_names),
        out_specs=[spec] * len(out_names),
        out_shape=[jax.ShapeDtypeStruct((padded,), jnp.float64)
                   for _ in out_names],
        interpret=interpret,
    ))
    args = [jax.ShapeDtypeStruct((padded,), jnp.float64) for _ in in_names]
    return call.lower(*args).compile(
        compiler_options={"xla_backend_optimization_level": "0"})


def run_columns(formula, cols, out_names, tile: int = DEFAULT_TILE,
                interpret: bool = True) -> dict[str, np.ndarray]:
    """Run an elementwise column formula as a Pallas kernel.

    ``formula(xp, cols) -> dict`` must be pure elementwise arithmetic over
    the batch axis (the :mod:`repro.core.pricing` contract). Columns are
    padded to a tile multiple with neutral 1.0 rows (every pricing
    denominator stays non-zero) and the pad is sliced off the outputs.
    The tile is *not* shrunk to the batch, and padded lengths are
    bucketed to powers of two above the tile (:func:`padded_length`), so
    a sweep of ragged batch sizes shares O(log) cached executables
    instead of triggering a per-length recompile.
    """
    in_names = tuple(cols)
    n = len(next(iter(cols.values())))
    padded = padded_length(n, tile)
    with enable_x64():
        compiled = _compiled_call(formula, in_names, tuple(out_names),
                                  padded, tile, interpret)
        ins = [jnp.asarray(np.pad(np.asarray(cols[name], dtype=np.float64),
                                  (0, padded - n), constant_values=1.0))
               for name in in_names]
        outs = compiled(*ins)
        return {name: np.asarray(out)[:n]
                for name, out in zip(out_names, outs)}


# --- the compiled f32 lowering (see "Numerics contract" above) ---------------
def _columns_kernel_f32(*refs, formula, in_names, out_names):
    """One grid step: price an (8, 128) candidate tile in float32.

    ``refs[0]`` is the validity tile (1.0 on real candidate rows, 0.0 on
    the ragged tail) — masking through a shipped column instead of a
    baked-in batch length keeps the executable cacheable across every
    batch that buckets to the same padded length."""
    valid = refs[0][...] != 0.0
    cols = {name: ref[...] for name, ref in zip(in_names, refs[1:])}
    out = formula(jnp, cols)
    for name, ref in zip(out_names, refs[1 + len(in_names):]):
        # bool outputs (the capacity check) travel as 0.0/1.0 float32
        ref[...] = jnp.where(valid, out[name].astype(jnp.float32),
                             jnp.float32(0.0))


@functools.lru_cache(maxsize=64)
def _compiled_call_f32(formula, in_names: tuple[str, ...],
                       out_names: tuple[str, ...], padded: int,
                       interpret: bool):
    """The jitted 2D-tiled pallas call. No opt-level-0 pin, no barriers —
    the compiled path trades bit-identity for speed and settles its
    numerics through the drift-budget contract instead. Cached per
    (formula, column layout, bucketed padded length)."""
    kernel = functools.partial(_columns_kernel_f32, formula=formula,
                               in_names=in_names, out_names=out_names)
    rows = padded // F32_LANES
    spec = pl.BlockSpec((F32_SUBLANES, F32_LANES), lambda i: (i, 0))
    return jax.jit(pl.pallas_call(
        kernel,
        grid=(rows // F32_SUBLANES,),
        in_specs=[spec] * (1 + len(in_names)),
        out_specs=[spec] * len(out_names),
        out_shape=[jax.ShapeDtypeStruct((rows, F32_LANES), jnp.float32)
                   for _ in out_names],
        interpret=interpret,
    ))


def run_columns_f32(formula, cols, out_names,
                    interpret: bool | str = "auto"
                    ) -> dict[str, np.ndarray]:
    """Run an elementwise column formula as the compiled f32 kernel.

    The flat candidate axis is padded to a power-of-two multiple of
    :data:`F32_BLOCK` (:func:`padded_length`) and reshaped into
    (sublane-rows, 128) blocks; one grid step prices an (8, 128) tile.
    The ragged tail is masked to zero inside the kernel via a shipped
    validity column — no neutral-row padding, so pad rows cost nothing
    and garbage in them can never leak into real outputs.

    ``interpret="auto"`` runs the real (non-interpret) lowering on an
    accelerator backend and the interpret-mode f32 twin on CPU — same
    tiling, masking and dtype, so the drift contract is testable without
    hardware. Outputs are float32 (:mod:`.drift` re-prices decisions
    exactly; see the module docstring's numerics contract).
    """
    in_names = tuple(cols)
    n = len(next(iter(cols.values())))
    padded = padded_length(n, F32_BLOCK)
    rows = padded // F32_LANES
    if interpret == "auto":
        interpret = jax.default_backend() == "cpu"
    call = _compiled_call_f32(formula, in_names, tuple(out_names), padded,
                              bool(interpret))

    def block(col: np.ndarray) -> jnp.ndarray:
        flat = np.pad(np.asarray(col, dtype=np.float32), (0, padded - n))
        return jnp.asarray(flat.reshape(rows, F32_LANES))

    valid = np.zeros(padded, dtype=np.float32)
    valid[:n] = 1.0
    ins = [jnp.asarray(valid.reshape(rows, F32_LANES))]
    ins += [block(cols[name]) for name in in_names]
    outs = call(*ins)
    return {name: np.asarray(out).reshape(-1)[:n]
            for name, out in zip(out_names, outs)}

"""Pallas-lowered DSE pricing kernels (see ``kernel.py`` for the
bit-exactness story and the compiled-f32 numerics contract). Selected via
``pricing_backend="pallas"`` (interpret f64, bit-identical) or
``"pallas-compiled"`` (f32 (8, 128) tiles, settled through the
drift-budget contract in :mod:`.drift`) on
``repro.core.pricing.price_plans`` / ``DSEEngine``."""
from .drift import (DEFAULT_BAND, DRIFT_ENV_VAR, BandedSelection,
                    DriftBandError, banded_winner_rows, certify_banded_rows,
                    drift_band)
from .ops import certify, certify_f32, pallas_columns, pallas_columns_f32

__all__ = ["certify", "certify_f32", "pallas_columns", "pallas_columns_f32",
           "banded_winner_rows", "certify_banded_rows", "drift_band",
           "BandedSelection", "DriftBandError", "DEFAULT_BAND",
           "DRIFT_ENV_VAR"]

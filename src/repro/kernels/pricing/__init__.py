"""Pallas-lowered DSE pricing kernels: the backend matrix.

Three kernel variants sit behind ``repro.core.pricing.price_plans``
(see ``kernel.py`` for the bit-exactness story and the compiled-f32
numerics contract):

================  ======  ==============  =================================
backend name      dtype   execution       guarantee
================  ======  ==============  =================================
``pallas``        f64     interpret       bit-identical to the numpy
                          (any host)      scalar reference (``certify``)
``pallas-         f32     compiled        every output within the declared
compiled``                (accelerator)   relative drift band δ of the f64
                                          reference (``certify_f32``)
``pallas-         f32     interpret twin  same contract as compiled: same
compiled``                (CPU hosts)     (8, 128) tiling, masking, f32
                                          dtype — so CI certifies the
                                          identical numerics
================  ======  ==============  =================================

Selection: ``DSEEngine(pricing_backend=...)`` /
``price_plans(backend=...)`` take the backend *name*; ``"auto"``
resolves through ``repro.core.pricing.default_backend`` —
``$DFMODEL_PRICING_BACKEND`` if set (unknown spellings raise), else
``numpy``. ``pallas-compiled`` is the only backend in
``repro.core.pricing.APPROX_BACKENDS``: decisions made from its f32
columns must go through the drift-budget contract in :mod:`.drift`
(banded candidate selection via :func:`banded_winner_rows` — every row
within δ of the f32 argmin is re-priced exactly in f64 — then
:func:`certify_banded_rows`, which raises :class:`DriftBandError` if
observed drift ever exceeds δ). Final winner pricing resolves to
``repro.core.pricing.exact_backend``, so sweep outputs stay
bit-identical to the scalar reference end to end even though the mass
pricing ran in f32. The band δ is ``$DFMODEL_DRIFT_BAND`` (default
``1e-5``, ~25× above observed drift)."""
from .drift import (DEFAULT_BAND, DRIFT_ENV_VAR, BandedSelection,
                    DriftBandError, banded_winner_rows, certify_banded_rows,
                    drift_band)
from .ops import certify, certify_f32, pallas_columns, pallas_columns_f32

__all__ = ["certify", "certify_f32", "pallas_columns", "pallas_columns_f32",
           "banded_winner_rows", "certify_banded_rows", "drift_band",
           "BandedSelection", "DriftBandError", "DEFAULT_BAND",
           "DRIFT_ENV_VAR"]

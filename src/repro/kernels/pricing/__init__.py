"""Pallas-lowered DSE pricing kernel (see ``kernel.py`` for the
bit-exactness story). Selected via ``pricing_backend="pallas"`` on
``repro.core.pricing.price_plans`` / ``DSEEngine``."""
from .ops import certify, pallas_columns

__all__ = ["certify", "pallas_columns"]

"""Reference inputs + oracle for the pricing-kernel certification.

The oracle is :func:`repro.core.pricing.price_plan_scalar` — the literal
float64 transcription of the serial sweep's arithmetic; the kernel must
reproduce it bit for bit. The inputs come from
:func:`repro.core.pricing.random_plan_vectors`, the same seeded generator
the property tests in ``tests/test_pricing.py`` draw from, so every
backend is certified against one input distribution.
"""
from __future__ import annotations

from repro.core.pricing import price_plan_scalar, random_plan_vectors

__all__ = ["price_rows_scalar", "random_plan_vectors"]


def price_rows_scalar(vectors) -> list[dict[str, float]]:
    """Oracle rows for a batch (one scalar-reference dict per vector)."""
    return [price_plan_scalar(v) for v in vectors]

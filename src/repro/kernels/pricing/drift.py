"""The drift-budget contract: certified winner selection over f32 pricing.

The compiled f32 kernel (``kernel.run_columns_f32``) trades the repo's
bit-identity invariant for speed; this module is what buys the invariant
back at the only place it matters — *decisions*. The contract:

* A declared relative tolerance band δ (:func:`drift_band`, env
  ``DFMODEL_DRIFT_BAND``, default ``1e-5``): every f32 output is promised
  to sit within relative δ of its f64 reference value. The promise is
  enforced, not assumed — every candidate the banded selection re-prices
  yields an (f32, f64) pair, and observed drift beyond δ raises
  :class:`DriftBandError` (the certify-or-die house rule, extended to
  approximate arithmetic).
* :func:`banded_winner_rows` reproduces the serial reference scan —
  first row minimizing the lexicographic (infeasible, iter_time) key —
  *exactly*, using f32 columns for the cheap mass of candidates and
  exact f64 re-pricing (the numpy reference arithmetic, bit-identical to
  ``price_plan_scalar``) only where f32 cannot be trusted:

  1. **Feasibility is resolved exactly first.** With drift ≤ δ, a row
     with f32 mem ≤ cap·(1−δ) is certainly feasible and one with
     f32 mem > cap·(1+δ) certainly infeasible; everything between is
     re-priced exactly. This must happen *before* the pool minimum is
     taken — an optimistic superset minimum from a truly-infeasible row
     could shrink the re-pricing threshold below the true winner.
  2. **The band around the f32 argmin.** Over the now-exact feasible
     pool, every row whose f32 iter-time ≤ min·(1+δ)/(1−δ) provably
     contains every row that could be the f64 argmin (f64 ∈
     [f32/(1+δ), f32/(1−δ)] for every in-band row); those rows are
     re-priced exactly and the winner is the first-index f64 argmin —
     the same tie semantics as ``np.argmin`` / the serial scan.
  3. **Empty pool fallback.** When no row is feasible the reference
     semantics pick the global iter-time argmin; the same band logic
     runs over all rows.

``certify_banded_rows`` wraps the selection with the winner-identity
check against a reference row list — the engine's per-group
certification on the ``pallas-compiled`` backend.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Mapping, Sequence

import numpy as np

#: Environment override for the declared relative drift band.
DRIFT_ENV_VAR = "DFMODEL_DRIFT_BAND"

#: Default relative tolerance band δ. The pricing formula's observed f32
#: drift on the seeded certification distribution is ≲ 4e-7 (a handful of
#: ulps); 1e-5 leaves ~25× headroom while keeping the re-priced band a
#: sliver of the candidate mass.
DEFAULT_BAND = 1e-5


class DriftBandError(RuntimeError):
    """Observed f32 drift exceeded the declared band — the compiled
    backend broke its numerics contract and no selection it contributed
    to can be trusted."""


def drift_band() -> float:
    """The declared relative drift band: ``$DFMODEL_DRIFT_BAND`` if set
    (validated — unknown spellings raise, same contract as
    ``DFMODEL_PRICING_BACKEND``), else :data:`DEFAULT_BAND`."""
    env = os.environ.get(DRIFT_ENV_VAR, "").strip()
    if not env:
        return DEFAULT_BAND
    try:
        band = float(env)
    except ValueError:
        raise ValueError(
            f"invalid {DRIFT_ENV_VAR} value {env!r}; expected a float "
            f"relative tolerance, e.g. '1e-5'") from None
    if not (0.0 < band < 0.5) or not math.isfinite(band):
        raise ValueError(
            f"{DRIFT_ENV_VAR} must lie in (0, 0.5), got {band!r}")
    return band


@dataclasses.dataclass
class BandedSelection:
    """One banded selection over f32-priced candidates.

    ``rows`` index the priced arrays (local indexing — remap through a
    survivor map yourself when the arrays cover pruned rows);
    ``winner_iter``/``winner_mem`` are the winners' EXACT f64 values
    (every winner is by construction in the re-priced set), so
    downstream feasibility flags never touch f32."""

    rows: list[int]
    winner_iter: list[float]
    winner_mem: list[float]
    repriced: np.ndarray          # unique row indices exactly re-priced
    stats: dict                   # band / rows / caps / repriced /
                                  # ambiguous_mem / band_hits /
                                  # fallback_caps / max_iter_drift /
                                  # max_mem_drift


def _exact_iter_mem(cols: Mapping[str, np.ndarray], rows: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Exact f64 (iter_time, per_chip_mem_bytes) for a row subset — the
    numpy reference arithmetic (``pricing._selection``, whose two columns
    are copied op-for-op from ``_price`` and certified bit-identical to
    the scalar reference)."""
    from repro.core.pricing import _selection

    sub = {k: np.asarray(c, dtype=np.float64)[rows]
           for k, c in cols.items()}
    out = _selection(np, sub)
    return (np.asarray(out["iter_time"], dtype=np.float64),
            np.asarray(out["per_chip_mem_bytes"], dtype=np.float64))


def banded_winner_rows(cols: Mapping[str, np.ndarray],
                       f32: Mapping[str, np.ndarray],
                       capacities: Sequence[float],
                       band: float | None = None) -> BandedSelection:
    """The drift-banded batched argmin: per capacity, the row the f64
    serial scan would pick, computed from f32 columns + exact re-pricing
    of the ambiguous slivers (see the module docstring for the
    soundness argument).

    ``cols`` are the candidates' INPUT columns (``PlanMatrix.cols`` — the
    exact re-pricing source); ``f32`` the compiled kernel's priced
    columns (``iter_time``, ``per_chip_mem_bytes``). Raises
    :class:`DriftBandError` when any re-priced row's observed drift
    exceeds the declared band.
    """
    delta = drift_band() if band is None else float(band)
    it32 = np.asarray(f32["iter_time"], dtype=np.float64)
    mem32 = np.asarray(f32["per_chip_mem_bytes"], dtype=np.float64)
    n = int(it32.shape[0])
    stats = {"band": delta, "rows": n, "caps": len(capacities),
             "repriced": 0, "ambiguous_mem": 0, "band_hits": 0,
             "fallback_caps": 0, "max_iter_drift": 0.0,
             "max_mem_drift": 0.0}
    if n == 0:
        return BandedSelection([-1] * len(capacities), [], [],
                               np.empty(0, dtype=np.int64), stats)

    exact_it = np.empty(n, dtype=np.float64)
    exact_mem = np.empty(n, dtype=np.float64)
    have = np.zeros(n, dtype=bool)

    def ensure_exact(mask: np.ndarray) -> None:
        rows = np.flatnonzero(mask & ~have)
        if rows.size:
            exact_it[rows], exact_mem[rows] = _exact_iter_mem(cols, rows)
            have[rows] = True

    rows_out: list[int] = []
    winner_iter: list[float] = []
    winner_mem: list[float] = []
    for cap in capacities:
        cap = float(cap)
        definite = mem32 <= cap * (1.0 - delta)
        ambiguous = ~definite & (mem32 <= cap * (1.0 + delta))
        stats["ambiguous_mem"] += int(ambiguous.sum())
        # (1) exact feasibility first — the pool must be the true f64
        # feasible set before its minimum can bound the winner
        ensure_exact(ambiguous)
        pool = definite | (ambiguous & have & (exact_mem <= cap))
        if not pool.any():
            # reference semantics: no feasible row → global iter argmin
            pool = np.ones(n, dtype=bool)
            stats["fallback_caps"] += 1
        pool_rows = np.flatnonzero(pool)
        # (2) the band around the f32 pool minimum provably contains
        # every possible f64 argmin
        m32 = float(it32[pool_rows].min())
        thresh = m32 * (1.0 + delta) / (1.0 - delta)
        cand = pool_rows[it32[pool_rows] <= thresh]
        stats["band_hits"] += int(cand.size)
        cand_mask = np.zeros(n, dtype=bool)
        cand_mask[cand] = True
        ensure_exact(cand_mask)
        # (3) first-index f64 argmin — cand is ascending, np.argmin
        # returns the first minimum, so ties resolve exactly like the
        # serial scan
        w = int(cand[np.argmin(exact_it[cand])])
        rows_out.append(w)
        winner_iter.append(float(exact_it[w]))
        winner_mem.append(float(exact_mem[w]))

    repriced = np.flatnonzero(have)
    stats["repriced"] = int(repriced.size)
    if repriced.size:
        it_den = np.where(exact_it[repriced] != 0.0,
                          np.abs(exact_it[repriced]), 1.0)
        mem_den = np.where(exact_mem[repriced] != 0.0,
                           np.abs(exact_mem[repriced]), 1.0)
        it_drift = float(np.max(
            np.abs(it32[repriced] - exact_it[repriced]) / it_den))
        mem_drift = float(np.max(
            np.abs(mem32[repriced] - exact_mem[repriced]) / mem_den))
        stats["max_iter_drift"] = it_drift
        stats["max_mem_drift"] = mem_drift
        # in-production partial certification: every re-priced row is an
        # (f32, f64) pair — drift beyond the declared band voids every
        # bound above, so die rather than return a selection
        if it_drift > delta or mem_drift > delta:
            raise DriftBandError(
                f"compiled f32 pricing drifted beyond the declared band "
                f"{delta:g} (observed iter drift {it_drift:.3e}, mem "
                f"drift {mem_drift:.3e} over {repriced.size} re-priced "
                f"rows); the drift-budget contract is void")
    return BandedSelection(rows_out, winner_iter, winner_mem, repriced,
                           stats)


def certify_banded_rows(cols: Mapping[str, np.ndarray],
                        f32: Mapping[str, np.ndarray],
                        capacities: Sequence[float],
                        expected: Sequence[int], backend: str,
                        survivors: np.ndarray | Sequence[int] | None = None,
                        band: float | None = None) -> BandedSelection:
    """Certify-or-die for the compiled backend: the banded selection over
    ``f32`` must reproduce the reference winner rows exactly. ``expected``
    is in original-enumeration indexing; when the priced arrays cover
    only pruned ``survivors`` the banded rows are remapped through the
    survivor index map before comparing. Returns the selection (winners'
    exact values + drift stats) on success."""
    sel = banded_winner_rows(cols, f32, capacities, band=band)
    rows = sel.rows
    if survivors is not None:
        smap = np.asarray(survivors, dtype=np.int64)
        rows = [int(smap[r]) if r >= 0 else -1 for r in rows]
    if list(rows) != list(expected):
        raise RuntimeError(
            f"pricing backend {backend!r} selected different candidates "
            f"than the numpy reference under the drift-banded contract "
            f"({rows} != {list(expected)}); the band does not preserve "
            f"winners")
    return sel

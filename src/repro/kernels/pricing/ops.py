"""Public wrappers: the ``pallas`` pricing backends + certification harness.

``pallas_columns`` is what ``repro.core.pricing._dispatch`` calls when
``pricing_backend="pallas"`` is selected (interpret-mode f64, certified
bit-identical); ``pallas_columns_f32`` backs ``"pallas-compiled"`` (the
f32 (8, 128)-tiled lowering, settled through the drift contract in
:mod:`.drift`). ``certify`` / ``certify_f32`` are the gates
``tools/check_pricing_backend.py`` runs in CI.
"""
from __future__ import annotations

import functools

import numpy as np

from .kernel import DEFAULT_TILE, run_columns, run_columns_f32


@functools.lru_cache(maxsize=256)
def _probe_outputs(formula, in_names: tuple[str, ...]
                   ) -> tuple[tuple[str, ...], tuple[bool, ...]]:
    """Output names + bool-ness of a column formula, discovered once per
    (formula, column layout) on a neutral all-ones row — every pricing
    denominator stays non-zero, and dtype discovery (the bool capacity
    check) does not depend on the row's values. Memoised so repeated
    kernel dispatches skip the probe entirely."""
    sample = {name: np.ones(1, dtype=np.float64) for name in in_names}
    out = formula(np, sample)
    return (tuple(out),
            tuple(np.asarray(v).dtype == np.bool_ for v in out.values()))


def pallas_columns(formula, cols, tile: int = DEFAULT_TILE,
                   interpret: bool = True) -> dict[str, np.ndarray]:
    """Run an elementwise column formula on the Pallas backend.

    Output keys/dtypes come from the memoised one-row probe (floats
    travel through the kernel as float64; bool outputs — the capacity
    check — round-trip as 0.0/1.0 and are restored here).
    """
    names, is_bool = _probe_outputs(formula, tuple(cols))
    out = run_columns(formula, cols, list(names), tile=tile,
                      interpret=interpret)
    for key, flag in zip(names, is_bool):
        if flag:
            out[key] = out[key].astype(np.bool_)
    return out


def pallas_columns_f32(formula, cols,
                       interpret: bool | str = "auto"
                       ) -> dict[str, np.ndarray]:
    """Run an elementwise column formula on the compiled f32 backend.

    Float outputs are float32 with bounded relative drift vs the f64
    envelope — NOT bit-identical; consumers must route decisions through
    :mod:`.drift` (see the kernel docstring's numerics contract). Bool
    outputs are restored from their 0.0/1.0 encoding, but near-boundary
    bits (e.g. ``feasible`` within the band of the capacity) are only as
    trustworthy as f32 — the banded selection re-checks them exactly.
    """
    names, is_bool = _probe_outputs(formula, tuple(cols))
    out = run_columns_f32(formula, cols, list(names), interpret=interpret)
    for key, flag in zip(names, is_bool):
        if flag:
            out[key] = out[key].astype(np.bool_)
    return out


def certify(n: int = 512, seed: int = 0,
            tile: int = DEFAULT_TILE) -> dict:
    """Prove row-identity of the Pallas pricing kernel against the float64
    scalar reference on ``n`` seeded random plan vectors.

    Raises ``AssertionError`` naming the diverging columns if any output
    bit differs; returns a small report dict otherwise. This is the same
    bit-exactness story ``tools/check_pricing_backend.py`` enforces for
    the numpy and jax backends.
    """
    from repro.core.pricing import _price, stack_plans

    from .ref import price_rows_scalar, random_plan_vectors

    vectors = random_plan_vectors(n, seed)
    got = pallas_columns(_price, stack_plans(vectors), tile=tile)
    ref_rows = price_rows_scalar(vectors)
    mismatches: dict[str, int] = {}
    for key in ref_rows[0]:
        want = np.array([r[key] for r in ref_rows])
        col = got[key]
        if want.dtype == np.bool_:
            bad = int((col.astype(bool) != want).sum())
        else:
            bad = int((col.view(np.uint64) != want.view(np.uint64)).sum())
        if bad:
            mismatches[key] = bad
    if mismatches:
        raise AssertionError(
            f"pallas pricing kernel diverged from the scalar reference "
            f"(rows with differing bits per column): {mismatches}")
    return {"rows": n, "tile": tile, "outputs": len(ref_rows[0]),
            "bit_identical": True}


def certify_f32(n: int = 512, seed: int = 0,
                band: float | None = None) -> dict:
    """Prove the compiled f32 kernel honours the declared drift band
    against the float64 scalar reference on ``n`` seeded random plan
    vectors.

    Every float output's relative drift must stay within the band, and
    every ``feasible`` bit may disagree only where the exact memory
    footprint itself lies within the band of the capacity (the zone the
    banded selection re-prices exactly). Raises ``AssertionError``
    otherwise; returns a drift report dict on success.
    """
    from repro.core.pricing import _price, stack_plans

    from .drift import drift_band
    from .ref import price_rows_scalar, random_plan_vectors

    delta = drift_band() if band is None else float(band)
    vectors = random_plan_vectors(n, seed)
    cols = stack_plans(vectors)
    got = pallas_columns_f32(_price, cols)
    ref_rows = price_rows_scalar(vectors)
    drifts: dict[str, float] = {}
    violations: dict[str, float] = {}
    for key in ref_rows[0]:
        want = np.array([r[key] for r in ref_rows])
        if want.dtype == np.bool_:
            flipped = got[key].astype(bool) != want
            if flipped.any():
                mem = np.array([r["per_chip_mem_bytes"] for r in ref_rows])
                cap = cols["mem_capacity"]
                margin = np.abs(mem - cap) / np.abs(cap)
                worst = float(margin[flipped].max())
                drifts["feasible_margin"] = worst
                if worst > delta:
                    violations["feasible"] = worst
            continue
        g = got[key].astype(np.float64)
        denom = np.where(want != 0.0, np.abs(want), 1.0)
        worst = float(np.max(np.abs(g - want) / denom))
        drifts[key] = worst
        if worst > delta:
            violations[key] = worst
    if violations:
        raise AssertionError(
            f"compiled f32 pricing kernel exceeded the declared drift "
            f"band {delta:g} (worst relative drift per column): "
            f"{violations}")
    return {"rows": n, "band": delta,
            "max_drift": max(drifts.values(), default=0.0),
            "drift_by_column": drifts, "within_band": True}

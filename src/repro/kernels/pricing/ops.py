"""Public wrappers: the ``pallas`` pricing backend + certification harness.

``pallas_columns`` is what ``repro.core.pricing._dispatch`` calls when
``pricing_backend="pallas"`` is selected; ``certify`` is the bit-exactness
gate ``tools/check_pricing_backend.py`` runs in CI.
"""
from __future__ import annotations

import numpy as np

from .kernel import DEFAULT_TILE, run_columns


def pallas_columns(formula, cols, tile: int = DEFAULT_TILE,
                   interpret: bool = True) -> dict[str, np.ndarray]:
    """Run an elementwise column formula on the Pallas backend.

    Output keys/dtypes are discovered by probing the numpy formula on the
    first row (floats travel through the kernel as float64; bool outputs —
    the capacity check — round-trip as 0.0/1.0 and are restored here).
    """
    sample = {k: np.asarray(v, dtype=np.float64)[:1] for k, v in cols.items()}
    probe = formula(np, sample)
    out = run_columns(formula, cols, list(probe), tile=tile,
                      interpret=interpret)
    for key, val in probe.items():
        if np.asarray(val).dtype == np.bool_:
            out[key] = out[key].astype(np.bool_)
    return out


def certify(n: int = 512, seed: int = 0,
            tile: int = DEFAULT_TILE) -> dict:
    """Prove row-identity of the Pallas pricing kernel against the float64
    scalar reference on ``n`` seeded random plan vectors.

    Raises ``AssertionError`` naming the diverging columns if any output
    bit differs; returns a small report dict otherwise. This is the same
    bit-exactness story ``tools/check_pricing_backend.py`` enforces for
    the numpy and jax backends.
    """
    from repro.core.pricing import _price, stack_plans

    from .ref import price_rows_scalar, random_plan_vectors

    vectors = random_plan_vectors(n, seed)
    got = pallas_columns(_price, stack_plans(vectors), tile=tile)
    ref_rows = price_rows_scalar(vectors)
    mismatches: dict[str, int] = {}
    for key in ref_rows[0]:
        want = np.array([r[key] for r in ref_rows])
        col = got[key]
        if want.dtype == np.bool_:
            bad = int((col.astype(bool) != want).sum())
        else:
            bad = int((col.view(np.uint64) != want.view(np.uint64)).sum())
        if bad:
            mismatches[key] = bad
    if mismatches:
        raise AssertionError(
            f"pallas pricing kernel diverged from the scalar reference "
            f"(rows with differing bits per column): {mismatches}")
    return {"rows": n, "tile": tile, "outputs": len(ref_rows[0]),
            "bit_identical": True}

"""Pallas TPU kernels for the compute hot-spots DFModel's intra-chip pass
fuses (DESIGN.md §3): each fused dataflow partition that the optimizer emits
maps to one of these kernels on TPU.

  flash_attention  — the canonical fused {MHA1, Softmax, MHA2} partition
                     (paper Fig 2C / §VII.B partition 2). Causal, GQA.
  decode_attention — split-KV fused decode attention with exported LSE for
                     cross-chip context-parallel combine.
  ssd              — Mamba2 SSD intra-chunk kernel (scores·decay·values + chunk
                     state), the hot loop of the hybrid/ssm architectures.
  rmsnorm          — fused RMSNorm (+ optional residual add).
  pricing          — the DSE price phase tiled over the candidate axis
                     (interpret-mode float64, certified bit-identical to
                     the scalar reference; ``pricing_backend="pallas"``).

Every kernel ships ``ops.py`` (public wrapper; jit'd with interpret fallback
for the compute kernels, interpret-mode certified for pricing) and ``ref.py``
(the oracle its tests sweep against).
"""
from .flash_attention.ops import flash_attention
from .decode_attention.ops import decode_attention
from .ssd.ops import ssd_chunk
from .rmsnorm.ops import fused_rmsnorm
from .pricing.ops import pallas_columns

__all__ = ["flash_attention", "decode_attention", "ssd_chunk",
           "fused_rmsnorm", "pallas_columns"]

"""Public jit'd wrapper for the flash-attention kernel.

On CPU (this container) the kernel runs in ``interpret=True`` mode for
validation; on TPU it lowers to Mosaic. ``flash_attention`` is the drop-in
replacement for the {MHA1, Softmax, MHA2} fused partition.
"""
from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, Hkv, Sk, hd) -> (B, H, Sq, hd)."""
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)


# ---------------------- differentiable (training) path -----------------------
@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_train(q, k, v, causal: bool = True, block_q: int = 128,
                          block_k: int = 128, interpret: bool | None = None):
    """flash_attention with a Pallas backward (FlashAttention-2): the
    (Sq, Sk) probability matrix never exists in HBM in either direction."""
    from .backward import flash_attention_fwd_lse
    if interpret is None:
        interpret = not _on_tpu()
    o, _ = flash_attention_fwd_lse(q, k, v, causal=causal, block_q=block_q,
                                   block_k=block_k, interpret=interpret)
    return o


def _fa_train_fwd(q, k, v, causal, block_q, block_k, interpret):
    from .backward import flash_attention_fwd_lse
    if interpret is None:
        interpret = not _on_tpu()
    o, lse = flash_attention_fwd_lse(q, k, v, causal=causal,
                                     block_q=block_q, block_k=block_k,
                                     interpret=interpret)
    return o, (q, k, v, o, lse)


def _fa_train_bwd(causal, block_q, block_k, interpret, res, do):
    from .backward import flash_attention_bwd
    if interpret is None:
        interpret = not _on_tpu()
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                     block_q=block_q, block_k=block_k,
                                     interpret=interpret)
    return dq, dk, dv


flash_attention_train.defvjp(_fa_train_fwd, _fa_train_bwd)

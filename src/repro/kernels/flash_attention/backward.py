"""FlashAttention backward as Pallas TPU kernels (FlashAttention-2 §3.2).

The forward-with-LSE variant exports the per-row log-sum-exp so the backward
never rematerializes the (Sq, Sk) probability matrix in HBM: each tile
recomputes P = exp(QKᵀ·scale − LSE) in VMEM and contracts it immediately.

Two kernels, mirroring the FA-2 work partition:
  · dKV kernel — grid (B·H, kv-blocks, q-blocks): the q dimension is
    sequential and carries (dk, dv) accumulators in VMEM; one pass over Q/dO
    per kv tile. GQA reduction over the query heads of a kv head happens
    outside (a cheap reshape-sum).
  · dQ kernel — grid (B·H, q-blocks, kv-blocks): kv sequential, carries the
    dq accumulator.

D = rowsum(dO ∘ O) is precomputed outside (one elementwise pass).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params

NEG_INF = -1e30


# ------------------------- forward with LSE export ---------------------------
def _fa_fwd_lse_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                       acc_ref, m_ref, l_ref, *, causal, scale,
                       block_q, block_k, num_k):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    if causal:
        pl.when(ik * block_k <= iq * block_q + block_q - 1)(_body)
    else:
        _body()

    @pl.when(ik == num_k - 1)
    def _finalize():
        l = l_ref[...]
        lsafe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / lsafe[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(lsafe)


def flash_attention_fwd_lse(q, k, v, causal=True, block_q=128, block_k=128,
                            interpret=False):
    """Forward returning (o, lse) — the training-path variant."""
    b, h, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    n_rep = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    num_q, num_k = sq // block_q, sk // block_k
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(b * h, sq, hd)
    kr = k.reshape(b * hkv, sk, hd)
    vr = v.reshape(b * hkv, sk, hd)

    def q_map(ih, iq, ik):
        return (ih, iq, 0)

    def lse_map(ih, iq, ik):
        return (ih, iq)

    def kv_map(ih, iq, ik):
        ib, ihq = ih // h, ih % h
        return (ib * hkv + ihq // n_rep, ik, 0)

    kernel = functools.partial(_fa_fwd_lse_kernel, causal=causal,
                               scale=scale, block_q=block_q,
                               block_k=block_k, num_k=num_k)
    o, lse = pl.pallas_call(
        kernel,
        grid=(b * h, num_q, num_k),
        in_specs=[pl.BlockSpec((1, block_q, hd), q_map),
                  pl.BlockSpec((1, block_k, hd), kv_map),
                  pl.BlockSpec((1, block_k, hd), kv_map)],
        out_specs=[pl.BlockSpec((1, block_q, hd), q_map),
                   pl.BlockSpec((1, block_q), lse_map)],
        out_shape=[jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
                   jax.ShapeDtypeStruct((b * h, sq), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32),
                        pltpu.VMEM((block_q,), jnp.float32),
                        pltpu.VMEM((block_q,), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return o.reshape(b, h, sq, hd), lse.reshape(b, h, sq)


# ----------------------------- tile recompute --------------------------------
def _tile_p(q, k, lse, scale, causal, iq, ik, block_q, block_k):
    """P = exp(QKᵀ·scale − LSE) for one (q, k) tile, fp32."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
    return jnp.exp(s - lse[:, None])


# ------------------------------- dKV kernel ----------------------------------
def _fa_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dd_ref,
                       dk_ref, dv_ref, dk_acc, dv_acc, *, causal, scale,
                       block_q, block_k, num_q):
    ik = pl.program_id(1)   # kv block (parallel)
    iq = pl.program_id(2)   # q block (sequential, carries accumulators)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        dd = dd_ref[0]
        p = _tile_p(q, k, lse, scale, causal, iq, ik, block_q, block_k)
        # dV += Pᵀ dO
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        # dP = dO Vᵀ ; dS = P ∘ (dP − D)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dd[:, None])
        # dK += dSᵀ Q · scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if causal:
        # q blocks strictly above the diagonal see no kv of this tile
        pl.when(iq * block_q + block_q - 1 >= ik * block_k)(_body)
    else:
        _body()

    @pl.when(iq == num_q - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


# -------------------------------- dQ kernel ----------------------------------
def _fa_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dd_ref,
                      dq_ref, dq_acc, *, causal, scale, block_q, block_k,
                      num_k):
    iq = pl.program_id(1)   # q block (parallel)
    ik = pl.program_id(2)   # kv block (sequential, carries dq)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        dd = dd_ref[0]
        p = _tile_p(q, k, lse, scale, causal, iq, ik, block_q, block_k)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dd[:, None])
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if causal:
        pl.when(ik * block_k <= iq * block_q + block_q - 1)(_body)
    else:
        _body()

    @pl.when(ik == num_k - 1)
    def _finalize():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


# ------------------------------ host wrapper ---------------------------------
def flash_attention_bwd(q, k, v, o, lse, do, causal=True,
                        block_q=128, block_k=128, interpret=False):
    """Returns (dq, dk, dv). q/o/do: (B,H,Sq,hd); k,v: (B,Hkv,Sk,hd);
    lse: (B,H,Sq). GQA: per-query-head dk/dv are reduced over the group."""
    b, h, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    n_rep = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    num_q, num_k = sq // block_q, sk // block_k
    scale = 1.0 / math.sqrt(hd)

    # D = rowsum(dO ∘ O) — one cheap elementwise pass
    dd = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    qr = q.reshape(b * h, sq, hd)
    kr = k.reshape(b * hkv, sk, hd)
    vr = v.reshape(b * hkv, sk, hd)
    dor = do.reshape(b * h, sq, hd)
    lser = lse.reshape(b * h, sq)
    ddr = dd.reshape(b * h, sq)

    def kv_of(ih):
        ib, ihq = ih // h, ih % h
        return ib * hkv + ihq // n_rep

    # ---- dk / dv (per query head; reduce over the GQA group afterwards) ----
    dkv = pl.pallas_call(
        functools.partial(_fa_bwd_dkv_kernel, causal=causal, scale=scale,
                          block_q=block_q, block_k=block_k, num_q=num_q),
        grid=(b * h, num_k, num_q),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda ih, ik, iq: (ih, iq, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda ih, ik, iq: (kv_of(ih), ik, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda ih, ik, iq: (kv_of(ih), ik, 0)),
            pl.BlockSpec((1, block_q, hd), lambda ih, ik, iq: (ih, iq, 0)),
            pl.BlockSpec((1, block_q), lambda ih, ik, iq: (ih, iq)),
            pl.BlockSpec((1, block_q), lambda ih, ik, iq: (ih, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, hd), lambda ih, ik, iq: (ih, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda ih, ik, iq: (ih, ik, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b * h, sk, hd), jnp.float32),
                   jax.ShapeDtypeStruct((b * h, sk, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr, dor, lser, ddr)
    dk_h, dv_h = dkv
    dk = dk_h.reshape(b, hkv, n_rep, sk, hd).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(b, hkv, n_rep, sk, hd).sum(axis=2).astype(v.dtype)

    # ---- dq --------------------------------------------------------------
    dq = pl.pallas_call(
        functools.partial(_fa_bwd_dq_kernel, causal=causal, scale=scale,
                          block_q=block_q, block_k=block_k, num_k=num_k),
        grid=(b * h, num_q, num_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda ih, iq, ik: (ih, iq, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda ih, iq, ik: (kv_of(ih), ik, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda ih, iq, ik: (kv_of(ih), ik, 0)),
            pl.BlockSpec((1, block_q, hd), lambda ih, iq, ik: (ih, iq, 0)),
            pl.BlockSpec((1, block_q), lambda ih, iq, ik: (ih, iq)),
            pl.BlockSpec((1, block_q), lambda ih, iq, ik: (ih, iq)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda ih, iq, ik: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr, dor, lser, ddr)
    return dq.reshape(b, h, sq, hd), dk, dv

"""Pure-jnp oracle for the flash-attention kernel (GQA, causal/bidir)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, Hkv, Sk, hd). fp32 math."""
    b, h, sq, hd = q.shape
    hkv = k.shape[1]
    n_rep = h // hkv
    k = jnp.repeat(k, n_rep, axis=1)
    v = jnp.repeat(v, n_rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        qi = jnp.arange(sq)[:, None]
        ki = jnp.arange(k.shape[2])[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)

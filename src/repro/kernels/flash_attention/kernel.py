"""FlashAttention forward as a Pallas TPU kernel.

This is the executable form of the paper's fused intra-chip partition
{MHA1, Softmax, MHA2} (Fig 2C, §VII.B): scores and probabilities never leave
VMEM; only Q/K/V tiles stream from HBM and only O tiles stream back — exactly
the DRAM-traffic reduction DFModel's dataflow mode models.

TPU mapping notes (vs the CUDA original):
  · grid = (B·H, Sq/bq, Sk/bk); the innermost kv dimension is sequential
    ("arbitrary") and carries running (m, l, acc) in VMEM scratch — the MXU
    analogue of the SM-local accumulator.
  · block shapes are (bq, hd)/(bk, hd) with bq=bk=128·k to keep both matmuls
    MXU-aligned (hd is 64 or 128 for all assigned archs).
  · GQA is handled in the K/V index_map (query head → kv head), avoiding the
    materialized head-repeat a naive port would do.
  · causal masking skips fully-masked kv blocks via pl.when on the block
    index — the tile-level equivalent of FlashAttention's early exit.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref, *, causal: bool, scale: float,
               block_q: int, block_k: int, num_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _body():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    if causal:
        # skip kv blocks strictly above the diagonal (fully masked)
        pl.when(ik * block_k <= iq * block_q + block_q - 1)(_body)
    else:
        _body()

    @pl.when(ik == num_k - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)             # fully-masked rows → 0
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, block_q: int = 128,
                        block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, Hkv, Sk, hd) -> (B, H, Sq, hd)."""
    b, h, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0, "GQA requires H % Hkv == 0"
    n_rep = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    num_q, num_k = sq // block_q, sk // block_k
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(b * h, sq, hd)
    kr = k.reshape(b * hkv, sk, hd)
    vr = v.reshape(b * hkv, sk, hd)

    def q_map(ih, iq, ik):
        return (ih, iq, 0)

    def kv_map(ih, iq, ik):
        # query head ih = ib*h + ihq → kv row ib*hkv + ihq // n_rep
        ib = ih // h
        ihq = ih % h
        return (ib * hkv + ihq // n_rep, ik, 0)

    kernel = functools.partial(_fa_kernel, causal=causal, scale=scale,
                               block_q=block_q, block_k=block_k, num_k=num_k)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, num_q, num_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, hd)

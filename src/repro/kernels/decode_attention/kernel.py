"""Split-KV decode attention as a Pallas TPU kernel (FlashDecoding-style).

One query token attends over a long KV cache. The KV sequence is the
streaming dimension: grid = (B·Hkv, S/bk), running (m, l, acc) in VMEM.
All q heads in a GQA group are processed together as the matmul M dimension
(n_rep × hd GEMM rows) so the MXU sees a real matrix even at batch 1.

Exports the log-sum-exp alongside O so the context-parallel combine
(``repro.parallel.context``) can merge per-shard partial attentions across
chips — the distributed half of the paper's fused-decode partition.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import SMEM, tpu_compiler_params

NEG_INF = -1e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref, *, scale: float, block_k: int,
                num_k: int, n_rep: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[0]

    @pl.when(ik * block_k < kv_len)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (n_rep, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (n_rep, block_k), 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == num_k - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(safe)).astype(lse_ref.dtype)


def decode_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len, block_k: int = 256,
                         interpret: bool = False):
    """q: (B, H, hd); k/v: (B, Hkv, S, hd). Returns (o (B,H,hd), lse (B,H))."""
    b, h, hd = q.shape
    _, hkv, s, _ = k.shape
    n_rep = h // hkv
    block_k = min(block_k, s)
    assert s % block_k == 0
    num_k = s // block_k
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(b * hkv, n_rep, hd)
    kr = k.reshape(b * hkv, s, hd)
    vr = v.reshape(b * hkv, s, hd)
    len_arr = jnp.full((1,), kv_len, jnp.int32) if not hasattr(kv_len, "shape") \
        else kv_len.reshape(1).astype(jnp.int32)

    kernel = functools.partial(_dec_kernel, scale=scale, block_k=block_k,
                               num_k=num_k, n_rep=n_rep)
    o, lse = pl.pallas_call(
        kernel,
        grid=(b * hkv, num_k),
        in_specs=[
            pl.BlockSpec(memory_space=SMEM),
            pl.BlockSpec((1, n_rep, hd), lambda ih, ik: (ih, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda ih, ik: (ih, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda ih, ik: (ih, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_rep, hd), lambda ih, ik: (ih, 0, 0)),
            pl.BlockSpec((1, n_rep), lambda ih, ik: (ih, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, n_rep, hd), q.dtype),
            jax.ShapeDtypeStruct((b * hkv, n_rep), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_rep, hd), jnp.float32),
            pltpu.VMEM((n_rep,), jnp.float32),
            pltpu.VMEM((n_rep,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(len_arr, qr, kr, vr)
    return o.reshape(b, h, hd), lse.reshape(b, h)

"""Public jit'd wrapper for split-KV decode attention."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import decode_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_k", "return_lse", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len, block_k: int = 256, return_lse: bool = True,
                     interpret: bool | None = None):
    """q: (B, H, hd); k, v: (B, Hkv, S, hd). Returns o [, lse]."""
    if interpret is None:
        interpret = not _on_tpu()
    o, lse = decode_attention_fwd(q, k, v, kv_len, block_k=block_k,
                                  interpret=interpret)
    return (o, lse) if return_lse else o

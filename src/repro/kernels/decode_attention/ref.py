"""Pure-jnp oracle for split-KV decode attention (with LSE export)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array | int,
                         return_lse: bool = False):
    """q: (B, H, hd); k, v: (B, Hkv, S, hd); kv_len: valid prefix length.

    Returns o (B, H, hd) [, lse (B, H)] — the un-normalized form
    (o·softmax denominators applied), fp32 math.
    """
    b, h, hd = q.shape
    hkv, s = k.shape[1], k.shape[2]
    n_rep = h // hkv
    k = jnp.repeat(k, n_rep, axis=1)
    v = jnp.repeat(v, n_rep, axis=1)
    logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    mask = jnp.arange(s)[None, None, :] < kv_len
    logits = jnp.where(mask, logits, -jnp.inf)
    m = logits.max(axis=-1)
    p = jnp.exp(logits - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32)) / l[..., None]
    if return_lse:
        return o.astype(q.dtype), (m + jnp.log(l)).astype(jnp.float32)
    return o.astype(q.dtype)

"""Version-compatibility shims for the jax/pallas surface the kernels use.

The TPU pallas compiler-params dataclass was renamed across jax releases:
older releases (including the 0.4.x line this repo pins) expose
``pltpu.TPUCompilerParams``, newer ones renamed it to
``pltpu.CompilerParams`` (and deprecate the old name). Every kernel in
this package builds its ``compiler_params=`` through
:func:`tpu_compiler_params` so the same source runs on both sides of the
rename instead of dying with an import-time ``AttributeError``.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

#: The concrete params class of the installed jax: the new name wins when
#: both exist (on such versions the old name is a deprecation alias).
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams(**kwargs)`` on jax versions that have it,
    ``pltpu.TPUCompilerParams(**kwargs)`` otherwise. Keyword-only, so the
    call sites read identically to the modern API."""
    return _COMPILER_PARAMS_CLS(**kwargs)


#: The TPU memory-space enum went through the same rename
#: (``TPUMemorySpace`` → ``MemorySpace``); kernels import the members they
#: use from here instead of guessing the enum's current name.
_MEMORY_SPACE = getattr(pltpu, "MemorySpace", None) or getattr(
    pltpu, "TPUMemorySpace")

SMEM = _MEMORY_SPACE.SMEM
ANY = _MEMORY_SPACE.ANY

"""Pure-jnp oracle for fused residual-add + RMSNorm."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_rmsnorm_ref(x: jax.Array, w: jax.Array,
                      residual: jax.Array | None = None,
                      eps: float = 1e-6):
    """x: (T, d). Returns (normed, new_residual). fp32 accumulation."""
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype), xf.astype(x.dtype)

from .ops import fused_rmsnorm

__all__ = ["fused_rmsnorm"]

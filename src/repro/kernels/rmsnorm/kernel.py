"""Fused residual-add + RMSNorm Pallas TPU kernel.

The unfused sequence (add → square-mean → rsqrt-scale) is three HBM
round-trips of the (T, d) activation; fusing keeps the row tile in VMEM and
writes both the normed output and the updated residual once — the
row-granularity analogue of the paper's intra-chip tensor pinning.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params


def _rms_kernel(x_ref, w_ref, r_ref, y_ref, rout_ref, *, eps: float,
                has_residual: bool):
    x = x_ref[...].astype(jnp.float32)
    if has_residual:
        x = x + r_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)
    rout_ref[...] = x.astype(rout_ref.dtype)


def fused_rmsnorm_fwd(x: jax.Array, w: jax.Array,
                      residual: jax.Array | None = None,
                      eps: float = 1e-6, block_rows: int = 256,
                      interpret: bool = False):
    """x: (T, d) -> (normed (T, d), new_residual (T, d))."""
    t, d = x.shape
    block_rows = min(block_rows, t)
    assert t % block_rows == 0
    has_res = residual is not None
    res = residual if has_res else x   # dummy operand when unused

    kernel = functools.partial(_rms_kernel, eps=eps, has_residual=has_res)
    y, rout = pl.pallas_call(
        kernel,
        grid=(t // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), x.dtype),
            jax.ShapeDtypeStruct((t, d), x.dtype),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w, res)
    return y, rout

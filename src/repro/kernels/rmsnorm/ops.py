"""Public jit'd wrapper for fused residual-add + RMSNorm."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import fused_rmsnorm_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def fused_rmsnorm(x: jax.Array, w: jax.Array,
                  residual: jax.Array | None = None, eps: float = 1e-6,
                  block_rows: int = 256, interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return fused_rmsnorm_fwd(x, w, residual, eps=eps,
                             block_rows=block_rows, interpret=interpret)

"""The learned cost model: ridge regressor + calibrated keep-threshold.

Training flow (see ``docs/LEARNED.md``):

1. :func:`~repro.learned.features.harvest_rows` turns the memoised
   candidate sets into ``(feature row → selection iter_time)`` pairs,
   one per enumerated candidate, grouped by candidate set.
2. A :class:`~repro.search.surrogate.RidgeModel` (closed-form normal
   equations, standardized — the same machinery the search surrogate
   uses) regresses iteration time on the features.
3. **Quantile calibration** turns the score into a keep-threshold with a
   stated recall target: for every harvested group, find the fractional
   rank ``rank/n`` of the group's true argmin under the model's
   ordering (the fraction a keep-threshold must *exceed* to capture it,
   since the stage keeps ``ceil(keep_frac · n)`` rows); the calibrated
   ``keep_frac`` is just above the ``recall_target`` quantile of those
   fractions — the smallest top-fraction that would have contained the
   true winner in at least ``recall_target`` of the harvested groups.

The calibration is a *quality* statement, not a correctness one: the
rank stage always unions the model's top-k with the exact-bound
dominance staircase (:func:`repro.learned.rank.rank_keep`), so winners
are preserved even by a maximally wrong model — and certified at
runtime under the house certify-or-die rule.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import math
import os

import numpy as np

from ..search.surrogate import RidgeModel
from .features import FEATURE_NAMES, harvest_rows

#: On-disk format version: bumped on any schema change; ``load`` refuses
#: a mismatch rather than silently misinterpreting arrays.
FORMAT_VERSION = 1

#: Staleness guard: below this many harvested training rows (or fewer
#: than ``MIN_TRAIN_GROUPS`` candidate sets) the harvest cannot support
#: a trustworthy ranking and ``fit_ranker`` returns ``None`` — callers
#: degrade to rank-off.
MIN_TRAIN_ROWS = 64
MIN_TRAIN_GROUPS = 2

#: Default stated recall target of the calibrated keep-threshold.
DEFAULT_RECALL_TARGET = 0.95

#: Calibrated keep fractions are clipped here: never below 5% (a model
#: that aced the harvest must still keep a real top slice on unseen
#: groups), never above 1.0.
MIN_KEEP_FRAC = 0.05


@dataclasses.dataclass(frozen=True)
class LearnedModel:
    """A trained, calibrated candidate ranker (frozen; picklable — it
    ships to pool workers inside plan-phase task payloads)."""

    version: int
    feature_names: tuple[str, ...]
    ridge: RidgeModel
    n_train: int                 # harvested training rows
    n_groups: int                # harvested candidate sets
    recall_target: float         # stated target the calibration aimed at
    keep_frac: float             # calibrated top-fraction achieving it
    recall: float                # achieved harvest recall at keep_frac

    def score(self, X: np.ndarray) -> np.ndarray:
        """Predicted iteration time per feature row (lower is better —
        the rank stage keeps the smallest scores)."""
        return self.ridge.predict(X)

    @property
    def fingerprint(self) -> str:
        """Content hash — cache key for pruned views ranked by this
        exact model (weights + calibration)."""
        h = hashlib.sha256()
        h.update(np.ascontiguousarray(self.ridge.beta).tobytes())
        h.update(np.ascontiguousarray(self.ridge.mean).tobytes())
        h.update(np.ascontiguousarray(self.ridge.std).tobytes())
        h.update(f"{self.version}|{self.keep_frac}|{self.n_train}".encode())
        return h.hexdigest()[:16]

    # -- persistence ---------------------------------------------------------
    def save(self, path: str) -> None:
        """Versioned single-file persistence (``np.savez``), written
        atomically so a crashed writer never leaves a torn model."""
        buf = io.BytesIO()
        np.savez(
            buf,
            version=np.int64(self.version),
            meta=np.frombuffer(json.dumps({
                "feature_names": list(self.feature_names),
                "n_train": self.n_train,
                "n_groups": self.n_groups,
                "recall_target": self.recall_target,
                "keep_frac": self.keep_frac,
                "recall": self.recall,
            }).encode(), dtype=np.uint8),
            mean=self.ridge.mean, std=self.ridge.std, beta=self.ridge.beta)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(buf.getvalue())
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "LearnedModel":
        """Inverse of :meth:`save`; raises ``ValueError`` on a format
        version this code does not speak."""
        with np.load(path) as z:
            version = int(z["version"])
            if version != FORMAT_VERSION:
                raise ValueError(
                    f"learned-model format version {version} at {path!r}; "
                    f"this build reads version {FORMAT_VERSION}")
            meta = json.loads(bytes(z["meta"]).decode())
            ridge = RidgeModel(mean=z["mean"], std=z["std"], beta=z["beta"])
        return cls(version=version,
                   feature_names=tuple(meta["feature_names"]),
                   ridge=ridge, n_train=int(meta["n_train"]),
                   n_groups=int(meta["n_groups"]),
                   recall_target=float(meta["recall_target"]),
                   keep_frac=float(meta["keep_frac"]),
                   recall=float(meta["recall"]))


def _winner_rank_fracs(scores: np.ndarray, y: np.ndarray,
                       groups: list[slice]) -> np.ndarray:
    """Per harvested group: ``rank/n`` of the true argmin (first row of
    minimal target, the selection tie-break) in the model's score
    ordering — the fraction a keep-threshold must strictly exceed to
    capture the winner, because the rank stage keeps ``ceil(frac · n)``
    rows and ``ceil(frac · n) >= rank + 1  ⟺  frac > rank/n``.  A
    perfect model scores 0.0 in every group regardless of group size."""
    fracs = []
    for sl in groups:
        gy, gs = y[sl], scores[sl]
        n = len(gy)
        winner = int(np.argmin(gy))          # first minimum = tie-break row
        order = np.lexsort((np.arange(n), gs))
        rank = int(np.nonzero(order == winner)[0][0])
        fracs.append(rank / n)
    return np.asarray(fracs)


def fit_ranker(cache=None, *, recall_target: float = DEFAULT_RECALL_TARGET,
               lam: float = 1e-3, min_rows: int = MIN_TRAIN_ROWS,
               min_groups: int = MIN_TRAIN_GROUPS) -> LearnedModel | None:
    """Train + calibrate a :class:`LearnedModel` from the memo harvest.

    Returns ``None`` when the harvest fails the staleness guard (fewer
    than ``min_rows`` rows or ``min_groups`` groups) — the caller's
    signal to run rank-off rather than trust a model fit on noise.
    """
    if not 0.0 < recall_target <= 1.0:
        raise ValueError(
            f"recall_target must be in (0, 1], got {recall_target}")
    X, y, groups = harvest_rows(cache)
    if len(X) < min_rows or len(groups) < min_groups:
        return None
    ridge = RidgeModel.fit(X, y, lam=lam)
    scores = ridge.predict(X)
    fracs = _winner_rank_fracs(scores, y, groups)
    # the recall_target quantile of winner-rank fractions, nudged to the
    # next float so the strict frac > rank/n capture condition holds at
    # the quantile row itself ("higher" interpolation keeps the
    # guarantee exact on the empirical distribution)
    keep_frac = float(np.nextafter(
        np.quantile(fracs, recall_target, method="higher"), 1.0))
    keep_frac = min(1.0, max(MIN_KEEP_FRAC, keep_frac))
    recall = float(np.mean(fracs < keep_frac))
    return LearnedModel(
        version=FORMAT_VERSION, feature_names=FEATURE_NAMES, ridge=ridge,
        n_train=int(len(X)), n_groups=len(groups),
        recall_target=recall_target, keep_frac=keep_frac, recall=recall)


def rank_keep_count(n: int, keep_frac: float) -> int:
    """Top-k size for a group of ``n`` survivors: ``ceil(frac * n)``,
    at least 1 so the model always nominates somebody."""
    return max(1, int(math.ceil(keep_frac * n)))

"""Feature schema of the learned rank stage.

One candidate row is featurized as the concatenation of

* its :data:`~repro.search.surrogate.PLAN_FEATURE_FIELDS` columns — the
  inputs of the ``pricing._price`` iteration-time expression (stage
  times, pipeline shape, backward multipliers), taken straight from the
  candidate :class:`~repro.core.pricing.PlanMatrix`;
* a **derived basis** (:data:`DERIVED_FEATURE_NAMES`) motivated by the
  shape of the paper's Eq. 7 pricing expression ``iter_time = (n_micro
  + pp - 1) · (t_fwd + t_bwd) + exposed_dp`` — a product-of-maxes form
  no linear map of the raw columns can rank.  The basis therefore
  carries the expression's two *components* (the pipeline term
  ``t_pipe`` and the exposed-DP term ``t_exposed``) plus log-scaled
  parts for cross-group calibration.  The basis gives the model the
  shape of the cost; the ridge still learns the weights (on this
  reproduction's pricing model they converge near the true Eq. 7
  combination — by design: a cost model that cannot recover the cost it
  was harvested from would be a poor one), and nothing downstream
  trusts them: the rank stage stays winner-preserving by construction
  even under an adversarially wrong model; and
* a per-group **system block** shared by every row of the group:
  log-scaled chip magnitudes + chip-count (the same resolvers and
  scaling :func:`repro.search.surrogate.cell_features` uses) and a
  topology-family one-hot over :data:`repro.systems.topology.TOPOLOGIES`.

The system block deliberately excludes the memory and network specs:
training pairs are harvested from memo space ``"candmat"`` whose keys
carry (work, chip, n_chips, topology) but not the memory variant — and
the network's effect on iteration time is already present in the
harvested ``t_net_stage`` / ``t_p2p`` / ``t_dp`` stage-time features.
Within one group the system block is constant, so it never reorders
rows of a single group; across groups it lets one model calibrate
predictions for systems it has not planned yet.
"""
from __future__ import annotations

import math
from typing import Any, Iterable

import numpy as np

from ..search.surrogate import PLAN_FEATURE_FIELDS
from ..systems.chips import ChipSpec
from ..systems.topology import TOPOLOGIES

#: Topology-family vocabulary of the one-hot block, frozen in sorted
#: order so feature indices are stable across processes and sessions.
TOPOLOGY_VOCAB: tuple[str, ...] = tuple(sorted(TOPOLOGIES))

#: Names of the per-group system-feature block, in column order.
SYSTEM_FEATURE_NAMES: tuple[str, ...] = (
    "log_peak_flops", "log_sram_capacity", "dataflow", "log_n_chips",
) + tuple(f"topo_{name}" for name in TOPOLOGY_VOCAB)

#: Eq. 7-shaped derived columns (module docstring), in column order.
DERIVED_FEATURE_NAMES: tuple[str, ...] = (
    "t_pipe",            # (n_micro + pp - 1) · (t_fwd + t_bwd)
    "t_exposed",         # max(0, t_dp - n_micro · t_comp·bfm · 0.5)
    "log_t_pipe",        # log10(t_pipe)
    "log_t_fwd",         # log10(max(t_comp, t_net, t_p2p))
    "log_t_bwd",         # log10(max(t_comp·bfm, t_net·bfm·bcm, t_p2p))
    "log_span",          # log10(n_micro + pp - 1)
    "log_t_dp",          # log10(t_dp)
    "log_dp_overlap",    # log10(n_micro · t_comp·bfm · 0.5)
)

#: Full feature-vector schema: plan columns, derived basis, system block.
FEATURE_NAMES: tuple[str, ...] = (PLAN_FEATURE_FIELDS
                                  + DERIVED_FEATURE_NAMES
                                  + SYSTEM_FEATURE_NAMES)

#: Floor inside the log features — keeps zero stage times finite without
#: disturbing the ordering of realistic (≫ 1e-30 s) times.
_LOG_FLOOR = 1e-30


def derived_features(cols: dict[str, Any] | Any) -> np.ndarray:
    """The ``(n_rows, len(DERIVED_FEATURE_NAMES))`` log-basis block for
    one candidate matrix ``cols`` mapping (see module docstring)."""
    def col(name: str) -> np.ndarray:
        return np.asarray(cols[name], dtype=np.float64)

    def log10(x: np.ndarray) -> np.ndarray:
        return np.log10(np.maximum(x, _LOG_FLOOR))

    t_comp, t_net, t_p2p = col("t_comp_stage"), col("t_net_stage"), \
        col("t_p2p")
    bfm, bcm = col("bwd_flop_mult"), col("bwd_comm_mult")
    t_fwd = np.maximum(np.maximum(t_comp, t_net), t_p2p)
    t_bwd = np.maximum(np.maximum(t_comp * bfm, t_net * (bfm * bcm)), t_p2p)
    span = col("n_micro") + col("pp") - 1.0
    overlap = col("n_micro") * (t_comp * bfm) * 0.5
    t_pipe = span * (t_fwd + t_bwd)
    t_exposed = np.maximum(0.0, col("t_dp") - overlap)
    return np.stack([
        t_pipe,
        t_exposed,
        log10(t_pipe),
        log10(t_fwd),
        log10(t_bwd),
        log10(span),
        log10(col("t_dp")),
        log10(overlap),
    ], axis=1)


def topology_family(topology_name: str) -> str | None:
    """Map a concrete topology name (``"torus2d_4x4"``, ``"fc16"``) back
    to its :data:`TOPOLOGY_VOCAB` family — the longest vocabulary entry
    prefixing it — or ``None`` for a family the vocabulary predates."""
    best = None
    for fam in TOPOLOGY_VOCAB:
        if topology_name.startswith(fam):
            if best is None or len(fam) > len(best):
                best = fam
    return best


def system_features(chip: ChipSpec, n_chips: int,
                    topology_name: str) -> np.ndarray:
    """The per-group system block (see module docstring).  An unknown
    topology family degrades to an all-zero one-hot rather than raising:
    the rank stage is winner-preserving regardless of feature quality,
    so a new family must not break planning."""
    base = [math.log10(chip.peak_flops),
            math.log10(chip.sram_capacity),
            float(chip.dataflow),
            math.log10(max(n_chips, 1))]
    onehot = [0.0] * len(TOPOLOGY_VOCAB)
    fam = topology_family(topology_name)
    if fam is not None:
        onehot[TOPOLOGY_VOCAB.index(fam)] = 1.0
    return np.asarray(base + onehot, dtype=np.float64)


def candidate_features(cols: dict[str, Any] | Any,
                       system: np.ndarray) -> np.ndarray:
    """Stack the full ``(n_rows, len(FEATURE_NAMES))`` feature matrix for
    one candidate group: :data:`PLAN_FEATURE_FIELDS` columns out of the
    matrix ``cols`` mapping, the :func:`derived_features` log basis, and
    the broadcast ``system`` block."""
    plan = np.stack([np.asarray(cols[f], dtype=np.float64)
                     for f in PLAN_FEATURE_FIELDS], axis=1)
    derived = derived_features(cols)
    sys_block = np.broadcast_to(np.asarray(system, dtype=np.float64),
                                (plan.shape[0], len(system)))
    return np.concatenate([plan, derived, sys_block], axis=1)


def harvest_rows(cache=None) -> tuple[np.ndarray, np.ndarray, list[slice]]:
    """Training-set extraction: ``(features, iter_time, groups)``.

    Walks memo space ``"candmat"`` via
    :meth:`repro.core.memo.SolveCache.harvest` — the local tier first,
    then shared-store entries other workers of the sweep computed — and
    emits one training row per *enumerated* candidate: its feature
    vector (above) against the exact ``selection_columns`` iteration
    time the dominance filter already computes.  The target is *linear*
    iteration time: Eq. 7 is linear in the derived component features,
    so linear space is where the ridge can actually recover it (a log
    target would re-introduce the ``log(a + b)`` nonlinearity the basis
    exists to remove).  ``groups`` holds one row-slice per harvested
    candidate set, so calibration can ask "where did this group's true
    argmin land in the model's ranking?".
    """
    from ..core.memo import GLOBAL_CACHE

    cache = GLOBAL_CACHE if cache is None else cache
    xs: list[np.ndarray] = []
    ys: list[np.ndarray] = []
    groups: list[slice] = []
    start = 0
    for key, cands in cache.harvest("candmat"):
        n = len(cands)
        if not n or not _candmat_key_ok(key):
            continue
        _work, chip, n_chips, topology = key[0], key[1], key[2], key[3]
        sysvec = system_features(chip, int(n_chips), topology.name)
        xs.append(candidate_features(cands.matrix.cols, sysvec))
        sel = cands.selection()
        ys.append(np.asarray(sel["iter_time"], dtype=np.float64))
        groups.append(slice(start, start + n))
        start += n
    if not xs:
        return (np.zeros((0, len(FEATURE_NAMES))), np.zeros(0), [])
    return np.concatenate(xs), np.concatenate(ys), groups


def _candmat_key_ok(key: Iterable) -> bool:
    """A ``"candmat"`` key this module can featurize: the structural key
    ``candidate_matrix`` writes — ``(work, chip, n_chips, topology, …)``
    with a :class:`ChipSpec` chip and a named topology.  Foreign entries
    (version skew through a shared store) are skipped, not raised."""
    try:
        return (isinstance(key, tuple) and len(key) >= 4
                and isinstance(key[1], ChipSpec)
                and isinstance(key[3].name, str))
    except AttributeError:
        return False

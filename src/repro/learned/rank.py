"""The rank stage: policy knobs + the winner-preserving keep rule.

Where it sits in the pipeline (``docs/ARCHITECTURE.md``): enumerate →
prune (feasibility + dominance) → **rank** → price → certify.  The rank
stage runs on the dominance survivors of one candidate group and keeps

    (the learned model's top ``keep_frac`` fraction by predicted
     iteration time)  ∪  (the rows the dominance lower bound cannot
     exclude at the group's actual memory capacities)

The second set — :func:`bound_keep` — is what makes the stage
winner-preserving *by construction*: for each capacity the group will
actually be selected at, the exact winner time is already known from the
dominance filter's selection prepass (``iter_time`` there is the exact
scalar expression, not an approximation), so any row whose *lower bound*
``iter_lb`` exceeds it provably cannot be that capacity's winner.  The
rows no capacity can exclude that way — plus the no-feasible fallback
row — are kept regardless of what the model thinks.  The model's top-k
rides along as the learned keep-set whose recall the calibration in
:mod:`repro.learned.model` states and the bench gate checks.  Runtime
certification (sampled scalar full-matrix scans inside
``plan_design_groups``) re-proves winner identity on every sweep under
the house certify-or-die rule.

Policy resolution copies the ``DFMODEL_PRUNE`` idiom: ``rank="auto"`` →
``$DFMODEL_RANK`` → **off** (the learned stage is opt-in: unlike the
dominance filter it needs a harvest to be useful, and a cold process has
none).  ``$DFMODEL_RANK_KEEP_FRAC`` overrides the model's calibrated
keep fraction.
"""
from __future__ import annotations

import os
from typing import Sequence

import numpy as np

from .model import rank_keep_count

RANK_ENV_VAR = "DFMODEL_RANK"
RANK_KEEP_ENV_VAR = "DFMODEL_RANK_KEEP_FRAC"

RANK_MODES = ("on", "off", "auto")

#: Accepted spellings for ``DFMODEL_RANK`` — same table, same
#: raise-on-garbage contract as ``DFMODEL_PRUNE``.
_RANK_SPELLINGS = {
    "on": "on", "1": "on", "true": "on", "yes": "on",
    "off": "off", "0": "off", "false": "off", "no": "off",
}


def default_rank() -> str:
    env = os.environ.get(RANK_ENV_VAR, "").strip().lower()
    if not env:
        return "off"
    try:
        return _RANK_SPELLINGS[env]
    except KeyError:
        raise ValueError(
            f"unknown {RANK_ENV_VAR} value {env!r}; expected one of "
            f"{sorted(_RANK_SPELLINGS)}") from None


def resolve_rank(policy: str | bool) -> bool:
    """Normalize a ``rank=`` policy to a bool (``"auto"`` → env → off)."""
    if isinstance(policy, bool):
        return policy
    if policy not in RANK_MODES:
        raise ValueError(f"unknown rank policy {policy!r}; "
                         f"expected a bool or one of {RANK_MODES}")
    if policy == "auto":
        policy = default_rank()
    return policy == "on"


def rank_keep_frac() -> float | None:
    """``$DFMODEL_RANK_KEEP_FRAC`` as a float in (0, 1], ``None`` when
    unset (→ the model's calibrated fraction decides)."""
    env = os.environ.get(RANK_KEEP_ENV_VAR, "").strip()
    if not env:
        return None
    try:
        frac = float(env)
    except ValueError:
        raise ValueError(f"{RANK_KEEP_ENV_VAR} must parse as a float, "
                         f"got {env!r}") from None
    if not 0.0 < frac <= 1.0:
        raise ValueError(
            f"{RANK_KEEP_ENV_VAR} must lie in (0, 1], got {frac}")
    return frac


def bound_keep(iter_time: np.ndarray, iter_lb: np.ndarray,
               mem: np.ndarray, capacities: Sequence[float]) -> np.ndarray:
    """The rows the dominance lower bound cannot exclude — the rank
    stage's certification safety set, evaluated per *actual* capacity.

    For capacity ``c`` the winner time ``W_c = min(iter_time[mem <= c])``
    is exact (the selection prepass computes the full scalar iteration-
    time expression), so a feasible row with ``iter_lb > W_c`` provably
    loses at ``c``: its true time is at least its lower bound.  A row is
    kept iff some capacity cannot exclude it — ``mem <= c`` and
    ``iter_lb <= W_c`` — plus the first global ``iter_time`` argmin,
    which is the selection's fallback winner when no row fits.  Every
    per-capacity lexicographic winner satisfies ``iter_lb <= iter_time =
    W_c`` at its own capacity, so dropping the complement is winner-
    preserving regardless of the model's opinion of it."""
    it = np.asarray(iter_time)
    lb = np.asarray(iter_lb)
    m = np.asarray(mem)
    keep = np.zeros(len(it), dtype=bool)
    if not len(it):
        return keep
    for cap in {float(c) for c in capacities}:
        feas = m <= cap
        if feas.any():
            keep |= feas & (lb <= it[feas].min())
    keep[int(np.argmin(it))] = True  # the no-feasible fallback winner
    return keep


def rank_keep(scores: np.ndarray, iter_time: np.ndarray,
              iter_lb: np.ndarray, mem: np.ndarray,
              capacities: Sequence[float], keep_frac: float) -> np.ndarray:
    """Boolean keep-mask of the rank stage over one group's dominance
    survivors: the model's top ``ceil(keep_frac * n)`` rows by
    ``scores`` (ascending, enumeration order breaking ties) unioned with
    the :func:`bound_keep` safety set."""
    n = len(scores)
    keep = np.zeros(n, dtype=bool)
    if n == 0:
        return keep
    order = np.lexsort((np.arange(n), np.asarray(scores)))
    keep[order[:rank_keep_count(n, keep_frac)]] = True
    keep |= bound_keep(iter_time, iter_lb, mem, capacities)
    return keep

"""Learned cost model as a certified third pruning stage
(``repro.learned``).

The DSE pipeline's rank stage (``docs/LEARNED.md``): a ridge regressor
trained on ``(candidate features → priced iteration time)`` pairs
harvested from memo space ``"candmat"`` scores every dominance-survivor
row, and the pipeline prices only the model's calibrated top fraction
union the rows no pricing-free argument can exclude — winners provably
identical to the unranked pipeline and re-certified at runtime under the
house certify-or-die rule.

Public surface:

* :func:`~repro.learned.model.fit_ranker` /
  :class:`~repro.learned.model.LearnedModel` — training, quantile-
  calibrated keep-threshold (stated recall target), versioned
  ``save``/``load`` persistence, the harvest-size staleness guard.
* :func:`~repro.learned.rank.rank_keep` /
  :func:`~repro.learned.rank.bound_keep` — the winner-preserving
  keep rule applied inside
  :func:`repro.core.interchip.prune_matrix`.
* :func:`~repro.learned.rank.default_rank` /
  :func:`~repro.learned.rank.resolve_rank` /
  :func:`~repro.learned.rank.rank_keep_frac` — the ``DFMODEL_RANK`` /
  ``DFMODEL_RANK_KEEP_FRAC`` policy knobs (same strict-spelling contract
  as ``DFMODEL_PRUNE``).
* :mod:`repro.learned.features` — the feature schema
  (:data:`~repro.learned.features.FEATURE_NAMES`) and the
  :func:`~repro.learned.features.harvest_rows` training-set extraction.
"""
from .features import (DERIVED_FEATURE_NAMES, FEATURE_NAMES,
                       SYSTEM_FEATURE_NAMES, TOPOLOGY_VOCAB,
                       candidate_features, derived_features, harvest_rows,
                       system_features)
from .model import (DEFAULT_RECALL_TARGET, FORMAT_VERSION, MIN_TRAIN_GROUPS,
                    MIN_TRAIN_ROWS, LearnedModel, fit_ranker, rank_keep_count)
from .rank import (RANK_ENV_VAR, RANK_KEEP_ENV_VAR, RANK_MODES, bound_keep,
                   default_rank, rank_keep, rank_keep_frac, resolve_rank)

__all__ = [
    "DEFAULT_RECALL_TARGET",
    "DERIVED_FEATURE_NAMES",
    "FEATURE_NAMES",
    "FORMAT_VERSION",
    "LearnedModel",
    "MIN_TRAIN_GROUPS",
    "MIN_TRAIN_ROWS",
    "RANK_ENV_VAR",
    "RANK_KEEP_ENV_VAR",
    "RANK_MODES",
    "SYSTEM_FEATURE_NAMES",
    "TOPOLOGY_VOCAB",
    "bound_keep",
    "candidate_features",
    "default_rank",
    "derived_features",
    "fit_ranker",
    "harvest_rows",
    "rank_keep",
    "rank_keep_count",
    "rank_keep_frac",
    "resolve_rank",
    "system_features",
]

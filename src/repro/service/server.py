"""The DSE service daemon.

Lifecycle follows the shared-store server in
:mod:`repro.core.memo_store`: an AF_UNIX listener polling a stop flag,
one thread per client connection, a structured reply for every request
that parses, and a client crash killing only its own connection thread.
The crucial ordering detail: :meth:`DSEService.start` warms the engine
(forks/spawns every pool worker) **before** any service thread exists —
forking a multithreaded process later is the documented deadlock hazard
the engine's transport auto-pick exists to avoid.

Run standalone::

    PYTHONPATH=src python -m repro.service.server --socket /tmp/dse.sock

or in-process (tests, benchmarks, examples)::

    with DSEService(max_workers=4, shared_cache=True) as svc:
        ...  # DSEClient(svc.path)
"""
from __future__ import annotations

import contextlib
import os
import pickle
import shutil
import socket
import tempfile
import threading
import time

from ..core.dse_engine import DSEEngine
from ..core.memo_store import diff_stats, recv_msg, send_msg
from .protocol import (PROTOCOL_VERSION, RequestError, error_msg, parse_query,
                       resolve_query)
from .scheduler import Scheduler, Ticket


class DSEService:
    """Long-lived DSE sweep daemon over one warm engine.

    Parameters
    ----------
    socket_path:
        Where to listen. Default: a fresh temp directory (removed on
        close). The path is available as :attr:`path` once started.
    engine:
        An existing :class:`~repro.core.dse_engine.DSEEngine` to serve
        with (it will be switched into warm-session mode; the caller
        keeps ownership and teardown stays with the caller). Default:
        the service builds its own from ``engine_kwargs`` and tears it
        down on close.
    batch_cells:
        Scheduler fairness quota — max *new* cells one client may
        introduce per scheduling round.
    """

    def __init__(self, socket_path: str | None = None,
                 engine: DSEEngine | None = None, *,
                 batch_cells: int = 8, **engine_kwargs):
        self._owns_engine = engine is None
        self.engine = engine or DSEEngine(**engine_kwargs)
        self.batch_cells = batch_cells
        self._tmpdir: str | None = None
        if socket_path is None:
            self._tmpdir = tempfile.mkdtemp(prefix="dfmodel-dse-service-")
            socket_path = os.path.join(self._tmpdir, "dse.sock")
        self.path = socket_path
        self.scheduler: Scheduler | None = None
        self._srv: socket.socket | None = None
        self._stop = threading.Event()
        self._accept_thread: threading.Thread | None = None
        self._started = False
        self._t0 = 0.0
        self._store_stats0: dict | None = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "DSEService":
        if self._started:
            return self
        # warm the engine FIRST: all pool workers must exist before this
        # process grows accept/scheduler threads (fork safety)
        self.engine.start()
        store = self.engine._session_store
        if store is not None:
            with contextlib.suppress(Exception):
                self._store_stats0 = store.stats()
        self.scheduler = Scheduler(self.engine,
                                   batch_cells=self.batch_cells).start()
        srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            srv.bind(self.path)
            srv.listen(64)
            srv.settimeout(0.1)  # poll the stop flag between accepts
        except OSError:
            srv.close()
            self.scheduler.close()
            if self._owns_engine:
                self.engine.shutdown()
            raise
        self._srv = srv
        self._t0 = time.monotonic()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="dse-service-accept")
        self._accept_thread.start()
        self._started = True
        return self

    def close(self) -> None:
        """Stop accepting, drain the scheduler, tear down what we own."""
        if not self._started:
            return
        self._started = False
        self._stop.set()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=10)
        if self._srv is not None:
            with contextlib.suppress(OSError):
                self._srv.close()
        with contextlib.suppress(OSError):
            os.unlink(self.path)
        if self.scheduler is not None:
            self.scheduler.close()
        if self._owns_engine:
            self.engine.shutdown()
        if self._tmpdir is not None:
            shutil.rmtree(self._tmpdir, ignore_errors=True)

    def __enter__(self) -> "DSEService":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def wait(self, timeout: float | None = None) -> bool:
        """Block until a client's ``shutdown`` request (or timeout)."""
        return self._stop.wait(timeout)

    # -- connection handling -------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            threading.Thread(target=self._handle, args=(conn,),
                             daemon=True).start()

    def _handle(self, conn: socket.socket) -> None:
        """One client connection: requests in, streams out.

        A malformed request gets a structured error reply and the
        connection stays usable; an unframeable/garbage message (or a
        dead client socket) closes only this connection — the daemon,
        the warm pool and every other client keep running.
        """
        try:
            while not self._stop.is_set():
                try:
                    msg = recv_msg(conn)
                except (pickle.UnpicklingError, EOFError, AttributeError,
                        ImportError, IndexError, ValueError) as exc:
                    # undecodable frame: reply (best effort), drop client
                    with contextlib.suppress(OSError):
                        send_msg(conn, error_msg(
                            "bad-frame", f"undecodable request: {exc!r}"))
                    return
                if msg is None:
                    return  # client closed cleanly
                op = msg.get("op") if isinstance(msg, dict) else None
                if op == "ping":
                    send_msg(conn, {"kind": "pong",
                                    "protocol": PROTOCOL_VERSION})
                elif op == "stats":
                    send_msg(conn, self._stats())
                elif op == "shutdown":
                    send_msg(conn, {"kind": "bye"})
                    self._stop.set()
                    return
                elif op == "query":
                    if not self._query(conn, msg):
                        return
                else:
                    send_msg(conn, error_msg(
                        "bad-op", f"unknown op {op!r}; expected one of "
                                  f"query/ping/stats/shutdown"))
        except OSError:
            return  # client died mid-message; daemon stays up
        finally:
            with contextlib.suppress(OSError):
                conn.close()

    def _query(self, conn: socket.socket, msg: dict) -> bool:
        """Run one query exchange; False if the connection is dead."""
        try:
            query = parse_query(msg)
            ticket = Ticket(query, resolve_query(query))
        except RequestError as exc:
            send_msg(conn, error_msg(exc.code, str(exc)))
            return True  # the *connection* is fine; daemon keeps serving
        self.scheduler.submit(ticket)
        try:
            while True:
                out = ticket.out.get()
                send_msg(conn, out)
                if out.get("kind") in ("done", "error"):
                    return True
        except OSError:
            # client disconnected mid-stream: stop emitting for this
            # ticket; in-flight cells still price and stay in the shared
            # memo for everyone else — the warm pool is untouched
            ticket.cancel()
            return False

    def _stats(self) -> dict:
        store = self.engine._session_store
        store_stats = None
        if store is not None:
            with contextlib.suppress(Exception):
                store_stats = store.stats()
        return {
            "kind": "stats",
            "protocol": PROTOCOL_VERSION,
            "uptime_s": time.monotonic() - self._t0,
            "scheduler": self.scheduler.stats(),
            "engine": {"max_workers": self.engine.max_workers,
                       "session_active": self.engine.session_active,
                       "warm_pool": self.engine._session_pool is not None,
                       "pricing_backend": self.engine.pricing_backend,
                       "prune": self.engine.prune,
                       "rank": self.engine.rank,
                       "rank_model": self.engine._ranker is not None,
                       "shared_cache": self.engine.shared_cache},
            "shared_store": store_stats,
            "shared_store_delta": diff_stats(self._store_stats0, store_stats),
        }


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description="DFModel DSE service daemon")
    ap.add_argument("--socket", default=None,
                    help="unix socket path (default: fresh temp dir)")
    ap.add_argument("--workers", type=int, default=None,
                    help="engine pool size (default: cpu count)")
    ap.add_argument("--shared-cache", action="store_true",
                    help="share one cross-process memo store across requests")
    ap.add_argument("--backend", default="auto",
                    help="pricing backend (numpy/jax/pallas/pallas-compiled)")
    ap.add_argument("--prune", default="auto", help="candidate pruning policy")
    ap.add_argument("--rank", default="auto",
                    help="learned rank-stage policy (on/off/auto; "
                         "auto follows $DFMODEL_RANK, default off)")
    ap.add_argument("--rank-model", default=None, metavar="PATH",
                    help="persist/load the trained ranker at PATH so warm "
                         "sessions survive daemon restarts")
    ap.add_argument("--batch-cells", type=int, default=8,
                    help="scheduler fairness quota per client per round")
    args = ap.parse_args(argv)
    svc = DSEService(socket_path=args.socket,
                     batch_cells=args.batch_cells,
                     max_workers=args.workers,
                     shared_cache=args.shared_cache,
                     pricing_backend=args.backend,
                     prune=args.prune,
                     rank=args.rank,
                     rank_model_path=args.rank_model)
    with svc:
        print(f"dse-service: serving on {svc.path}", flush=True)
        try:
            svc.wait()
        except KeyboardInterrupt:
            pass
    print("dse-service: stopped", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""The service scheduler: one engine thread, many concurrent queries.

Design constraints this encodes:

* The warm :class:`~repro.core.dse_engine.DSEEngine` is not thread-safe
  across concurrent calls, so exactly ONE scheduler thread drives it;
  connection threads only enqueue tickets and drain per-ticket output
  queues. Parallelism comes from the engine's warm worker pool, not
  from concurrent engine calls.
* **Dedup**: priced cells land in a shared result memo keyed by
  ``(work_key, cell)`` (:meth:`repro.service.protocol.Resolved.cell_key`)
  that outlives individual requests. Within a round, a cell wanted by
  several clients is *introduced* by one and delivered to all —
  overlapping grids are priced exactly once (``cells_priced`` counts
  engine prices, ``dedup_hits`` counts rows served without one).
* **Fairness**: each scheduling round visits active sweep tickets in a
  rotating order and lets each introduce at most ``batch_cells`` new
  cells, so a huge query cannot starve a small one.
* **Budgets**: a sweep ticket's ``budget`` bounds how many fresh prices
  it can *cause*; rows served from the memo or another client's
  concurrent work are free. Cells that nobody has budget for are
  skipped and reported in the ``done`` summary.
* **Certification**: rows are emitted straight from the engine's
  streaming path (:meth:`~repro.core.dse_engine.DSEEngine.sweep_cells_iter`),
  which runs the house certify-or-die checks *before* yielding — the
  scheduler never emits an uncertified row. ``search`` queries run with
  ``certify=True`` (the exhaustive-oracle check) and ``reprice`` queries
  raise inside the engine on any winner mismatch.

``search`` and ``reprice`` queries run as atomic units between sweep
rounds (their engine calls are not interruptible); their priced
observations seed the same result memo, so a later sweep over the same
cells streams instantly.

The engine-level learned rank stage (``rank=`` / ``$DFMODEL_RANK``, see
:mod:`repro.learned`) applies to every query the scheduler routes —
sweeps, searches and reprices all flow through the same plan → rank →
price pipeline — and because one engine serves all requests, its
:meth:`~repro.core.dse_engine.DSEEngine._ranker_for_run` refit check
sees the memo harvest grow across *requests*: a warm daemon's ranker
improves as clients price new regions of the design space.
"""
from __future__ import annotations

import itertools
import queue
import threading

from .protocol import Query, Resolved, error_msg


class Ticket:
    """One admitted query: its output stream plus sweep bookkeeping."""

    _ids = itertools.count(1)

    def __init__(self, query: Query, resolved: Resolved):
        self.id = next(Ticket._ids)
        self.query = query
        self.resolved = resolved
        self.out: queue.SimpleQueue = queue.SimpleQueue()
        self._cancelled = threading.Event()
        self.failed = False
        # sweep bookkeeping (grid index -> (cell_key, cell))
        self.remaining: dict[int, tuple] = {}
        self.rows = 0
        self.dedup_hits = 0
        self.budget_used = 0
        self.skipped = 0
        self.best: tuple | None = None  # (infeasible, iter_time, index, point)

    # -- client-side stream control ------------------------------------------
    def cancel(self) -> None:
        """Client went away mid-stream: stop emitting; the scheduler
        drops the ticket at the next round. Cells it introduced that are
        already in flight still get priced (and serve other waiters)."""
        self._cancelled.set()

    @property
    def cancelled(self) -> bool:
        return self._cancelled.is_set()

    def emit(self, msg: dict) -> None:
        if not self.cancelled:
            self.out.put(msg)

    # -- row accounting ------------------------------------------------------
    def note_row(self, index: int, cell, point) -> None:
        self.rows += 1
        key = ((point is None or not point.plan.feasible),
               float("inf") if point is None else float(point.plan.iter_time),
               index, point)
        if self.best is None or key[:3] < self.best[:3]:
            self.best = key

    def budget_left(self) -> bool:
        return self.query.budget is None or self.budget_used < self.query.budget


_STOP = object()


class Scheduler:
    """Single-threaded multiplexer over one warm engine (see module
    docstring for the dedup / fairness / budget contract)."""

    def __init__(self, engine, batch_cells: int = 8):
        if batch_cells < 1:
            raise ValueError(f"batch_cells must be >= 1, got {batch_cells}")
        self.engine = engine
        self.batch_cells = batch_cells
        self._inbox: queue.SimpleQueue = queue.SimpleQueue()
        self._results: dict[tuple, object] = {}   # cell_key -> point | None
        self._lock = threading.Lock()
        self._stats = {"requests": 0, "rows_streamed": 0, "cells_priced": 0,
                       "dedup_hits": 0, "errors": 0, "memo_cells": 0}
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="dse-service-scheduler")

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Scheduler":
        self._thread.start()
        return self

    def close(self) -> None:
        self._inbox.put(_STOP)
        self._thread.join(timeout=60)

    def submit(self, ticket: Ticket) -> None:
        self._inbox.put(ticket)

    def stats(self) -> dict:
        with self._lock:
            out = dict(self._stats)
        out["memo_cells"] = len(self._results)
        return out

    def _bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self._stats[key] += n

    # -- main loop -----------------------------------------------------------
    def _run(self) -> None:
        active: list[Ticket] = []
        rotate = 0
        while True:
            # ingest: block when idle, drain opportunistically when busy
            if not active:
                item = self._inbox.get()
                if item is _STOP:
                    return
                self._admit(item, active)
            while True:
                try:
                    item = self._inbox.get_nowait()
                except queue.Empty:
                    break
                if item is _STOP:
                    return
                self._admit(item, active)
            if active:
                self._round(active, rotate)
                rotate += 1
                active = self._finish_pass(active)

    # -- admission -----------------------------------------------------------
    def _admit(self, t: Ticket, active: list[Ticket]) -> None:
        self._bump("requests")
        if t.query.mode == "search":
            self._run_search(t)
            return
        if t.query.mode == "reprice":
            self._run_reprice(t)
            return
        res = t.resolved
        for gidx in res.indices:
            cell = res.grid[gidx]
            key = res.cell_key(cell)
            if key in self._results:
                # a previous (or concurrent, earlier-admitted) request
                # already priced this cell — serve it from the memo
                point = self._results[key]
                t.emit({"kind": "row", "index": gidx, "cell": cell,
                        "point": point})
                t.note_row(gidx, cell, point)
                t.dedup_hits += 1
                self._bump("rows_streamed")
                self._bump("dedup_hits")
            else:
                t.remaining[gidx] = (key, cell)
        if t.remaining:
            active.append(t)
        else:
            t.emit({"kind": "done", "summary": self._summary(t)})

    # -- sweep rounds --------------------------------------------------------
    def _round(self, active: list[Ticket], rotate: int) -> None:
        live = [t for t in active if not t.cancelled and not t.failed]
        if not live:
            return
        start = rotate % len(live)
        order = live[start:] + live[:start]
        # fair interleaving: each ticket may introduce at most
        # batch_cells NEW cells per round; joining a cell another ticket
        # introduced (or one already priced) costs nothing
        introduced: dict[tuple, tuple] = {}   # key -> (resolved, cell, owner)
        for t in order:
            quota = self.batch_cells
            for gidx, (key, cell) in t.remaining.items():
                if quota == 0:
                    break
                if key in self._results or key in introduced:
                    continue
                if not t.budget_left():
                    break
                introduced[key] = (t.resolved, cell, t.id)
                t.budget_used += 1
                quota -= 1
        if not introduced:
            return
        # group by work semantics: one engine call per work_key batch
        by_work: dict[tuple, list[tuple]] = {}
        for key, (res, cell, owner) in introduced.items():
            by_work.setdefault(res.work_key, []).append((key, cell, res,
                                                        owner))
        for work_key, entries in by_work.items():
            res = entries[0][2]
            cells = [cell for _key, cell, _res, _owner in entries]
            try:
                for item in self.engine.sweep_cells_iter(res.work_fn, cells,
                                                         res.spec):
                    key, _cell, _res, owner = entries[item.index]
                    self._results[key] = item.point
                    self._bump("cells_priced")
                    self._deliver(active, key, owner)
            except Exception as exc:  # engine failure must not kill the daemon
                self._bump("errors")
                for t in active:
                    if (not t.cancelled and not t.failed
                            and t.resolved.work_key == work_key):
                        t.failed = True
                        t.emit(error_msg("engine-error",
                                         f"sweep failed: {exc!r}"))

    def _deliver(self, active: list[Ticket], key: tuple, owner: int) -> None:
        point = self._results[key]
        for t in active:
            if t.cancelled or t.failed:
                continue
            hits = [gidx for gidx, (k, _c) in t.remaining.items() if k == key]
            for gidx in hits:
                _key, cell = t.remaining.pop(gidx)
                t.emit({"kind": "row", "index": gidx, "cell": cell,
                        "point": point})
                t.note_row(gidx, cell, point)
                self._bump("rows_streamed")
                if t.id != owner:
                    # a shared solve: this client got the row without
                    # paying for the price — the cross-client dedup hit
                    # the bench block and its gate certify
                    t.dedup_hits += 1
                    self._bump("dedup_hits")

    def _finish_pass(self, active: list[Ticket]) -> list[Ticket]:
        still: list[Ticket] = []
        for t in active:
            if t.cancelled or t.failed:
                continue
            if not t.budget_left() and t.remaining:
                # out of budget: keep only cells some OTHER live ticket
                # can still pay for (we will be served by its dedup)
                for gidx, (key, _cell) in list(t.remaining.items()):
                    sharable = any(
                        key in (k for k, _c in u.remaining.values())
                        and u.budget_left()
                        for u in active
                        if u is not t and not u.cancelled and not u.failed)
                    if not sharable:
                        del t.remaining[gidx]
                        t.skipped += 1
            if t.remaining:
                still.append(t)
            else:
                t.emit({"kind": "done", "summary": self._summary(t)})
        return still

    def _summary(self, t: Ticket) -> dict:
        winner = None
        if t.best is not None:
            infeasible, iter_time, index, point = t.best
            winner = {"index": index,
                      "cell": t.resolved.grid[index],
                      "feasible": not infeasible,
                      "iter_time": iter_time,
                      "row": None if point is None else point.row()}
        return {"mode": t.query.mode, "rows": t.rows,
                "dedup_hits": t.dedup_hits, "budget_used": t.budget_used,
                "skipped": t.skipped, "winner": winner}

    # -- search / reprice queries (atomic between sweep rounds) --------------
    def _run_search(self, t: Ticket) -> None:
        from ..search.policy import make_policy

        res = t.resolved
        budget = t.query.budget or len(res.grid)
        try:
            policy = make_policy(t.query.policy, seed=t.query.seed,
                                 batch_size=t.query.batch_size)
            result = self.engine.search(
                res.work_fn, res.spec, policy=policy, budget=budget,
                certify=True,
                progress=lambda rec: t.emit({"kind": "progress", **rec}))
        except Exception as exc:
            self._bump("errors")
            t.emit(error_msg("search-failed", f"{exc!r}"))
            return
        # harvest: search observations went through the same certified
        # plan->price path as a sweep, so they seed the shared memo and
        # later sweeps over these cells stream for free
        for obs in result.evaluated.values():
            key = res.cell_key(res.grid[obs.index])
            if key not in self._results:
                self._results[key] = obs.point
        if result.best_index >= 0:
            cell = res.grid[result.best_index]
            t.emit({"kind": "row", "index": result.best_index, "cell": cell,
                    "point": result.best_point})
            t.note_row(result.best_index, cell, result.best_point)
            self._bump("rows_streamed")
        t.emit({"kind": "done", "summary": {
            "mode": "search", "policy": result.policy,
            "budget": result.budget, "evals_used": result.evals_used,
            "cheap_evals": result.cheap_evals,
            "certified": result.certified,
            "oracle_index": result.oracle_index,
            "best_index": result.best_index,
            "seconds": result.seconds,
            "winner": self._summary(t)["winner"]}})

    def _run_reprice(self, t: Ticket) -> None:
        res = t.resolved
        try:
            report = self.engine.reprice_grid(res.work_fn, res.spec)
        except Exception as exc:
            self._bump("errors")
            t.emit(error_msg("reprice-failed", f"{exc!r}"))
            return
        t.emit({"kind": "done", "summary": {"mode": "reprice", **report}})

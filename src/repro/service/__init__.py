"""DSE-as-a-service: a long-lived sweep daemon over a warm DSEEngine.

The paper's pitch — interactive "what system should I build for this
workload" queries — only pays off when many clients can ask overlapping
what-if questions against a *warm* engine instead of cold-starting a
sweep each time. This package turns every prior engine layer into that
multi-tenant surface:

* :class:`~repro.service.server.DSEService` — the daemon. Owns ONE
  :class:`~repro.core.dse_engine.DSEEngine` in warm-session mode
  (process pool + cross-process memo store created once, reused by
  every request) and serves concurrent clients over an AF_UNIX socket
  with the same length-prefixed-pickle framing as the shared-store
  server (:mod:`repro.core.memo_store`).
* :class:`~repro.service.scheduler.Scheduler` — multiplexes concurrent
  queries: overlapping cells across clients are priced exactly once
  (shared result memo + per-round dedup), clients are interleaved
  round-robin with a per-round cell quota, and per-client budgets bound
  how many fresh solves any one client can cause.
* :class:`~repro.service.client.DSEClient` — streaming consumer: rows
  arrive grid-index-tagged as plan groups finish, so a live Pareto
  frontier or an early-stop answer is available before the sweep ends.
* :mod:`~repro.service.protocol` — the wire protocol: requests carry a
  scenario name (plus optional :class:`~repro.search.DenseGridSpec`
  overrides or an explicit cell subset) and a mode — ``sweep``
  (exhaustive), ``search`` (budgeted policy by name), or ``reprice``
  (whole-grid chunked re-pricing).

Every row a client sees has already passed the house certify-or-die
checks inside the engine's streaming path — the daemon never relaxes
the bit-identity contract (`docs/ARCHITECTURE.md` states the rule).

    from repro.service import DSEService, DSEClient

    with DSEService(max_workers=4, shared_cache=True) as svc:
        with DSEClient(svc.path) as cli:
            reply = cli.sweep(scenario="llm", smoke=True)
            print(reply.summary["winner"])
"""
from .client import DSEClient, ServiceError, SweepReply
from .protocol import (MODES, PROTOCOL_VERSION, Query, RequestError,
                       parse_query, resolve_query)
from .scheduler import Scheduler, Ticket
from .server import DSEService

__all__ = [
    "DSEClient",
    "DSEService",
    "MODES",
    "PROTOCOL_VERSION",
    "Query",
    "RequestError",
    "Scheduler",
    "ServiceError",
    "SweepReply",
    "Ticket",
    "parse_query",
    "resolve_query",
]

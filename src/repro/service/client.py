"""Streaming client for the DSE service daemon.

One :class:`DSEClient` is one connection — concurrent queries need one
client each (which is exactly how the dedup/fairness machinery is meant
to be exercised). Rows stream back as the warm engine's plan groups
finish, so consumers can render a live Pareto frontier or stop early by
simply closing the client; the daemon cancels the ticket and keeps the
shared work for everyone else.
"""
from __future__ import annotations

import contextlib
import dataclasses
import socket
import time
from typing import Iterator

from ..core.dse import DesignPoint, GridCell
from ..core.dse_engine import pareto_frontier
from ..core.memo_store import recv_msg, send_msg


class ServiceError(RuntimeError):
    """A structured error reply from the daemon (``code`` + message)."""

    def __init__(self, code: str, message: str):
        super().__init__(f"{code}: {message}")
        self.code = code


@dataclasses.dataclass
class SweepReply:
    """A collected query result: grid-index-tagged rows + done summary."""

    indices: list[int]
    cells: list[GridCell]
    points: list[DesignPoint | None]
    summary: dict
    progress: list[dict]

    def live_points(self) -> list[DesignPoint]:
        """The priced points in grid order — for a full-grid sweep this
        list is bit-identical to a direct ``DSEEngine.sweep``."""
        order = sorted(range(len(self.indices)),
                       key=lambda k: self.indices[k])
        return [self.points[k] for k in order if self.points[k] is not None]

    def rows(self) -> list[dict]:
        return [p.row() for p in self.live_points()]

    def frontier(self) -> list[DesignPoint]:
        return pareto_frontier(self.live_points())

    @property
    def winner(self) -> dict | None:
        return self.summary.get("winner")


class DSEClient:
    """Blocking client over the service's unix socket."""

    def __init__(self, path: str, connect_timeout: float = 20.0):
        self.path = path
        deadline = time.monotonic() + connect_timeout
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        while True:
            try:
                self._sock.connect(path)
                break
            except (FileNotFoundError, ConnectionRefusedError):
                if time.monotonic() >= deadline:
                    self._sock.close()
                    raise
                time.sleep(0.05)

    def close(self) -> None:
        with contextlib.suppress(OSError):
            self._sock.close()

    def __enter__(self) -> "DSEClient":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- control ops ---------------------------------------------------------
    def _roundtrip(self, msg: dict) -> dict:
        send_msg(self._sock, msg)
        reply = recv_msg(self._sock)
        if reply is None:
            raise ConnectionError("service closed the connection")
        if reply.get("kind") == "error":
            raise ServiceError(reply.get("code", "error"),
                               reply.get("message", ""))
        return reply

    def ping(self) -> dict:
        return self._roundtrip({"op": "ping"})

    def stats(self) -> dict:
        return self._roundtrip({"op": "stats"})

    def shutdown_server(self) -> None:
        self._roundtrip({"op": "shutdown"})

    # -- queries -------------------------------------------------------------
    def query_iter(self, **fields) -> Iterator[dict]:
        """Send one query, yield its stream (rows / progress), return on
        the terminal ``done`` (also yielded). A structured error reply
        raises :class:`ServiceError`; the connection stays usable."""
        send_msg(self._sock, {"op": "query", **fields})
        while True:
            msg = recv_msg(self._sock)
            if msg is None:
                raise ConnectionError("service closed mid-stream")
            kind = msg.get("kind")
            if kind == "error":
                raise ServiceError(msg.get("code", "error"),
                                   msg.get("message", ""))
            yield msg
            if kind == "done":
                return

    def _collect(self, **fields) -> SweepReply:
        indices: list[int] = []
        cells: list[GridCell] = []
        points: list[DesignPoint | None] = []
        progress: list[dict] = []
        summary: dict = {}
        for msg in self.query_iter(**fields):
            kind = msg["kind"]
            if kind == "row":
                indices.append(msg["index"])
                cells.append(msg["cell"])
                points.append(msg["point"])
            elif kind == "progress":
                progress.append(msg)
            elif kind == "done":
                summary = msg["summary"]
        return SweepReply(indices=indices, cells=cells, points=points,
                          summary=summary, progress=progress)

    def sweep(self, scenario: str = "llm", smoke: bool = True,
              **fields) -> SweepReply:
        """Exhaustive (or ``cells=``-restricted, ``budget=``-bounded)
        sweep; rows collected, winner in ``reply.summary['winner']``."""
        return self._collect(mode="sweep", scenario=scenario, smoke=smoke,
                             **fields)

    def search(self, scenario: str = "llm", smoke: bool = True,
               policy: str = "halving", budget: int | None = None,
               **fields) -> SweepReply:
        """Budgeted policy search; the certified winner is the single
        streamed row, per-round progress in ``reply.progress``."""
        return self._collect(mode="search", scenario=scenario, smoke=smoke,
                             policy=policy, budget=budget, **fields)

    def reprice(self, scenario: str = "llm", smoke: bool = True,
                **fields) -> dict:
        """Whole-grid chunk-streamed re-pricing; returns the engine's
        report dict (``winners_identical`` is certify-or-die)."""
        return self._collect(mode="reprice", scenario=scenario, smoke=smoke,
                             **fields).summary

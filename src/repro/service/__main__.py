"""``python -m repro.service`` — run the standalone DSE daemon.

Preferred over ``-m repro.service.server`` (which works too, but trips
runpy's already-imported warning because the package imports the server
module at import time).
"""
from .server import main

if __name__ == "__main__":
    raise SystemExit(main())

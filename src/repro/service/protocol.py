"""Wire protocol of the DSE service.

Length-prefixed pickled dicts over an AF_UNIX stream socket — exactly
the framing :mod:`repro.core.memo_store` uses between sweep workers and
the shared-store daemon (``send_msg`` / ``recv_msg`` are re-exported
from there, so both daemons ride one battle-tested transport).

A connection carries a sequence of request/reply exchanges. Control
ops (``ping`` / ``stats`` / ``shutdown``) get a single reply; a
``query`` op gets a *stream*:

    client -> server   {"op": "query", "mode": "sweep", ...}
    server -> client   zero or more {"kind": "row" | "progress"} messages
    server -> client   exactly one  {"kind": "done" | "error"} terminal

``row`` messages are grid-index-tagged (``index`` is the cell's index in
the request's resolved design grid) and carry the fully priced
:class:`~repro.core.dse.DesignPoint` (``None`` for undecomposable
cells), so progressive consumers can maintain a live Pareto frontier or
stop early by closing the connection. Every row was certified inside
the engine's streaming path before it was emitted — the service never
weakens the certify-or-die rule.

Requests are plain data: scenarios and search policies travel by *name*
(resolved server-side from :mod:`repro.workloads.scenarios` and
:func:`repro.search.make_policy`), never as pickled callables.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from ..core.dse import GridCell
from ..core.dse_engine import SweepSpec
from ..core.memo_store import recv_msg, send_msg  # noqa: F401  (re-export)

PROTOCOL_VERSION = 1
MODES = ("sweep", "search", "reprice")


class RequestError(ValueError):
    """A malformed request. The daemon answers with a structured
    ``{"kind": "error", "code", "message"}`` reply and keeps serving."""

    def __init__(self, code: str, message: str):
        super().__init__(message)
        self.code = code


def error_msg(code: str, message: str) -> dict:
    return {"kind": "error", "code": code, "message": message}


@dataclasses.dataclass(frozen=True)
class Query:
    """One validated query, still unresolved (names, not callables)."""

    mode: str = "sweep"
    scenario: str = "llm"
    smoke: bool = True
    #: optional explicit cell subset: indices into the resolved grid
    cells: tuple[int, ...] | None = None
    #: optional DenseGridSpec field overrides replacing the scenario grid
    dense: dict | None = None
    #: global-batch scale applied to the scenario workload (ScaledWorkFn)
    workload_scale: float = 1.0
    #: sweep mode: max cells this client may cause to be priced;
    #: search mode: full-evaluation budget (None → grid size)
    budget: int | None = None
    policy: str = "halving"
    seed: int = 0
    batch_size: int | None = None
    client: str = ""


_QUERY_FIELDS = {f.name for f in dataclasses.fields(Query)}


def parse_query(msg: dict) -> Query:
    """Validate a raw ``query`` message into a :class:`Query`."""
    if not isinstance(msg, dict):
        raise RequestError("bad-request", f"expected a dict, got "
                                          f"{type(msg).__name__}")
    fields = {k: v for k, v in msg.items() if k != "op"}
    unknown = set(fields) - _QUERY_FIELDS
    if unknown:
        raise RequestError("bad-field",
                           f"unknown query fields {sorted(unknown)}; "
                           f"known: {sorted(_QUERY_FIELDS)}")
    try:
        q = Query(**fields)
    except TypeError as exc:
        raise RequestError("bad-request", str(exc)) from exc
    if q.mode not in MODES:
        raise RequestError("bad-mode",
                           f"unknown mode {q.mode!r}; available: {MODES}")
    if not isinstance(q.scenario, str):
        raise RequestError("bad-scenario", "scenario must be a string name")
    if q.budget is not None and (not isinstance(q.budget, int)
                                 or q.budget < 1):
        raise RequestError("bad-budget",
                           f"budget must be a positive int, got {q.budget!r}")
    if q.cells is not None:
        try:
            cells = tuple(int(i) for i in q.cells)
        except (TypeError, ValueError) as exc:
            raise RequestError(
                "bad-cells", f"cells must be grid indices: {exc}") from exc
        if len(set(cells)) != len(cells):
            raise RequestError("bad-cells", "cells contains duplicates")
        q = dataclasses.replace(q, cells=cells)
    if q.dense is not None and not isinstance(q.dense, dict):
        raise RequestError("bad-dense",
                           "dense must be a dict of DenseGridSpec fields")
    try:
        scale = float(q.workload_scale)
    except (TypeError, ValueError) as exc:
        raise RequestError("bad-scale",
                           f"workload_scale must be a number: {exc}") from exc
    if scale <= 0:
        raise RequestError("bad-scale",
                           f"workload_scale must be > 0, got {scale}")
    return dataclasses.replace(q, workload_scale=scale)


@dataclasses.dataclass(frozen=True)
class Resolved:
    """A query bound to the callables/grids it named.

    ``work_key`` identifies the *work semantics* of a cell independently
    of which request asked for it — the scheduler's cross-client dedup
    key is ``(work_key, cell)``, so two clients sweeping overlapping
    grids (even different subsets, even via different DenseGridSpec
    overrides with the same sweep parameters) share one priced solve per
    cell.
    """

    work_fn: Callable
    spec: SweepSpec
    grid: tuple[GridCell, ...]
    #: the grid indices this query covers (the whole grid by default)
    indices: tuple[int, ...]
    work_key: tuple

    def cell_key(self, cell: GridCell) -> tuple:
        return (self.work_key, cell)


def resolve_query(q: Query) -> Resolved:
    """Bind a :class:`Query` to its scenario work_fn, sweep spec and
    grid. Name-resolution failures become :class:`RequestError`\\ s."""
    from ..workloads.scenarios import get_scenario

    try:
        sc = get_scenario(q.scenario, smoke=q.smoke)
    except KeyError as exc:
        raise RequestError("unknown-scenario", str(exc)) from exc
    work_fn, spec = sc.work_fn, sc.spec
    if q.dense is not None:
        from ..search.grid import DenseGridSpec

        try:
            spec = DenseGridSpec(**q.dense).spec()
        except (TypeError, ValueError) as exc:
            raise RequestError("bad-dense", str(exc)) from exc
    if q.workload_scale != 1.0:
        from ..search.grid import ScaledWorkFn

        work_fn = ScaledWorkFn(work_fn, q.workload_scale)
    grid = tuple(spec.grid())
    if q.cells is not None:
        bad = [i for i in q.cells if not 0 <= i < len(grid)]
        if bad:
            raise RequestError(
                "bad-cells", f"cell indices out of range (grid size "
                             f"{len(grid)}): {bad[:5]}")
        indices = q.cells
    else:
        indices = tuple(range(len(grid)))
    if q.mode == "search" and q.policy is not None:
        from ..search.policy import POLICY_NAMES

        if q.policy not in POLICY_NAMES:
            raise RequestError(
                "unknown-policy", f"unknown search policy {q.policy!r}; "
                                  f"available: {POLICY_NAMES}")
    work_key = (q.scenario, bool(q.smoke), q.workload_scale, spec.n_chips,
                spec.max_tp, spec.max_pp, spec.execution)
    return Resolved(work_fn=work_fn, spec=spec, grid=grid, indices=indices,
                    work_key=work_key)

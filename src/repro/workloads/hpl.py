"""High-Performance LINPACK dataflow graph (paper §VI.C.3 — 5M² HPL).

Right-looking LU with partial pivoting: per block-column iteration —
panel factorization (tall-skinny, poorly parallel), panel broadcast,
triangular solve of the U row-block, trailing-matrix GEMM update (dominant,
2/3·N³ total). We model the steady-state iteration at 50% progress (trailing
matrix N/2 × N/2), which reproduces HPL's compute-bound character on every
system (paper Fig 14: "all system setups achieve high utilization").
"""
from __future__ import annotations

from ..core.graph import DataflowGraph, Kernel, KernelKind, Tensor
from ..core.interchip import TrainWorkload

BYTES = 8  # HPL is fp64


def hpl_iteration_graph(n: float = 5e6, nb: int = 512) -> DataflowGraph:
    m = n / 2  # steady-state trailing size
    ks = [
        Kernel("PanelLU", 2.0 * m * nb * nb, KernelKind.GEMM,
               gemm_dims=(int(m), nb, nb)),
        Kernel("PanelBcast", 0.0, KernelKind.COMM),
        Kernel("TRSM", 1.0 * nb * nb * m, KernelKind.GEMM,
               gemm_dims=(nb, nb, int(m))),
        Kernel("Update", 2.0 * m * nb * m, KernelKind.GEMM,
               gemm_dims=(int(m), nb, int(m))),
    ]
    ts = [
        Tensor("panel", "PanelLU", "PanelBcast", m * nb * BYTES),
        Tensor("panel_b", "PanelBcast", "TRSM", m * nb * BYTES),
        Tensor("urow", "TRSM", "Update", nb * m * BYTES),
    ]
    return DataflowGraph(ks, ts, f"hpl_n{int(n)}")


def hpl_workload(n: float = 5e6, nb: int = 512) -> TrainWorkload:
    g = hpl_iteration_graph(n, nb)
    return TrainWorkload(name="hpl_5m2", layer_graph=g,
                         n_layers=1, global_batch=1, microbatch=1,
                         bwd_flop_mult=0.0,        # no backward pass
                         optimizer_bytes_per_param_byte=0.0)

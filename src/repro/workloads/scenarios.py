"""Named DSE scenarios — the paper's four workload families (§VI.C:
GPT3-1T, DLRM-793B, HPL-5M², FFT-1T) plus MoE, Mamba2 and serving/decode
sweeps as first-class scenarios.

Each scenario bundles a *picklable* workload builder (a module-level
function, so ``DSEEngine`` can ship it across process boundaries even under
spawn semantics) with the sweep grid the paper uses for that family, plus a
``smoke`` variant small enough for tests and CI: fewer chips per system, a
reduced grid, and a smaller model that still fits a 64-chip machine.

Consumed by ``benchmarks/bench_dse.py`` and ``examples/dse_scenario.py``:

    engine = DSEEngine()
    result = engine.sweep_scenario("llm", smoke=True)
    result.frontier   # Pareto-optimal systems (util × cost eff × power eff)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from ..configs.mamba2_130m import CONFIG as MAMBA2_130M
from ..configs.mamba2_130m import SMOKE as MAMBA2_SMOKE
from ..configs.qwen3_moe_235b import CONFIG as QWEN3_MOE_235B
from ..configs.qwen3_moe_235b import SMOKE as QWEN3_MOE_SMOKE
from ..core.dse_engine import SweepSpec
from ..core.interchip import TrainWorkload
from ..systems.system import SystemSpec
from .dlrm import dlrm_workload
from .fft import fft_workload
from .hpl import hpl_workload
from .llm import (GPT3_1T, GPT3_175B, LLAMA3_70B, LLAMA_68M, LLMShape,
                  decode_workload, gpt_workload, mamba_workload)


def _shape_from_config(cfg) -> LLMShape:
    """Adapt a ``repro.models.config.ModelConfig`` (the runtime's config
    record) to the graph builders' ``LLMShape``."""
    return LLMShape(name=cfg.name, n_layers=cfg.n_layers,
                    d_model=cfg.d_model, n_heads=cfg.n_heads,
                    n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff,
                    vocab=cfg.vocab, d_head=cfg.head_dim,
                    moe_experts=cfg.moe_experts, moe_top_k=cfg.moe_top_k)


# --- module-level builders (picklable; signature: system -> TrainWorkload) ---
def llm_work(system: SystemSpec) -> TrainWorkload:
    return gpt_workload(GPT3_1T, global_batch=512, microbatch=1)


def llm_smoke_work(system: SystemSpec) -> TrainWorkload:
    # GPT3-1T cannot fit the smoke-sized machines; 175B reproduces the same
    # qualitative heat map at 64 chips.
    return gpt_workload(GPT3_175B, global_batch=512, microbatch=1)


def dlrm_work(system: SystemSpec) -> TrainWorkload:
    return dlrm_workload()


def hpl_work(system: SystemSpec) -> TrainWorkload:
    return hpl_workload()


def fft_work(system: SystemSpec) -> TrainWorkload:
    return fft_workload()


def moe_work(system: SystemSpec) -> TrainWorkload:
    return gpt_workload(_shape_from_config(QWEN3_MOE_235B),
                        global_batch=512, microbatch=1)


def moe_smoke_work(system: SystemSpec) -> TrainWorkload:
    return gpt_workload(_shape_from_config(QWEN3_MOE_SMOKE),
                        global_batch=64, microbatch=1)


def mamba2_work(system: SystemSpec) -> TrainWorkload:
    cfg = MAMBA2_130M
    return mamba_workload(_shape_from_config(cfg), global_batch=512,
                          microbatch=1, d_state=cfg.ssm_state,
                          expand=cfg.ssm_expand)


def mamba2_smoke_work(system: SystemSpec) -> TrainWorkload:
    cfg = MAMBA2_SMOKE
    return mamba_workload(_shape_from_config(cfg), global_batch=64,
                          microbatch=1, d_state=cfg.ssm_state,
                          expand=cfg.ssm_expand)


def serving_work(system: SystemSpec) -> TrainWorkload:
    # LLaMA3-70B decode: 32 requests per microbatch against an 8K KV cache
    return decode_workload(LLAMA3_70B, kv_len=8192, global_batch=512,
                           microbatch=32)


def serving_smoke_work(system: SystemSpec) -> TrainWorkload:
    return decode_workload(LLAMA_68M, kv_len=2048, global_batch=64,
                           microbatch=8)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One workload family's sweep: builder + grid + smoke variant."""

    name: str
    description: str
    work_fn: Callable[[SystemSpec], TrainWorkload]
    spec: SweepSpec
    smoke_work_fn: Callable[[SystemSpec], TrainWorkload] | None = None
    smoke_spec: SweepSpec | None = None

    def resolved(self, smoke: bool) -> "Scenario":
        """The scenario with its smoke variant promoted, if requested."""
        if not smoke:
            return self
        return dataclasses.replace(
            self, work_fn=self.smoke_work_fn or self.work_fn,
            spec=self.smoke_spec or self.spec,
            smoke_work_fn=None, smoke_spec=None)


_SMOKE_GRID = dict(n_chips=64,
                   chips=("H100", "TPUv4", "SN30"),
                   topologies=("torus2d", "dragonfly"),
                   mem_net=(("DDR", "PCIe"), ("HBM", "PCIe"),
                            ("HBM", "NVLink")))

# HPL/FFT run one global problem instance (global_batch=1 ⇒ DP=1); the whole
# machine must be absorbed by TP (×PP), so TP is unbounded for those.
SCENARIOS: dict[str, Scenario] = {
    "llm": Scenario(
        name="llm",
        description="GPT3-1T training, global batch 512 (Figs 10-13)",
        work_fn=llm_work, spec=SweepSpec(max_tp=64),
        smoke_work_fn=llm_smoke_work,
        smoke_spec=SweepSpec(max_tp=64, **_SMOKE_GRID)),
    "dlrm": Scenario(
        name="dlrm",
        description="DLRM-793B recommendation training (Fig 14)",
        work_fn=dlrm_work, spec=SweepSpec(max_tp=64),
        smoke_spec=SweepSpec(max_tp=64, **_SMOKE_GRID)),
    "hpl": Scenario(
        name="hpl",
        description="HPL 5M×5M LINPACK (Fig 15)",
        work_fn=hpl_work, spec=SweepSpec(max_tp=None),
        smoke_spec=SweepSpec(max_tp=None, **_SMOKE_GRID)),
    "fft": Scenario(
        name="fft",
        description="1T-point distributed FFT (Figs 16-17)",
        work_fn=fft_work, spec=SweepSpec(max_tp=None),
        smoke_spec=SweepSpec(max_tp=None, **_SMOKE_GRID)),
    "moe": Scenario(
        name="moe",
        description="Qwen3-MoE-235B training (128 experts, top-8)",
        work_fn=moe_work, spec=SweepSpec(max_tp=64),
        smoke_work_fn=moe_smoke_work,
        smoke_spec=SweepSpec(max_tp=64, **_SMOKE_GRID)),
    "mamba2": Scenario(
        name="mamba2",
        description="Mamba2-130M SSD training (attention-free)",
        work_fn=mamba2_work, spec=SweepSpec(max_tp=64),
        smoke_work_fn=mamba2_smoke_work,
        smoke_spec=SweepSpec(max_tp=64, **_SMOKE_GRID)),
    "serving": Scenario(
        name="serving",
        description="LLaMA3-70B decode serving (batch 32, 8K KV cache)",
        work_fn=serving_work, spec=SweepSpec(max_tp=64),
        smoke_work_fn=serving_smoke_work,
        smoke_spec=SweepSpec(max_tp=64, **_SMOKE_GRID)),
}


def scenario_names() -> list[str]:
    return list(SCENARIOS)


def get_scenario(name: str, smoke: bool = False) -> Scenario:
    try:
        sc = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"available: {scenario_names()}") from None
    return sc.resolved(smoke)

"""Named DSE scenarios — the paper's four workload families (§VI.C:
GPT3-1T, DLRM-793B, HPL-5M², FFT-1T) plus MoE, Mamba2 and serving/decode
sweeps as first-class scenarios.

Each scenario bundles a *picklable* workload builder (a module-level
function, so ``DSEEngine`` can ship it across process boundaries even under
spawn semantics) with the sweep grid the paper uses for that family, plus a
``smoke`` variant small enough for tests and CI: fewer chips per system, a
reduced grid, and a smaller model that still fits a 64-chip machine.

Consumed by ``benchmarks/bench_dse.py`` and ``examples/dse_scenario.py``:

    engine = DSEEngine()
    result = engine.sweep_scenario("llm", smoke=True)
    result.frontier   # Pareto-optimal systems (util × cost eff × power eff)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from ..configs.mamba2_130m import CONFIG as MAMBA2_130M
from ..configs.mamba2_130m import SMOKE as MAMBA2_SMOKE
from ..configs.qwen3_moe_235b import CONFIG as QWEN3_MOE_235B
from ..configs.qwen3_moe_235b import SMOKE as QWEN3_MOE_SMOKE
from ..core.dse_engine import SweepSpec
from ..core.interchip import TrainWorkload
from ..models.config import ModelConfig
from ..systems.system import SystemSpec
from .dlrm import dlrm_workload
from .fft import fft_workload
from .hpl import hpl_workload
from .llm import (BYTES, GPT3_1T, GPT3_175B, LLAMA3_70B, LLAMA_68M, LLMShape,
                  decode_workload, gpt_workload, mamba_decode_workload,
                  mamba_workload)


def _shape_from_config(cfg) -> LLMShape:
    """Adapt a ``repro.models.config.ModelConfig`` (the runtime's config
    record) to the graph builders' ``LLMShape``."""
    return LLMShape(name=cfg.name, n_layers=cfg.n_layers,
                    d_model=cfg.d_model, n_heads=cfg.n_heads,
                    n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff,
                    vocab=cfg.vocab, d_head=cfg.head_dim,
                    moe_experts=cfg.moe_experts, moe_top_k=cfg.moe_top_k)


# --- module-level builders (picklable; signature: system -> TrainWorkload) ---
def llm_work(system: SystemSpec) -> TrainWorkload:
    return gpt_workload(GPT3_1T, global_batch=512, microbatch=1)


def llm_smoke_work(system: SystemSpec) -> TrainWorkload:
    # GPT3-1T cannot fit the smoke-sized machines; 175B reproduces the same
    # qualitative heat map at 64 chips.
    return gpt_workload(GPT3_175B, global_batch=512, microbatch=1)


def dlrm_work(system: SystemSpec) -> TrainWorkload:
    return dlrm_workload()


def hpl_work(system: SystemSpec) -> TrainWorkload:
    return hpl_workload()


def fft_work(system: SystemSpec) -> TrainWorkload:
    return fft_workload()


def moe_work(system: SystemSpec) -> TrainWorkload:
    return gpt_workload(_shape_from_config(QWEN3_MOE_235B),
                        global_batch=512, microbatch=1)


def moe_smoke_work(system: SystemSpec) -> TrainWorkload:
    return gpt_workload(_shape_from_config(QWEN3_MOE_SMOKE),
                        global_batch=64, microbatch=1)


def mamba2_work(system: SystemSpec) -> TrainWorkload:
    cfg = MAMBA2_130M
    return mamba_workload(_shape_from_config(cfg), global_batch=512,
                          microbatch=1, d_state=cfg.ssm_state,
                          expand=cfg.ssm_expand)


def mamba2_smoke_work(system: SystemSpec) -> TrainWorkload:
    cfg = MAMBA2_SMOKE
    return mamba_workload(_shape_from_config(cfg), global_batch=64,
                          microbatch=1, d_state=cfg.ssm_state,
                          expand=cfg.ssm_expand)


def serving_work(system: SystemSpec) -> TrainWorkload:
    # LLaMA3-70B decode: 32 requests per microbatch against an 8K KV cache
    return decode_workload(LLAMA3_70B, kv_len=8192, global_batch=512,
                           microbatch=32)


def serving_smoke_work(system: SystemSpec) -> TrainWorkload:
    return decode_workload(LLAMA_68M, kv_len=2048, global_batch=64,
                           microbatch=8)


# --- executable twins (the modeled-vs-measured bridge) -----------------------
@dataclasses.dataclass(frozen=True)
class ExecutableTwin:
    """The executable half of a validation pair.

    One twin fixes a runtime ``ModelConfig`` plus decode batch geometry such
    that a ``ServeEngine`` decode step over ``batch`` request slots with
    ``kv_len`` cache slots does, token for token, the work the analytical
    decode workload (:meth:`workload`) prices. The correspondence is not
    assumed: :meth:`assert_correspondence` recomputes FLOPs/token and KV
    bytes/request *closed-form from the config dims* and raises unless the
    workload's dataflow graphs agree — the two sides are maintained
    independently (graph builders vs runtime config), so this is the tripwire
    that keeps them from drifting apart.

    ``dense_experts`` mirrors the runtime's decode-time MoE semantics: at one
    token per request the engine runs every expert densely
    (``repro.models.layers.moe_dense`` — dropless, no dispatch), so the
    matched analytical graph prices all ``moe_experts`` experts, not
    ``moe_top_k``.
    """

    scenario: str
    cfg: ModelConfig
    batch: int                   # request slots per decode step
    kv_len: int                  # cache slots per request (engine max_len)
    prompt_len: int = 16         # measurement prompt (slots beyond it idle)
    dense_experts: bool = False  # decode-time MoE: all experts, densely
    wall_gate: bool = False      # big enough that wall-clock is compute/
                                 # memory-bound, not dispatch-bound

    def shape(self) -> LLMShape:
        """The graph builders' view of this twin (seq=1: one token/step)."""
        s = _shape_from_config(self.cfg)
        s = dataclasses.replace(s, seq=1, batch=self.batch)
        if self.dense_experts and s.moe_experts:
            s = dataclasses.replace(s, moe_top_k=s.moe_experts)
        return s

    def workload(self) -> TrainWorkload:
        """The matched analytical decode workload (one decode step per
        'iteration': ``global_batch == microbatch == batch``), including the
        embedding/LM-head blocks the executable step runs every token."""
        s = self.shape()
        if self.cfg.family == "ssm":
            return mamba_decode_workload(
                s, global_batch=self.batch, microbatch=self.batch,
                d_state=self.cfg.ssm_state, expand=self.cfg.ssm_expand,
                lm_head=True)
        return decode_workload(s, kv_len=self.kv_len,
                               global_batch=self.batch,
                               microbatch=self.batch, lm_head=True)

    # --- closed-form accounting (independent of the graph builders) --------
    def flops_per_token(self) -> float:
        """Forward FLOPs one decoded token costs, recomputed from the config
        dims alone (embedding + layers + LM head)."""
        cfg = self.cfg
        d = cfg.d_model
        if cfg.family == "ssm":
            d_in = cfg.ssm_expand * d
            n = cfg.ssm_state
            per_layer = (2.0 * d * (2 * d_in + 2 * n)      # in-proj
                         + 2.0 * d_in * cfg.ssm_conv       # causal conv
                         + 6.0 * d_in * n                  # SSD recurrence
                         + 3.0 * d_in                      # gate
                         + 2.0 * d_in * d)                 # out-proj
        else:
            q = cfg.n_heads * cfg.hd
            kv = cfg.n_kv_heads * cfg.hd
            per_layer = (2.0 * d * (q + 2 * kv)            # QKV
                         + 4.0 * self.kv_len * q           # QK^T + PV
                         + 2.0 * q * d)                    # out-proj
            if cfg.moe_experts:
                k_eff = cfg.moe_experts if self.dense_experts else cfg.moe_top_k
                per_layer += 2.0 * d * cfg.moe_experts     # router
                per_layer += 2.0 * k_eff * 3 * d * cfg.d_ff
            else:
                per_layer += 2.0 * 3 * d * cfg.d_ff        # gated MLP
        return cfg.n_layers * per_layer + 2.0 * d + 2.0 * d * cfg.vocab

    def kv_bytes_per_request(self) -> float:
        """Decode-state bytes one request holds per layer-stack pass: K+V
        cache slots (attention) or the SSD recurrent state + conv window
        (SSM; f32 state, bf16 conv window)."""
        cfg = self.cfg
        if cfg.family == "ssm":
            d_in = cfg.ssm_expand * cfg.d_model
            state = d_in * cfg.ssm_state * 4.0
            conv = (cfg.ssm_conv - 1) * (d_in + 2 * cfg.ssm_state) * BYTES
            return cfg.n_layers * (state + conv)
        return (cfg.n_layers
                * 2.0 * self.kv_len * cfg.n_kv_heads * cfg.hd * BYTES)

    def assert_correspondence(self) -> dict:
        """Certify twin ↔ analytical-workload agreement; raise on drift.

        Returns the compared quantities (for reports/tests). FLOPs/token must
        agree exactly; KV bytes/request (attention families) likewise.
        """
        work = self.workload()
        g = work.layer_graph
        graph_flops = g.total_flops() * work.n_layers
        for blk in (work.pre_graph, work.post_graph):
            if blk is not None:
                graph_flops += blk.total_flops()
        graph_per_token = graph_flops / self.batch
        closed = self.flops_per_token()
        if abs(graph_per_token - closed) > 1e-6 * closed:
            raise AssertionError(
                f"twin {self.scenario!r}: FLOPs/token mismatch — graph "
                f"{graph_per_token:.6g} vs closed-form {closed:.6g}")
        out = {"flops_per_token": closed}
        if self.cfg.family != "ssm":
            attn = next(k for k in g.kernels if k.name == "AttnDec")
            graph_kv = attn.weight_bytes / self.batch * work.n_layers
            closed_kv = self.kv_bytes_per_request()
            if abs(graph_kv - closed_kv) > 1e-6 * closed_kv:
                raise AssertionError(
                    f"twin {self.scenario!r}: KV bytes/request mismatch — "
                    f"graph {graph_kv:.6g} vs closed-form {closed_kv:.6g}")
            out["kv_bytes_per_request"] = closed_kv
        return out


def _serving_twin() -> ExecutableTwin:
    # runtime mirror of workloads.llm.LLAMA_68M (the serving smoke shape)
    cfg = ModelConfig(name="llama_68m", family="dense", n_layers=2,
                      d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                      vocab=32000, param_dtype="bfloat16")
    return ExecutableTwin(scenario="serving", cfg=cfg, batch=8, kv_len=2048,
                          wall_gate=True)


def _moe_twin() -> ExecutableTwin:
    cfg = dataclasses.replace(QWEN3_MOE_SMOKE, param_dtype="bfloat16")
    return ExecutableTwin(scenario="moe", cfg=cfg, batch=8, kv_len=256,
                          dense_experts=True)


def _mamba2_twin() -> ExecutableTwin:
    cfg = dataclasses.replace(MAMBA2_SMOKE, param_dtype="bfloat16")
    return ExecutableTwin(scenario="mamba2", cfg=cfg, batch=8, kv_len=256)


_TWINS: dict[str, Callable[[], ExecutableTwin]] = {
    "serving": _serving_twin,
    "moe": _moe_twin,
    "mamba2": _mamba2_twin,
}


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One workload family's sweep: builder + grid + smoke variant."""

    name: str
    description: str
    work_fn: Callable[[SystemSpec], TrainWorkload]
    spec: SweepSpec
    smoke_work_fn: Callable[[SystemSpec], TrainWorkload] | None = None
    smoke_spec: SweepSpec | None = None

    def resolved(self, smoke: bool) -> "Scenario":
        """The scenario with its smoke variant promoted, if requested."""
        if not smoke:
            return self
        return dataclasses.replace(
            self, work_fn=self.smoke_work_fn or self.work_fn,
            spec=self.smoke_spec or self.spec,
            smoke_work_fn=None, smoke_spec=None)

    def executable_twin(self) -> ExecutableTwin:
        """The runtime twin of this scenario's smoke decode workload, with
        its modeled↔measured correspondence certified (raises on drift).
        Only the families the jax execution layer can serve have twins."""
        try:
            build = _TWINS[self.name]
        except KeyError:
            raise NotImplementedError(
                f"scenario {self.name!r} has no executable twin; "
                f"available: {sorted(_TWINS)}") from None
        twin = build()
        twin.assert_correspondence()
        return twin


_SMOKE_GRID = dict(n_chips=64,
                   chips=("H100", "TPUv4", "SN30"),
                   topologies=("torus2d", "dragonfly"),
                   mem_net=(("DDR", "PCIe"), ("HBM", "PCIe"),
                            ("HBM", "NVLink")))

# HPL/FFT run one global problem instance (global_batch=1 ⇒ DP=1); the whole
# machine must be absorbed by TP (×PP), so TP is unbounded for those.
SCENARIOS: dict[str, Scenario] = {
    "llm": Scenario(
        name="llm",
        description="GPT3-1T training, global batch 512 (Figs 10-13)",
        work_fn=llm_work, spec=SweepSpec(max_tp=64),
        smoke_work_fn=llm_smoke_work,
        smoke_spec=SweepSpec(max_tp=64, **_SMOKE_GRID)),
    "dlrm": Scenario(
        name="dlrm",
        description="DLRM-793B recommendation training (Fig 14)",
        work_fn=dlrm_work, spec=SweepSpec(max_tp=64),
        smoke_spec=SweepSpec(max_tp=64, **_SMOKE_GRID)),
    "hpl": Scenario(
        name="hpl",
        description="HPL 5M×5M LINPACK (Fig 15)",
        work_fn=hpl_work, spec=SweepSpec(max_tp=None),
        smoke_spec=SweepSpec(max_tp=None, **_SMOKE_GRID)),
    "fft": Scenario(
        name="fft",
        description="1T-point distributed FFT (Figs 16-17)",
        work_fn=fft_work, spec=SweepSpec(max_tp=None),
        smoke_spec=SweepSpec(max_tp=None, **_SMOKE_GRID)),
    "moe": Scenario(
        name="moe",
        description="Qwen3-MoE-235B training (128 experts, top-8)",
        work_fn=moe_work, spec=SweepSpec(max_tp=64),
        smoke_work_fn=moe_smoke_work,
        smoke_spec=SweepSpec(max_tp=64, **_SMOKE_GRID)),
    "mamba2": Scenario(
        name="mamba2",
        description="Mamba2-130M SSD training (attention-free)",
        work_fn=mamba2_work, spec=SweepSpec(max_tp=64),
        smoke_work_fn=mamba2_smoke_work,
        smoke_spec=SweepSpec(max_tp=64, **_SMOKE_GRID)),
    "serving": Scenario(
        name="serving",
        description="LLaMA3-70B decode serving (batch 32, 8K KV cache)",
        work_fn=serving_work, spec=SweepSpec(max_tp=64),
        smoke_work_fn=serving_smoke_work,
        smoke_spec=SweepSpec(max_tp=64, **_SMOKE_GRID)),
}


def scenario_names() -> list[str]:
    return list(SCENARIOS)


def get_scenario(name: str, smoke: bool = False) -> Scenario:
    try:
        sc = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"available: {scenario_names()}") from None
    return sc.resolved(smoke)

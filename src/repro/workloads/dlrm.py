"""DLRM dataflow graph (paper §VI.C.2 — 793B-parameter recommendation model).

Structure (Mudigere et al. [61]): huge sparse embedding tables (model-parallel
→ all-to-all to redistribute pooled embeddings), bottom MLP on dense features,
pairwise feature interaction, top MLP. Embedding bytes dominate memory; the
all-to-all dominates the network (the paper's DLRM heatmaps show NVLink /
dragonfly winning for exactly this reason).
"""
from __future__ import annotations

from ..core.graph import DataflowGraph, Kernel, KernelKind, Tensor
from ..core.interchip import TrainWorkload

BYTES = 2


def dlrm_layer_graph(batch: int = 4096, n_tables: int = 856,
                     table_rows: float = 5e6, embed_dim: int = 128,
                     n_dense: int = 13, bottom_mlp=(512, 256, 128),
                     top_mlp=(1024, 1024, 512, 256, 1)) -> DataflowGraph:
    ks: list[Kernel] = []
    ts: list[Tensor] = []
    emb_out = batch * n_tables * embed_dim * BYTES

    ks.append(Kernel("EmbLookup", 2.0 * batch * n_tables * embed_dim,
                     KernelKind.EMBEDDING,
                     weight_bytes=n_tables * table_rows * embed_dim * BYTES))
    ks.append(Kernel("EmbA2A", 0.0, KernelKind.COMM))
    ts.append(Tensor("emb_pooled", "EmbLookup", "EmbA2A", emb_out))

    prev, prev_d = "EmbA2A", n_tables * embed_dim
    d_in = n_dense
    for i, d_out in enumerate(bottom_mlp):
        ks.append(Kernel(f"BotMLP{i}", 2.0 * batch * d_in * d_out,
                         KernelKind.GEMM, weight_bytes=d_in * d_out * BYTES,
                         gemm_dims=(batch, d_in, d_out)))
        if i:
            ts.append(Tensor(f"bot{i}", f"BotMLP{i-1}", f"BotMLP{i}",
                             batch * d_in * BYTES))
        d_in = d_out

    # pairwise interaction of (tables + 1) feature vectors
    f = n_tables + 1
    ks.append(Kernel("Interact", 2.0 * batch * f * f * embed_dim,
                     KernelKind.GEMM, gemm_dims=(f, embed_dim, f)))
    ts.append(Tensor("emb_feat", prev, "Interact", emb_out))
    ts.append(Tensor("bot_feat", f"BotMLP{len(bottom_mlp)-1}", "Interact",
                     batch * bottom_mlp[-1] * BYTES))

    d_in = f * (f - 1) // 2 + bottom_mlp[-1]
    prev = "Interact"
    prev_b = batch * d_in * BYTES
    for i, d_out in enumerate(top_mlp):
        ks.append(Kernel(f"TopMLP{i}", 2.0 * batch * d_in * d_out,
                         KernelKind.GEMM, weight_bytes=d_in * d_out * BYTES,
                         gemm_dims=(batch, d_in, d_out)))
        ts.append(Tensor(f"top{i}", prev, f"TopMLP{i}", prev_b))
        prev, prev_b, d_in = f"TopMLP{i}", batch * d_out * BYTES, d_out

    return DataflowGraph(ks, ts, f"dlrm_b{batch}")


def dlrm_workload(global_batch: int = 65536, microbatch: int = 4096,
                  params: float = 793e9) -> TrainWorkload:
    """793B DLRM: parameters dominated by embedding tables."""
    embed_dim = 128
    n_tables = 856
    rows = params / (n_tables * embed_dim)
    g = dlrm_layer_graph(batch=microbatch, n_tables=n_tables,
                         table_rows=rows, embed_dim=embed_dim)
    return TrainWorkload(name="dlrm_793b", layer_graph=g, n_layers=1,
                         global_batch=global_batch, microbatch=microbatch,
                         # embedding grads are sparse → tiny DP traffic;
                         # approximate with dense MLP grads only via mult
                         optimizer_bytes_per_param_byte=1.5)

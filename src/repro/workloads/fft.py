"""Distributed FFT dataflow graph (paper §VI.C.4 — 1T-point FFT).

Pencil/volumetric decomposition [44]: three local FFT stages separated by two
global transposes (all-to-all). Communication-intensive — the paper's FFT
heatmaps (Fig 16/17) show NVLink/dragonfly dominating, mirroring DLRM.
"""
from __future__ import annotations

import math

from ..core.graph import DataflowGraph, Kernel, KernelKind, Tensor
from ..core.interchip import TrainWorkload

BYTES = 8  # complex64


def fft_graph(n_points: float = 1e12) -> DataflowGraph:
    n1 = round(n_points ** (1 / 3))
    flops_stage = 5.0 * n_points * math.log2(max(n1, 2))  # 5N log2(n) per dim
    vol = n_points * BYTES
    ks = [
        Kernel("FFT_x", flops_stage, KernelKind.FFT, gemm_dims=(n1, n1, n1)),
        Kernel("Transpose1", 0.0, KernelKind.COMM),
        Kernel("FFT_y", flops_stage, KernelKind.FFT, gemm_dims=(n1, n1, n1)),
        Kernel("Transpose2", 0.0, KernelKind.COMM),
        Kernel("FFT_z", flops_stage, KernelKind.FFT, gemm_dims=(n1, n1, n1)),
    ]
    ts = [
        Tensor("v1", "FFT_x", "Transpose1", vol),
        Tensor("v2", "Transpose1", "FFT_y", vol),
        Tensor("v3", "FFT_y", "Transpose2", vol),
        Tensor("v4", "Transpose2", "FFT_z", vol),
    ]
    return DataflowGraph(ks, ts, f"fft_{n_points:.0e}")


def fft_workload(n_points: float = 1e12) -> TrainWorkload:
    return TrainWorkload(name="fft_1t", layer_graph=fft_graph(n_points),
                         n_layers=1, global_batch=1, microbatch=1,
                         bwd_flop_mult=0.0,
                         optimizer_bytes_per_param_byte=0.0)

"""LLM dataflow-graph builders (paper Fig 2A generalized).

Builds the per-layer kernel graph {QKV, MHA1, Softmax, MHA2, Proj, FFN0,
FFN1, Add} for one microbatch, extended for GQA, MoE (router + expert GEMMs),
Mamba2/SSD layers, cross-attention (VLM / enc-dec), and decode-phase graphs
(one token against a KV cache). All FLOPs are forward-pass; byte sizes are
bf16 activations unless noted.
"""
from __future__ import annotations

import dataclasses

from ..core.graph import DataflowGraph, Kernel, KernelKind, Tensor

BYTES = 2  # bf16


@dataclasses.dataclass(frozen=True)
class LLMShape:
    """Model + batch geometry for graph building."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    seq: int = 2048
    batch: int = 1                   # sequences per microbatch
    moe_experts: int = 0
    moe_top_k: int = 0
    d_head: int | None = None
    gated: bool = True           # SwiGLU (3 FFN mats) vs classic GELU MLP (2)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def params(self) -> float:
        """Approximate parameter count (weights only)."""
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        if self.moe_experts:
            ffn = self.moe_experts * 3 * d * self.d_ff + d * self.moe_experts
        else:
            ffn = 3 * d * self.d_ff  # gated MLP (SwiGLU-style)
        return self.n_layers * (attn + ffn) + 2 * self.vocab * d

    @property
    def active_params(self) -> float:
        d = self.d_model
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        if self.moe_experts:
            ffn = self.moe_top_k * 3 * d * self.d_ff + d * self.moe_experts
        else:
            ffn = 3 * d * self.d_ff
        return self.n_layers * (attn + ffn) + 2 * self.vocab * d


def gpt_layer_graph(s: LLMShape, causal: bool = True,
                    cross_attention: bool = False) -> DataflowGraph:
    """One transformer layer for one microbatch of s.batch sequences."""
    T = s.batch * s.seq                      # tokens in the microbatch
    d, hd = s.d_model, s.head_dim
    q_dim, kv_dim = s.n_heads * hd, s.n_kv_heads * hd
    att_factor = 0.5 if causal else 1.0      # causal masking halves the work

    ks: list[Kernel] = []
    ts: list[Tensor] = []

    def K(name, flops, kind, weight_bytes=0.0, gemm_dims=None):
        ks.append(Kernel(name, flops, kind, weight_bytes, gemm_dims))

    def E(name, src, dst, b):
        ts.append(Tensor(name, src, dst, b))

    K("LN1", 5.0 * T * d, KernelKind.NORM)
    K("QKV", 2.0 * T * d * (q_dim + 2 * kv_dim), KernelKind.GEMM,
      weight_bytes=d * (q_dim + 2 * kv_dim) * BYTES, gemm_dims=(T, d, q_dim + 2 * kv_dim))
    # fused attention region kept as explicit kernels (the intra-chip pass
    # decides to fuse them — FlashAttention correspondence). MHA1/MHA2 are
    # ATTENTION kind: head-sharded under TP (Megatron), no weights.
    K("MHA1", 2.0 * T * s.seq * q_dim * att_factor, KernelKind.ATTENTION,
      gemm_dims=(T, hd, s.seq))
    K("Softmax", 5.0 * T * s.seq * s.n_heads * att_factor, KernelKind.SOFTMAX)
    K("MHA2", 2.0 * T * s.seq * q_dim * att_factor, KernelKind.ATTENTION,
      gemm_dims=(T, s.seq, hd))
    K("Proj", 2.0 * T * q_dim * d, KernelKind.GEMM, weight_bytes=q_dim * d * BYTES,
      gemm_dims=(T, q_dim, d))
    K("Add1", T * d, KernelKind.ELEMENTWISE)
    K("LN2", 5.0 * T * d, KernelKind.NORM)

    E("x_ln1", "LN1", "QKV", T * d * BYTES)
    E("qkv_scores", "QKV", "MHA1", T * (q_dim + 2 * kv_dim) * BYTES)
    E("scores", "MHA1", "Softmax", T * s.seq * s.n_heads * BYTES * att_factor)
    E("probs", "Softmax", "MHA2", T * s.seq * s.n_heads * BYTES * att_factor)
    E("attn_out", "MHA2", "Proj", T * q_dim * BYTES)
    E("proj_out", "Proj", "Add1", T * d * BYTES)
    E("resid1", "Add1", "LN2", T * d * BYTES)

    prev = "LN2"
    if cross_attention:
        K("XQ", 2.0 * T * d * q_dim, KernelKind.GEMM, weight_bytes=d * q_dim * BYTES,
          gemm_dims=(T, d, q_dim))
        K("XAttn", 4.0 * T * s.seq * q_dim, KernelKind.ATTENTION,
          gemm_dims=(T, hd, s.seq))
        K("XProj", 2.0 * T * q_dim * d, KernelKind.GEMM,
          weight_bytes=(q_dim * d + 2 * d * kv_dim) * BYTES, gemm_dims=(T, q_dim, d))
        K("AddX", T * d, KernelKind.ELEMENTWISE)
        K("LNX", 5.0 * T * d, KernelKind.NORM)
        E("x_xq", "LN2", "XQ", T * d * BYTES)
        E("xq_attn", "XQ", "XAttn", T * q_dim * BYTES)
        E("xattn_out", "XAttn", "XProj", T * q_dim * BYTES)
        E("xproj_out", "XProj", "AddX", T * d * BYTES)
        E("residx", "AddX", "LNX", T * d * BYTES)
        prev = "LNX"

    if s.moe_experts:
        K("Router", 2.0 * T * d * s.moe_experts, KernelKind.ROUTER,
          weight_bytes=d * s.moe_experts * BYTES)
        # top-k experts each run a gated MLP on its share of tokens
        tok_flops = 2.0 * (T * s.moe_top_k) * d * s.d_ff * 3
        K("FFN0", tok_flops * 2 / 3, KernelKind.GEMM,
          weight_bytes=s.moe_experts * 2 * d * s.d_ff * BYTES,
          gemm_dims=(T * s.moe_top_k, d, s.d_ff))
        K("FFN1", tok_flops * 1 / 3, KernelKind.GEMM,
          weight_bytes=s.moe_experts * s.d_ff * d * BYTES,
          gemm_dims=(T * s.moe_top_k, s.d_ff, d))
        K("Add2", T * d, KernelKind.ELEMENTWISE)
        E("x_rt", prev, "Router", T * d * BYTES)
        E("dispatched", "Router", "FFN0", T * s.moe_top_k * d * BYTES)
        E("ffn_mid", "FFN0", "FFN1", T * s.moe_top_k * s.d_ff * BYTES)
        E("ffn_out", "FFN1", "Add2", T * d * BYTES)
    else:
        up = 2 if s.gated else 1   # SwiGLU has gate+up projections
        K("FFN0", 2.0 * T * d * s.d_ff * up, KernelKind.GEMM,
          weight_bytes=up * d * s.d_ff * BYTES, gemm_dims=(T, d, s.d_ff))
        K("FFN1", 2.0 * T * s.d_ff * d, KernelKind.GEMM,
          weight_bytes=s.d_ff * d * BYTES, gemm_dims=(T, s.d_ff, d))
        K("Add2", T * d, KernelKind.ELEMENTWISE)
        E("x_ffn", prev, "FFN0", T * d * BYTES)
        E("ffn_mid", "FFN0", "FFN1", T * s.d_ff * BYTES)
        E("ffn_out", "FFN1", "Add2", T * d * BYTES)

    return DataflowGraph(ks, ts, f"{s.name}_layer_s{s.seq}_b{s.batch}")


def mamba_layer_graph(s: LLMShape, d_state: int = 128,
                      expand: int = 2) -> DataflowGraph:
    """Mamba2 (SSD) layer: in-proj, conv, SSD chunk scan, gate, out-proj."""
    T = s.batch * s.seq
    d = s.d_model
    d_in = expand * d
    ks = [
        Kernel("InProj", 2.0 * T * d * (2 * d_in + 2 * d_state), KernelKind.GEMM,
               weight_bytes=d * (2 * d_in + 2 * d_state) * BYTES,
               gemm_dims=(T, d, 2 * d_in)),
        Kernel("Conv1d", 2.0 * T * d_in * 4, KernelKind.ELEMENTWISE,
               weight_bytes=d_in * 4 * BYTES),
        Kernel("SSD", 6.0 * T * d_in * d_state, KernelKind.SCAN,
               gemm_dims=(T, d_state, d_in)),
        Kernel("Gate", T * d_in * 3.0, KernelKind.ELEMENTWISE),
        Kernel("OutProj", 2.0 * T * d_in * d, KernelKind.GEMM,
               weight_bytes=d_in * d * BYTES, gemm_dims=(T, d_in, d)),
    ]
    ts = [
        Tensor("xz", "InProj", "Conv1d", T * d_in * BYTES),
        Tensor("xc", "Conv1d", "SSD", T * d_in * BYTES),
        Tensor("y_ssd", "SSD", "Gate", T * d_in * BYTES),
        Tensor("y_gate", "Gate", "OutProj", T * d_in * BYTES),
    ]
    return DataflowGraph(ks, ts, f"{s.name}_mamba_s{s.seq}_b{s.batch}")


def decode_layer_graph(s: LLMShape, kv_len: int,
                       cross_attention: bool = False) -> DataflowGraph:
    """One layer of single-token decode for a batch of s.batch requests.

    KV cache reads dominate: MHA kernels stream kv_len keys/values per head.
    """
    B = s.batch
    d, hd = s.d_model, s.head_dim
    q_dim, kv_dim = s.n_heads * hd, s.n_kv_heads * hd
    ks = [
        Kernel("QKV", 2.0 * B * d * (q_dim + 2 * kv_dim), KernelKind.GEMM,
               weight_bytes=d * (q_dim + 2 * kv_dim) * BYTES, gemm_dims=(B, d, q_dim)),
        Kernel("AttnDec", 4.0 * B * kv_len * q_dim, KernelKind.ATTENTION,
               gemm_dims=(B * s.n_heads, hd, kv_len)),
        Kernel("Proj", 2.0 * B * q_dim * d, KernelKind.GEMM,
               weight_bytes=q_dim * d * BYTES, gemm_dims=(B, q_dim, d)),
    ]
    ts = [
        Tensor("q", "QKV", "AttnDec", B * q_dim * BYTES),
        Tensor("attn_out", "AttnDec", "Proj", B * q_dim * BYTES),
    ]
    # KV cache traffic is modeled as kernel 'weight' bytes of AttnDec (it
    # streams from DRAM each step, exactly like weights):
    ks[1] = dataclasses.replace(
        ks[1], weight_bytes=2.0 * B * kv_len * kv_dim * BYTES)
    if s.moe_experts:
        ks.append(Kernel("Router", 2.0 * B * d * s.moe_experts,
                         KernelKind.ROUTER, weight_bytes=d * s.moe_experts * BYTES))
        ks.append(Kernel("FFN", 2.0 * B * s.moe_top_k * 3 * d * s.d_ff,
                         KernelKind.GEMM,
                         weight_bytes=s.moe_experts * 3 * d * s.d_ff * BYTES,
                         gemm_dims=(B * s.moe_top_k, d, s.d_ff)))
        ts.append(Tensor("x_rt", "Proj", "Router", B * d * BYTES))
        ts.append(Tensor("disp", "Router", "FFN",
                         B * s.moe_top_k * d * BYTES))
    else:
        ks.append(Kernel("FFN", 2.0 * B * 3 * d * s.d_ff, KernelKind.GEMM,
                         weight_bytes=3 * d * s.d_ff * BYTES, gemm_dims=(B, d, s.d_ff)))
        ts.append(Tensor("x_ffn", "Proj", "FFN", B * d * BYTES))
    return DataflowGraph(ks, ts, f"{s.name}_decode_kv{kv_len}_b{B}")


def embedding_graph(s: LLMShape) -> DataflowGraph:
    T = s.batch * s.seq
    return DataflowGraph(
        [Kernel("Embed", 2.0 * T * s.d_model, KernelKind.EMBEDDING,
                weight_bytes=s.vocab * s.d_model * BYTES)],
        [], f"{s.name}_embed")


def lm_head_graph(s: LLMShape) -> DataflowGraph:
    T = s.batch * s.seq
    return DataflowGraph(
        [Kernel("LMHead", 2.0 * T * s.d_model * s.vocab, KernelKind.GEMM,
                weight_bytes=s.vocab * s.d_model * BYTES, gemm_dims=(T, s.d_model, s.vocab))],
        [], f"{s.name}_head")


def gpt_workload(s: LLMShape, global_batch: int,
                 microbatch: int = 1):
    """Full training workload (paper's GPT3 setups)."""
    from ..core.interchip import TrainWorkload
    ms = dataclasses.replace(s, batch=microbatch)
    return TrainWorkload(
        name=s.name,
        layer_graph=gpt_layer_graph(ms),
        n_layers=s.n_layers,
        global_batch=global_batch,
        microbatch=microbatch,
        pre_graph=embedding_graph(ms),
        post_graph=lm_head_graph(ms),
    )


def mamba_workload(s: LLMShape, global_batch: int, microbatch: int = 1,
                   d_state: int = 128, expand: int = 2):
    """Mamba2/SSD training workload: attention-free layers, same embedding
    and LM-head blocks as the transformer setups."""
    from ..core.interchip import TrainWorkload
    ms = dataclasses.replace(s, batch=microbatch)
    return TrainWorkload(
        name=s.name,
        layer_graph=mamba_layer_graph(ms, d_state=d_state, expand=expand),
        n_layers=s.n_layers,
        global_batch=global_batch,
        microbatch=microbatch,
        pre_graph=embedding_graph(ms),
        post_graph=lm_head_graph(ms),
    )


def decode_workload(s: LLMShape, kv_len: int, global_batch: int,
                    microbatch: int = 1, lm_head: bool = False):
    """Serving/decode-phase workload: one token per request against a
    ``kv_len`` KV cache, ``microbatch`` requests per pipeline microbatch.

    Inference-only semantics: no backward pass (``bwd_flop_mult=0``), no
    optimizer state, and no DP gradient all-reduce — DP replicas serve
    disjoint request streams. ``global_batch`` is the number of requests
    per 'iteration' (one decode step across the serving batch).

    ``lm_head=True`` adds the embedding/LM-head blocks at one token per
    request — the executable decode step runs them every step, and for
    small-vocab-dominated shapes the head is comparable to all layers
    combined, so validation against measured execution must include it.
    """
    from ..core.interchip import TrainWorkload
    ms = dataclasses.replace(s, batch=microbatch)
    tok = dataclasses.replace(s, batch=microbatch, seq=1)
    return TrainWorkload(
        name=f"{s.name}_decode",
        layer_graph=decode_layer_graph(ms, kv_len),
        n_layers=s.n_layers,
        global_batch=global_batch,
        microbatch=microbatch,
        pre_graph=embedding_graph(tok) if lm_head else None,
        post_graph=lm_head_graph(tok) if lm_head else None,
        bwd_flop_mult=0.0,
        bwd_comm_mult=0.0,
        optimizer_bytes_per_param_byte=0.0,
        dp_allreduce=False,
    )


def mamba_decode_workload(s: LLMShape, global_batch: int,
                          microbatch: int = 1, d_state: int = 128,
                          expand: int = 2, lm_head: bool = False):
    """Mamba2/SSD decode workload: one token per request, recurrent state
    instead of a KV cache (the per-step SSD cost is ``seq``-independent, so
    the seq=1 layer graph *is* the decode graph). Same inference-only
    semantics as :func:`decode_workload`."""
    from ..core.interchip import TrainWorkload
    tok = dataclasses.replace(s, batch=microbatch, seq=1)
    return TrainWorkload(
        name=f"{s.name}_decode",
        layer_graph=mamba_layer_graph(tok, d_state=d_state, expand=expand),
        n_layers=s.n_layers,
        global_batch=global_batch,
        microbatch=microbatch,
        pre_graph=embedding_graph(tok) if lm_head else None,
        post_graph=lm_head_graph(tok) if lm_head else None,
        bwd_flop_mult=0.0,
        bwd_comm_mult=0.0,
        optimizer_bytes_per_param_byte=0.0,
        dp_allreduce=False,
    )


# --- named shapes from the paper ---------------------------------------------
GPT3_175B = LLMShape("gpt3_175b", 96, 12288, 96, 96, 4 * 12288, 50257,
                     seq=2048, gated=False)
GPT3_1T = LLMShape("gpt3_1t", 128, 25600, 160, 160, 4 * 25600, 51200,
                   seq=2048, gated=False)
GPT_100T = LLMShape("gpt_100t", 512, 80000, 500, 500, 4 * 80000, 51200,
                    seq=2048, gated=False)
LLAMA3_8B = LLMShape("llama3_8b", 32, 4096, 32, 8, 14336, 128256, seq=8192)
LLAMA3_70B = LLMShape("llama3_70b", 80, 8192, 64, 8, 28672, 128256, seq=8192)
LLAMA3_405B = LLMShape("llama3_405b", 126, 16384, 128, 8, 53248, 128256,
                       seq=8192)
LLAMA_68M = LLMShape("llama_68m", 2, 768, 12, 12, 3072, 32000, seq=2048)

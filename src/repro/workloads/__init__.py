from .llm import (LLMShape, gpt_layer_graph, gpt_workload, decode_layer_graph,
                  GPT3_175B, GPT3_1T, GPT_100T, LLAMA3_8B, LLAMA3_70B,
                  LLAMA3_405B, LLAMA_68M)
from .dlrm import dlrm_workload
from .hpl import hpl_workload
from .fft import fft_workload
from .scenarios import SCENARIOS, Scenario, get_scenario, scenario_names

__all__ = [
    "SCENARIOS", "Scenario", "get_scenario", "scenario_names",
    "LLMShape", "gpt_layer_graph", "gpt_workload", "decode_layer_graph",
    "GPT3_175B", "GPT3_1T", "GPT_100T", "LLAMA3_8B", "LLAMA3_70B",
    "LLAMA3_405B", "LLAMA_68M",
    "dlrm_workload", "hpl_workload", "fft_workload",
]

from .engine import ServeEngine, GenerationResult
from .specdecode import speculative_generate

__all__ = ["ServeEngine", "GenerationResult", "speculative_generate"]

"""Sequence speculative decoding (Leviathan et al. [50]; paper §VIII.B).

Draft model proposes K tokens autoregressively; the target model scores the
whole window in ONE forward pass; tokens are accepted with probability
min(1, p_target/p_draft) (greedy variant: accept while argmax matches).
The analytical twin (expected tokens/step vs K and acceptance rate) lives in
core/serving.py; this is the executable version used by the tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import forward
from ..models.config import ModelConfig


def speculative_generate(target_cfg: ModelConfig, target_params,
                         draft_cfg: ModelConfig, draft_params,
                         prompt: jax.Array, n_tokens: int, window: int = 4):
    """Greedy sequence speculative decoding (KV-less reference executor:
    both models re-run on the growing sequence — correctness oracle for the
    acceptance logic, small-model scale).

    prompt: (1, S). Returns (tokens list, acceptance_rate, n_target_calls).
    """
    seq = prompt
    produced = 0
    accepted_total = 0
    proposed_total = 0
    target_calls = 0
    out: list[int] = []
    while produced < n_tokens:
        k = min(window, n_tokens - produced)
        # draft proposes k tokens greedily
        dseq = seq
        proposal = []
        for _ in range(k):
            dlogits = forward(draft_cfg, draft_params, dseq, remat=False)
            nxt = jnp.argmax(dlogits[:, -1], -1).astype(jnp.int32)
            proposal.append(int(nxt[0]))
            dseq = jnp.concatenate([dseq, nxt[:, None]], axis=1)
        # target verifies in one pass over seq + proposal
        ver_in = jnp.concatenate(
            [seq, jnp.asarray([proposal], jnp.int32)], axis=1)
        tlogits = forward(target_cfg, target_params, ver_in, remat=False)
        target_calls += 1
        s0 = seq.shape[1]
        greedy = jnp.argmax(tlogits[0, s0 - 1:s0 - 1 + k], -1)
        n_acc = 0
        for i in range(k):
            if int(greedy[i]) == proposal[i]:
                n_acc += 1
            else:
                break
        accepted = proposal[:n_acc]
        # bonus token from the target at the first mismatch (or window end)
        bonus = int(greedy[n_acc]) if n_acc < k else int(
            jnp.argmax(tlogits[0, s0 - 1 + k], -1))
        new_toks = accepted + [bonus]
        out.extend(new_toks)
        produced += len(new_toks)
        seq = jnp.concatenate(
            [seq, jnp.asarray([new_toks], jnp.int32)], axis=1)
        accepted_total += n_acc
        proposed_total += k
    rate = accepted_total / max(proposed_total, 1)
    return out[:n_tokens], rate, target_calls

"""Batched serving engine: prefill + decode with a slot-based KV cache
(continuous-batching-lite: fixed slots, per-slot position counters, greedy or
temperature sampling). This is the executable twin of the paper's §VIII.A
serving model — TTFT = prefill latency, TPOT = decode step latency.

Two decode drivers share the jitted step:

* :meth:`ServeEngine.generate` — the serving path: one sync at the end of
  the decode loop, so XLA pipelines step dispatch (throughput-faithful
  TPOT over the whole run);
* :meth:`ServeEngine.decode_steady` — the measurement path: warmup steps
  are discarded (compile + cache effects), then every steady-state step is
  individually synced and timed, so the validation loop gets a per-step
  sample distribution instead of one average.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig


@dataclasses.dataclass
class GenerationResult:
    tokens: list
    ttft: float
    tpot: float
    tokens_per_s: float


@dataclasses.dataclass
class SteadyTiming:
    """Steady-state decode timings: ``step_times`` are post-warmup decode
    steps, each synced (``block_until_ready``) before its clock is read."""

    ttft: float                  # prefill + first sampled token, synced
    warmup: int                  # discarded decode steps before timing
    step_times: list[float]      # seconds per timed steady-state step
    batch: int                   # request slots served per step

    @property
    def tpot(self) -> float:
        """Mean steady-state time-per-output-token (seconds)."""
        return sum(self.step_times) / max(len(self.step_times), 1)

    @property
    def tokens_per_s(self) -> float:
        t = self.tpot
        return self.batch / t if t > 0 else 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_len: int = 1024):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # both jitted paths close over cfg and thread the cross-attention
        # memory operand — tests assert a memory change reaches the logits
        self._decode = jax.jit(
            lambda p, c, t, pos, mem: decode_step(cfg, p, c, t, pos,
                                                  memory=mem))
        self._prefill = jax.jit(
            lambda p, t, mem: prefill(cfg, p, t, memory=mem))

    # --- shared plumbing ----------------------------------------------------
    def _check_window(self, s: int, n_tokens: int) -> None:
        if s + n_tokens > self.max_len:
            raise ValueError(
                f"decode window overflows the KV cache: prompt length {s} "
                f"+ {n_tokens} new tokens > max_len {self.max_len}; "
                f"re-create the engine with max_len >= {s + n_tokens}")

    def _rehome(self, cache0: dict, b: int, s: int) -> dict:
        """Move the prefill cache (length s) into the serving-length cache.

        ``_check_window`` has already bounded ``s`` strictly below
        ``max_len``, so the slot write below cannot clip silently.
        """
        cache = init_cache(self.cfg, b, self.max_len)
        if "k" in cache0:
            cache["k"] = cache["k"].at[:, :, :, :s].set(cache0["k"])
            cache["v"] = cache["v"].at[:, :, :, :s].set(cache0["v"])
        if "ssm" in cache0:
            cache["ssm"] = cache0["ssm"]
            cache["conv"] = cache0["conv"]
        return cache

    @staticmethod
    def _sample(logits: jax.Array, temperature: float,
                rng: jax.Array | None):
        if temperature <= 0.0 or rng is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(rng, logits / temperature
                                      ).astype(jnp.int32)

    @staticmethod
    def _next_key(rng: jax.Array | None):
        """Per-step subkey: a fixed key every step would make 'sampling'
        draw the same categorical variate at each position."""
        if rng is None:
            return None, None
        rng, sub = jax.random.split(rng)
        return rng, sub

    # --- serving path -------------------------------------------------------
    def generate(self, prompts: jax.Array, n_tokens: int,
                 memory: jax.Array | None = None,
                 temperature: float = 0.0,
                 rng: jax.Array | None = None) -> GenerationResult:
        """prompts: (B, S) int32 (same length; pad upstream)."""
        b, s = prompts.shape
        self._check_window(s, n_tokens)
        t0 = time.perf_counter()
        logits, cache0 = self._prefill(self.params, prompts, memory)
        cache = self._rehome(cache0, b, s)
        rng, sub = self._next_key(rng)
        next_tok = self._sample(logits[:, -1], temperature, sub)
        jax.block_until_ready(next_tok)
        ttft = time.perf_counter() - t0

        toks = [next_tok]
        t1 = time.perf_counter()
        pos = s
        for _ in range(n_tokens - 1):
            logits_i, cache = self._decode(self.params, cache, toks[-1],
                                           jnp.int32(pos), memory)
            rng, sub = self._next_key(rng)
            toks.append(self._sample(logits_i, temperature, sub))
            pos += 1
        jax.block_until_ready(toks[-1])
        dt = time.perf_counter() - t1
        tpot = dt / max(n_tokens - 1, 1)
        return GenerationResult(
            tokens=[t.tolist() for t in toks], ttft=ttft, tpot=tpot,
            tokens_per_s=b * n_tokens / (ttft + dt))

    # --- measurement path ---------------------------------------------------
    def decode_steady(self, prompts: jax.Array, n_steps: int = 16,
                      warmup: int = 2,
                      memory: jax.Array | None = None) -> SteadyTiming:
        """Steady-state greedy decode with per-step timing.

        Runs prefill, then ``warmup`` decode steps whose times are discarded
        (the first step pays compilation, the next ones cache/allocator
        warmup), then ``n_steps`` steps each synced and timed individually.
        The decode step's cost is ``max_len``-shaped (slot attention runs
        over the whole cache regardless of position), so every steady step
        does identical work — the per-step spread is measurement noise, not
        workload drift, which is what lets the validation report quote a
        trimmed mean.
        """
        b, s = prompts.shape
        self._check_window(s, warmup + n_steps + 1)
        t0 = time.perf_counter()
        logits, cache0 = self._prefill(self.params, prompts, memory)
        cache = self._rehome(cache0, b, s)
        tok = self._sample(logits[:, -1], 0.0, None)
        jax.block_until_ready(tok)
        ttft = time.perf_counter() - t0

        pos = s
        for _ in range(warmup):
            logits_i, cache = self._decode(self.params, cache, tok,
                                           jnp.int32(pos), memory)
            tok = self._sample(logits_i, 0.0, None)
            pos += 1
        jax.block_until_ready(tok)

        times: list[float] = []
        for _ in range(n_steps):
            t1 = time.perf_counter()
            logits_i, cache = self._decode(self.params, cache, tok,
                                           jnp.int32(pos), memory)
            tok = self._sample(logits_i, 0.0, None)
            jax.block_until_ready(tok)
            times.append(time.perf_counter() - t1)
            pos += 1
        return SteadyTiming(ttft=ttft, warmup=warmup, step_times=times,
                            batch=b)

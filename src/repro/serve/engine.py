"""Batched serving engine: prefill + decode with a slot-based KV cache
(continuous-batching-lite: fixed slots, per-slot position counters, greedy or
temperature sampling). This is the executable twin of the paper's §VIII.A
serving model — TTFT = prefill latency, TPOT = decode step latency.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp

from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig


@dataclasses.dataclass
class GenerationResult:
    tokens: list
    ttft: float
    tpot: float
    tokens_per_s: float


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_len: int = 1024):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, c, t, pos, mem: decode_step(cfg, p, c, t, pos,
                                                  memory=mem))
        self._prefill = jax.jit(
            lambda p, t, mem: prefill(cfg, p, t, memory=mem))

    def generate(self, prompts: jax.Array, n_tokens: int,
                 memory: jax.Array | None = None,
                 temperature: float = 0.0,
                 rng: jax.Array | None = None) -> GenerationResult:
        """prompts: (B, S) int32 (same length; pad upstream)."""
        b, s = prompts.shape
        assert s + n_tokens <= self.max_len
        t0 = time.perf_counter()
        logits, cache0 = self._prefill(self.params, prompts, memory)
        # re-home the prefill cache into the serving-length cache
        cache = init_cache(self.cfg, b, self.max_len)
        if "k" in cache0:
            cache["k"] = cache["k"].at[:, :, :, :s].set(cache0["k"])
            cache["v"] = cache["v"].at[:, :, :, :s].set(cache0["v"])
        if "ssm" in cache0:
            cache["ssm"] = cache0["ssm"]
            cache["conv"] = cache0["conv"]
        next_tok = self._sample(logits[:, -1], temperature, rng)
        jax.block_until_ready(next_tok)
        ttft = time.perf_counter() - t0

        toks = [next_tok]
        t1 = time.perf_counter()
        pos = s
        for i in range(n_tokens - 1):
            logits_i, cache = self._decode(self.params, cache, toks[-1],
                                           jnp.int32(pos), memory)
            toks.append(self._sample(logits_i, temperature, rng))
            pos += 1
        jax.block_until_ready(toks[-1])
        dt = time.perf_counter() - t1
        tpot = dt / max(n_tokens - 1, 1)
        return GenerationResult(
            tokens=[t.tolist() for t in toks], ttft=ttft, tpot=tpot,
            tokens_per_s=b * n_tokens / (ttft + dt))

    @staticmethod
    def _sample(logits: jax.Array, temperature: float,
                rng: jax.Array | None):
        if temperature <= 0.0 or rng is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(rng, logits / temperature
                                      ).astype(jnp.int32)

"""Measurement channels for the validation loop.

Two channels, different trust models:

* **dry-run** — lower + compile the twin's decode step (ShapeDtypeStruct
  inputs, no device arrays) and count FLOPs / bytes / collective link
  bytes from the optimized HLO via `repro.launch.hlocost`. Deterministic,
  machine-independent, meaningful on CPU-only CI — this is the channel the
  gate *requires*.
* **wall-clock** — run the twin for real on a `ServeEngine` and time
  steady-state decode steps (warmup discarded, per-step sync, trimmed
  mean). Only meaningful where the machine is quiet; the gate applies
  generous declared bands and records exact ratios.

Both protocols are env-tunable (`DFMODEL_VALIDATION_REPEATS`,
`DFMODEL_VALIDATION_WARMUP`) so CI and a quiet workstation can use the
same entry points at different fidelities.
"""
from __future__ import annotations

import dataclasses
import math
import os
import time

from .cases import ValidationCase

REPEATS_ENV_VAR = "DFMODEL_VALIDATION_REPEATS"
WARMUP_ENV_VAR = "DFMODEL_VALIDATION_WARMUP"

DEFAULT_REPEATS = 16
DEFAULT_WARMUP = 2


def _int_env(var: str, default: int, lo: int, hi: int) -> int:
    env = os.environ.get(var, "").strip()
    if not env:
        return default
    try:
        val = int(env)
    except ValueError:
        raise ValueError(
            f"invalid {var} value {env!r}; expected an integer") from None
    if not (lo <= val <= hi):
        raise ValueError(f"{var} must lie in [{lo}, {hi}], got {val}")
    return val


def validation_repeats() -> int:
    """Timed steady-state decode steps per case:
    ``$DFMODEL_VALIDATION_REPEATS`` (validated), else
    :data:`DEFAULT_REPEATS`."""
    return _int_env(REPEATS_ENV_VAR, DEFAULT_REPEATS, 1, 10_000)


def validation_warmup() -> int:
    """Discarded decode steps before timing starts:
    ``$DFMODEL_VALIDATION_WARMUP`` (validated), else
    :data:`DEFAULT_WARMUP`."""
    return _int_env(WARMUP_ENV_VAR, DEFAULT_WARMUP, 0, 10_000)


def trimmed_mean(xs: list[float], trim: float = 0.2) -> float:
    """Mean of the central (1 − 2·trim) fraction — the repeat protocol's
    noise-robust location estimate (GC pauses and scheduler preemption
    land in the discarded tails)."""
    if not xs:
        raise ValueError("trimmed_mean of an empty sample")
    ordered = sorted(xs)
    k = int(len(ordered) * trim)
    kept = ordered[k:len(ordered) - k] or ordered
    return sum(kept) / len(kept)


def have_jax() -> bool:
    try:
        import jax  # noqa: F401
    except Exception:
        return False
    return True


# --- dry-run channel ---------------------------------------------------------
def measure_dryrun(case: ValidationCase) -> dict:
    """Lower + compile the twin's decode step and price the optimized HLO.

    Per-decode-step quantities, counted by the same trip-count-aware cost
    model (`repro.launch.hlocost.analyze`) the TPU dry-run uses — the
    validation loop is exactly the dryrun pipeline pointed back at the
    analytical model.
    """
    import jax
    import jax.numpy as jnp

    from ..launch import hlocost
    from ..models import decode_step, init_cache, init_params

    twin = case.twin
    cfg = twin.cfg
    pspec = jax.eval_shape(lambda k: init_params(cfg, k),
                           jax.ShapeDtypeStruct((2,), jnp.uint32))
    cache = jax.eval_shape(lambda: init_cache(cfg, twin.batch, twin.kv_len))
    tok = jax.ShapeDtypeStruct((twin.batch,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    step = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q,
                                                  memory=None))
    t0 = time.perf_counter()
    compiled = step.lower(pspec, cache, tok, pos).compile()
    summary = hlocost.analyze(compiled.as_text())
    return {
        "flops": summary.flops,
        "bytes": summary.bytes_accessed,
        "collective_bytes": summary.link_traffic_bytes,
        "collective_by_kind": dict(summary.collective_bytes),
        "compile_s": time.perf_counter() - t0,
    }


# --- host calibration --------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HostCalibration:
    """Measured effective rates of the machine running the wall-clock
    channel — the roofline constants of the one-chip host system."""

    flop_rate: float             # effective bf16 matmul FLOP/s
    mem_bw: float                # effective stream bandwidth, bytes/s


_CALIBRATION: HostCalibration | None = None


def calibrate_host(force: bool = False) -> HostCalibration:
    """Measure the host's effective matmul FLOP/s and stream bandwidth
    (best of 5, jitted, synced). Cached per process — calibration costs
    seconds and the answer doesn't change under us."""
    global _CALIBRATION
    if _CALIBRATION is not None and not force:
        return _CALIBRATION
    import jax
    import jax.numpy as jnp

    n = 2048
    a = jnp.ones((n, n), jnp.bfloat16)
    b = jnp.ones((n, n), jnp.bfloat16)
    mm = jax.jit(lambda x, y: x @ y)
    mm(a, b).block_until_ready()
    best = math.inf
    for _ in range(5):
        t0 = time.perf_counter()
        mm(a, b).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    flop_rate = 2.0 * n**3 / best

    big = jnp.ones((64 * 1024 * 1024,), jnp.float32)      # 256 MB
    stream = jax.jit(lambda v: v * 1.000001)
    stream(big).block_until_ready()
    best = math.inf
    for _ in range(5):
        t0 = time.perf_counter()
        stream(big).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    mem_bw = 2.0 * big.nbytes / best                      # read + write

    _CALIBRATION = HostCalibration(flop_rate=flop_rate, mem_bw=mem_bw)
    return _CALIBRATION


# --- wall-clock channel ------------------------------------------------------
def measure_wallclock(case: ValidationCase, repeats: int | None = None,
                      warmup: int | None = None, seed: int = 0) -> dict:
    """Run the twin on a real ``ServeEngine`` and time steady-state decode.

    Protocol: prefill once, discard ``warmup`` decode steps, then time
    ``repeats`` individually-synced steps; TPOT is the 20 %-trimmed mean.
    The engine's cache is ``kv_len`` slots, and slot attention always runs
    over the full cache, so a short measurement prompt still exercises the
    full modeled KV traffic.
    """
    import jax

    from ..models import init_params
    from ..serve.engine import ServeEngine

    repeats = validation_repeats() if repeats is None else repeats
    warmup = validation_warmup() if warmup is None else warmup
    twin = case.twin
    window = twin.prompt_len + warmup + repeats + 1
    if window > twin.kv_len:
        raise ValueError(
            f"case {case.name!r}: measurement window {window} exceeds the "
            f"twin's kv_len {twin.kv_len}; lower "
            f"{REPEATS_ENV_VAR}/{WARMUP_ENV_VAR}")
    params = init_params(twin.cfg, jax.random.PRNGKey(seed))
    engine = ServeEngine(twin.cfg, params, max_batch=twin.batch,
                         max_len=twin.kv_len)
    prompts = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (twin.batch, twin.prompt_len),
        0, twin.cfg.vocab)
    timing = engine.decode_steady(prompts, n_steps=repeats, warmup=warmup)
    tpot = trimmed_mean(timing.step_times)
    return {
        "tpot": tpot,
        "tpot_mean": timing.tpot,
        "ttft": timing.ttft,
        "tokens_per_s": twin.batch / tpot,
        "repeats": repeats,
        "warmup": warmup,
        "step_time_min": min(timing.step_times),
        "step_time_max": max(timing.step_times),
    }

"""Modeled-vs-measured validation loop.

One contract, two halves: :mod:`repro.validation.cases` pairs each smoke
serving scenario's analytical workload with its certified executable twin;
:mod:`repro.validation.measure` runs the twin (HLO dry-run counts and
steady-state wall clock); :mod:`repro.validation.report` compares the two
under declared error bands and persists ``BENCH_validation.json`` for the
``tools/check_validation.py`` gate.

The cases/report halves are numpy-only — importable (and gateable) on
CPU-only CI with no jax; everything that needs jax lives behind function
bodies in :mod:`repro.validation.measure`.
"""
from .cases import (CASE_NAMES, ValidationCase, build_case, host_system,
                    predict_case, validation_cases)
from .measure import (HostCalibration, calibrate_host, have_jax,
                      measure_dryrun, measure_wallclock, trimmed_mean,
                      validation_repeats, validation_warmup)
from .report import (REPORT_PATH, build_case_report, bytes_factor,
                     check_case, check_report, hybrid_step_time, load_report,
                     validation_band, wall_band, write_report)

__all__ = [
    "CASE_NAMES", "ValidationCase", "build_case", "host_system",
    "predict_case", "validation_cases",
    "HostCalibration", "calibrate_host", "have_jax", "measure_dryrun",
    "measure_wallclock", "trimmed_mean", "validation_repeats",
    "validation_warmup",
    "REPORT_PATH", "build_case_report", "bytes_factor", "check_case",
    "check_report", "hybrid_step_time", "load_report", "validation_band",
    "wall_band", "write_report",
]

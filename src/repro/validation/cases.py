"""Validation cases: one scenario, two halves, one contract.

A :class:`ValidationCase` pairs a scenario's analytical decode workload
(`TrainWorkload`, priced by the `repro.core` machinery) with its certified
:class:`~repro.workloads.scenarios.ExecutableTwin` (a runtime `ModelConfig`
plus batch geometry a `ServeEngine` can actually run). Building a case
re-runs the twin's correspondence certification — a case whose two halves
disagree on FLOPs/token or KV bytes cannot be constructed.

The prediction side is numpy-only and jax-free: the host is modeled as a
one-chip :class:`~repro.systems.system.SystemSpec` whose peak FLOP/s and
memory bandwidth come from runtime calibration
(`repro.validation.measure.calibrate_host`) or from the committed baseline,
and the analytical iter time flows through the *real* pipeline —
`evaluate_plan` → `plan_vector_for` → `decompose_iter_time` — never a
side-channel formula.
"""
from __future__ import annotations

import dataclasses

from ..core.dse import plan_vector_for
from ..core.interchip import TrainWorkload, evaluate_plan
from ..core.pricing import decompose_iter_time
from ..systems.chips import ChipSpec, InterconnectSpec, MemorySpec
from ..systems.system import SystemSpec
from ..systems.topology import Topology, TopologyDim
from ..workloads.scenarios import ExecutableTwin, get_scenario

#: scenarios with an executable twin — the validated serving set
CASE_NAMES: tuple[str, ...] = ("serving", "mamba2", "moe")


@dataclasses.dataclass(frozen=True)
class ValidationCase:
    """One scenario's modeled↔measured pair."""

    name: str
    twin: ExecutableTwin
    work: TrainWorkload          # the analytical half (twin.workload())

    @property
    def steps_per_iter(self) -> int:
        """Decode steps one analytical 'iteration' covers (the twin pins
        global_batch == microbatch, so this is 1 by construction)."""
        return self.work.global_batch // self.work.microbatch

    # --- analytical per-step totals (the dry-run channel's predictions) ----
    def predicted_flops(self) -> float:
        """Forward FLOPs of one decode step (batch × per-token work)."""
        g = self.work
        total = g.layer_graph.total_flops() * g.n_layers
        for blk in (g.pre_graph, g.post_graph):
            if blk is not None:
                total += blk.total_flops()
        return total

    def predicted_bytes(self) -> float:
        """Idealized DRAM traffic of one decode step: every weight byte,
        KV/state byte and inter-kernel activation byte exactly once. The
        executable lowering re-materializes tensors at fusion boundaries,
        so measured bytes sit *above* this floor by a bounded factor (the
        bytes band is asymmetric for exactly that reason)."""
        g = self.work
        layer = (g.layer_graph.total_weight_bytes()
                 + sum(t.bytes_ for t in g.layer_graph.tensors))
        total = layer * g.n_layers
        for blk in (g.pre_graph, g.post_graph):
            if blk is not None:
                total += (blk.total_weight_bytes()
                          + sum(t.bytes_ for t in blk.tensors))
        return total

    def predicted_collective_bytes(self) -> float:
        """Link traffic of one decode step — identically zero on the
        one-chip host (TP = PP = DP = 1), and the dry-run channel asserts
        the measured HLO agrees (a collective appearing in a single-device
        lowering is a sharding bug, not noise)."""
        return 0.0


def build_case(name: str) -> ValidationCase:
    """Build (and certify) one scenario's validation case."""
    twin = get_scenario(name).executable_twin()
    return ValidationCase(name=name, twin=twin, work=twin.workload())


def validation_cases() -> list[ValidationCase]:
    return [build_case(n) for n in CASE_NAMES]


# --- the host as a one-chip system ------------------------------------------
def host_system(flop_rate: float, mem_bw: float,
                mem_capacity: float = 64e9) -> SystemSpec:
    """The measurement host as a DFModel system: one chip at the *measured*
    effective peak (not the vendor datasheet), one memory at the measured
    stream bandwidth, a single-node topology. Price/power are unit-valued —
    efficiency metrics are meaningless for a validation host."""
    link = InterconnectSpec("host-loop", bandwidth=1e9, latency=1e-6,
                            price_per_link=0.0, power_per_link=0.0)
    chip = ChipSpec("host", tiles=1, tile_flops=flop_rate,
                    sram_capacity=32 * 2**20, price=1.0, power=1.0,
                    dataflow=False)
    mem = MemorySpec("host-ram", bandwidth=mem_bw, capacity=mem_capacity,
                     price=1.0, power=1.0)
    topo = Topology("host", (TopologyDim(1, "ring", link),))
    return SystemSpec("host", chip, mem, topo)


def predict_case(case: ValidationCase, flop_rate: float,
                 mem_bw: float) -> dict:
    """The analytical prediction for one case on the calibrated host.

    Routes through the same machinery every DSE cell is priced with:
    ``evaluate_plan`` at (TP, PP, DP) = (1, 1, 1) on the one-chip system,
    then the intra-chip pass and the certified per-term decomposition.
    Times are per decode step (seconds); counts are per decode step too.
    """
    system = host_system(flop_rate, mem_bw)
    topo = system.topology
    plan = evaluate_plan(case.work, system, 1, 1, 1, topo, topo, topo,
                         execution="kbk")
    if plan is None:
        raise RuntimeError(f"case {case.name!r}: host plan infeasible")
    vec = plan_vector_for(case.work, system, plan, execution="kbk")
    terms = decompose_iter_time(vec)
    steps = case.steps_per_iter
    return {
        "flops": case.predicted_flops(),
        "bytes": case.predicted_bytes(),
        "collective_bytes": case.predicted_collective_bytes(),
        "t_compute": terms["t_compute"] / steps,
        "t_memory": terms["t_memory"] / steps,
        "t_collective": terms["t_collective"] / steps,
        "step_time": terms["iter_time"] / steps,
    }

"""Predicted-vs-measured report: ratios, declared bands, and the gate.

The report is a dict-of-dicts persisted as ``BENCH_validation.json``; the
gate (`tools/check_validation.py`) re-derives predictions and applies
:func:`check_report`. Band semantics, per channel:

* **dry-run flops** — symmetric relative band (default ±25 %,
  ``DFMODEL_VALIDATION_BAND``). The analytical graph and the compiled HLO
  count the same matmuls; disagreement here is a modeling bug.
* **dry-run bytes** — asymmetric ratio band ``[0.9, BYTES_FACTOR]``
  (``DFMODEL_VALIDATION_BYTES_FACTOR``). The prediction is an idealized
  floor (each byte moved once); XLA re-materializes tensors at fusion
  boundaries, converts the bf16 cache to f32 for contractions, and copies
  loop state, so measured bytes sit well above the floor — but bounded,
  and never meaningfully *below* it.
* **dry-run collectives** — exact: a one-chip lowering must move zero
  link bytes, and any collective in the HLO is a sharding bug.
* **wall-clock compute term** — one-sided for every case: the analytical
  compute time (host priced at its *measured* matmul rate) must not exceed
  measured TPOT × band — a lower-bound sanity check that survives
  dispatch-dominated tiny twins.
* **wall-clock hybrid fidelity** — two-sided (``WALL_BAND``), applied only
  to cases flagged ``wall_gate`` (the serving twin): the hybrid roofline
  — HLO-measured flops/bytes priced at calibrated host rates,
  ``max(flops/flop_rate, bytes/mem_bw)`` — must land within WALL_BAND× of
  measured TPOT on both sides. This is the paper's modeled-vs-measured
  claim (§X: predictions average 1.25× of measured) restated for the host.
"""
from __future__ import annotations

import json
import os
import pathlib

BAND_ENV_VAR = "DFMODEL_VALIDATION_BAND"
BYTES_FACTOR_ENV_VAR = "DFMODEL_VALIDATION_BYTES_FACTOR"
WALL_BAND_ENV_VAR = "DFMODEL_VALIDATION_WALL_BAND"

DEFAULT_BAND = 0.25
DEFAULT_BYTES_FACTOR = 24.0
DEFAULT_WALL_BAND = 2.5

REPORT_PATH = pathlib.Path(__file__).resolve().parents[3] / \
    "BENCH_validation.json"


def _float_env(var: str, default: float, lo: float, hi: float) -> float:
    env = os.environ.get(var, "").strip()
    if not env:
        return default
    try:
        val = float(env)
    except ValueError:
        raise ValueError(
            f"invalid {var} value {env!r}; expected a float") from None
    if not (lo <= val <= hi):
        raise ValueError(f"{var} must lie in [{lo}, {hi}], got {val}")
    return val


def validation_band() -> float:
    """Symmetric relative band for dry-run FLOPs (and the floor of the
    bytes band): ``$DFMODEL_VALIDATION_BAND``, else
    :data:`DEFAULT_BAND`."""
    return _float_env(BAND_ENV_VAR, DEFAULT_BAND, 0.0, 10.0)


def bytes_factor() -> float:
    """Upper edge of the asymmetric bytes ratio band (measured/predicted):
    ``$DFMODEL_VALIDATION_BYTES_FACTOR``, else
    :data:`DEFAULT_BYTES_FACTOR`."""
    return _float_env(BYTES_FACTOR_ENV_VAR, DEFAULT_BYTES_FACTOR, 1.0, 1e4)


def wall_band() -> float:
    """Two-sided multiplicative band for the hybrid-roofline wall-clock
    check on ``wall_gate`` cases: ``$DFMODEL_VALIDATION_WALL_BAND``, else
    :data:`DEFAULT_WALL_BAND`."""
    return _float_env(WALL_BAND_ENV_VAR, DEFAULT_WALL_BAND, 1.0, 100.0)


def hybrid_step_time(dry: dict, flop_rate: float, mem_bw: float) -> float:
    """Hybrid roofline: *measured* HLO flops/bytes priced at *calibrated*
    host rates. Isolates the pricing model from the byte-count gap —
    within 1.25× of measured TPOT on this host's serving twin."""
    return max(dry["flops"] / flop_rate, dry["bytes"] / mem_bw)


def build_case_report(name: str, predicted: dict, dry: dict,
                      wall: dict | None, calibration: dict | None,
                      wall_gate: bool) -> dict:
    """Assemble one case's row: raw numbers plus every gated ratio."""
    row = {
        "case": name,
        "wall_gate": wall_gate,
        "predicted": predicted,
        "dryrun": dry,
        "ratios": {
            "flops": dry["flops"] / predicted["flops"],
            "bytes": dry["bytes"] / predicted["bytes"],
        },
        "collective_delta_bytes": abs(
            dry["collective_bytes"] - predicted["collective_bytes"]),
    }
    if wall is not None and calibration is not None:
        hybrid = hybrid_step_time(dry, calibration["flop_rate"],
                                  calibration["mem_bw"])
        row["wallclock"] = wall
        row["calibration"] = calibration
        row["ratios"]["compute_term"] = predicted["t_compute"] / wall["tpot"]
        row["ratios"]["step_time"] = predicted["step_time"] / wall["tpot"]
        row["ratios"]["hybrid"] = hybrid / wall["tpot"]
        row["hybrid_step_time"] = hybrid
    return row


def check_case(row: dict, band: float | None = None,
               byte_factor: float | None = None,
               wband: float | None = None) -> list[str]:
    """Apply the declared bands to one case row; return violations
    (empty list == pass). Wall-clock checks only run if the row has a
    wall-clock section — absence is the caller's skip, not a failure."""
    band = validation_band() if band is None else band
    byte_factor = bytes_factor() if byte_factor is None else byte_factor
    wband = wall_band() if wband is None else wband
    name = row["case"]
    out: list[str] = []

    r_flops = row["ratios"]["flops"]
    if abs(r_flops - 1.0) > band:
        out.append(f"{name}: dry-run flops ratio {r_flops:.4f} outside "
                   f"1±{band}")
    r_bytes = row["ratios"]["bytes"]
    if not (1.0 - band <= r_bytes <= byte_factor):
        out.append(f"{name}: dry-run bytes ratio {r_bytes:.4f} outside "
                   f"[{1.0 - band}, {byte_factor}]")
    if row["collective_delta_bytes"] != 0.0:
        out.append(f"{name}: one-chip lowering moved "
                   f"{row['collective_delta_bytes']:.0f} collective link "
                   f"bytes (expected exactly 0)")

    if "wallclock" in row:
        r_comp = row["ratios"]["compute_term"]
        if r_comp > wband:
            out.append(f"{name}: predicted compute term is {r_comp:.3f}× "
                       f"measured TPOT — a lower bound exceeding measured "
                       f"by more than {wband}× means the compute model is "
                       f"broken, not the machine slow")
        if row["wall_gate"]:
            r_hyb = row["ratios"]["hybrid"]
            if not (1.0 / wband <= r_hyb <= wband):
                out.append(f"{name}: hybrid-roofline step time is "
                           f"{r_hyb:.3f}× measured TPOT, outside "
                           f"[1/{wband}, {wband}]")
    return out


def check_report(report: dict, band: float | None = None,
                 byte_factor: float | None = None,
                 wband: float | None = None) -> list[str]:
    """Gate a full report dict; returns all violations across cases."""
    out: list[str] = []
    for row in report["cases"]:
        out.extend(check_case(row, band=band, byte_factor=byte_factor,
                              wband=wband))
    return out


def write_report(report: dict, path: pathlib.Path | str = REPORT_PATH
                 ) -> pathlib.Path:
    path = pathlib.Path(path)
    path.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    return path


def load_report(path: pathlib.Path | str = REPORT_PATH) -> dict:
    return json.loads(pathlib.Path(path).read_text())

"""Design-space exploration scenario (paper §VI.C in miniature):

"We must train GPT3-175B on 64 accelerators. Which chip, memory,
interconnect and topology should we buy, for throughput / for cost
efficiency / for power efficiency?"

  PYTHONPATH=src python examples/dse_scenario.py
"""
from repro.core.dse import sweep
from repro.workloads.llm import GPT3_175B, gpt_workload


def main():
    pts = sweep(lambda sys_: gpt_workload(GPT3_175B, global_batch=512,
                                          microbatch=1),
                n_chips=64,
                chips=("H100", "TPUv4", "SN30"),
                topologies=("torus2d", "dragonfly", "dgx2"),
                mem_net=(("DDR", "PCIe"), ("HBM", "NVLink")),
                max_tp=64)
    pts = [p for p in pts if p.plan.feasible]
    print(f"{len(pts)} feasible design points\n")

    for metric, label in [("utilization", "throughput utilization"),
                          ("cost_eff", "cost efficiency (FLOP/s/$)"),
                          ("power_eff", "power efficiency (FLOP/s/W)")]:
        best = max(pts, key=lambda p: getattr(p, metric))
        r = best.row()
        print(f"best {label}:")
        print(f"  {r['chip']} + {r['memory']} + {r['link']} on "
              f"{r['topology']}  (TP={r['tp']} PP={r['pp']} DP={r['dp']})")
        print(f"  util={r['utilization']:.3f}  "
              f"cost={r['cost_eff_gflops_per_usd']:.2f} GFLOP/s/$  "
              f"power={r['power_eff_gflops_per_w']:.1f} GFLOP/s/W")
        print(f"  latency split: compute {r['t_compute']:.0%} / "
              f"memory {r['t_memory']:.0%} / network {r['t_network']:.0%}\n")


if __name__ == "__main__":
    main()

"""Design-space exploration scenario (paper §VI.C in miniature):

"We must train GPT3-175B on 64 accelerators. Which chip, memory,
interconnect and topology should we buy, for throughput / for cost
efficiency / for power efficiency?"

Runs through the phase-split parallel+cached ``DSEEngine`` scenario API:
the smoke LLM scenario is exactly this question, and the Pareto frontier
is the shortlist a system architect would actually take to procurement.
Workers run only the discrete plan phase; the whole grid is then priced
in one batched call (numpy, or jax.vmap via
DFMODEL_PRICING_BACKEND=jax). The streaming section at the end shows
``sweep_iter``: points arrive as plan groups finish, and the sweep stops
submitting work once enough feasible systems have streamed out.

  PYTHONPATH=src python examples/dse_scenario.py
"""
from repro.core import DSEEngine, stop_after_feasible
from repro.workloads.scenarios import get_scenario


def main():
    engine = DSEEngine()
    res = engine.sweep_scenario("llm", smoke=True)
    pts = [p for p in res.points if p.plan.feasible] or res.points
    print(f"{len(pts)} feasible design points "
          f"({len(res.spec.grid())} grid cells swept)\n")

    for metric, label in [("utilization", "throughput utilization"),
                          ("cost_eff", "cost efficiency (FLOP/s/$)"),
                          ("power_eff", "power efficiency (FLOP/s/W)")]:
        best = max(pts, key=lambda p: getattr(p, metric))
        r = best.row()
        print(f"best {label}:")
        print(f"  {r['chip']} + {r['memory']} + {r['link']} on "
              f"{r['topology']}  (TP={r['tp']} PP={r['pp']} DP={r['dp']})")
        print(f"  util={r['utilization']:.3f}  "
              f"cost={r['cost_eff_gflops_per_usd']:.2f} GFLOP/s/$  "
              f"power={r['power_eff_gflops_per_w']:.1f} GFLOP/s/W")
        print(f"  latency split: compute {r['t_compute']:.0%} / "
              f"memory {r['t_memory']:.0%} / network {r['t_network']:.0%}\n")

    print(f"Pareto frontier (utilization × cost eff × power eff): "
          f"{len(res.frontier)} systems")
    for p in res.frontier:
        r = p.row()
        print(f"  {r['chip']:6s} {r['memory']:4s} {r['link']:7s} "
              f"{r['topology']:16s} util={r['utilization']:.3f} "
              f"cost={r['cost_eff_gflops_per_usd']:.2f} "
              f"power={r['power_eff_gflops_per_w']:.1f}")

    # streaming with early exit: stop once 5 feasible systems have arrived
    sc = get_scenario("llm", smoke=True)
    print("\nstreaming (stop after 5 feasible systems):")
    for item in engine.sweep_iter(sc.work_fn, sc.spec,
                                  stop=stop_after_feasible(5)):
        if item.point is None:
            continue
        r = item.point.row()
        tag = "feasible" if r["feasible"] else "infeasible"
        print(f"  grid[{item.index:2d}] {r['chip']:6s} {r['memory']:4s} "
              f"{r['link']:7s} util={r['utilization']:.3f} ({tag})")


if __name__ == "__main__":
    main()

"""DFModel plan → real sharded execution, closing the loop on 8 host devices.

1. DFModel's planner analyzes the architecture's dataflow graph and predicts
   the mapping's bottleneck.
2. The launcher builds the mesh + shardings and jit-compiles the real
   train step.
3. The trip-count-aware HLO cost model extracts the compiled collective
   schedule, which is compared against DFModel's prediction.

  PYTHONPATH=src python examples/plan_and_launch.py --arch olmoe_1b_7b
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse   # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch import hlocost
    from repro.launch.mesh import make_axis_rules
    from repro.launch.shardings import batch_shardings, param_shardings
    from repro.models import init_params, loss_fn, synth_batch
    from repro.parallel.logical import use_rules

    cfg = get_config(args.arch, smoke=True)

    # --- 1. analytical plan (one block of the real architecture) -----------
    from repro.launch.plan import block_graph, v5e_system
    from repro.core.sharding import solve_sharding
    from repro.core.intrachip import optimize_intra_chip
    sys_ = v5e_system()
    g = block_graph(get_config(args.arch), 4096, 16)
    sol = solve_sharding(g, 16, sys_.topology, [0])
    sharded = g.scaled(1 / 16, 1 / 16)
    pred = optimize_intra_chip(sharded, sys_.chip, sys_.memory,
                               h_n=sol.h_n, h_m=sol.h_m)
    print(f"DFModel prediction for {args.arch} (one block, TP=16):")
    print(f"  bottleneck={pred.bottleneck}  partitions={pred.n_partitions}  "
          f"comm bytes/block={sol.comm_bytes / 1e6:.1f} MB")

    # --- 2. real sharded step on the local 2x4 mesh ------------------------
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = make_axis_rules(mesh, cfg)
    with mesh, use_rules(rules, mesh):
        ps = param_shardings(cfg, mesh)
        bs = batch_shardings(cfg, mesh, args.batch)
        params = jax.device_put(init_params(cfg, jax.random.PRNGKey(0)), ps)
        batch = synth_batch(cfg, args.batch, args.seq)
        batch = {k: jax.device_put(v, bs[k]) for k, v in batch.items()}
        step = jax.jit(lambda p, b: loss_fn(cfg, p, b),
                       in_shardings=(ps, bs))
        compiled = step.lower(params, batch).compile()
        loss = compiled(params, batch)
    print(f"\nreal sharded step on {mesh.devices.shape} mesh: "
          f"loss={float(loss):.4f}")

    # --- 3. compiled collective schedule vs the model -----------------------
    s = hlocost.analyze(compiled.as_text())
    print("\ncompiled collective schedule (top 5):")
    for rec in hlocost.collective_schedule(s, top=5):
        print(f"  {rec['kind']:>20s}  {rec['payload_bytes'] / 1e6:8.2f} MB "
              f"x{rec['trips']:.0f} trips  (S={rec['participants']})")
    print(f"total per-device link traffic: "
          f"{s.link_traffic_bytes / 1e6:.1f} MB")


if __name__ == "__main__":
    main()

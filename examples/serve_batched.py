"""Batched serving example: prefill + autoregressive decode with a slot KV
cache, reporting TTFT / TPOT / tokens-per-second — the executable twin of
the paper's §VIII.A serving study.

  PYTHONPATH=src python examples/serve_batched.py --arch olmo_1b --tokens 24
"""
import argparse

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # reduced config: runs on CPU
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=args.batch,
                         max_len=args.prompt_len + args.tokens + 1)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    res = engine.generate(prompts, n_tokens=args.tokens,
                          temperature=args.temperature,
                          rng=jax.random.PRNGKey(2))
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"generate={args.tokens}")
    print(f"TTFT  {res.ttft * 1e3:8.1f} ms   (prefill, includes compile)")
    print(f"TPOT  {res.tpot * 1e3:8.2f} ms/token")
    print(f"thru  {res.tokens_per_s:8.1f} tok/s (system)")
    for b in range(min(args.batch, 2)):
        toks = [t[b] for t in res.tokens]
        print(f"request {b}: {toks[:12]}{' ...' if len(toks) > 12 else ''}")


if __name__ == "__main__":
    main()

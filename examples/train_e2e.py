"""End-to-end training driver: a GPT-style model on synthetic tokens with
the full substrate — data pipeline, AdamW + cosine schedule, gradient
accumulation, async checkpointing, straggler monitoring, crash-resume.

Default preset is a ~20M-parameter model so the loop runs in minutes on
CPU; ``--full`` selects the ~110M-parameter config (the deliverable scale —
same code path, longer wall time).

  PYTHONPATH=src python examples/train_e2e.py --steps 200
  PYTHONPATH=src python examples/train_e2e.py --full --steps 300
  PYTHONPATH=src python examples/train_e2e.py --resume   # continue from ckpt
"""
import argparse
import pathlib

import jax

from repro.models import init_params, param_count
from repro.models.config import ModelConfig
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticTokens
from repro.train.fault import StragglerMonitor
from repro.train.optimizer import AdamWConfig, adamw_init, cosine_schedule
from repro.train.trainer import make_train_step

SMALL = ModelConfig(name="gpt_20m", family="dense", n_layers=4, d_model=256,
                    n_heads=8, n_kv_heads=8, d_ff=1024, vocab=32000,
                    gated=False)
FULL = ModelConfig(name="gpt_110m", family="dense", n_layers=12, d_model=768,
                   n_heads=12, n_kv_heads=12, d_ff=3072, vocab=50304,
                   gated=False)  # GPT-2-small geometry (~110M params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    args = ap.parse_args()

    cfg = FULL if args.full else SMALL
    mgr = CheckpointManager(pathlib.Path(args.ckpt_dir) / cfg.name, keep=2)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    start = 0
    if args.resume and mgr.latest_step() is not None:
        start, tree = mgr.restore()
        params, opt = tree["params"], tree["opt"]
        print(f"resumed from step {start}")
    print(f"model {cfg.name}: {param_count(params):,} params")

    sched = cosine_schedule(1.0, warmup=20, total=args.steps)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-4),
                                      accum=args.accum, schedule=sched),
                      donate_argnums=(0, 1))
    data = iter(SyntheticTokens(vocab=cfg.vocab, batch=args.batch,
                                seq=args.seq, seed=17))
    mon = StragglerMonitor()

    import time
    for step in range(start, args.steps):
        batch = next(data)
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        flagged = mon.record(step, dt)
        if step % 10 == 0 or step == args.steps - 1:
            toks = args.batch * args.seq / dt
            print(f"step {step:4d}  loss {loss:7.4f}  {dt * 1e3:7.1f} ms "
                  f"({toks:,.0f} tok/s){'  [straggler]' if flagged else ''}")
        if (step + 1) % 50 == 0:
            mgr.save_async(step + 1, {"params": params, "opt": opt})
    mgr.wait()
    print(f"done; stragglers flagged: {len(mon.events)} "
          f"({100 * mon.straggler_fraction:.1f}%)")
    print(f"checkpoints in {mgr.dir}")


if __name__ == "__main__":
    main()

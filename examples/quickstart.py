"""DFModel quickstart: map GPT3-175B onto 8 SambaNova SN10 RDUs (paper §VII).

Runs the paper's two optimization passes on the workload dataflow graph and
prints the mapping ladder of Table VI: kernel-by-kernel baseline → DFModel-
optimized dataflow mapping, on an 8×1 ring and a 4×2 torus.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

from repro.core.intrachip import optimize_intra_chip
from repro.core.sharding import solve_sharding
from repro.systems.chips import DDR, PCIE, SN10
from repro.systems.topology import ring, torus2d
from repro.workloads.llm import GPT3_175B, gpt_layer_graph

DDR_200 = dataclasses.replace(DDR, bandwidth=200e9)


def analyze(tp: int, topo, label: str):
    graph = gpt_layer_graph(dataclasses.replace(GPT3_175B, batch=1))
    # inter-chip pass: per-kernel sharding schemes + collective costs (Eq 5/6)
    sol = solve_sharding(graph, tp, topo, list(range(len(topo.dims))))
    sharded = graph.scaled(flop_scale=1.0 / tp, bytes_scale=1.0 / tp)
    # intra-chip pass: fuse kernels into streaming dataflow partitions (§V)
    df = optimize_intra_chip(sharded, SN10, DDR_200, h_n=sol.h_n,
                             h_m=sol.h_m, p_max=8)
    kbk = optimize_intra_chip(sharded, SN10, DDR_200, h_n=sol.h_n,
                              h_m=sol.h_m, mode="kbk")
    print(f"\n--- {label} (TP={tp}) ---")
    print(f"kernel-by-kernel: {kbk.total_time * 1e3:8.3f} ms/microbatch  "
          f"(bottleneck: {kbk.bottleneck})")
    print(f"DFModel dataflow: {df.total_time * 1e3:8.3f} ms/microbatch  "
          f"({df.n_partitions} fused partitions, "
          f"bottleneck: {df.bottleneck})")
    print(f"speedup: {kbk.total_time / df.total_time:.2f}x")
    names = [k.name for k in sharded.kernels]
    parts: dict = {}
    for name, pid in zip(names, df.assign):
        parts.setdefault(int(pid), []).append(name)
    for pid in sorted(parts):
        print(f"  partition {pid}: {{{', '.join(parts[pid])}}}")
    return df.total_time


t81 = analyze(8, ring(8, PCIE), "8x1 PCIe ring")
t42 = analyze(4, torus2d(8, PCIE), "4x2 PCIe torus (TP=4, DP=2)")
print(f"\n4x2 torus system speedup vs 8x1 ring: {2 * t81 / t42:.2f}x "
      f"(two DP replicas; paper: 1.28x)")

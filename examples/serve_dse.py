"""DSE-as-a-service: one warm daemon, many concurrent consumers.

A procurement study rarely happens in one shot — analysts iterate,
each re-asking variations of "which system should we buy?" over mostly
the same design grid. Cold-starting a ``DSEEngine`` (worker pool spawn,
memo store from scratch) for every question throws the warm state away.
``DSEService`` keeps one engine warm behind a unix socket and
multiplexes every consumer over it:

* two clients sweeping *overlapping* grids concurrently — the shared
  cells are priced exactly once and streamed to both (watch
  ``dedup_hits``);
* a repeat of the full sweep answered entirely from the shared memo
  (zero new prices, bit-identical rows);
* a budgeted ``halving`` search as just another query mode, its
  certified winner agreeing with the exhaustive sweep's.

Every row a client receives went through the engine's certify-or-die
streaming path before it was emitted — the service adds multiplexing,
never a weaker correctness story.

  PYTHONPATH=src python examples/serve_dse.py
"""
import threading

from repro.service import DSEClient, DSEService


def main():
    with DSEService(batch_cells=4) as svc:
        print(f"daemon up on {svc.path}\n")

        # -- two concurrent clients, overlapping grids -------------------
        # the smoke llm grid has 18 cells; client A takes the front
        # two-thirds, client B the back two-thirds — 6 cells overlap
        a_cells = list(range(0, 12))
        b_cells = list(range(6, 18))
        replies = {}

        def run(name, cells):
            with DSEClient(svc.path) as cli:
                replies[name] = cli.sweep(cells=cells, client=name)

        threads = [threading.Thread(target=run, args=("A", a_cells)),
                   threading.Thread(target=run, args=("B", b_cells))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        with DSEClient(svc.path) as cli:
            sched = cli.stats()["scheduler"]
        print("concurrent clients over overlapping grids:")
        for name in ("A", "B"):
            s = replies[name].summary
            w = s["winner"]
            print(f"  client {name}: {s['rows']} rows, "
                  f"{s['dedup_hits']} served by the other client's work; "
                  f"winner cell {w['index']} "
                  f"(iter_time {w['iter_time']:.4f}s)")
        print(f"  daemon: {sched['cells_priced']} cells priced for "
              f"{sched['rows_streamed']} rows streamed "
              f"({sched['dedup_hits']} dedup hits)\n")

        # -- warm repeat: the whole grid from the shared memo ------------
        with DSEClient(svc.path) as cli:
            rep = cli.sweep()
            after = cli.stats()["scheduler"]["cells_priced"]
        print(f"warm full sweep: {rep.summary['rows']} rows, "
              f"{rep.summary['dedup_hits']} from memo, "
              f"cells priced total still {after} -> zero new solves")
        best = rep.winner
        print(f"  winner: cell {best['index']} "
              f"{best['row']['chip']} + {best['row']['memory']} + "
              f"{best['row']['link']} on {best['row']['topology']} "
              f"(util {best['row']['utilization']:.3f})\n")

        # -- search as a query mode --------------------------------------
        with DSEClient(svc.path) as cli:
            sr = cli.search(policy="halving", budget=6)
        s = sr.summary
        print(f"search(halving, budget=6): winner cell {s['best_index']} "
              f"in {s['evals_used']} full evals, "
              f"certified={s['certified']} "
              f"(oracle argmin {s['oracle_index']})")
        assert s["best_index"] == best["index"], "search/sweep disagree"
        print("\nserve_dse: OK")


if __name__ == "__main__":
    main()

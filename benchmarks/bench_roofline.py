"""§Roofline deliverable: the dry-run roofline table.

Reads results/dryrun/*.json (produced by ``python -m repro.launch.dryrun``)
and reports, per (arch × shape × mesh): the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, the roofline fraction, and
DFModel's own prediction for the same cell.
"""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"

TITLE = "dry-run roofline: all (arch x shape x mesh) cells (TPU v5e terms)"


def load_cells(pattern: str = "*.json") -> list[dict]:
    out = []
    for p in sorted(RESULTS.glob(pattern)):
        try:
            out.append(json.loads(p.read_text()))
        except Exception:
            continue
    return out


def rows_from(cells: list[dict]) -> list[dict]:
    rows = []
    for r in cells:
        rf = r.get("roofline", {})
        plan = r.get("dfmodel_plan", {})
        plan_t = plan.get("iter_time_s", plan.get("total_time_s", ""))
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "mesh": "2x16x16" if r["multi_pod"] else "16x16",
            "t_comp_s": rf.get("t_compute_s"),
            "t_mem_s": rf.get("t_memory_s"),
            "t_coll_s": rf.get("t_collective_s"),
            "dominant": rf.get("dominant"),
            "useful": rf.get("useful_ratio"),
            "frac": rf.get("roofline_fraction"),
            "GiB/dev": r["memory"]["bytes_per_device"] / 2 ** 30,
            "dfmodel_t_s": plan_t,
            "compile_s": r.get("compile_s"),
        })
    return rows


def run(quick: bool = False):
    cells = load_cells()
    if not cells:
        return [{"note": "no dry-run artifacts; run "
                 "`PYTHONPATH=src python -m repro.launch.dryrun --all`"}]
    rows = rows_from(cells)
    # summary: per-mesh dominant-term census
    census: dict = {}
    for r in rows:
        key = (r["mesh"], r["dominant"])
        census[key] = census.get(key, 0) + 1
    for (mesh, dom), n in sorted(census.items()):
        rows.append({"arch": "census", "shape": "", "mesh": mesh,
                     "t_comp_s": "", "t_mem_s": "", "t_coll_s": "",
                     "dominant": dom, "useful": "", "frac": "",
                     "GiB/dev": "", "dfmodel_t_s": "", "compile_s": n})
    return rows

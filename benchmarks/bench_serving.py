"""LLM serving model (paper §VIII.A, Fig 20): Llama3-8B on 16 SN40L RDUs.

Sweeps (TP, PP); reports TTFT, TPOT, prefill/decode throughput and the
phase breakdowns. Validation anchor: paper models 1188 tok/s decode at
TP=16/PP=1 vs 1100 measured (8% error).
"""
from __future__ import annotations

import dataclasses

from repro.core.serving import serving_sweep
from repro.systems.chips import ICI, SN40L, MemorySpec
from repro.systems.system import SystemSpec
from repro.systems.topology import torus2d
from repro.workloads.llm import LLAMA3_8B, decode_layer_graph, gpt_layer_graph

TITLE = "Fig 20: serving Llama3-8B on 16 SN40L (TTFT/TPOT/throughput)"

# SN40L serving node: big DDR + HBM tiers; model the HBM tier for decode
SN40L_MEM = MemorySpec("sn40l_hbm", bandwidth=1600e9, capacity=64e9,
                       price=8_000, power=80)


def run(quick: bool = False):
    batch = 8
    s = dataclasses.replace(LLAMA3_8B, seq=1024, batch=batch)
    prefill = gpt_layer_graph(dataclasses.replace(s, batch=1))
    decode = decode_layer_graph(s, kv_len=1024)
    system = SystemSpec("sn40l16", SN40L, SN40L_MEM, torus2d(16, ICI))
    pts = serving_sweep(prefill, decode, n_layers=LLAMA3_8B.n_layers,
                        system=system, batch=batch, net_latency=150e-9)
    rows = []
    for p in pts:
        rows.append({
            "tp": p.tp, "pp": p.pp,
            "ttft_ms": p.ttft * 1e3, "tpot_ms": p.tpot * 1e3,
            "prefill_tok_s": p.prefill_throughput,
            "decode_tok_s": p.decode_throughput,
            "decode_mem%": 100 * p.breakdown_decode["memory"],
            "decode_net%": 100 * p.breakdown_decode["network"],
            "decode_comp%": 100 * p.breakdown_decode["compute"],
        })
    tp16 = [p for p in pts if p.tp == 16 and p.pp == 1]
    if tp16:
        rows.append({
            "tp": "anchor", "pp": "",
            "ttft_ms": "", "tpot_ms": "",
            "prefill_tok_s": "paper modeled 1188 tok/s, measured 1100",
            "decode_tok_s": tp16[0].decode_throughput,
            "decode_mem%": "", "decode_net%": "", "decode_comp%": "",
        })
    return rows

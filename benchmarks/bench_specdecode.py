"""Speculative decoding study (paper §VIII.B, Fig 21).

Llama3-405B target served on 16 SN40L; drafts ∈ {68M, 8B, 70B};
schemes ∈ {sequence, tree}; sweep window K and acceptance rate.
Draft/verify step times come from the serving model (memory-bound decode).
"""
from __future__ import annotations

import dataclasses

from repro.core.serving import speculative_throughput
from repro.systems.chips import SN40L
from repro.workloads.llm import (LLAMA3_405B, LLAMA3_70B, LLAMA3_8B,
                                 LLAMA_68M, LLMShape)

TITLE = "Fig 21: speculative decoding — draft size × scheme × window × accept"

N_CHIPS = 16
MEM_BW = 1600e9  # SN40L HBM tier


def _decode_step_time(shape: LLMShape) -> float:
    """Memory-bound decode step: stream active params once per token across
    the TP group (the regime Fig 20 shows for decode)."""
    bytes_ = shape.active_params * 2.0
    return bytes_ / (MEM_BW * N_CHIPS) + 20e-6  # + per-step launch/net alpha


def run(quick: bool = False):
    target_t = _decode_step_time(LLAMA3_405B)
    drafts = {"68M": LLAMA_68M, "8B": LLAMA3_8B, "70B": LLAMA3_70B}
    accepts = (0.6, 0.8) if quick else (0.5, 0.6, 0.7, 0.8, 0.9)
    windows = (2, 4, 8) if quick else (1, 2, 4, 6, 8, 10)
    base = 1.0 / target_t  # plain autoregressive decoding

    rows = []
    for dname, dshape in drafts.items():
        draft_t = _decode_step_time(dshape)
        for scheme in ("sequence", "tree"):
            best = (0.0, None, None)
            for k in windows:
                for a in accepts:
                    tps = speculative_throughput(draft_t, target_t, k, a,
                                                 scheme)
                    if tps > best[0]:
                        best = (tps, k, a)
                    rows.append({
                        "draft": dname, "scheme": scheme, "window": k,
                        "accept": a, "tok_s": tps,
                        "speedup_vs_plain": tps / base,
                    })
            rows.append({"draft": dname, "scheme": scheme, "window": "best",
                         "accept": best[2], "tok_s": best[0],
                         "speedup_vs_plain": best[0] / base})
    return rows

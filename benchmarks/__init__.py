"""Benchmark harness package — one module per paper table/figure.

An explicit package (not an implicit namespace package) so that both
invocation styles the repo uses resolve the same way from the repo root:
``python -m benchmarks.run --smoke`` (tools/ci.sh, the workflow) and
``from benchmarks.bench_dse import speedup_report`` (tools/check_bench.py).
"""

"""Design-space exploration heat maps (paper §VI.C, Figs 10-17).

7 workload scenarios × (4 chips × 5 topologies × 4 mem/net combos = 80
systems), 1024 accelerators each, driven through the phase-split
parallel+cached ``DSEEngine``. Reports utilization, cost efficiency, power
efficiency, the compute/memory/network breakdown, the paper's key
observation ratios, the Pareto frontier per workload family, and — the
engine's contract — the wall-clock comparison of the phased
(plan-parallel + batched-priced) path against the PR 1 per-point path,
the serial uncached baseline, and the shared-memo-store parallel path,
with bit-identical ``DesignPoint.row()`` output across every path. The
comparison (points/sec per path + memo-cache and shared-store stats)
becomes the committed ``BENCH_dse.json`` CI baseline via
``tools/check_bench.py --update``; the harness itself writes no file.
"""
from __future__ import annotations

import json
import os
import pathlib
import time

from repro.core import (DSEEngine, cache_stats, caching_disabled,
                        clear_caches, sweep)
from repro.search import (DenseGridSpec, RandomSearch, SuccessiveHalving,
                          SurrogateSearch)
from repro.workloads.scenarios import get_scenario, scenario_names

from .common import geomean

TITLE = "DSE heatmaps: 7 workload scenarios on 80 systems"



def _ratio(points, pred_num, pred_den, metric):
    num = [getattr(p, metric) for p in points if pred_num(p)]
    den = [getattr(p, metric) for p in points if pred_den(p)]
    if not num or not den:
        return float("nan")
    return geomean(num) / geomean(den)


def observations(name: str, pts) -> list[dict]:
    """The paper's §VI.C bullet-point ratios, recomputed on our sweep."""
    is_nv = lambda p: p.system.topology.dims[0].link.name == "NVLink"
    is_pcie = lambda p: p.system.topology.dims[0].link.name == "PCIe"
    is_drag = lambda p: p.system.topology.name.startswith("dragonfly")
    simple = lambda p: not is_drag(p)
    rdu = lambda p: p.system.chip.name == "SN30"
    gpu_tpu = lambda p: p.system.chip.name in ("H100", "TPUv4")
    tpu = lambda p: p.system.chip.name == "TPUv4"
    wse = lambda p: p.system.chip.name == "WSE2"
    not_wse = lambda p: not wse(p)
    hbm = lambda p: p.system.memory.name == "HBM"
    ddr = lambda p: p.system.memory.name == "DDR"

    rows = []

    def obs(label, paper, num, den, metric="utilization"):
        rows.append({"workload": name, "observation": label,
                     "paper": paper,
                     "ours": _ratio(pts, num, den, metric)})

    if name == "llm":
        obs("RDU vs GPU/TPU util", 1.52, rdu, gpu_tpu)
        obs("RDU vs GPU/TPU cost-eff", 1.59, rdu, gpu_tpu, "cost_eff")
        obs("RDU vs GPU/TPU power-eff", 1.60, rdu, gpu_tpu, "power_eff")
        obs("GPU/TPU HBM vs DDR util", 1.66,
            lambda p: gpu_tpu(p) and hbm(p), lambda p: gpu_tpu(p) and ddr(p))
        obs("RDU HBM vs DDR util", 1.0,
            lambda p: rdu(p) and hbm(p), lambda p: rdu(p) and ddr(p))
        obs("dragonfly vs simple util (PCIe)", 1.21,
            lambda p: is_drag(p) and is_pcie(p),
            lambda p: simple(p) and is_pcie(p))
        obs("WSE NVLink vs PCIe util", 5.15,
            lambda p: wse(p) and is_nv(p), lambda p: wse(p) and is_pcie(p))
        obs("WSE vs rest cost-eff", 0.06, wse, not_wse, "cost_eff")
        obs("WSE vs rest power-eff", 0.20, wse, not_wse, "power_eff")
    elif name == "dlrm":
        obs("NVLink vs PCIe util", 6.30, is_nv, is_pcie)
        obs("dragonfly vs simple util", 2.51, is_drag, simple)
        obs("TPU vs others util", 4.43, tpu, lambda p: not tpu(p))
        obs("WSE vs others util", 0.10, wse, not_wse)
    elif name == "hpl":
        obs("NVLink vs PCIe util (≈1: all high)", 1.0, is_nv, is_pcie)
        obs("WSE vs rest cost-eff", 0.09, wse, not_wse, "cost_eff")
        obs("WSE vs rest power-eff", 0.33, wse, not_wse, "power_eff")
    elif name == "fft":
        obs("NVLink vs PCIe util", 7.02, is_nv, is_pcie)
        obs("dragonfly vs simple util", 3.22, is_drag, simple)
        obs("TPU vs others util", 5.11, tpu, lambda p: not tpu(p))
        obs("WSE vs others util", 0.09, wse, not_wse)
    return rows


def _search_entry(engine: DSEEngine, work_fn, spec, policy,
                  budget: int) -> dict:
    """Run one certified search and distill the gated numbers.

    ``DSEEngine.search`` raises if the policy misses the exhaustive
    argmin, so a returned entry IS the certification proof.
    ``points_per_s`` uses the search-only wall clock (the last round's
    elapsed time, before the oracle pass runs) — the metric describes
    the budgeted search, not the certification overhead."""
    clear_caches()
    n = len(spec.grid())
    res = engine.search(work_fn, spec, policy=policy, budget=budget)
    search_s = res.rounds[-1]["elapsed_s"] if res.rounds else res.seconds
    return {"policy": res.policy, "grid_points": n, "budget": res.budget,
            "evals_used": res.evals_used, "cheap_evals": res.cheap_evals,
            "eval_frac": res.evals_used / n if n else 1.0,
            "best_index": res.best_index, "oracle_index": res.oracle_index,
            "winner_identical": res.best_index == res.oracle_index,
            "certified": res.certified,
            "best_iter_time": (res.best_objective[1]
                               if res.best_objective else float("inf")),
            "points_per_s": (res.evals_used / search_s
                             if search_s else float("inf")),
            "search_s": search_s, "total_s": res.seconds}


def search_block(sc, spec) -> dict:
    """The report's ``search`` block: budgeted policies, each certified.

    * ``smoke.policies`` — all three shipped policies on the scenario's
      smoke grid. Random and surrogate get ``budget = grid size`` (an
      exhaustive-order walk, so certification is an identity check on
      the bookkeeping); halving runs genuinely budget-limited off its
      cheap selection bound.
    * ``dense`` — successive halving on the :class:`DenseGridSpec`
      scaled-variant grid (≥ 10× the paper's 80 systems), budgeted at
      20 % of exhaustive; ``eval_frac`` records how much it actually
      spent and ``tools/check_bench.py`` gates it at ≤ 0.2.
    """
    engine = DSEEngine(phased=True)
    n = len(spec.grid())
    smoke_policies = {
        "random": _search_entry(engine, sc.work_fn, spec,
                                RandomSearch(seed=0, batch_size=8), n),
        "halving": _search_entry(engine, sc.work_fn, spec,
                                 SuccessiveHalving(eta=4),
                                 max(1, -(-n // 4))),
        "surrogate": _search_entry(
            engine, sc.work_fn, spec,
            SurrogateSearch(seed=0, batch_size=6, min_train=6), n),
    }
    dense_spec = DenseGridSpec().spec()
    dense_n = len(dense_spec.grid())
    dense = _search_entry(engine, sc.work_fn, dense_spec,
                          SuccessiveHalving(eta=8),
                          max(1, dense_n // 5))
    return {"smoke": {"grid_points": n, "policies": smoke_policies},
            "dense": dense}


def _stream_entry(sc, spec, target_rows: int = 131_072,
                  chunk_rows: int = 65_536) -> dict:
    """Raw chunked-kernel throughput of the compiled f32 backend.

    Tiles one real candidate matrix up to ``target_rows`` rows and prices
    it in fixed ``chunk_rows`` blocks — the streaming regime
    ``DSEEngine.reprice_grid`` runs in, minus the per-group certification
    overhead, so ``rows_per_s`` here is the kernel-side ceiling. Chunks
    are a power of two, so after the first block every block reuses the
    same cached executable."""
    import numpy as np

    from repro.core.dse import build_system, candidate_matrix
    from repro.core.pricing import price_plans

    grid = spec.grid()
    system = build_system(grid[0], spec.n_chips)
    work = sc.work_fn(system)
    cands = candidate_matrix(work, system, max_tp=spec.max_tp,
                             max_pp=spec.max_pp, execution=spec.execution)
    cols = cands.matrix.cols
    n = len(next(iter(cols.values())))
    reps = -(-target_rows // n)
    big = {k: np.tile(v, reps) for k, v in cols.items()}
    rows = n * reps
    t0 = time.perf_counter()
    for off in range(0, rows, chunk_rows):
        sl = {k: v[off:off + chunk_rows] for k, v in big.items()}
        price_plans(sl, backend="pallas-compiled")
    dt = time.perf_counter() - t0
    return {"rows": rows, "chunk_rows": chunk_rows, "seconds": dt,
            "rows_per_s": rows / dt if dt else float("inf")}


def compiled_block(sc, spec) -> dict:
    """The report's ``compiled`` block: the f32 drift-budget contract.

    * ``smoke`` — every shipped smoke scenario swept serially with
      ``pricing_backend="pallas-compiled"`` next to a ``numpy`` twin;
      ``winners_identical`` compares the full ``DesignPoint.row()``
      lists (the sweep itself already certifies banded selection
      against the f64 reference in-call — certify-or-die — so the row
      comparison is the end-to-end proof on top), and ``drift``
      carries the engine's aggregated band accounting.
    * ``grid`` — :meth:`DSEEngine.reprice_grid` over a
      ``DenseGridSpec.dense(100_000)`` grid (≥ 10⁵ cells): the
      chunk-streamed whole-grid pricing report, winners certified per
      group under the drift band, ``repriced_frac`` the fraction of
      candidate rows that needed the exact f64 re-price.
    * ``stream`` — raw chunked-kernel rows/sec on a ≥ 131072-row tiled
      matrix (the certification-free pricing ceiling).

    ``tools/check_bench.py`` gates winner identity, the grid-cell
    floor, the re-priced-fraction ceiling, and the throughput floors.
    On a jax-less interpreter the block is ``{"available": False}`` and
    the gate skips it (mirroring the jax-backend legs elsewhere)."""
    from repro.core.pricing import available_backends

    if "pallas-compiled" not in available_backends():
        return {"available": False}
    from repro.kernels.pricing.drift import drift_band

    smoke: dict[str, dict] = {}
    for name in scenario_names():
        ssc = get_scenario(name, smoke=True)
        clear_caches()
        ref = DSEEngine(phased=True, parallel=False,
                        pricing_backend="numpy")
        ref_rows = [p.row() for p in ref.sweep(ssc.work_fn, ssc.spec)]
        clear_caches()
        engine = DSEEngine(phased=True, parallel=False,
                           pricing_backend="pallas-compiled")
        t0 = time.perf_counter()
        pts = engine.sweep(ssc.work_fn, ssc.spec)
        dt = time.perf_counter() - t0
        smoke[name] = {
            "points": len(pts),
            "winners_identical": [p.row() for p in pts] == ref_rows,
            "seconds": dt,
            "points_per_s": len(pts) / dt if dt else float("inf"),
            "drift": engine.last_drift_stats,
        }
    grid_engine = DSEEngine(phased=True, parallel=False,
                            pricing_backend="pallas-compiled")
    grid = grid_engine.reprice_grid(sc.work_fn,
                                    DenseGridSpec.dense(100_000).spec())
    stream = _stream_entry(sc, spec)
    return {
        "available": True,
        "backend": "pallas-compiled",
        "band": drift_band(),
        "winners_identical": (grid["winners_identical"]
                              and all(e["winners_identical"]
                                      for e in smoke.values())),
        "smoke": smoke,
        "grid": grid,
        "stream": stream,
    }


def service_block(scenario_name: str, smoke: bool) -> dict:
    """The report's ``service`` block: the warm-daemon contract.

    One fresh :class:`repro.service.DSEService` is measured through two
    request phases:

    * **cold** — daemon start (engine warm-up: worker pool + shared
      store) plus TWO concurrent clients sweeping overlapping
      two-thirds grids that together cover the whole grid. The memo is
      empty, so every ``dedup_hit`` here is genuinely *cross-client*:
      a shared cell priced once by the scheduler and streamed to both.
      ``cold_request_s`` is the whole phase — what pricing the grid
      costs without a resident daemon.
    * **warm** — one client repeats the full-grid sweep against the
      now-warm daemon; every row is served from the shared memo with
      zero new prices. ``warm_speedup = cold_request_s /
      warm_request_s`` and ``rows_per_s`` is the warm streaming rate.

    ``winners_identical`` compares the warm sweep's full row list to a
    direct ``DSEEngine.sweep`` — the multiplexing layer must not
    perturb a single bit. ``tools/check_bench.py`` gates the speedup
    ($DFMODEL_BENCH_SERVICE_MIN_SPEEDUP), the cross-client dedup count
    ($DFMODEL_BENCH_SERVICE_MIN_DEDUP), row identity, and the warm
    rows/sec floor."""
    import threading

    from repro.service import DSEClient, DSEService

    sc = get_scenario(scenario_name, smoke=smoke)
    direct = [p.row() for p in DSEEngine(parallel=False).sweep(sc.work_fn,
                                                               sc.spec)]
    n = len(sc.spec.grid())
    a_cells = list(range(0, 2 * n // 3))
    b_cells = list(range(n // 3, n))

    t0 = time.perf_counter()
    svc = DSEService(batch_cells=8)
    svc.start()
    try:
        def run(name, cells):
            with DSEClient(svc.path) as cli:
                cli.sweep(scenario=scenario_name, smoke=smoke, cells=cells,
                          client=name)

        threads = [threading.Thread(target=run, args=("A", a_cells)),
                   threading.Thread(target=run, args=("B", b_cells))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        cold_s = time.perf_counter() - t0

        with DSEClient(svc.path) as cli:
            # snapshot before the warm repeat: dedup_hits here are the
            # cross-client ones from the cold concurrent phase
            sched = cli.stats()["scheduler"]
            t0 = time.perf_counter()
            rep = cli.sweep(scenario=scenario_name, smoke=smoke)
            warm_s = time.perf_counter() - t0
    finally:
        svc.close()
    return {
        "grid_points": n,
        "clients": 2,
        "overlap_cells": len(set(a_cells) & set(b_cells)),
        "cold_request_s": cold_s,
        "warm_request_s": warm_s,
        "warm_speedup": cold_s / warm_s if warm_s else float("inf"),
        "rows_per_s": (rep.summary["rows"] / warm_s
                       if warm_s else float("inf")),
        "dedup_hits": sched["dedup_hits"],
        "cells_priced": sched["cells_priced"],
        "rows_streamed": sched["rows_streamed"],
        "winners_identical": rep.rows() == direct,
    }


def learned_block(sc, spec) -> dict:
    """The report's ``learned`` block: the rank-stage contract.

    Flow (mirrors a warm service session): every smoke scenario is swept
    once prune-on to build the ``candmat`` harvest, a
    :class:`repro.learned.model.LearnedModel` is fitted + calibrated
    from it, then

    * **scenarios** — every smoke scenario swept rank-on vs rank-off:
      per-scenario dominance survivors vs rank survivors, and
      DesignPoint rows compared bit-for-bit (``winners_identical``);
    * **grid** — a full :class:`DenseGridSpec` ``reprice_grid`` pass
      rank-on: ``shrink_vs_dominance = survived / rank_survived`` is the
      dense-grid pricing-volume reduction the gate checks (winners are
      certified inside the call — it raises rather than report a lie).

    ``tools/check_bench.py`` gates ``winners_identical``, the dense-grid
    shrink floor ($DFMODEL_BENCH_RANK_SHRINK, default 3×), and
    ``model.recall >= model.recall_target`` — the calibration must
    actually achieve the recall it states."""
    from repro.learned.model import fit_ranker

    clear_caches()
    warm = DSEEngine(phased=True, parallel=False, prune="on")
    for name in scenario_names():
        warm.sweep_scenario(name, smoke=True)
    model = fit_ranker()
    if model is None:
        return {"enabled": False}
    scenarios: dict[str, dict] = {}
    dom = ranked = 0
    identical = True
    for name in scenario_names():
        on = DSEEngine(phased=True, parallel=False, prune="on", rank="on")
        res_on = on.sweep_scenario(name, smoke=True)
        st = on.last_plan_stats or {}
        off = DSEEngine(phased=True, parallel=False, prune="on", rank="off")
        res_off = off.sweep_scenario(name, smoke=True)
        same = ([p.row() for p in res_on.points]
                == [p.row() for p in res_off.points])
        identical = identical and same
        dom += st.get("survived", 0)
        ranked += st.get("rank_survived", 0)
        scenarios[name] = {"survived": st.get("survived", 0),
                           "rank_survived": st.get("rank_survived", 0),
                           "winners_identical": same}
    dense = DenseGridSpec().spec()
    eng = DSEEngine(prune="on", rank="on")
    rep = eng.reprice_grid(sc.work_fn, dense)
    return {
        "enabled": True,
        "model": {"n_train": model.n_train, "n_groups": model.n_groups,
                  "keep_frac": model.keep_frac, "recall": model.recall,
                  "recall_target": model.recall_target},
        "scenarios": scenarios,
        "smoke_survived": dom,
        "smoke_rank_survived": ranked,
        "smoke_shrink_vs_dominance": dom / max(1, ranked),
        "winners_identical": identical,
        "grid": {"cells": rep["cells"], "rank": rep["rank"],
                 "enumerated": rep["enumerated"],
                 "survived": rep["survived"],
                 "rank_survived": rep["rank_survived"],
                 "winners_identical": rep["winners_identical"]},
        "shrink_vs_dominance": (rep["survived"]
                                / max(1, rep["rank_survived"])),
    }


def _frontier_rows(name: str, result) -> list[dict]:
    return [{"workload": name, "pareto": True, **p.row()}
            for p in result.frontier]


def speedup_report(scenario_name: str = "llm", smoke: bool = True,
                   json_path: pathlib.Path | str | None = None
                   ) -> list[dict]:
    """Wall-clock comparison of the evaluation paths on one grid.

    Paths (all produce bit-identical ``DesignPoint.row()`` lists):

    * ``serial_uncached``   — scalar reference, every solve cold.
    * ``serial_perpoint``   — per-point path: scalar eval, memo cache.
    * ``serial_phased``     — in-process phased path: columnar candidate
      selection (one batched argmin per system group) + one batched
      pricing call.
    * ``parallel_perpoint`` — per-point eval in a process pool.
    * ``parallel_phased``   — the engine default: plan groups in the pool
      shipping (pruned) candidate matrices + survivor index maps, batched
      selection-certify + pricing in the parent, candidate pruning ON.
    * ``parallel_phased_noprune`` — the same engine with ``prune="off"``:
      every enumerated candidate priced, the PR 3 baseline. The report's
      ``prune`` block pairs this with ``parallel_phased`` — identical
      rows, strictly fewer priced candidates — which
      ``tools/check_bench.py`` gates on.
    * ``cold_parallel_shared`` — the phased parallel path with the
      cross-process shared memo store (``DSEEngine(shared_cache=True)``,
      :mod:`repro.core.memo_store`): every worker reuses every other
      worker's solves within the sweep. Cold like ``parallel_phased``;
      its aggregated cross-process store stats land in the report's
      ``shared_cache`` block (``hits`` > 0 is the cross-worker-reuse
      proof ``tools/check_bench.py`` gates on).
    * ``*_warm``            — per-point vs phased serial re-sweeps on a hot
      cache (the re-pricing regime: memory/interconnect what-ifs over
      already-solved plans).

    With an explicit ``json_path``, writes the report (points/sec per
    path, the phased-vs-per-point speedups, memo-cache hit/miss/size
    stats, the shared-store cross-process stats) as JSON —
    ``tools/check_bench.py`` does this for both the committed
    ``BENCH_dse.json`` baseline (``--update``) and the fresh comparison
    copy. The default writes no file, so the bench harness never
    clobbers the baseline mid-CI-run.
    """
    sc = get_scenario(scenario_name, smoke=smoke)
    spec = sc.spec
    paths: dict[str, dict] = {}
    rows_by_path: dict[str, list[dict]] = {}

    def measure(label: str, fn, clear: bool = True) -> None:
        if clear:
            clear_caches()
        t0 = time.perf_counter()
        pts = fn()
        dt = time.perf_counter() - t0
        paths[label] = {"seconds": dt, "points": len(pts),
                        "points_per_s": len(pts) / dt if dt else float("inf")}
        rows_by_path[label] = [p.row() for p in pts]

    def serial_sweep(phased: bool):
        return sweep(sc.work_fn, n_chips=spec.n_chips, chips=spec.chips,
                     topologies=spec.topologies, mem_net=spec.mem_net,
                     max_tp=spec.max_tp, max_pp=spec.max_pp,
                     execution=spec.execution, phased=phased)

    def uncached_scalar_sweep():
        with caching_disabled():
            return serial_sweep(False)

    perpoint = DSEEngine(phased=False)
    phased = DSEEngine(phased=True)
    measure("serial_uncached", uncached_scalar_sweep)
    # hot-cache re-sweeps directly follow their cold run (same in-process
    # cache): the re-pricing regime where batching dominates
    measure("serial_perpoint", lambda: serial_sweep(False))
    measure("perpoint_warm", lambda: serial_sweep(False), clear=False)
    measure("serial_phased", lambda: serial_sweep(True))
    measure("phased_warm", lambda: serial_sweep(True), clear=False)
    # snapshot before the pool runs: parallel workers own their caches, so
    # the parent's stats describe the serial cold+warm phased sequence
    stats = cache_stats()
    measure("parallel_perpoint", lambda: perpoint.sweep(sc.work_fn, spec))
    measure("parallel_phased", lambda: phased.sweep(sc.work_fn, spec))
    plan_stats = phased.last_plan_stats or {}
    noprune = DSEEngine(phased=True, prune="off")
    measure("parallel_phased_noprune",
            lambda: noprune.sweep(sc.work_fn, spec))
    # parallel=True + ≥2 workers: the shared row must exercise a real
    # multi-process pool even on a single-core runner (where "auto"
    # would stay serial and never create the store, failing the gate's
    # cross-worker-reuse check with no actual regression)
    shared = DSEEngine(phased=True, shared_cache=True, parallel=True,
                       max_workers=max(2, os.cpu_count() or 1))
    measure("cold_parallel_shared", lambda: shared.sweep(sc.work_fn, spec))
    shared_stats = shared.last_shared_stats
    search = search_block(sc, spec)
    compiled = compiled_block(sc, spec)
    service = service_block(scenario_name, smoke)
    learned = learned_block(sc, spec)

    ref = rows_by_path["serial_uncached"]
    identical = all(rows == ref for rows in rows_by_path.values())

    def ratio(a: str, b: str) -> float:
        return (paths[a]["seconds"] / paths[b]["seconds"]
                if paths[b]["seconds"] else float("inf"))

    report = {
        "workload": scenario_name,
        "smoke": smoke,
        "grid_points": len(spec.grid()),
        "rows_identical": identical,
        "paths": paths,
        # headline: the re-pricing regime (hot solve cache), where the
        # phased path's shared enumeration + batched pricing actually
        # differ from PR 1's per-point loop. Cold sweeps are bounded by
        # the identical discrete solves, so their ratio sits near 1.
        "speedup_phased_vs_perpoint": ratio("perpoint_warm", "phased_warm"),
        "speedup_phased_vs_perpoint_cold": ratio("serial_perpoint",
                                                 "serial_phased"),
        "speedup_phased_vs_perpoint_parallel": ratio("parallel_perpoint",
                                                     "parallel_phased"),
        "speedup_engine_vs_serial_uncached": ratio("serial_uncached",
                                                   "parallel_phased"),
        # cold parallel with vs without the cross-process shared store.
        # On the tiny smoke grid the store's per-op cost is visible (the
        # grouped phased path leaves little cross-worker redundancy), so
        # this ratio hovers near 1; the gated invariant is cross-worker
        # reuse (shared_cache.hits > 0) with bit-identical rows.
        "speedup_shared_vs_parallel_phased": ratio("parallel_phased",
                                                   "cold_parallel_shared"),
        # candidate pruning: the prune-on engine vs its prune-off twin on
        # the same cold grid. The gated invariants: identical winners
        # (both rows ride the global rows_identical check too), strictly
        # fewer candidate rows priced, throughput not below the unpruned
        # engine's floor.
        "prune": {
            "enabled": bool(plan_stats.get("prune", False)),
            "enumerated": plan_stats.get("enumerated", 0),
            "survived": plan_stats.get("survived", 0),
            "priced": plan_stats.get("priced", 0),
            "scalar_certified_groups":
                plan_stats.get("scalar_certified_groups", 0),
            "shrink": (plan_stats.get("priced", 0)
                       / plan_stats.get("enumerated", 1)
                       if plan_stats.get("enumerated") else 1.0),
            "winners_identical": (rows_by_path["parallel_phased"]
                                  == rows_by_path["parallel_phased_noprune"]),
            "points_per_s_on": paths["parallel_phased"]["points_per_s"],
            "points_per_s_off":
                paths["parallel_phased_noprune"]["points_per_s"],
        },
        # budgeted search: every policy certified against the exhaustive
        # argmin (the search call raises otherwise), plus the dense-grid
        # halving run whose eval_frac the gate caps at 20 % of exhaustive
        "search": search,
        # compiled f32 pricing under the drift-budget contract: every
        # smoke scenario's winners identical to the f64 scalar reference,
        # the 10^5-cell dense grid certified group-by-group, plus the
        # raw chunk-streamed kernel throughput ceiling
        "compiled": compiled,
        # the warm daemon: cold concurrent clients (cross-client dedup)
        # vs a warm full-grid repeat served from the shared memo, rows
        # bit-identical to a direct engine sweep
        "service": service,
        # the learned rank stage: calibrated model from the smoke-sweep
        # harvest, per-scenario rank-on/off winner identity, and the
        # dense-grid pricing-volume shrink over dominance-only
        "learned": learned,
        "shared_cache": shared_stats,
        "cache": {"hits": stats.hits, "misses": stats.misses,
                  "entries": stats.entries,
                  "by_space": {s: {"hits": h, "misses": m, "entries": e}
                               for s, (h, m, e) in stats.by_space.items()}},
    }
    if json_path is not None:
        pathlib.Path(json_path).write_text(json.dumps(report, indent=2))
    out = [{"path": label, "workload": scenario_name,
            "rows_identical": identical, **vals}
           for label, vals in paths.items()]
    out.append({"path": "speedup", "workload": scenario_name,
                "phased_vs_perpoint": report["speedup_phased_vs_perpoint"],
                "phased_vs_perpoint_cold":
                    report["speedup_phased_vs_perpoint_cold"],
                "phased_vs_perpoint_parallel":
                    report["speedup_phased_vs_perpoint_parallel"],
                "vs_serial_uncached":
                    report["speedup_engine_vs_serial_uncached"]})
    out.append({"path": "prune", "workload": scenario_name,
                **report["prune"]})
    for pol, entry in search["smoke"]["policies"].items():
        out.append({"path": f"search:{pol}", "workload": scenario_name,
                    **entry})
    out.append({"path": "search:dense", "workload": scenario_name,
                **search["dense"]})
    if compiled.get("available"):
        for name, entry in compiled["smoke"].items():
            out.append({"path": f"compiled:{name}",
                        "points": entry["points"],
                        "winners_identical": entry["winners_identical"],
                        "points_per_s": entry["points_per_s"]})
        grid = compiled["grid"]
        out.append({"path": "compiled:grid", "cells": grid["cells"],
                    "priced_rows": grid["priced_rows"],
                    "chunks": grid["chunks"],
                    "winners_identical": grid["winners_identical"],
                    "repriced_frac": grid["repriced_frac"],
                    "cells_per_s": grid["cells_per_s"],
                    "rows_per_s": grid["rows_per_s"]})
        out.append({"path": "compiled:stream", **compiled["stream"]})
    else:
        out.append({"path": "compiled", "available": False})
    out.append({"path": "service", **service})
    if learned.get("enabled"):
        out.append({"path": "learned", "keep_frac": learned["model"]["keep_frac"],
                    "recall": learned["model"]["recall"],
                    "smoke_shrink": learned["smoke_shrink_vs_dominance"],
                    "grid_shrink": learned["shrink_vs_dominance"],
                    "winners_identical": learned["winners_identical"]})
    else:
        out.append({"path": "learned", "enabled": False})
    out.extend(stats.rows())
    if shared_stats is not None:
        out.append({"space": "SHARED", "backend": shared_stats["backend"],
                    "hits": shared_stats["hits"],
                    "misses": shared_stats["misses"],
                    "entries": shared_stats["entries"],
                    "dropped": shared_stats["dropped"]})
    return out


def run(quick: bool = False):
    engine = DSEEngine()
    out = []
    for name in scenario_names():
        res = engine.sweep_scenario(name, smoke=quick)
        out.extend(res.rows())
        feas = [p for p in res.points if p.plan.feasible]
        out.extend(observations(name, feas or res.points))
        out.extend(_frontier_rows(name, res))
    out.extend(speedup_report("llm", smoke=quick))
    return out

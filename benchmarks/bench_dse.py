"""Design-space exploration heat maps (paper §VI.C, Figs 10-17).

4 workloads × (4 chips × 5 topologies × 4 mem/net combos = 80 systems),
1024 accelerators each, now driven through the parallel+cached
``DSEEngine``. Reports utilization, cost efficiency, power efficiency, the
compute/memory/network breakdown, the paper's key observation ratios, the
Pareto frontier per workload family, and — the engine's contract — the
wall-clock speedup of the parallel+cached path over the serial uncached
baseline with bit-identical ``DesignPoint.row()`` output.
"""
from __future__ import annotations

import time

from repro.core import DSEEngine, caching_disabled, clear_caches, sweep
from repro.workloads.scenarios import get_scenario, scenario_names

from .common import geomean

TITLE = "DSE heatmaps: GPT3-1T / DLRM-793B / HPL-5M² / FFT-1T on 80 systems"


def _ratio(points, pred_num, pred_den, metric):
    num = [getattr(p, metric) for p in points if pred_num(p)]
    den = [getattr(p, metric) for p in points if pred_den(p)]
    if not num or not den:
        return float("nan")
    return geomean(num) / geomean(den)


def observations(name: str, pts) -> list[dict]:
    """The paper's §VI.C bullet-point ratios, recomputed on our sweep."""
    is_nv = lambda p: p.system.topology.dims[0].link.name == "NVLink"
    is_pcie = lambda p: p.system.topology.dims[0].link.name == "PCIe"
    is_drag = lambda p: p.system.topology.name.startswith("dragonfly")
    simple = lambda p: not is_drag(p)
    rdu = lambda p: p.system.chip.name == "SN30"
    gpu_tpu = lambda p: p.system.chip.name in ("H100", "TPUv4")
    tpu = lambda p: p.system.chip.name == "TPUv4"
    wse = lambda p: p.system.chip.name == "WSE2"
    not_wse = lambda p: not wse(p)
    hbm = lambda p: p.system.memory.name == "HBM"
    ddr = lambda p: p.system.memory.name == "DDR"

    rows = []

    def obs(label, paper, num, den, metric="utilization"):
        rows.append({"workload": name, "observation": label,
                     "paper": paper,
                     "ours": _ratio(pts, num, den, metric)})

    if name == "llm":
        obs("RDU vs GPU/TPU util", 1.52, rdu, gpu_tpu)
        obs("RDU vs GPU/TPU cost-eff", 1.59, rdu, gpu_tpu, "cost_eff")
        obs("RDU vs GPU/TPU power-eff", 1.60, rdu, gpu_tpu, "power_eff")
        obs("GPU/TPU HBM vs DDR util", 1.66,
            lambda p: gpu_tpu(p) and hbm(p), lambda p: gpu_tpu(p) and ddr(p))
        obs("RDU HBM vs DDR util", 1.0,
            lambda p: rdu(p) and hbm(p), lambda p: rdu(p) and ddr(p))
        obs("dragonfly vs simple util (PCIe)", 1.21,
            lambda p: is_drag(p) and is_pcie(p),
            lambda p: simple(p) and is_pcie(p))
        obs("WSE NVLink vs PCIe util", 5.15,
            lambda p: wse(p) and is_nv(p), lambda p: wse(p) and is_pcie(p))
        obs("WSE vs rest cost-eff", 0.06, wse, not_wse, "cost_eff")
        obs("WSE vs rest power-eff", 0.20, wse, not_wse, "power_eff")
    elif name == "dlrm":
        obs("NVLink vs PCIe util", 6.30, is_nv, is_pcie)
        obs("dragonfly vs simple util", 2.51, is_drag, simple)
        obs("TPU vs others util", 4.43, tpu, lambda p: not tpu(p))
        obs("WSE vs others util", 0.10, wse, not_wse)
    elif name == "hpl":
        obs("NVLink vs PCIe util (≈1: all high)", 1.0, is_nv, is_pcie)
        obs("WSE vs rest cost-eff", 0.09, wse, not_wse, "cost_eff")
        obs("WSE vs rest power-eff", 0.33, wse, not_wse, "power_eff")
    elif name == "fft":
        obs("NVLink vs PCIe util", 7.02, is_nv, is_pcie)
        obs("dragonfly vs simple util", 3.22, is_drag, simple)
        obs("TPU vs others util", 5.11, tpu, lambda p: not tpu(p))
        obs("WSE vs others util", 0.09, wse, not_wse)
    return rows


def _frontier_rows(name: str, result) -> list[dict]:
    return [{"workload": name, "pareto": True, **p.row()}
            for p in result.frontier]


def speedup_report(scenario_name: str = "llm", smoke: bool = True) -> dict:
    """Serial uncached baseline vs parallel+cached engine, same grid.

    The contract: ≥4× wall-clock on a multi-core host for the default
    80-point sweep, with bit-identical ``DesignPoint.row()`` lists.
    """
    sc = get_scenario(scenario_name, smoke=smoke)
    spec = sc.spec

    clear_caches()
    t0 = time.perf_counter()
    with caching_disabled():
        base = sweep(sc.work_fn, n_chips=spec.n_chips, chips=spec.chips,
                     topologies=spec.topologies, mem_net=spec.mem_net,
                     max_tp=spec.max_tp, max_pp=spec.max_pp,
                     execution=spec.execution)
    t_serial = time.perf_counter() - t0

    clear_caches()
    engine = DSEEngine()
    t0 = time.perf_counter()
    pts = engine.sweep(sc.work_fn, spec)
    t_engine = time.perf_counter() - t0

    identical = [p.row() for p in base] == [p.row() for p in pts]
    return {"workload": scenario_name,
            "grid_points": len(spec.grid()),
            "serial_uncached_s": t_serial,
            "engine_s": t_engine,
            "speedup": t_serial / t_engine if t_engine else float("inf"),
            "rows_identical": identical}


def run(quick: bool = False):
    engine = DSEEngine()
    out = []
    for name in scenario_names():
        res = engine.sweep_scenario(name, smoke=quick)
        out.extend(res.rows())
        feas = [p for p in res.points if p.plan.feasible]
        out.extend(observations(name, feas or res.points))
        out.extend(_frontier_rows(name, res))
    out.append(speedup_report("llm", smoke=quick))
    return out

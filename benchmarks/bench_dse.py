"""Design-space exploration heat maps (paper §VI.C, Figs 10-17).

4 workloads × (4 chips × 5 topologies × 4 mem/net combos = 80 systems),
1024 accelerators each. Reports utilization, cost efficiency, power
efficiency and the compute/memory/network breakdown, plus the paper's key
observation ratios computed from our reproduction.
"""
from __future__ import annotations

from repro.core.dse import (DEFAULT_CHIPS, DEFAULT_MEM_NET,
                            DEFAULT_TOPOLOGIES, sweep)
from repro.workloads.dlrm import dlrm_workload
from repro.workloads.fft import fft_workload
from repro.workloads.hpl import hpl_workload
from repro.workloads.llm import GPT3_1T, GPT3_175B, gpt_workload

from .common import geomean

TITLE = "DSE heatmaps: GPT3-1T / DLRM-793B / HPL-5M² / FFT-1T on 80 systems"


def _workloads(quick: bool):
    # quick mode shrinks to 64 chips, where GPT3-1T cannot fit; use 175B
    llm = GPT3_175B if quick else GPT3_1T
    return {
        "llm": lambda sys_: gpt_workload(llm, global_batch=512, microbatch=1),
        "dlrm": lambda sys_: dlrm_workload(),
        "hpl": lambda sys_: hpl_workload(),
        "fft": lambda sys_: fft_workload(),
    }


def _ratio(points, pred_num, pred_den, metric):
    num = [getattr(p, metric) for p in points if pred_num(p)]
    den = [getattr(p, metric) for p in points if pred_den(p)]
    if not num or not den:
        return float("nan")
    return geomean(num) / geomean(den)


def observations(name: str, pts) -> list[dict]:
    """The paper's §VI.C bullet-point ratios, recomputed on our sweep."""
    is_nv = lambda p: p.system.topology.dims[0].link.name == "NVLink"
    is_pcie = lambda p: p.system.topology.dims[0].link.name == "PCIe"
    is_drag = lambda p: p.system.topology.name.startswith("dragonfly")
    simple = lambda p: not is_drag(p)
    rdu = lambda p: p.system.chip.name == "SN30"
    gpu_tpu = lambda p: p.system.chip.name in ("H100", "TPUv4")
    tpu = lambda p: p.system.chip.name == "TPUv4"
    wse = lambda p: p.system.chip.name == "WSE2"
    not_wse = lambda p: not wse(p)
    hbm = lambda p: p.system.memory.name == "HBM"
    ddr = lambda p: p.system.memory.name == "DDR"

    rows = []

    def obs(label, paper, num, den, metric="utilization"):
        rows.append({"workload": name, "observation": label,
                     "paper": paper,
                     "ours": _ratio(pts, num, den, metric)})

    if name == "llm":
        obs("RDU vs GPU/TPU util", 1.52, rdu, gpu_tpu)
        obs("RDU vs GPU/TPU cost-eff", 1.59, rdu, gpu_tpu, "cost_eff")
        obs("RDU vs GPU/TPU power-eff", 1.60, rdu, gpu_tpu, "power_eff")
        obs("GPU/TPU HBM vs DDR util", 1.66,
            lambda p: gpu_tpu(p) and hbm(p), lambda p: gpu_tpu(p) and ddr(p))
        obs("RDU HBM vs DDR util", 1.0,
            lambda p: rdu(p) and hbm(p), lambda p: rdu(p) and ddr(p))
        obs("dragonfly vs simple util (PCIe)", 1.21,
            lambda p: is_drag(p) and is_pcie(p),
            lambda p: simple(p) and is_pcie(p))
        obs("WSE NVLink vs PCIe util", 5.15,
            lambda p: wse(p) and is_nv(p), lambda p: wse(p) and is_pcie(p))
        obs("WSE vs rest cost-eff", 0.06, wse, not_wse, "cost_eff")
        obs("WSE vs rest power-eff", 0.20, wse, not_wse, "power_eff")
    elif name == "dlrm":
        obs("NVLink vs PCIe util", 6.30, is_nv, is_pcie)
        obs("dragonfly vs simple util", 2.51, is_drag, simple)
        obs("TPU vs others util", 4.43, tpu, lambda p: not tpu(p))
        obs("WSE vs others util", 0.10, wse, not_wse)
    elif name == "hpl":
        obs("NVLink vs PCIe util (≈1: all high)", 1.0, is_nv, is_pcie)
        obs("WSE vs rest cost-eff", 0.09, wse, not_wse, "cost_eff")
        obs("WSE vs rest power-eff", 0.33, wse, not_wse, "power_eff")
    elif name == "fft":
        obs("NVLink vs PCIe util", 7.02, is_nv, is_pcie)
        obs("dragonfly vs simple util", 3.22, is_drag, simple)
        obs("TPU vs others util", 5.11, tpu, lambda p: not tpu(p))
        obs("WSE vs others util", 0.09, wse, not_wse)
    return rows


def run(quick: bool = False):
    n_chips = 64 if quick else 1024
    chips = ("H100", "TPUv4", "SN30") if quick else DEFAULT_CHIPS
    topos = ("torus2d", "dragonfly") if quick else DEFAULT_TOPOLOGIES
    mem_net = (("DDR", "PCIe"), ("HBM", "NVLink")) if quick \
        else DEFAULT_MEM_NET
    out = []
    for name, work_fn in _workloads(quick).items():
        # HPL/FFT run one global problem instance (global_batch=1 ⇒ DP=1);
        # the whole machine must be absorbed by TP (×PP), so TP is unbounded
        max_tp = None if name in ("hpl", "fft") else 64
        pts = sweep(work_fn, n_chips=n_chips, chips=chips,
                    topologies=topos, mem_net=mem_net, max_tp=max_tp)
        for p in pts:
            out.append({"workload": name, **p.row()})
        feas = [p for p in pts if p.plan.feasible]
        out.extend(observations(name, feas or pts))
    return out

"""Validation benchmarks (paper Figs 6, 7, 8).

Fig 8: fix 1024 A100s, sweep (TP, PP, DP); report the iteration-time
breakdown per combo (fwd/bwd/bubble/comms) — the Calculon comparison grid.

Fig 7: fix 1024 H100s, sweep the high-bandwidth NVLink domain size with
switch scale-out (the Rail-Only design); utilization should be nearly flat
above a modest domain size — Rail-Only's thesis.

Fig 6: modeled utilization of LLM training on the four Table-V chips vs the
paper's measured-performance anchors.
"""
from __future__ import annotations

import dataclasses

from repro.core.interchip import optimize_inter_chip
from repro.systems.chips import (A100, H100, HBM, NVLINK, SN30, TPU_V4,
                                 WSE2)
from repro.systems.system import SystemSpec
from repro.systems.topology import Topology, TopologyDim, dgx1
from repro.workloads.llm import GPT3_1T, GPT3_175B, gpt_workload

TITLE = "validation: Fig 8 (TP/PP/DP sweep), Fig 7 (rail-only), Fig 6 anchors"


def fig8_parallelism_sweep(quick: bool) -> list[dict]:
    n = 128 if quick else 1024
    system = SystemSpec("dgx_a100", A100, HBM, dgx1(n, NVLINK))
    work = gpt_workload(GPT3_1T if not quick else GPT3_175B,
                        global_batch=512, microbatch=1)
    combos = [(8, 16, n // 128), (8, 8, n // 64), (4, 16, n // 64),
              (16, 8, n // 128), (8, 4, n // 32)]
    rows = []
    for tp, pp, dp in combos:
        if tp * pp * dp != n:
            continue
        try:
            p = optimize_inter_chip(work, system, fixed=(tp, pp, dp))
        except ValueError:
            continue
        total = p.iter_time
        rows.append({
            "fig": "8", "tp": tp, "pp": pp, "dp": dp,
            "iter_s": total, "util": p.utilization,
            "fwd%": 100 * p.breakdown["fwd"] / total,
            "bwd%": 100 * p.breakdown["bwd"] / total,
            "bubble%": 100 * p.breakdown["bubble"] / total,
            "tp_comm%": 100 * p.breakdown["tp_comm"] / total,
            "dp_comm%": 100 * p.breakdown["dp_exposed"] / total,
        })
    return rows


def fig7_rail_only(quick: bool) -> list[dict]:
    n = 128 if quick else 1024
    work = gpt_workload(GPT3_1T if not quick else GPT3_175B,
                        global_batch=512, microbatch=1)
    rows = []
    for domain in (4, 8, 16, 32):
        if domain > n:
            continue
        topo = Topology(f"rail{domain}",
                        (TopologyDim(domain, "fc", NVLINK),
                         TopologyDim(n // domain, "switch", NVLINK)))
        system = SystemSpec(f"h100_rail{domain}", H100, HBM, topo)
        # rail-only semantics: TP confined to the NVLink domain
        p = optimize_inter_chip(work, system, max_tp=domain,
                                allow_subdivision=False)
        rows.append({"fig": "7", "nvlink_domain": domain,
                     "util": p.utilization, "iter_s": p.iter_time,
                     "plan": f"tp{p.tp}/pp{p.pp}/dp{p.dp}"})
    # Rail-Only claim: utilization roughly flat in domain size
    if rows:
        utils = [r["util"] for r in rows]
        rows.append({"fig": "7", "nvlink_domain": "spread",
                     "util": max(utils) - min(utils),
                     "iter_s": 0.0, "plan": "max-min (flat ⇒ small)"})
    return rows


# paper Fig 6 measured-utilization anchors (approximate, read from figure)
_MEASURED_UTIL = {"H100": 0.40, "TPUv4": 0.45, "SN30": 0.55, "WSE2": 0.35}


def fig6_anchors(quick: bool) -> list[dict]:
    """Modeled utilization per chip with the chip's NATIVE execution model
    (kbk for GPU/TPU, dataflow for RDU/WSE) — the §VI setting — against the
    paper's measured anchors."""
    from repro.core.dse import sweep
    n = 64 if quick else 256
    rows = []
    pts = sweep(lambda sys_: gpt_workload(GPT3_175B, global_batch=256,
                                          microbatch=1),
                n_chips=n, chips=("H100", "TPUv4", "SN30", "WSE2"),
                topologies=("dgx1",), mem_net=(("HBM", "NVLink"),),
                max_tp=64)
    for p in pts:
        meas = _MEASURED_UTIL[p.system.chip.name]
        rows.append({"fig": "6", "chip": p.system.chip.name,
                     "modeled_util": p.utilization,
                     "paper_measured_util": meas,
                     "model/measured": p.utilization / meas})
    return rows


def run(quick: bool = False):
    return fig8_parallelism_sweep(quick) + fig7_rail_only(quick) \
        + fig6_anchors(quick)

"""Benchmark harness — one module per paper table/figure.

Usage:
  PYTHONPATH=src python -m benchmarks.run             # full sweeps
  PYTHONPATH=src python -m benchmarks.run --quick     # reduced sweeps
  PYTHONPATH=src python -m benchmarks.run --only dse  # one module
  PYTHONPATH=src python -m benchmarks.run --smoke     # CI gate: quick mode,
                                                      # fast module subset

Each module prints its rows as an aligned table plus one
``CSV,name,us_per_call,derived`` line for machine consumption.
"""
from __future__ import annotations

import argparse
import time
import traceback

from . import (bench_3dmemory, bench_dse, bench_mappings,
               bench_memory_sweep, bench_roofline, bench_serving,
               bench_solver, bench_specdecode, bench_validation)
from .common import emit, table

MODULES = {
    "solver": bench_solver,
    "validation": bench_validation,
    "mappings": bench_mappings,
    "memory_sweep": bench_memory_sweep,
    "dse": bench_dse,
    "serving": bench_serving,
    "specdecode": bench_specdecode,
    "3dmemory": bench_3dmemory,
    "roofline": bench_roofline,
}


# the CI smoke gate: cheap enough for every PR, still exercises the solver
# DPs and the full DSE engine path (parallel sweep + cache + Pareto)
SMOKE_MODULES = ("solver", "dse")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: --quick grids, fast module subset")
    ap.add_argument("--only", choices=list(MODULES))
    args = ap.parse_args()
    if args.smoke:
        args.quick = True

    if args.only:
        names = [args.only]
    elif args.smoke:
        names = list(SMOKE_MODULES)
    else:
        names = list(MODULES)
    failures = []
    for name in names:
        mod = MODULES[name]
        print(f"\n=== {name}: {mod.TITLE} ===")
        t0 = time.perf_counter()
        try:
            rows = mod.run(quick=args.quick)
        except Exception:
            traceback.print_exc()
            failures.append(name)
            continue
        dt = time.perf_counter() - t0
        print(table(rows))
        emit(name, dt, f"rows={len(rows)}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()

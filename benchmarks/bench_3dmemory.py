"""3D-memory chip-balance study (paper §VIII.C, Fig 22).

1024 SN40L-class chips, 2080 iso-area units split between compute and SRAM;
sweep the compute fraction 20-80% under three off-chip memories: 2D DDR
(100 GB/s), 2.5D HBM (1 TB/s), 3D-stacked (100 TB/s). Workload: one layer
of a projected 100T-parameter GPT, TP-sharded over the pod.

Paper observations reproduced: low-bandwidth memory wants more on-chip SRAM;
3D memory lets the chip spend almost all area on compute.
"""
from __future__ import annotations

import dataclasses

from repro.core.intrachip import optimize_intra_chip
from repro.core.sharding import solve_sharding
from repro.systems.chips import DDR_2D, HBM_25D, MEM_3D, SN40L
from repro.systems.topology import torus2d, ring
from repro.systems.chips import ICI

from repro.workloads.llm import GPT_100T, gpt_layer_graph

TITLE = "Fig 22: compute/SRAM area split under 2D DDR / 2.5D HBM / 3D memory"

UNITS = 2080
UNIT_FLOPS = SN40L.peak_flops / 1040          # one compute unit
UNIT_SRAM = SN40L.sram_capacity / 1040        # one memory unit


def run(quick: bool = False):
    tp = 1024
    topo = torus2d(tp, ICI)
    g = gpt_layer_graph(dataclasses.replace(GPT_100T, batch=1))
    sol = solve_sharding(g, tp, topo, [0, 1])
    sharded = g.scaled(flop_scale=1.0 / tp, bytes_scale=1.0 / tp)
    flops_per_chip = sharded.total_flops()

    rows = []
    best = {}
    fracs = (0.2, 0.5, 0.8) if quick else (0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8)
    for mem in (DDR_2D, HBM_25D, MEM_3D):
        for frac in fracs:
            n_comp = int(UNITS * frac)
            chip = dataclasses.replace(
                SN40L, tiles=n_comp, tile_flops=UNIT_FLOPS,
                sram_capacity=(UNITS - n_comp) * UNIT_SRAM)
            res = optimize_intra_chip(sharded, chip, mem, h_n=sol.h_n,
                                      h_m=sol.h_m)
            thru = flops_per_chip / res.total_time          # FLOP/s achieved
            rows.append({
                "memory": mem.name, "compute_frac": frac,
                "achieved_tflops": thru / 1e12,
                "peak_tflops": chip.peak_flops / 1e12,
                "util": thru / chip.peak_flops,
                "bottleneck": res.bottleneck,
            })
            if thru > best.get(mem.name, (0, 0))[0]:
                best[mem.name] = (thru, frac)
    for mname, (thru, frac) in best.items():
        rows.append({"memory": mname, "compute_frac": f"best={frac}",
                     "achieved_tflops": thru / 1e12, "peak_tflops": "",
                     "util": "", "bottleneck": ""})
    return rows

"""Mapping ladder + hierarchical roofline (paper §VII, Table VI, Fig 18).

GPT3 175B on 8 SN10 RDUs (DDR 200 GB/s, PCIe 25 GB/s):
  non-dataflow (kbk) → vendor 4-partition dataflow → DFModel 8×1 ring →
  DFModel 4×2 torus. Reports stepwise + cumulative speedups and each
  mapping's two operational intensities (memory & network rooflines).
"""
from __future__ import annotations

import dataclasses

from repro.core.intrachip import (evaluate_intra_assignment,
                                  optimize_intra_chip)
from repro.core.roofline import HierPoint
from repro.core.sharding import solve_sharding
from repro.systems.chips import DDR, PCIE, SN10
from repro.systems.topology import ring, torus2d
from repro.workloads.llm import GPT3_175B, gpt_layer_graph

TITLE = "Table VI / Fig 18: GPT3-175B mapping ladder on 8×SN10"

DDR_200 = dataclasses.replace(DDR, bandwidth=200e9)
VENDOR = {"LN1": 0, "QKV": 0, "MHA1": 1, "Softmax": 1, "MHA2": 1,
          "Proj": 1, "Add1": 1, "LN2": 1, "FFN0": 2, "FFN1": 3, "Add2": 3}


def _roofline(name, intra, shard, tp):
    g = gpt_layer_graph(dataclasses.replace(GPT3_175B, batch=1))
    flops = g.total_flops() / tp
    return HierPoint(name=name, flops=flops,
                     dram_bytes=max(intra.dram_traffic, 1.0),
                     net_bytes=max(shard.comm_bytes, 1.0),
                     peak_flops=SN10.peak_flops,
                     dram_bw=DDR_200.bandwidth, net_bw=PCIE.bandwidth)


def run(quick: bool = False):
    g = gpt_layer_graph(dataclasses.replace(GPT3_175B, batch=1))

    def setup(tp, topo):
        sol = solve_sharding(g, tp, topo, list(range(len(topo.dims))))
        sharded = g.scaled(flop_scale=1.0 / tp, bytes_scale=1.0 / tp)
        return sol, sharded

    sol8, g8 = setup(8, ring(8, PCIE))
    sol4, g4 = setup(4, torus2d(8, PCIE))

    kbk = optimize_intra_chip(g8, SN10, DDR_200, h_n=sol8.h_n, h_m=sol8.h_m,
                              mode="kbk")
    vendor = evaluate_intra_assignment(
        g8, [VENDOR[k.name] for k in g8.kernels], SN10, DDR_200,
        h_n=sol8.h_n, h_m=sol8.h_m)
    df81 = optimize_intra_chip(g8, SN10, DDR_200, h_n=sol8.h_n,
                               h_m=sol8.h_m, p_max=8)
    df42 = optimize_intra_chip(g4, SN10, DDR_200, h_n=sol4.h_n,
                               h_m=sol4.h_m, p_max=8)

    # system throughput: DP=2 on the 4×2 torus runs two replicas
    ladder = [
        ("non-dataflow (Calculon-style)", "8x1 ring", kbk.total_time, 1.0),
        ("vendor 4-partition dataflow", "8x1 ring", vendor.total_time, None),
        ("DFModel dataflow", "8x1 ring", df81.total_time, None),
        ("DFModel dataflow", "4x2 torus (TP4xDP2)", df42.total_time / 2.0,
         None),
    ]
    paper = [1.0, 4.05, 4.8, 6.13]
    rows = []
    prev = None
    for (name, topo, t, _), pacc in zip(ladder, paper):
        step = 1.0 if prev is None else prev / t
        rows.append({
            "mapping": name, "topology": topo, "time_per_ubatch_s": t,
            "stepwise_x": step,
            "accum_x": ladder[0][2] / t,
            "paper_accum_x": pacc,
        })
        prev = t
    # Fig 18 roofline points
    for name, intra, sol, tp in [
            ("kbk 8x1", kbk, sol8, 8), ("vendor 8x1", vendor, sol8, 8),
            ("dfmodel 8x1", df81, sol8, 8), ("dfmodel 4x2", df42, sol4, 4)]:
        pt = _roofline(name, intra, sol, tp)
        rows.append({
            "mapping": f"roofline:{name}", "topology": "",
            "time_per_ubatch_s": intra.total_time,
            "stepwise_x": pt.oi_mem, "accum_x": pt.oi_net,
            "paper_accum_x": f"bound={pt.bound}",
        })
    return rows

"""Solver-scale benchmark (paper §I claim: a trillion-parameter LLM on a
thousand-accelerator datacenter — design space O(10^295) — mapped in
20 minutes on 64 CPUs; our DP/B&B core solves its equivalent in seconds
on one CPU)."""
from __future__ import annotations

import time

from repro.core.interchip import optimize_inter_chip
from repro.core.solver import design_space_size
from repro.systems.chips import A100, HBM, NVLINK
from repro.systems.system import SystemSpec
from repro.systems.topology import dgx1
from repro.workloads.llm import GPT3_1T, gpt_workload

TITLE = "solver scale: GPT3-1T onto 1024 A100s (paper: O(10^295), 20 min)"


def run(quick: bool = False):
    n_chips = 256 if quick else 1024
    system = SystemSpec("dgx_a100", A100, HBM, dgx1(n_chips, NVLINK))
    work = gpt_workload(GPT3_1T, global_batch=512, microbatch=1)
    logsize = design_space_size(work.layer_graph, p_max=GPT3_1T.n_layers,
                                n_chips=n_chips)
    t0 = time.perf_counter()
    plan = optimize_inter_chip(work, system, max_tp=64)
    dt = time.perf_counter() - t0
    return [{
        "workload": "gpt3_1t", "chips": n_chips,
        "design_space_log10": logsize,
        "solve_seconds": dt,
        "best": plan.summary(),
        "paper_reference": "O(10^295) in 20 min on 64 CPUs",
    }]

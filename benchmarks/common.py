"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable


def timed(fn: Callable, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, time.perf_counter() - t0


def table(rows: list[dict], cols: list[str] | None = None,
          floatfmt: str = "{:.4g}") -> str:
    """Aligned text table; heterogeneous row schemas become sub-tables."""
    if not rows:
        return "(no rows)"
    if cols is None:
        groups: list[tuple[tuple, list[dict]]] = []
        for r in rows:
            key = tuple(r.keys())
            if groups and groups[-1][0] == key:
                groups[-1][1].append(r)
            else:
                groups.append((key, [r]))
        if len(groups) > 1:
            return "\n\n".join(table(g, list(k)) for k, g in groups)
        cols = list(groups[0][0])
    widths = {c: max(len(c), *(len(_fmt(r.get(c, ""), floatfmt))
                               for r in rows)) for c in cols}
    head = "  ".join(c.ljust(widths[c]) for c in cols)
    sep = "  ".join("-" * widths[c] for c in cols)
    body = "\n".join("  ".join(_fmt(r.get(c, ""), floatfmt).ljust(widths[c])
                               for c in cols) for r in rows)
    return f"{head}\n{sep}\n{body}"


def _fmt(v, floatfmt) -> str:
    if isinstance(v, float):
        return floatfmt.format(v)
    return str(v)


def geomean(xs) -> float:
    import math
    xs = [x for x in xs if x > 0]
    if not xs:
        return 0.0
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def emit(name: str, seconds: float, derived: str = ""):
    """The harness's one-line CSV contract: name,us_per_call,derived."""
    print(f"CSV,{name},{seconds * 1e6:.1f},{derived}")

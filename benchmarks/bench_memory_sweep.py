"""Dataflow vs non-dataflow over the memory design space (paper Fig 19).

300-TFLOPS accelerator, SRAM ∈ {150, 300, 500} MB × DRAM bw ∈ {100, 300,
600} GB/s; GPT3-175B on 8 chips in a 4×2 torus. Reports both mappings'
utilization per point and the dataflow/non-dataflow ratio (paper: dataflow
upper-bounds non-dataflow, 1.63× on average).
"""
from __future__ import annotations

import dataclasses

from repro.core.intrachip import optimize_intra_chip
from repro.core.sharding import solve_sharding
from repro.systems.chips import DDR, PCIE, SN10
from repro.systems.topology import torus2d
from repro.workloads.llm import GPT3_175B, gpt_layer_graph

from .common import geomean

TITLE = "Fig 19: dataflow vs non-dataflow across SRAM × DRAM-bw design space"


def run(quick: bool = False):
    tp = 4
    topo = torus2d(8, PCIE)
    g = gpt_layer_graph(dataclasses.replace(GPT3_175B, batch=1))
    sol = solve_sharding(g, tp, topo, [0, 1])
    sharded = g.scaled(flop_scale=1.0 / tp, bytes_scale=1.0 / tp)
    chip300 = dataclasses.replace(SN10, tiles=1000, tile_flops=300e12 / 1000)
    flops_per_chip = sharded.total_flops()

    rows, ratios = [], []
    for sram_mb in (150, 300, 500):
        for bw_gb in (100, 300, 600):
            chip = dataclasses.replace(chip300, sram_capacity=sram_mb * 1e6)
            mem = dataclasses.replace(DDR, bandwidth=bw_gb * 1e9)
            df = optimize_intra_chip(sharded, chip, mem, h_n=sol.h_n,
                                     h_m=sol.h_m)
            kbk = optimize_intra_chip(sharded, chip, mem, h_n=sol.h_n,
                                      h_m=sol.h_m, mode="kbk")
            u_df = flops_per_chip / (df.total_time * chip.peak_flops)
            u_kbk = flops_per_chip / (kbk.total_time * chip.peak_flops)
            ratios.append(kbk.total_time / df.total_time)
            rows.append({
                "sram_mb": sram_mb, "dram_gbps": bw_gb,
                "util_dataflow": u_df, "util_kbk": u_kbk,
                "dataflow_x": kbk.total_time / df.total_time,
                "df_partitions": df.n_partitions,
            })
    rows.append({"sram_mb": "avg", "dram_gbps": "",
                 "util_dataflow": "", "util_kbk": "",
                 "dataflow_x": geomean(ratios),
                 "df_partitions": "paper: 1.63x"})
    return rows

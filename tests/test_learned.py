"""Learned rank-stage tests: winner preservation of the keep rule
(seeded property tests against the scalar reference scan), model
persistence, the staleness guard's degradation to rank-off, the
certify-or-die check catching a tampered ranker, and the engine-level
acceptance property — rank-on and rank-off sweeps return identical
winners on every smoke scenario, serially and across every pool
transport.

Like test_dse_engine.py these avoid hypothesis so they run on a bare
install; the seeded random checks below are the property tests.
"""
from __future__ import annotations

import dataclasses
import multiprocessing

import numpy as np
import pytest

from repro.core import DSEEngine, clear_caches
from repro.core.interchip import scalar_winner_rows
from repro.core.memo import SolveCache
from repro.learned import (FEATURE_NAMES, FORMAT_VERSION, LearnedModel,
                           bound_keep, fit_ranker, rank_keep,
                           rank_keep_count, resolve_rank)
from repro.search.surrogate import RidgeModel
from repro.workloads.scenarios import scenario_names


def _random_group(rng, n):
    """A random candidate group: exact times, valid lower bounds
    (lb <= iter_time), memory sizes and a few actual capacities."""
    iter_time = rng.uniform(0.1, 10.0, size=n)
    iter_lb = iter_time * rng.uniform(0.2, 1.0, size=n)
    mem = rng.uniform(1.0, 100.0, size=n)
    caps = rng.uniform(1.0, 120.0, size=int(rng.integers(1, 4)))
    return iter_time, iter_lb, mem, caps


def _synthetic_model(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(50, len(FEATURE_NAMES)))
    y = rng.uniform(1.0, 2.0, size=50)
    return LearnedModel(version=FORMAT_VERSION, feature_names=FEATURE_NAMES,
                        ridge=RidgeModel.fit(X, y), n_train=50, n_groups=2,
                        recall_target=0.95, keep_frac=0.2, recall=1.0)


# ------------------------- keep-rule properties ------------------------------
def test_bound_keep_winner_preserving_seeded():
    """Every per-capacity scalar winner — and the no-feasible fallback
    row — survives bound_keep, for random groups and capacities."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        n = int(rng.integers(1, 40))
        iter_time, iter_lb, mem, caps = _random_group(rng, n)
        keep = bound_keep(iter_time, iter_lb, mem, caps)
        for row in scalar_winner_rows(iter_time, mem, caps):
            assert row >= 0 and keep[row]
        assert keep[int(np.argmin(iter_time))]


def test_rank_keep_winner_preserving_under_adversarial_scores():
    """The union rule holds even when the model is maximally wrong
    (scores = -iter_time ranks the best rows LAST): winners ride in on
    the bound_keep safety set, and the top-k budget is still honored."""
    rng = np.random.default_rng(1)
    for _ in range(200):
        n = int(rng.integers(1, 40))
        iter_time, iter_lb, mem, caps = _random_group(rng, n)
        frac = float(rng.uniform(0.05, 1.0))
        keep = rank_keep(-iter_time, iter_time, iter_lb, mem, caps, frac)
        for row in scalar_winner_rows(iter_time, mem, caps):
            assert keep[row]
        assert keep.sum() >= rank_keep_count(n, frac) > 0
        # restricting to the kept rows must reproduce the winners exactly
        kept = np.flatnonzero(keep)
        sub = scalar_winner_rows(iter_time[kept], mem[kept], caps)
        assert [int(kept[r]) for r in sub] == \
            scalar_winner_rows(iter_time, mem, caps)


def test_rank_keep_count_and_policy_parsing():
    assert rank_keep_count(10, 0.25) == 3   # ceil(2.5)
    assert rank_keep_count(10, 1.0) == 10
    assert rank_keep_count(3, 0.01) == 1    # never empty
    assert resolve_rank(True) is True and resolve_rank("off") is False
    assert resolve_rank("on") is True
    with pytest.raises(ValueError, match="rank policy"):
        resolve_rank("banana")


def test_rank_env_parsing(monkeypatch):
    from repro.learned.rank import default_rank, rank_keep_frac
    monkeypatch.delenv("DFMODEL_RANK", raising=False)
    assert default_rank() == "off"          # opt-in: unset means off
    monkeypatch.setenv("DFMODEL_RANK", "yes")
    assert default_rank() == "on" and resolve_rank("auto") is True
    monkeypatch.setenv("DFMODEL_RANK", "sideways")
    with pytest.raises(ValueError, match="DFMODEL_RANK"):
        default_rank()
    monkeypatch.delenv("DFMODEL_RANK_KEEP_FRAC", raising=False)
    assert rank_keep_frac() is None
    monkeypatch.setenv("DFMODEL_RANK_KEEP_FRAC", "0.25")
    assert rank_keep_frac() == 0.25
    for bad in ("0", "1.5", "frac"):
        monkeypatch.setenv("DFMODEL_RANK_KEEP_FRAC", bad)
        with pytest.raises(ValueError, match="DFMODEL_RANK_KEEP_FRAC"):
            rank_keep_frac()


# ------------------------------ persistence ----------------------------------
def test_model_save_load_roundtrip(tmp_path):
    model = _synthetic_model()
    path = str(tmp_path / "ranker.npz")
    model.save(path)
    back = LearnedModel.load(path)
    assert back.fingerprint == model.fingerprint
    assert back.feature_names == FEATURE_NAMES
    assert back.keep_frac == model.keep_frac
    assert back.recall == model.recall and back.n_train == model.n_train
    X = np.random.default_rng(7).normal(size=(9, len(FEATURE_NAMES)))
    np.testing.assert_array_equal(back.score(X), model.score(X))


def test_model_load_refuses_version_mismatch(tmp_path):
    model = _synthetic_model()
    path = str(tmp_path / "ranker.npz")
    dataclasses.replace(model, version=FORMAT_VERSION + 1).save(path)
    with pytest.raises(ValueError, match="format version"):
        LearnedModel.load(path)


# ---------------------------- staleness guard --------------------------------
def test_fit_ranker_staleness_guard_empty_cache():
    assert fit_ranker(SolveCache()) is None


def test_fit_ranker_rejects_bad_recall_target():
    with pytest.raises(ValueError, match="recall_target"):
        fit_ranker(SolveCache(), recall_target=1.5)


def test_engine_rank_on_degrades_to_off_when_cold():
    """rank='on' with no harvest yet must not die — it degrades to a
    plain pruned sweep (stats say so) instead of fitting on nothing."""
    clear_caches()
    eng = DSEEngine(parallel=False, prune="on", rank="on")
    res = eng.sweep_scenario("fft", smoke=True)
    assert res.points
    stats = eng.last_plan_stats
    assert stats["rank"] is False
    assert stats["rank_survived"] == stats["survived"] == stats["priced"]


def test_engine_requires_valid_rank_policy():
    with pytest.raises(ValueError, match="rank policy"):
        DSEEngine(rank="banana")
    with pytest.raises(ValueError):
        DSEEngine(rank="on", rank_keep_frac=1.5)


# ------------------------ certification (tamper test) ------------------------
def test_certification_catches_ranker_that_drops_winner(monkeypatch):
    """Certify-or-die for the rank stage itself: a keep rule that drops
    the true argmin must be caught by the sampled scalar certification
    inside plan_design_groups, not silently change a winner."""
    from repro.core.dse import plan_design_groups
    from repro.workloads.scenarios import get_scenario

    def evil_rank_keep(scores, iter_time, iter_lb, mem, capacities,
                       keep_frac):
        keep = np.ones(len(scores), dtype=bool)
        for row in scalar_winner_rows(iter_time, mem, capacities):
            if row >= 0:
                keep[row] = False        # drop every true winner
        if not keep.any():
            keep[0] = True               # never ship an empty group
        return keep

    monkeypatch.setattr("repro.learned.rank.rank_keep", evil_rank_keep)
    clear_caches()
    sc = get_scenario("fft", smoke=True)
    with pytest.raises(RuntimeError, match="not winner-preserving"):
        plan_design_groups(sc.work_fn, sc.spec.grid(), sc.spec.n_chips,
                           max_tp=sc.spec.max_tp, max_pp=sc.spec.max_pp,
                           execution=sc.spec.execution, prune="on",
                           certify=True, ranker=_synthetic_model(),
                           rank_keep_frac=0.5)
    clear_caches()  # tampered candmat views must not leak to later tests


# ----------------------- engine acceptance property --------------------------
def test_rank_on_off_engines_identical_across_all_scenarios():
    """The rank-stage acceptance property at engine level: with a warm
    harvest, a rank-on sweep returns DesignPoint rows identical to a
    rank-off sweep on EVERY smoke scenario, while pricing strictly fewer
    dominance survivors in aggregate."""
    clear_caches()
    warm = DSEEngine(parallel=False, prune="on")
    for name in scenario_names():
        warm.sweep_scenario(name, smoke=True)   # build the candmat harvest
    dom = ranked = 0
    for name in scenario_names():
        on = DSEEngine(parallel=False, prune="on", rank="on")
        res_on = on.sweep_scenario(name, smoke=True)
        stats = on.last_plan_stats
        assert stats["rank"] is True, name
        assert stats["rank_survived"] <= stats["survived"], name
        dom += stats["survived"]
        ranked += stats["rank_survived"]
        off = DSEEngine(parallel=False, prune="on", rank="off")
        res_off = off.sweep_scenario(name, smoke=True)
        assert off.last_plan_stats["rank"] is False
        assert ([p.row() for p in res_on.points]
                == [p.row() for p in res_off.points]), name
    assert ranked < dom, "the rank stage never dropped a row anywhere"


@pytest.mark.parametrize("ctx", ["fork", "spawn", "forkserver"])
def test_rank_on_off_identical_across_pool_transports(ctx):
    """Rank-on winners are identical to the serial rank-off reference
    under every pool transport: the parent-trained frozen model ships to
    the workers and ranks deterministically there."""
    if ctx not in multiprocessing.get_all_start_methods():
        pytest.skip(f"{ctx} not available on this platform")
    clear_caches()
    warm = DSEEngine(parallel=False, prune="on")
    ref = warm.sweep_scenario("llm", smoke=True)   # harvest + reference
    eng = DSEEngine(parallel=True, max_workers=2, mp_context=ctx,
                    pricing_backend="numpy", prune="on", rank="on")
    res = eng.sweep_scenario("llm", smoke=True)
    stats = eng.last_plan_stats
    assert stats["rank"] is True
    assert stats["rank_survived"] < stats["survived"]
    assert [p.row() for p in res.points] == [p.row() for p in ref.points]
    eng.shutdown()

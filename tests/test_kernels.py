"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.ops import fused_rmsnorm
from repro.kernels.rmsnorm.ref import fused_rmsnorm_ref
from repro.kernels.ssd.ops import ssd_chunk
from repro.kernels.ssd.ref import ssd_chunk_ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ------------------------------ flash attention -------------------------------
@pytest.mark.parametrize("b,h,hkv,s,hd", [
    (1, 4, 4, 256, 64),      # MHA
    (2, 8, 2, 512, 64),      # GQA 4:1
    (1, 8, 1, 256, 128),     # MQA
    (1, 4, 4, 384, 64),      # non-power-of-two seq (3 blocks)
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, hkv, s, hd, causal, dtype):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (b, h, s, hd), dtype)
    k = jax.random.normal(kk, (b, hkv, s, hd), dtype)
    v = jax.random.normal(kv, (b, hkv, s, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


def test_flash_attention_block_shape_independence():
    q = jax.random.normal(KEY, (1, 4, 512, 64), jnp.float32)
    k = jax.random.normal(KEY, (1, 4, 512, 64), jnp.float32)
    v = jax.random.normal(KEY, (1, 4, 512, 64), jnp.float32)
    a = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    b = flash_attention(q, k, v, block_q=256, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# ------------------------------ decode attention ------------------------------
@pytest.mark.parametrize("b,h,hkv,s,hd,kv_len", [
    (2, 8, 2, 1024, 64, 700),
    (1, 4, 4, 512, 128, 512),    # full cache
    (4, 8, 1, 2048, 64, 1),      # single valid token
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, h, hkv, s, hd, kv_len, dtype):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (b, h, hd), dtype)
    k = jax.random.normal(kk, (b, hkv, s, hd), dtype)
    v = jax.random.normal(kv, (b, hkv, s, hd), dtype)
    o, lse = decode_attention(q, k, v, kv_len, interpret=True)
    orf, lser = decode_attention_ref(q, k, v, kv_len, return_lse=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lser),
                               rtol=1e-3, atol=1e-3)


def test_decode_attention_lse_merges_shards_exactly():
    """Sharded-KV decode + LSE combine == unsharded decode (the context-
    parallel invariant used by parallel/context.py)."""
    b, h, s, hd = 2, 4, 512, 64
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (b, h, hd), jnp.float32)
    k = jax.random.normal(kk, (b, h, s, hd), jnp.float32)
    v = jax.random.normal(kv, (b, h, s, hd), jnp.float32)
    kv_len = 400
    o_full, _ = decode_attention_ref(q, k, v, kv_len, return_lse=True)
    parts = []
    for shard in range(2):
        ks = k[:, :, shard * 256:(shard + 1) * 256]
        vs = v[:, :, shard * 256:(shard + 1) * 256]
        local_len = np.clip(kv_len - shard * 256, 0, 256)
        o, lse = decode_attention_ref(q, ks, vs, int(local_len),
                                      return_lse=True)
        parts.append((o, lse))
    m = jnp.maximum(parts[0][1], parts[1][1])
    w0, w1 = jnp.exp(parts[0][1] - m), jnp.exp(parts[1][1] - m)
    merged = (parts[0][0] * w0[..., None] + parts[1][0] * w1[..., None]) / (
        (w0 + w1)[..., None])
    np.testing.assert_allclose(np.asarray(merged), np.asarray(o_full),
                               rtol=1e-5, atol=1e-5)


# ------------------------------ fused rmsnorm ---------------------------------
@pytest.mark.parametrize("t,d", [(256, 128), (512, 256), (1024, 1024)])
@pytest.mark.parametrize("with_residual", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rmsnorm_sweep(t, d, with_residual, dtype):
    kx, kw, kr = jax.random.split(KEY, 3)
    x = jax.random.normal(kx, (t, d), dtype)
    w = (jax.random.normal(kw, (d,), jnp.float32) * 0.1 + 1.0)
    r = jax.random.normal(kr, (t, d), dtype) if with_residual else None
    y, res = fused_rmsnorm(x, w, r, interpret=True)
    yr, resr = fused_rmsnorm_ref(x, w, r)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(res, np.float32),
                               np.asarray(resr, np.float32), **_tol(dtype))


# ------------------------------ SSD chunk scan --------------------------------
@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (2, 256, 64, 128, 128),
    (4, 512, 64, 128, 128),
    (1, 256, 128, 128, 64),
])
def test_ssd_chunk_sweep(bh, s, p, n, chunk):
    kx, kd, kb, kc = jax.random.split(KEY, 4)
    x = jax.random.normal(kx, (bh, s, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(kd, (bh, s), jnp.float32))
    B = jax.random.normal(kb, (bh, s, n), jnp.float32) * 0.3
    C = jax.random.normal(kc, (bh, s, n), jnp.float32) * 0.3
    dA = -0.1 * dt
    y, hf = ssd_chunk(x, dt, B, C, dA, chunk=chunk, interpret=True)
    # oracle: chunked reference with carried state
    ys, hs = [], []
    for i in range(bh):
        h_in = jnp.zeros((n, p))
        outs = []
        for c in range(s // chunk):
            sl = slice(c * chunk, (c + 1) * chunk)
            yc, h_in = ssd_chunk_ref(x[i, sl], dt[i, sl], B[i, sl],
                                     C[i, sl], dA[i, sl], h_in)
            outs.append(yc)
        ys.append(jnp.concatenate(outs, 0))
        hs.append(h_in)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(jnp.stack(hs)),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_matches_layer_scan():
    """The kernel's chunked recurrence equals models.layers' _ssd_chunk_scan
    (the structural twin used by the model)."""
    from repro.models.layers import _ssd_chunk_scan
    b, s, h, p, n = 2, 256, 2, 64, 128
    kx, kd, kb, kc = jax.random.split(KEY, 4)
    xs = jax.random.normal(kx, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(kd, (b, s, h), jnp.float32))
    B = jax.random.normal(kb, (b, s, n), jnp.float32) * 0.3
    C = jax.random.normal(kc, (b, s, n), jnp.float32) * 0.3
    A_log = jnp.zeros((h,))
    y_layer, _ = _ssd_chunk_scan(xs, dt, B, C, A_log, chunk=128)
    # kernel path: flatten (b, h) and precompute dA = dt * (-exp(A_log))
    xs_k = xs.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dt_k = dt.transpose(0, 2, 1).reshape(b * h, s)
    dA_k = dt_k * (-jnp.exp(A_log)).repeat(b)[..., None].reshape(b * h, 1)
    B_k = jnp.repeat(B[:, None], h, 1).reshape(b * h, s, n)
    C_k = jnp.repeat(C[:, None], h, 1).reshape(b * h, s, n)
    y_k, _ = ssd_chunk(xs_k, dt_k, B_k, C_k, dA_k, chunk=128, interpret=True)
    y_k = y_k.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_layer),
                               rtol=2e-4, atol=2e-4)

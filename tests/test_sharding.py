"""Sharding-scheme selection tests (paper §IV, Fig 4, §VI.A validation)."""
from __future__ import annotations

import itertools

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the dev extra (requirements-dev.txt)")
from hypothesis import given, settings

from repro.core.graph import KernelKind
from repro.core.sharding import (conversion_bytes, conversion_cost,
                                 expert_region_of, schemes_for,
                                 solve_sharding)
from repro.systems.chips import ICI
from repro.systems.topology import ring
from repro.workloads.llm import GPT3_175B, LLMShape, gpt_layer_graph

from conftest import dags

TOPO8 = ring(8, ICI)
DIMS = [0]


def test_megatron_pattern_recovered():
    """Paper §VI.A: lowest-communication sharding = 4 all-reduces per layer
    per iteration (2 in fwd: Proj + FFN1; doubled by the backward pass)."""
    import dataclasses
    g = gpt_layer_graph(dataclasses.replace(GPT3_175B, batch=1))
    sol = solve_sharding(g, 8, TOPO8, DIMS)
    by_name = {k.name: s for k, s in zip(g.kernels, sol.schemes)}
    assert by_name["QKV"].name == "col"        # column-parallel, no comm
    assert by_name["FFN0"].name == "col"
    assert by_name["Proj"].name == "row_ar"    # row-parallel + all-reduce
    assert by_name["FFN1"].name == "row_ar"
    assert by_name["MHA1"].name == "head"      # head-local attention
    n_allreduce_fwd = sum(1 for s in sol.schemes if s.name == "row_ar")
    assert n_allreduce_fwd == 2                # ×2 for bwd = 4 per iteration
    # all layout conversions are free in the optimal assignment
    assert sum(sol.h_m) == pytest.approx(0.0)


def test_solo_collapse():
    g = gpt_layer_graph(LLMShape("t", 2, 256, 4, 4, 1024, 1000, seq=128))
    sol = solve_sharding(g, 1, TOPO8, DIMS)
    assert sol.total_comm == 0.0
    assert all(s.name == "solo" for s in sol.schemes)


def test_conversion_cost_zero_cases():
    assert conversion_cost("R", "N", 1e9, TOPO8, DIMS, 8) == 0.0  # slice
    assert conversion_cost("M", "M", 1e9, TOPO8, DIMS, 8) == 0.0
    assert conversion_cost("M", "N", 1e9, TOPO8, DIMS, 1) == 0.0  # t=1
    assert conversion_cost("M", "R", 1e9, TOPO8, DIMS, 8) > 0.0   # all-gather
    assert conversion_cost("M", "N", 1e9, TOPO8, DIMS, 8) > 0.0   # a2a
    assert conversion_bytes("M", "N", 1e9, 8) == pytest.approx(1e9 * 7 / 8)
    assert conversion_bytes("R", "N", 1e9, 8) == 0.0


def test_schemes_flop_factors():
    from repro.core.graph import Kernel
    k = Kernel("mm", 1e9, KernelKind.GEMM, weight_bytes=1e6,
               gemm_dims=(128, 128, 128))
    for t in (2, 4, 8):
        for s in schemes_for(k, t):
            assert s.flop_factor in (1.0, 1.0 / t)
    assert len(schemes_for(k, 1)) == 1


def test_expert_region_detection():
    import dataclasses
    s = dataclasses.replace(GPT3_175B, moe_experts=8, moe_top_k=2, batch=1)
    g = gpt_layer_graph(s)
    region = expert_region_of(g)
    assert region == {"FFN0", "FFN1"}


def test_moe_router_prices_all_to_all():
    import dataclasses
    s = dataclasses.replace(GPT3_175B, moe_experts=8, moe_top_k=2, batch=1)
    g = gpt_layer_graph(s)
    sol = solve_sharding(g, 8, TOPO8, DIMS)
    by_name = {k.name: (sch, hn) for k, sch, hn
               in zip(g.kernels, sol.schemes, sol.h_n)}
    assert by_name["Router"][0].name == "ep_a2a"
    assert by_name["Router"][1] > 0.0          # dispatch+combine priced
    assert by_name["FFN0"][0].name.startswith("expert")  # comm-free GEMMs
    assert by_name["FFN0"][1] == 0.0


@given(dags(max_kernels=5, max_edges=4))
@settings(max_examples=25, deadline=None)
def test_icm_matches_exhaustive_on_small_graphs(g):
    """The greedy+ICM fallback must find the exhaustive optimum on graphs
    small enough to brute-force."""
    t = 4
    sol_exact = solve_sharding(g, t, TOPO8, DIMS, exhaustive_limit=12)
    sol_icm = solve_sharding(g, t, TOPO8, DIMS, exhaustive_limit=0)
    assert sol_icm.total_comm <= sol_exact.total_comm * 1.5 + 1e-12
    # exhaustive is never beaten (it is the optimum)
    assert sol_exact.total_comm <= sol_icm.total_comm + 1e-12

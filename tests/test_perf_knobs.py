"""Correctness of the §Perf hillclimb knobs (EXPERIMENTS.md §Perf):
FSDP sharding, shard_map MoE dispatch, mixed precision, bf16 matmuls."""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params, synth_batch
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"), JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, f"OUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_moe_shard_map_matches_gspmd_dispatch():
    """The hand-scheduled EP dispatch must equal the GSPMD capacity-buffer
    path bit-for-tolerance (same routing, same drops)."""
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import layers as L
    from repro.parallel.logical import use_rules
    from repro.launch.mesh import make_axis_rules

    cfg = get_config("olmoe_1b_7b", smoke=True)
    p = L.init_moe(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, cfg.d_model),
                          jnp.float32)
    ref = L.moe(p, x, cfg)                      # no mesh: gspmd path

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg_sm = dataclasses.replace(cfg, moe_dispatch="shard_map")
    rules = make_axis_rules(mesh)
    with mesh, use_rules(rules, mesh):
        out = jax.jit(lambda pp, xx: L.moe(pp, xx, cfg_sm))(p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("moe shard_map OK")
    """)


def test_fsdp_shards_every_large_param():
    _run("""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.launch.shardings import param_shardings

    cfg = get_config("olmo_1b", smoke=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    base = param_shardings(cfg, mesh, fsdp=False)
    fsdp = param_shardings(cfg, mesh, fsdp=True)
    n_more = 0
    for (pb, b), (pf, f) in zip(
            jax.tree_util.tree_leaves_with_path(base),
            jax.tree_util.tree_leaves_with_path(fsdp)):
        flat_b = [a for a in b.spec if a is not None]
        flat_f = [a for a in f.spec if a is not None]
        assert len(flat_f) >= len(flat_b)
        n_more += len(flat_f) > len(flat_b)
    assert n_more >= 5, n_more   # the big matrices picked up the data axis
    print("fsdp shardings OK", n_more)
    """)


def test_fsdp_train_step_matches_baseline_loss():
    """FSDP changes layout, not math: same loss as the replicated step."""
    _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import init_params, synth_batch
    from repro.parallel.logical import use_rules
    from repro.launch.mesh import make_axis_rules
    from repro.launch.shardings import (batch_shardings, opt_shardings,
                                        param_shardings)
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.trainer import make_train_step

    cfg = get_config("olmo_1b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = synth_batch(cfg, 8, 32)
    opt = adamw_init(params)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    _, _, m_ref = jax.jit(step)(params, opt, batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh, use_rules(make_axis_rules(mesh), mesh):
        ps = param_shardings(cfg, mesh, fsdp=True)
        os_ = opt_shardings(cfg, mesh, fsdp=True)
        bs = batch_shardings(cfg, mesh, 8)
        sp = jax.device_put(params, ps)
        so = jax.device_put(opt, os_)
        sb = {k: jax.device_put(v, bs[k]) for k, v in batch.items()}
        _, _, m = jax.jit(step, in_shardings=(ps, os_, bs),
                          out_shardings=(ps, os_, None))(sp, so, sb)
    assert abs(float(m["loss"]) - float(m_ref["loss"])) < 1e-2
    print("fsdp step OK", float(m["loss"]))
    """)


def test_mixed_precision_tracks_fp32_training():
    cfg = get_config("olmo_1b", smoke=True)
    batch = synth_batch(cfg, 2, 32)
    ocfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    from repro.train.trainer import make_train_step

    p32 = init_params(cfg, jax.random.PRNGKey(0))
    o32 = adamw_init(p32)
    s32 = jax.jit(make_train_step(cfg, ocfg))

    cfg16 = dataclasses.replace(cfg, param_dtype="bfloat16")
    p16 = init_params(cfg16, jax.random.PRNGKey(0))
    o16 = adamw_init(p16, master=True)
    s16 = jax.jit(make_train_step(cfg16, ocfg))

    for _ in range(5):
        p32, o32, m32 = s32(p32, o32, batch)
        p16, o16, m16 = s16(p16, o16, batch)
    assert float(m16["loss"]) == pytest.approx(float(m32["loss"]), rel=0.05)
    # master stays fp32 and close to the fp32 run's params
    master_leaf = jax.tree.leaves(o16["master"])[0]
    assert master_leaf.dtype == jnp.float32


def test_bf16_matmul_out_close_to_default():
    cfg = get_config("olmo_1b", smoke=True)
    cfg16 = dataclasses.replace(cfg, matmul_out="bf16")
    from repro.models import loss_fn
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = synth_batch(cfg, 2, 32)
    l_a = float(jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch))
    l_b = float(jax.jit(lambda p, b: loss_fn(cfg16, p, b))(params, batch))
    assert l_b == pytest.approx(l_a, rel=0.02)


def test_remat_policies_equal_forward_and_grads():
    cfg = get_config("olmo_1b", smoke=True)
    from repro.models import loss_fn
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = synth_batch(cfg, 2, 32)
    grads = {}
    for pol in ("full", "dots", "none"):
        c = dataclasses.replace(cfg, remat=pol)
        loss, g = jax.jit(jax.value_and_grad(
            lambda p: loss_fn(c, p, batch)))(params)
        grads[pol] = (float(loss), g)
    l0 = grads["full"][0]
    for pol in ("dots", "none"):
        assert grads[pol][0] == pytest.approx(l0, rel=1e-4)
        for a, b in zip(jax.tree.leaves(grads["full"][1]),
                        jax.tree.leaves(grads[pol][1])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-2, atol=1e-4)


def test_context_parallel_decode_matches_gspmd():
    """cfg.decode_attn='context_parallel' (shard_map LSE-combine over the
    seq-sharded KV cache) must match the GSPMD decode path."""
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import decode_step, init_cache, init_params
    from repro.parallel.logical import use_rules
    from repro.launch.mesh import make_axis_rules

    cfg = get_config("mistral_nemo_12b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, smax = 4, 64
    cache = init_cache(cfg, b, smax)
    cache["k"] = jax.random.normal(jax.random.PRNGKey(1), cache["k"].shape,
                                   cache["k"].dtype) * 0.3
    cache["v"] = jax.random.normal(jax.random.PRNGKey(2), cache["v"].shape,
                                   cache["v"].dtype) * 0.3
    tok = jax.random.randint(jax.random.PRNGKey(3), (b,), 0, cfg.vocab)
    pos = jnp.int32(17)
    ref, _ = jax.jit(lambda p, c: decode_step(cfg, p, c, tok, pos))(
        params, cache)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg_cp = dataclasses.replace(cfg, decode_attn="context_parallel")
    with mesh, use_rules(make_axis_rules(mesh), mesh):
        got, _ = jax.jit(lambda p, c: decode_step(cfg_cp, p, c, tok, pos))(
            params, cache)
    # bf16 cache + different accumulation order: tolerance is dtype noise
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=6e-2, atol=6e-2)
    agree = (np.asarray(got).argmax(-1) == np.asarray(ref).argmax(-1)).mean()
    assert agree == 1.0, agree
    print("cp-decode OK")
    """)

"""Distributed-execution integration tests.

JAX fixes the device count at first init, so multi-device cases run in
subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8. Each
script asserts internally and exits nonzero on failure.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    script = textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_pipeline_forward_matches_sequential():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.parallel.pipeline import pipeline_forward

    mesh = jax.make_mesh((4,), ("stage",))
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    params = jax.random.normal(key, (n_stages, d, d)) * 0.3
    xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    with mesh:
        run = pipeline_forward(mesh, stage_fn, n_stages, axis="stage")
        out = run(params, xs)

    ref = xs
    for s in range(n_stages):
        ref = jnp.tanh(ref @ params[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("pipeline OK")
    """)


def test_context_parallel_decode_matches_dense():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.context import context_parallel_decode
    from repro.kernels.decode_attention.ref import decode_attention_ref

    mesh = jax.make_mesh((8,), ("model",))
    b, h, s, hd = 2, 4, 1024, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (b, h, hd), jnp.float32)
    k = jax.random.normal(kk, (b, h, s, hd), jnp.float32)
    v = jax.random.normal(kv, (b, h, s, hd), jnp.float32)
    kv_len = jnp.int32(777)

    fn = context_parallel_decode(mesh, axis="model")
    with mesh:
        out = fn(q, k, v, kv_len)
    ref = decode_attention_ref(q, k, v, 777)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    print("context parallel OK")
    """)


def test_sharded_train_step_matches_single_device():
    """The production sharding assembly (param/batch shardings on a (2, 4)
    mesh) must compute the same loss and updates as single-device."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import init_params, loss_fn, synth_batch
    from repro.parallel.logical import use_rules
    from repro.launch.mesh import make_axis_rules
    from repro.launch.shardings import batch_shardings, param_shardings
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.trainer import make_train_step

    cfg = get_config("olmo_1b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = synth_batch(cfg, batch=8, seq=32)
    opt = adamw_init(params)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))

    # single device reference
    p_ref, o_ref, m_ref = jax.jit(step)(params, opt, batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = make_axis_rules(mesh)
    with mesh, use_rules(rules):
        ps = param_shardings(cfg, mesh)
        bs = batch_shardings(cfg, mesh, 8)
        os_ = {"m": ps, "v": ps, "step": NamedSharding(mesh, P())}
        sp = jax.device_put(params, ps)
        sb = {k: jax.device_put(v, bs[k]) for k, v in batch.items()}
        so = jax.device_put(opt, os_)
        p_sh, o_sh, m_sh = jax.jit(step, in_shardings=(ps, os_, bs),
                                   out_shardings=(ps, os_, None))(sp, so, sb)

    assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-2, (
        float(m_ref["loss"]), float(m_sh["loss"]))
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(jax.device_get(b), np.float32),
                                   rtol=3e-2, atol=3e-3)
    print("sharded train step OK")
    """)


def test_dp_grad_allreduce_emitted():
    """Data-parallel training must emit a gradient all-reduce in the
    compiled HLO — and hlocost must find and price it."""
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import hlocost

    mesh = jax.make_mesh((8,), ("data",))
    w = jnp.zeros((64, 64))

    def step(w, x):
        def loss(w):
            return jnp.sum((x @ w) ** 2)
        g = jax.grad(loss)(w)
        return w - 0.1 * g

    xs = NamedSharding(mesh, P("data", None))
    ws = NamedSharding(mesh, P())
    with mesh:
        comp = jax.jit(step, in_shardings=(ws, xs),
                       out_shardings=ws).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((128, 64), jnp.float32)).compile()
    s = hlocost.analyze(comp.as_text())
    ar = s.collective_bytes.get("all-reduce", 0.0)
    assert ar >= 64 * 64 * 4, s.collective_bytes
    print("AR_BYTES", ar)
    """)
    assert "AR_BYTES" in out


def test_moe_expert_parallel_lowms_to_collectives():
    """Expert-sharded MoE under GSPMD must produce collective ops."""
    _run("""
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_params, loss_fn, synth_batch
    from repro.parallel.logical import use_rules
    from repro.launch.mesh import make_axis_rules
    from repro.launch.shardings import batch_shardings, param_shardings
    from repro.launch import hlocost

    cfg = get_config("olmoe_1b_7b", smoke=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = make_axis_rules(mesh)
    with mesh, use_rules(rules):
        ps = param_shardings(cfg, mesh)
        bs = batch_shardings(cfg, mesh, 8)
        pspec = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        from repro.models.inputs import train_batch_specs
        specs = train_batch_specs(cfg, 8, 32)
        comp = jax.jit(lambda p, b: loss_fn(cfg, p, b),
                       in_shardings=(ps, bs)).lower(pspec, specs).compile()
    s = hlocost.analyze(comp.as_text())
    total = s.total_collective_bytes
    assert total > 0, "expert parallelism emitted no collectives"
    print("EP collective bytes", total)
    """)

"""Cross-process shared memo store tests (repro.core.memo_store).

Covers, for both backends (mmap table + socket server):

* concurrent put/get hammering from a real process pool — no torn reads,
  and exactly-once storage for racing writers of one key;
* server survival when a client process crashes mid-session, and
  graceful teardown afterwards;
* cross-process stats aggregation with exact expected counts;
* the memo-layer write-through contract (compute once across caches,
  ``None`` values shared, unpicklable keys/values degrading to
  local-only entries instead of breaking the solve).

``DFMODEL_TEST_MP_CONTEXT`` (fork | spawn | forkserver) pins the pool
start method — the CI matrix runs this file under all three.
"""
from __future__ import annotations

import concurrent.futures as cf
import multiprocessing
import os
import pickle
import sys

import pytest

from repro.core.memo import SolveCache
from repro.core.memo_store import (MmapStore, ServerStore, StoreHandle,
                                   choose_backend, create_store)

BACKENDS = ("mmap", "server")


def _mp_ctx() -> multiprocessing.context.BaseContext:
    methods = multiprocessing.get_all_start_methods()
    env = os.environ.get("DFMODEL_TEST_MP_CONTEXT")
    if env:
        if env not in methods:
            pytest.skip(f"start method {env!r} not available")
        return multiprocessing.get_context(env)
    # mirror DSEEngine._start_method: forking after jax started its worker
    # threads is a deadlock risk (and emits a RuntimeWarning); forkserver
    # keeps mmap-backend coverage (choose_backend maps it to "mmap") with a
    # pre-jax template process
    if "fork" in methods and "jax" not in sys.modules:
        return multiprocessing.get_context("fork")
    if "forkserver" in methods:
        return multiprocessing.get_context("forkserver")
    return multiprocessing.get_context(methods[0])


def _make_store(backend: str, ctx):
    if backend == "mmap":
        pytest.importorskip("fcntl")
        return MmapStore()
    return ServerStore(mp_context=ctx)


def _value_for(k: int, n_bytes: int) -> bytes:
    seed = b"value-%d-" % k
    return (seed * (n_bytes // len(seed) + 1))[:n_bytes]


# ---- module-level worker fns (picklable under spawn) ------------------------
def _hammer(args: tuple) -> list:
    handle, n_keys, rounds, n_bytes = args
    client = handle.connect()
    torn = []
    for r in range(rounds):
        for k in range(n_keys):
            key = b"key-%d" % k
            expect = _value_for(k, n_bytes)
            got = client.get("hammer", key)
            if got is None:
                client.put("hammer", key, expect)
            elif got != expect:
                torn.append((r, k, len(got)))
    client.flush()
    client.close()
    return torn


def _race_one_key(args: tuple) -> bytes:
    handle, worker_id = args
    client = handle.connect()
    client.put("race", b"the-key", b"from-worker-%d " % worker_id * 64)
    client.flush()
    value = client.get("race", b"the-key")
    client.close()
    return value


def _counted_ops(args: tuple) -> None:
    handle, worker_id = args
    client = handle.connect()
    own = b"own-%d" % worker_id
    assert client.get("agg", own) is None          # 1 miss
    client.put("agg", own, b"v")                   # 1 insert
    client.flush()
    assert client.get("agg", own) == b"v"          # 1 hit
    assert client.get("agg", b"common") == b"seed"  # 1 hit (parent-seeded)
    client.flush()
    client.close()


def _crash_after_put(handle: StoreHandle) -> None:
    client = handle.connect()
    client.put("crash", b"crash-key", b"crash-value")
    client.flush()
    os._exit(1)  # die without close(): the server must shrug it off


# ------------------------------ concurrency ----------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_concurrent_hammer_no_torn_reads_exactly_once(backend):
    """4 processes × 5 rounds over 24 shared keys with 8KB values: every
    read returns the full correct value (no torn/partial entries) and
    racing writers of one key leave exactly one stored entry."""
    ctx = _mp_ctx()
    store = _make_store(backend, ctx)
    try:
        n_keys, n_bytes = 24, 8192
        task = (store.handle(), n_keys, 5, n_bytes)
        with cf.ProcessPoolExecutor(max_workers=4, mp_context=ctx) as pool:
            torn = [t for out in pool.map(_hammer, [task] * 4) for t in out]
        assert torn == [], f"torn/corrupt reads: {torn[:5]}"
        for k in range(n_keys):
            assert store.get("hammer", b"key-%d" % k) == \
                _value_for(k, n_bytes)
        stats = store.stats()
        assert stats["entries"] == n_keys          # exactly-once storage
        assert stats["by_space"]["hammer"]["inserts"] == n_keys
        assert stats["dropped"] == 0
    finally:
        store.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_racing_writers_one_key_single_winner(backend):
    """Workers racing distinct values into one key: a single value wins,
    every subsequent read (any process) sees that same value."""
    ctx = _mp_ctx()
    store = _make_store(backend, ctx)
    try:
        tasks = [(store.handle(), i) for i in range(4)]
        with cf.ProcessPoolExecutor(max_workers=4, mp_context=ctx) as pool:
            seen = list(pool.map(_race_one_key, tasks))
        winner = store.get("race", b"the-key")
        assert winner is not None
        assert winner in {b"from-worker-%d " % i * 64 for i in range(4)}
        assert set(seen) == {winner}
        assert store.stats()["entries"] == 1
    finally:
        store.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_stats_aggregate_across_processes(backend):
    """Known per-worker op counts must sum exactly in the shared stats."""
    ctx = _mp_ctx()
    store = _make_store(backend, ctx)
    try:
        store.put("agg", b"common", b"seed")
        store.flush()
        tasks = [(store.handle(), i) for i in range(3)]
        with cf.ProcessPoolExecutor(max_workers=3, mp_context=ctx) as pool:
            list(pool.map(_counted_ops, tasks))
        agg = store.stats()["by_space"]["agg"]
        assert agg["misses"] == 3       # one first-get per worker key
        assert agg["hits"] == 6         # own re-get + common, per worker
        assert agg["inserts"] == 4      # 3 worker keys + the parent seed
        assert agg["dropped"] == 0
    finally:
        store.close()


# ------------------------------ server lifecycle -----------------------------
def test_server_survives_client_crash_and_tears_down():
    ctx = _mp_ctx()
    store = ServerStore(mp_context=ctx)
    path = store.path
    try:
        proc = ctx.Process(target=_crash_after_put, args=(store.handle(),))
        proc.start()
        proc.join(timeout=30)
        assert proc.exitcode == 1
        # the server kept the crashed client's flushed write and still
        # serves other clients
        assert store.get("crash", b"crash-key") == b"crash-value"
        store.put("crash", b"after", b"ok")
        store.flush()
        assert store.get("crash", b"after") == b"ok"
    finally:
        store.close()
    assert not os.path.exists(path)  # graceful teardown removed the socket


def test_dead_server_degrades_to_misses_not_errors():
    ctx = _mp_ctx()
    store = ServerStore(mp_context=ctx)
    client = store.handle().connect()
    client.put("x", b"k", b"v")
    client.flush()
    store.close()  # server gone; the surviving client must not raise
    assert client.get("x", b"k") is None
    client.put("x", b"k2", b"v2")
    client.flush()
    client.close()


# ------------------------------ mmap specifics -------------------------------
def test_mmap_oversize_value_dropped_not_stored():
    pytest.importorskip("fcntl")
    store = MmapStore(stripe_bytes=1 << 12)
    try:
        store.put("big", b"k", b"x" * (1 << 13))  # larger than a stripe
        assert store.get("big", b"k") is None
        stats = store.stats()
        assert stats["by_space"]["big"]["dropped"] == 1
        assert stats["entries"] == 0
    finally:
        store.close()


def test_mmap_full_stripe_drops_then_keeps_serving():
    pytest.importorskip("fcntl")
    store = MmapStore(n_stripes=1, stripe_bytes=1 << 12)
    try:
        for i in range(40):  # ~40 × 128B entries overflow the 4KB stripe
            store.put("fill", b"fk-%d" % i, b"y" * 128)
        stats = store.stats()
        assert stats["dropped"] > 0
        assert stats["entries"] + stats["dropped"] == 40
        # entries that made it in are still intact
        assert store.get("fill", b"fk-0") == b"y" * 128
    finally:
        store.close()


def test_mmap_owner_unlinks_file_on_close():
    pytest.importorskip("fcntl")
    store = MmapStore()
    reader = store.handle().connect()
    store.put("t", b"k", b"v")
    assert reader.get("t", b"k") == b"v"
    reader.close()
    path = store.path
    assert os.path.exists(path)
    store.close()
    assert not os.path.exists(path)


# ------------------------------ plumbing -------------------------------------
def test_handle_pickles_and_reconnects():
    pytest.importorskip("fcntl")
    store = MmapStore()
    try:
        store.put("p", b"k", b"v")
        handle = pickle.loads(pickle.dumps(store.handle()))
        client = handle.connect()
        assert client.get("p", b"k") == b"v"
        client.close()
    finally:
        store.close()
    with pytest.raises(ValueError):
        StoreHandle("carrier-pigeon", "/nope").connect()


def test_choose_backend_follows_transport():
    pytest.importorskip("fcntl")
    assert choose_backend("fork") == "mmap"
    assert choose_backend("forkserver") == "mmap"
    assert choose_backend("spawn") == "server"


def test_create_store_auto_and_explicit():
    ctx = _mp_ctx()
    store = create_store("auto", mp_context=ctx)
    try:
        assert store.backend == choose_backend(ctx.get_start_method())
    finally:
        store.close()
    with pytest.raises(ValueError):
        create_store("etcd")


# ------------------------------ memo layering --------------------------------
def test_write_through_computes_once_across_caches():
    """Two caches (standing in for two workers) sharing one store: the
    second cache's lookup is served from the store, including a ``None``
    value — a legitimate cached result for failed plan solves."""
    pytest.importorskip("fcntl")
    store = MmapStore()
    a, b = SolveCache(), SolveCache()
    a.attach_shared(store)
    b.attach_shared(store)
    try:
        calls = []
        key = ("plan", ("fp", 4, (1.5, 2.5)))
        va = a.get_or_compute("plan", key, lambda: calls.append("a") or None)
        vb = b.get_or_compute("plan", key,
                              lambda: calls.append("b") or "wrong")
        assert va is None and vb is None
        assert calls == ["a"], "second cache recomputed a shared solve"
        st = store.stats()
        assert st["by_space"]["plan"] == {"hits": 1, "misses": 1,
                                          "inserts": 1, "dropped": 0}
        assert b.stats().hits == 1  # a shared hit counts for the sweep too
    finally:
        a.detach_shared()
        b.detach_shared()
        store.close()


def test_unpicklable_keys_and_values_stay_local_only():
    pytest.importorskip("fcntl")
    store = MmapStore()
    cache = SolveCache()
    cache.attach_shared(store)
    try:
        weird_key = lambda: None  # hashable, unpicklable   # noqa: E731
        assert cache.get_or_compute("s", weird_key, lambda: 7) == 7
        assert cache.get_or_compute("s", weird_key, lambda: 8) == 7
        unpicklable = cache.get_or_compute("s", "vk", lambda: (lambda: 9))
        assert unpicklable() == 9
        assert store.stats()["inserts"] == 0  # nothing crossed the boundary
    finally:
        cache.detach_shared()
        store.close()


def test_detach_returns_client_and_keeps_local_entries():
    pytest.importorskip("fcntl")
    store = MmapStore()
    cache = SolveCache()
    cache.attach_shared(store)
    try:
        cache.get_or_compute("s", "k", lambda: 42)
        assert cache.detach_shared() is store
        assert cache.shared is None
        assert cache.get_or_compute("s", "k", lambda: 43) == 42  # local warm
    finally:
        store.close()


# ---- CacheStats per-space accounting ----------------------------------------
def test_cache_stats_space_hit_rate_guards_zero_lookups():
    """Per-space hit rates carry the same divide-by-zero guard as the
    aggregate: a space with zero lookups — entries only, e.g. inherited
    at fork time — and an unknown space both report 0.0 instead of
    raising, and ``rows()`` stays consistent with ``space_hit_rate``."""
    cache = SolveCache()
    cache.get_or_compute("hot", "k", lambda: 1)
    cache.get_or_compute("hot", "k", lambda: 2)      # 1 hit, 1 miss
    cache.get_or_compute("coldmiss", "k", lambda: 3)  # 0 hits, 1 miss
    # a space with entries but no recorded lookups: seed the data dict the
    # way a fork-inherited cache would look after the child's stats reset
    cache._data[("inherited", "k")] = 9
    stats = cache.stats()
    assert stats.space_hit_rate("hot") == 0.5
    assert stats.space_hit_rate("coldmiss") == 0.0
    assert stats.space_hit_rate("inherited") == 0.0   # zero lookups, no raise
    assert stats.space_hit_rate("never-seen") == 0.0  # unknown space, no raise
    assert stats.by_space["inherited"] == (0, 0, 1)
    by_row = {r["space"]: r for r in stats.rows()}
    for space in ("hot", "coldmiss", "inherited"):
        assert by_row[space]["hit_rate"] == stats.space_hit_rate(space)
    assert by_row["TOTAL"]["hit_rate"] == stats.hit_rate


def test_cache_stats_empty_cache_rates_all_zero():
    stats = SolveCache().stats()
    assert stats.hit_rate == 0.0
    assert stats.space_hit_rate("anything") == 0.0
    assert stats.rows()[-1] == {"space": "TOTAL", "hits": 0, "misses": 0,
                                "entries": 0, "hit_rate": 0.0}

"""DSEEngine tests: parallel determinism, memo-cache correctness, the
cross-process shared memo store, Pareto extraction, and the
infeasible-point skip contract.

These tests intentionally avoid hypothesis so they run on a bare
install — the seeded random checks below mirror the property tests in
test_solver.py for the vectorized minmax ``extra`` path.

The CI matrix re-runs this file with ``DFMODEL_TEST_MP_CONTEXT``
(fork | spawn | forkserver), ``DFMODEL_TEST_SHARED_CACHE`` (1 | 0),
``DFMODEL_TEST_PRUNE`` (1 | 0) and ``DFMODEL_TEST_RANK`` (1 | 0):
engines built through :func:`_engine` pick those up, so every pool
transport is exercised with the shared store, the candidate-pruning
stage and the learned rank stage both on and off.
"""
from __future__ import annotations

import dataclasses
import os
import warnings

import numpy as np
import pytest

from repro.core import (DSEEngine, SweepSpec, cache_stats, caching_disabled,
                        clear_caches, pareto_frontier, stop_after_feasible,
                        sweep)
from repro.core.dse import design_grid
from repro.core.memo import GLOBAL_CACHE
from repro.core.solver import minmax_partition, minmax_partition_scalar
from repro.workloads.llm import LLAMA_68M, gpt_workload
from repro.workloads.scenarios import get_scenario, scenario_names

# module-level so the workload builder is picklable under spawn semantics
def _tiny_work(system):
    return gpt_workload(LLAMA_68M, global_batch=64, microbatch=1)


def _engine(**kwargs) -> DSEEngine:
    """DSEEngine honoring the CI-matrix env knobs (explicit kwargs win)."""
    env_ctx = os.environ.get("DFMODEL_TEST_MP_CONTEXT")
    if env_ctx:
        kwargs.setdefault("mp_context", env_ctx)
    env_shared = os.environ.get("DFMODEL_TEST_SHARED_CACHE")
    if env_shared is not None:
        kwargs.setdefault("shared_cache",
                          env_shared not in ("0", "", "off"))
    env_prune = os.environ.get("DFMODEL_TEST_PRUNE")
    if env_prune is not None:
        kwargs.setdefault("prune",
                          "off" if env_prune in ("0", "", "off") else "on")
    env_rank = os.environ.get("DFMODEL_TEST_RANK")
    if env_rank is not None:
        kwargs.setdefault("rank",
                          "off" if env_rank in ("0", "", "off") else "on")
    return DSEEngine(**kwargs)


SMOKE_SPEC = SweepSpec(n_chips=16,
                       chips=("H100", "SN30"),
                       topologies=("torus2d", "dgx2"),
                       mem_net=(("DDR", "PCIe"), ("HBM", "NVLink")),
                       max_tp=16)


# --------------------- vectorized minmax (seeded fallback) --------------------
def test_minmax_extra_vectorized_matches_scalar_seeded():
    rng = np.random.default_rng(0)
    for _ in range(60):
        n = int(rng.integers(2, 10))
        costs = rng.uniform(0.1, 100.0, size=n).tolist()
        p = int(rng.integers(1, 5))
        pen = float(rng.uniform(0.0, 50.0))

        def extra(i, j, pen=pen):
            return pen + 0.25 * (j - i)

        vb, vo = minmax_partition(costs, p, extra=extra)
        sb, so = minmax_partition_scalar(costs, p, extra=extra)
        assert vb == sb
        assert vo == so  # bit-identical
        vb0, vo0 = minmax_partition(costs, p)
        sb0, so0 = minmax_partition_scalar(costs, p)
        assert (vb0, vo0) == (sb0, so0)


def test_minmax_extra_agrees_with_bnb_on_seeded_dags():
    """Seeded mirror of the hypothesis property in test_solver.py: on
    chain-connected random DAGs the extra-path DP matches the exact B&B
    certifier restricted to the same group count."""
    from conftest import random_dag
    from repro.core.solver import branch_and_bound

    rng = np.random.default_rng(7)
    for _ in range(15):
        g = random_dag(rng, max_kernels=6)
        p_eff = min(int(rng.integers(1, 4)), g.n)
        order = g.topo_order
        costs = [g.kernels[i].flops for i in order]
        w_topo = np.array([g.kernels[i].weight_bytes for i in order])

        def extra(i, j, w_topo=w_topo):
            return float(w_topo[i:j].sum()) * 1e-6

        def objective(assign, costs=costs, extra=extra):
            worst = 0.0
            for part in sorted(set(int(a) for a in assign)):
                members = [i for i in range(len(costs)) if assign[i] == part]
                lo, hi = min(members), max(members) + 1
                assert members == list(range(lo, hi))  # chain ⇒ contiguous
                worst = max(worst, float(sum(costs[lo:hi])) + extra(lo, hi))
            return worst

        _, bc = branch_and_bound(
            g, p_eff, objective,
            feasible=lambda a, p=p_eff: len(set(a.tolist())) == p)
        bounds, dp_obj = minmax_partition(costs, p_eff, extra=extra)
        assert len(bounds) == p_eff
        assert dp_obj == pytest.approx(bc, rel=1e-9)


def test_minmax_extra_objective_matches_returned_split():
    costs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0]

    def extra(i, j):
        return 0.5 * (j - i)

    bounds, obj = minmax_partition(costs, 3, extra=extra)
    assert bounds[0] == 0 and len(bounds) == 3
    ends = bounds[1:] + [len(costs)]
    groups = [sum(costs[i:j]) + extra(i, j) for i, j in zip(bounds, ends)]
    assert obj == pytest.approx(max(groups), rel=1e-12)


# ------------------------------ determinism ----------------------------------
def _scalar_reference(spec: SweepSpec):
    """The serial scalar path (plan+price per point, no batching)."""
    return sweep(_tiny_work, n_chips=spec.n_chips, chips=spec.chips,
                 topologies=spec.topologies, mem_net=spec.mem_net,
                 max_tp=spec.max_tp, phased=False)


def test_parallel_engine_matches_serial_sweep_exactly():
    """Parallel phased sweep must reproduce the scalar row list
    bit-for-bit — same order, same floats — on a 2-chip × 2-topology
    smoke grid."""
    clear_caches()
    with caching_disabled():
        serial = _scalar_reference(SMOKE_SPEC)
    clear_caches()
    engine = _engine(parallel=True, max_workers=2)
    par = engine.sweep(_tiny_work, SMOKE_SPEC)
    assert len(par) == len(serial) > 0
    assert [p.row() for p in par] == [p.row() for p in serial]


def test_serial_engine_matches_sweep_exactly():
    clear_caches()
    engine = DSEEngine(parallel=False)
    pts = engine.sweep(_tiny_work, SMOKE_SPEC)
    with caching_disabled():
        ref = _scalar_reference(SMOKE_SPEC)
    assert [p.row() for p in pts] == [p.row() for p in ref]


def test_perpoint_engine_matches_phased_engine():
    """The retained PR 1 per-point path and the phased path are the same
    sweep, bit for bit."""
    clear_caches()
    perpoint = _engine(parallel=True, max_workers=2, phased=False)
    a = perpoint.sweep(_tiny_work, SMOKE_SPEC)
    clear_caches()
    phased = _engine(parallel=True, max_workers=2, phased=True)
    b = phased.sweep(_tiny_work, SMOKE_SPEC)
    assert [p.row() for p in a] == [p.row() for p in b]


@pytest.mark.parametrize("method", ["spawn", "forkserver"])
def test_engine_explicit_mp_context_matches_serial(method):
    """Spawn-context plumbing: an explicit non-fork start method ships
    picklable tasks and still reproduces the scalar reference exactly."""
    import multiprocessing

    if method not in multiprocessing.get_all_start_methods():
        pytest.skip(f"{method} not available on this platform")
    clear_caches()
    with caching_disabled():
        ref = _scalar_reference(SMOKE_SPEC)
    clear_caches()
    engine = DSEEngine(parallel=True, max_workers=2, mp_context=method)
    assert engine._start_method() == method
    pts = engine.sweep(_tiny_work, SMOKE_SPEC)
    assert [p.row() for p in pts] == [p.row() for p in ref]


def test_engine_rejects_unknown_mp_context():
    with pytest.raises(ValueError):
        DSEEngine(mp_context="teleport")


def test_candidate_matrix_shipping_spawn_exactly_once():
    """Spawn workers ship one PlannedGroup (candidate matrix + winners)
    per (chip, net, topology) system group; the parent's batched
    re-pricing must account for every grid cell exactly once and at least
    one candidate per group — and still reproduce the scalar reference."""
    import multiprocessing

    if "spawn" not in multiprocessing.get_all_start_methods():
        pytest.skip("spawn not available on this platform")
    clear_caches()
    with caching_disabled():
        ref = _scalar_reference(SMOKE_SPEC)
    clear_caches()
    engine = _engine(parallel=True, max_workers=2, mp_context="spawn")
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a serial fallback would hide bugs
        pts = engine.sweep(_tiny_work, SMOKE_SPEC)
    assert [p.row() for p in pts] == [p.row() for p in ref]
    stats = engine.last_plan_stats
    assert stats is not None, "parallel phased path did not run"
    grid = SMOKE_SPEC.grid()
    system_groups = {(c, n, t) for c, _m, n, t in grid}
    assert stats["cells"] == len(grid)          # every cell exactly once
    assert stats["groups"] == len(system_groups)  # one matrix per system
    assert stats["candidates"] >= stats["groups"]
    # a second sweep resets the accounting rather than accumulating
    engine.sweep(_tiny_work, SMOKE_SPEC)
    assert engine.last_plan_stats["cells"] == len(grid)


def test_backend_divergence_is_detected_not_silently_accepted():
    """If the parent's batched selection (on a non-numpy backend) ever
    disagreed with the worker's shipped winners, the sweep must fail
    loudly (RuntimeError), because a silent disagreement would mean a
    non-certified backend."""
    pytest.importorskip("jax")
    from repro.core.dse import plan_design_groups

    clear_caches()
    grid = SMOKE_SPEC.grid()
    engine = DSEEngine(parallel=False, pricing_backend="jax")
    groups = plan_design_groups(_tiny_work, grid, SMOKE_SPEC.n_chips,
                                max_tp=SMOKE_SPEC.max_tp)
    tampered = [dataclasses.replace(
        g, winner_rows=tuple(r + 1 if r >= 0 else r
                             for r in g.winner_rows))
        for g in groups if len(g.matrix)]
    with pytest.raises(RuntimeError, match="not bit-identical"):
        engine._finish_plan_groups(tampered, len(grid))
    # the numpy-reference parent skips the tautological re-pricing pass
    clear_caches()
    ref_engine = DSEEngine(parallel=False)
    ref_engine._finish_plan_groups(groups, len(grid))
    assert ref_engine.last_plan_stats["verified"] is False


# ------------------------------ streaming ------------------------------------
def test_sweep_iter_delivers_every_index_exactly_once():
    clear_caches()
    engine = _engine(parallel=True, max_workers=2)
    items = list(engine.sweep_iter(_tiny_work, SMOKE_SPEC))
    grid = SMOKE_SPEC.grid()
    assert sorted(it.index for it in items) == list(range(len(grid)))
    assert all(it.cell == grid[it.index] for it in items)
    # re-ordered by grid index, the streamed points equal the batch sweep
    ordered = [it.point for it in sorted(items, key=lambda it: it.index)
               if it.point is not None]
    ref = _scalar_reference(SMOKE_SPEC)
    assert [p.row() for p in ordered] == [p.row() for p in ref]


def test_sweep_iter_early_exit_stops_submission():
    """With a serial engine the grid is planned lazily: stopping after the
    first item must leave the rest of the grid untouched."""
    calls = []

    def counting_work(system):
        calls.append(system.name)
        return _tiny_work(system)

    clear_caches()
    engine = DSEEngine(parallel=False)
    items = list(engine.sweep_iter(counting_work, SMOKE_SPEC,
                                   stop=lambda item: True))
    assert len(items) == 1
    assert len(calls) == 1 < len(SMOKE_SPEC.grid())


def test_sweep_iter_midstream_pool_failure_keeps_exactly_once():
    """If the pool dies after streaming some items, the serial fallback
    must deliver only the remaining indices — never duplicates."""
    clear_caches()
    engine = _engine(parallel=True, max_workers=2)
    grid = SMOKE_SPEC.grid()

    def flaky_parallel_iter(work_fn, spec, g, stop):
        for item in engine._serial_iter(work_fn, spec,
                                        [(0, g[0]), (3, g[3])], stop):
            yield item
        raise OSError("worker died")

    engine._parallel_iter = flaky_parallel_iter
    with pytest.warns(RuntimeWarning, match="streaming serially"):
        items = list(engine.sweep_iter(_tiny_work, SMOKE_SPEC))
    assert sorted(it.index for it in items) == list(range(len(grid)))


def test_sweep_iter_stop_after_feasible():
    clear_caches()
    engine = DSEEngine(parallel=False)
    items = list(engine.sweep_iter(_tiny_work, SMOKE_SPEC,
                                   stop=stop_after_feasible(2)))
    feas = [it for it in items
            if it.point is not None and it.point.plan.feasible]
    assert len(feas) == 2
    assert len(items) < len(SMOKE_SPEC.grid())


# ------------------------------ memo cache -----------------------------------
def test_cache_hits_on_default_style_grid_and_values_identical():
    """The default grid shares inner solves across points: the cache must
    actually hit, and cached results must equal cold solves exactly."""
    clear_caches()
    with caching_disabled():
        cold = sweep(_tiny_work, n_chips=SMOKE_SPEC.n_chips,
                     chips=SMOKE_SPEC.chips,
                     topologies=SMOKE_SPEC.topologies,
                     mem_net=SMOKE_SPEC.mem_net, max_tp=SMOKE_SPEC.max_tp)
    clear_caches()
    warm = sweep(_tiny_work, n_chips=SMOKE_SPEC.n_chips,
                 chips=SMOKE_SPEC.chips,
                 topologies=SMOKE_SPEC.topologies,
                 mem_net=SMOKE_SPEC.mem_net, max_tp=SMOKE_SPEC.max_tp)
    stats = cache_stats()
    assert stats.hits > 0
    assert stats.by_space["sharding"][0] > 0
    assert stats.by_space["minmax"][0] > 0
    assert [p.row() for p in warm] == [p.row() for p in cold]


def test_cache_second_run_is_pure_hit_and_identical():
    clear_caches()
    first = sweep(_tiny_work, n_chips=SMOKE_SPEC.n_chips,
                  chips=SMOKE_SPEC.chips, topologies=SMOKE_SPEC.topologies,
                  mem_net=SMOKE_SPEC.mem_net, max_tp=SMOKE_SPEC.max_tp)
    before = cache_stats()
    second = sweep(_tiny_work, n_chips=SMOKE_SPEC.n_chips,
                   chips=SMOKE_SPEC.chips, topologies=SMOKE_SPEC.topologies,
                   mem_net=SMOKE_SPEC.mem_net, max_tp=SMOKE_SPEC.max_tp)
    after = cache_stats()
    assert after.hits > before.hits
    assert after.misses == before.misses  # second run never solves cold
    assert [p.row() for p in second] == [p.row() for p in first]


# --------------------------- shared memo store -------------------------------
@pytest.mark.parametrize("method", ["fork", "spawn", "forkserver"])
def test_shared_cache_sweep_matches_serial(method):
    """Every pool transport, with the cross-process store attached, must
    reproduce the scalar reference bit-for-bit, populate the store, and
    detach + tear it down before the sweep returns."""
    import multiprocessing

    if method not in multiprocessing.get_all_start_methods():
        pytest.skip(f"{method} not available on this platform")
    clear_caches()
    with caching_disabled():
        ref = _scalar_reference(SMOKE_SPEC)
    clear_caches()
    engine = DSEEngine(parallel=True, max_workers=2, mp_context=method,
                       shared_cache=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a serial fallback would hide bugs
        # this test forks on purpose (the explicit transport matrix);
        # jax's at-fork advisory is expected here — the engine's AUTO
        # pick avoiding fork once jax is loaded is covered in
        # tests/test_search.py
        warnings.filterwarnings("ignore", message=r"os\.fork\(\)",
                                category=RuntimeWarning)
        pts = engine.sweep(_tiny_work, SMOKE_SPEC)
    assert [p.row() for p in pts] == [p.row() for p in ref]
    stats = engine.last_shared_stats
    assert stats is not None, "shared store did not run"
    assert stats["backend"] == ("server" if method == "spawn" else "mmap")
    assert stats["inserts"] > 0 and stats["entries"] > 0
    assert stats["misses"] > 0
    assert GLOBAL_CACHE.shared is None  # torn down, not leaked


def test_shared_cache_perpoint_path_matches_serial():
    clear_caches()
    with caching_disabled():
        ref = _scalar_reference(SMOKE_SPEC)
    clear_caches()
    engine = _engine(parallel=True, max_workers=2, phased=False,
                     shared_cache=True)
    pts = engine.sweep(_tiny_work, SMOKE_SPEC)
    assert [p.row() for p in pts] == [p.row() for p in ref]
    assert engine.last_shared_stats is not None
    assert engine.last_shared_stats["entries"] > 0


def test_shared_cache_sweep_iter_exactly_once_and_torn_down():
    clear_caches()
    engine = _engine(parallel=True, max_workers=2, shared_cache=True)
    items = list(engine.sweep_iter(_tiny_work, SMOKE_SPEC))
    grid = SMOKE_SPEC.grid()
    assert sorted(it.index for it in items) == list(range(len(grid)))
    assert GLOBAL_CACHE.shared is None
    assert engine.last_shared_stats is not None
    ordered = [it.point for it in sorted(items, key=lambda it: it.index)
               if it.point is not None]
    clear_caches()
    with caching_disabled():
        ref = _scalar_reference(SMOKE_SPEC)
    assert [p.row() for p in ordered] == [p.row() for p in ref]


def test_shared_cache_serial_engine_runs_without_store():
    clear_caches()
    engine = DSEEngine(parallel=False, shared_cache=True)
    pts = engine.sweep(_tiny_work, SMOKE_SPEC)
    assert engine.last_shared_stats is None  # no pool → no store
    assert GLOBAL_CACHE.shared is None
    with caching_disabled():
        ref = _scalar_reference(SMOKE_SPEC)
    assert [p.row() for p in pts] == [p.row() for p in ref]


def test_shared_cache_uncached_engine_stays_cold():
    clear_caches()
    engine = DSEEngine(parallel=True, max_workers=2, use_cache=False,
                       shared_cache=True)
    pts = engine.sweep(_tiny_work, SMOKE_SPEC)
    assert engine.last_shared_stats is None  # use_cache=False wins
    assert pts


def test_shared_cache_torn_down_on_pool_failure():
    """An unpicklable work_fn under spawn kills the pool before it runs;
    the sweep must fall back serially AND tear the store down."""
    import multiprocessing

    if "spawn" not in multiprocessing.get_all_start_methods():
        pytest.skip("spawn not available on this platform")
    clear_caches()
    unpicklable = lambda system: _tiny_work(system)  # noqa: E731
    engine = DSEEngine(parallel=True, max_workers=2, mp_context="spawn",
                       shared_cache=True)
    with pytest.warns(RuntimeWarning, match="falling back to serial"):
        pts = engine.sweep(unpicklable, SMOKE_SPEC)
    assert GLOBAL_CACHE.shared is None  # torn down despite the failure
    assert engine.last_shared_stats is not None  # stats captured first
    clear_caches()
    with caching_disabled():
        ref = _scalar_reference(SMOKE_SPEC)
    assert [p.row() for p in pts] == [p.row() for p in ref]


def test_engine_rejects_unknown_shared_cache():
    with pytest.raises(ValueError):
        DSEEngine(shared_cache="carrier-pigeon")


# --------------------------- infeasible points -------------------------------
def _undecomposable_work(system):
    # global_batch == 1 forces DP == 1; with max_tp == 1 and n_layers == 1
    # (pp ≤ 3) no (tp, pp, dp) decomposition of 16 chips exists.
    from repro.workloads.hpl import hpl_workload

    return hpl_workload()


def test_sweep_skips_undecomposable_points_without_crashing():
    spec = SweepSpec(n_chips=16, chips=("H100",), topologies=("torus2d",),
                     mem_net=(("HBM", "NVLink"),), max_tp=1)
    clear_caches()
    serial = sweep(_undecomposable_work, n_chips=spec.n_chips,
                   chips=spec.chips, topologies=spec.topologies,
                   mem_net=spec.mem_net, max_tp=spec.max_tp)
    assert serial == []  # skipped, not raised
    engine = DSEEngine()
    assert engine.sweep(_undecomposable_work, spec) == []


def test_sweep_returns_point_per_cell_when_all_decompose():
    # same workload, but with TP unbounded every cell decomposes (tp=16):
    # nothing may be dropped and order must follow the grid.
    spec = SweepSpec(n_chips=16, chips=("H100", "SN30"),
                     topologies=("torus2d",),
                     mem_net=(("HBM", "NVLink"),), max_tp=None)
    engine = DSEEngine()
    pts = engine.sweep(_undecomposable_work, spec)
    assert len(pts) == len(design_grid(spec.chips, spec.mem_net,
                                       spec.topologies))


# ------------------------------- Pareto --------------------------------------
class _FakePlan:
    def __init__(self, feasible):
        self.feasible = feasible


class _FakePoint:
    def __init__(self, u, c, p, feasible=True):
        self.utilization, self.cost_eff, self.power_eff = u, c, p
        self.plan = _FakePlan(feasible)


def test_pareto_frontier_drops_dominated_points():
    a = _FakePoint(0.9, 10.0, 5.0)
    b = _FakePoint(0.8, 20.0, 4.0)
    dominated = _FakePoint(0.7, 9.0, 3.0)   # worse than a everywhere
    front = pareto_frontier([a, b, dominated])
    assert a in front and b in front and dominated not in front


def test_pareto_frontier_feasible_auto_fallback():
    bad = _FakePoint(0.5, 5.0, 5.0, feasible=False)
    good = _FakePoint(0.4, 4.0, 4.0, feasible=True)
    # feasible point exists → frontier restricted to it even if dominated
    assert pareto_frontier([bad, good]) == [good]
    # no feasible points → fall back to all, frontier non-empty
    assert pareto_frontier([bad]) == [bad]
    assert pareto_frontier([]) == []


def test_pareto_points_mutually_nondominated():
    rng = np.random.default_rng(1)
    pts = [_FakePoint(*rng.uniform(0.1, 1.0, size=3)) for _ in range(40)]
    front = pareto_frontier(pts)
    assert front
    for x in front:
        for y in front:
            if x is y:
                continue
            assert not (y.utilization >= x.utilization
                        and y.cost_eff >= x.cost_eff
                        and y.power_eff >= x.power_eff)


# --------------------------- scenario registry -------------------------------
def test_scenario_registry_lists_all_families():
    assert set(scenario_names()) == {"llm", "dlrm", "hpl", "fft",
                                     "moe", "mamba2", "serving"}
    with pytest.raises(KeyError):
        get_scenario("nope")


def test_serving_scenario_is_inference_only():
    sc = get_scenario("serving", smoke=True)
    work = sc.work_fn(None)
    assert work.bwd_flop_mult == 0.0
    assert work.optimizer_bytes_per_param_byte == 0.0
    assert work.dp_allreduce is False


@pytest.mark.parametrize("name", ["llm", "dlrm", "hpl", "fft",
                                  "moe", "mamba2", "serving"])
def test_smoke_scenarios_sweep_and_have_nonempty_frontier(name):
    engine = _engine()
    res = engine.sweep_scenario(name, smoke=True)
    assert res.points, f"{name} smoke sweep returned no design points"
    assert res.frontier, f"{name} smoke sweep has an empty Pareto frontier"
    assert all(any(f is p for p in res.points) for f in res.frontier)
    # frontier rows carry the workload tag for the bench tables
    assert res.rows()[0]["workload"] == name


# --------------------------- candidate pruning -------------------------------
def test_prune_on_off_engines_identical_across_all_scenarios():
    """The pruning acceptance property at engine level: for EVERY
    scenario family, a prune-on sweep returns DesignPoint rows identical
    to a prune-off sweep, while pricing strictly fewer candidate rows in
    aggregate (last_plan_stats accounting)."""
    enumerated = survived = 0
    for name in scenario_names():
        clear_caches()
        on = DSEEngine(parallel=False, prune="on")
        res_on = on.sweep_scenario(name, smoke=True)
        stats = on.last_plan_stats
        assert stats is not None and stats["prune"] is True
        assert stats["priced"] == stats["survived"] <= stats["enumerated"]
        enumerated += stats["enumerated"]
        survived += stats["survived"]
        clear_caches()
        off = DSEEngine(parallel=False, prune="off")
        res_off = off.sweep_scenario(name, smoke=True)
        assert off.last_plan_stats["prune"] is False
        assert ([p.row() for p in res_on.points]
                == [p.row() for p in res_off.points]), name
    assert survived < enumerated, "pruning never dropped a row anywhere"


def test_survivor_index_map_shipping_spawn_exactly_once():
    """Spawn workers with a non-numpy parent ship PRUNED matrices plus
    survivor index maps, exactly one group per system; the parent's
    batched re-pricing covers only surviving rows, every shipped winner
    is a survivor, and the CERTIFY_EVERY-sampled groups additionally
    carry the unpruned matrix for the parent's scalar-scan check."""
    import multiprocessing

    pytest.importorskip("jax")
    if "spawn" not in multiprocessing.get_all_start_methods():
        pytest.skip("spawn not available on this platform")
    from repro.core.dse import CERTIFY_EVERY

    clear_caches()
    with caching_disabled():
        ref = _scalar_reference(SMOKE_SPEC)
    clear_caches()
    engine = DSEEngine(parallel=True, max_workers=2, mp_context="spawn",
                       pricing_backend="jax", prune="on")
    captured: dict = {}
    orig = engine._finish_plan_groups

    def spy(groups, n_cells):
        captured["groups"] = groups
        return orig(groups, n_cells)

    engine._finish_plan_groups = spy
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a serial fallback would hide bugs
        pts = engine.sweep(_tiny_work, SMOKE_SPEC)
    assert [p.row() for p in pts] == [p.row() for p in ref]
    groups = captured["groups"]
    grid = SMOKE_SPEC.grid()
    assert sorted(i for g in groups for i in g.indices) == \
        list(range(len(grid)))                      # every cell exactly once
    full_shipped = 0
    for g in groups:
        assert g.survivors is not None, "pruned group shipped no index map"
        assert len(g.survivors) == len(g.matrix) == g.prune_stats["survived"]
        assert list(g.survivors) == sorted(set(g.survivors))  # unique, sorted
        assert all(0 <= s < g.n_candidates for s in g.survivors)
        assert all(r in g.survivors for r in g.winner_rows if r >= 0)
        if g.full_matrix is not None:
            full_shipped += 1
            assert len(g.full_matrix) == g.n_candidates
    n_tasks = len({(c, n, t) for c, _m, n, t in grid})
    want_sampled = len([i for i in range(n_tasks) if i % CERTIFY_EVERY == 0])
    assert full_shipped == want_sampled
    stats = engine.last_plan_stats
    assert stats["survived"] < stats["enumerated"]
    assert stats["priced"] == stats["survived"]
    assert stats["scalar_certified_groups"] == want_sampled
    assert stats["parent_certified_groups"] == want_sampled
    assert stats["verified"] is True and stats["prune"] is True


def test_parent_scalar_certification_detects_dropped_winner():
    """If pruning (or IPC) ever mangled a shipped winner, the parent's
    sampled full-matrix re-pricing must fail loudly."""
    from repro.core.dse import plan_design_groups

    clear_caches()
    grid = SMOKE_SPEC.grid()
    groups = plan_design_groups(_tiny_work, grid, SMOKE_SPEC.n_chips,
                                max_tp=SMOKE_SPEC.max_tp, prune="on",
                                certify=True)
    assert any(g.full_matrix is not None for g in groups)
    tampered = [dataclasses.replace(
        g, winner_rows=tuple(r + 1 if r >= 0 else r for r in g.winner_rows))
        if g.full_matrix is not None else g for g in groups]
    engine = DSEEngine(parallel=False, prune="on")
    with pytest.raises(RuntimeError, match="not winner-preserving"):
        engine._finish_plan_groups(tampered, len(grid))
    # untampered groups certify clean
    engine._finish_plan_groups(groups, len(grid))
    assert engine.last_plan_stats["scalar_certified_groups"] > 0


def test_prune_off_engine_ships_full_matrices():
    """prune='off' keeps the PR 3 contract: full matrices, no survivor
    maps, no sampled certification shipping."""
    from repro.core.dse import plan_design_groups

    clear_caches()
    grid = SMOKE_SPEC.grid()
    groups = plan_design_groups(_tiny_work, grid, SMOKE_SPEC.n_chips,
                                max_tp=SMOKE_SPEC.max_tp, prune="off")
    for g in groups:
        assert g.survivors is None
        assert g.full_matrix is None
        assert len(g.matrix) == g.n_candidates
        assert g.prune_stats["survived"] == g.prune_stats["enumerated"]

"""Serving engine + §VIII analytical serving/spec-decode model tests."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.serving import (expected_accepted, serving_sweep,
                                speculative_throughput)
from repro.models import init_params
from repro.serve.engine import ServeEngine
from repro.systems.chips import HBM_V5E, ICI, SN40L
from repro.systems.system import SystemSpec
from repro.systems.topology import torus2d
from repro.workloads.llm import LLAMA3_8B, decode_layer_graph, gpt_layer_graph

KEY = jax.random.PRNGKey(0)


# ------------------------------ executable engine -----------------------------
def test_engine_generates_tokens():
    cfg = get_config("olmo_1b", smoke=True)
    params = init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    res = eng.generate(prompts, n_tokens=6)
    toks = jnp.asarray(res.tokens).T          # (B, n_tokens)
    assert toks.shape == (2, 6)
    assert res.ttft > 0 and res.tpot > 0 and res.tokens_per_s > 0
    assert bool((toks >= 0).all()) and bool((toks < cfg.vocab).all())


def test_engine_greedy_matches_forward_continuation():
    """Greedy generation must follow the model's own argmax continuation."""
    from repro.models import forward
    cfg = get_config("olmo_1b", smoke=True)
    params = init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_batch=1, max_len=32)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab)
    res = eng.generate(prompts, n_tokens=3)
    # reference: iterated full forward
    seq = prompts
    want = []
    for _ in range(3):
        logits = forward(cfg, params, seq, remat=False)
        nxt = logits[:, -1].argmax(-1)
        want.append(int(nxt[0]))
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
    got = [int(t[0]) for t in res.tokens]
    assert got == want


def test_engine_ssm_generates():
    cfg = get_config("mamba2_130m", smoke=True)
    params = init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_batch=1, max_len=48)
    prompts = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    res = eng.generate(prompts, n_tokens=4)
    assert len(res.tokens) == 4


# ------------------------------ analytical §VIII.A ----------------------------
def _sn40l_system(n=16):
    topo = torus2d(n, ICI)
    return SystemSpec("sn40l", SN40L, HBM_V5E, topo)


def test_serving_sweep_tradeoffs():
    """Paper Fig 20: increasing TP decreases TTFT/TPOT; increasing PP
    increases system-level throughput."""
    s = dataclasses.replace(LLAMA3_8B, batch=1)
    pre = gpt_layer_graph(s)
    dec = decode_layer_graph(s, kv_len=8192)
    pts = serving_sweep(pre, dec, n_layers=32, system=_sn40l_system(16))
    assert len(pts) >= 3
    by_tp = {p.tp: p for p in pts}
    tps = sorted(by_tp)
    # TTFT monotonically non-increasing in TP (more chips shard the prefill)
    assert by_tp[tps[-1]].ttft < by_tp[tps[0]].ttft
    # PP>1 point has higher decode throughput than its TPOT-1/x implies
    pp_pts = [p for p in pts if p.pp > 1]
    if pp_pts:
        p = pp_pts[0]
        assert p.decode_throughput * p.tpot > 0.99  # pipelined slots ≥ 1/x


def test_decode_is_memory_or_network_bound():
    """Paper: 'in the decode phase most time is spent on memory and network'."""
    s = dataclasses.replace(LLAMA3_8B, batch=8)
    dec = decode_layer_graph(s, kv_len=8192)
    pre = gpt_layer_graph(dataclasses.replace(s, batch=1))
    pts = serving_sweep(pre, dec, n_layers=32, system=_sn40l_system(16))
    tp16 = [p for p in pts if p.tp == 16]
    assert tp16
    bd = tp16[0].breakdown_decode
    assert bd["memory"] + bd["network"] > bd["compute"]


# ------------------------------ §VIII.B spec decode ----------------------------
def test_expected_accepted_formulas():
    # sequence: geometric series
    assert expected_accepted(3, 0.0, "sequence") == pytest.approx(1.0)
    assert expected_accepted(3, 1.0, "sequence") == pytest.approx(4.0)
    assert expected_accepted(2, 0.5, "sequence") == pytest.approx(1.75)
    # tree boosts the effective acceptance
    assert expected_accepted(3, 0.5, "tree") > expected_accepted(
        3, 0.5, "sequence")


def test_specdecode_monotonic_in_acceptance_and_window():
    td, tv = 1e-3, 1e-2
    t1 = speculative_throughput(td, tv, window=4, acceptance=0.5)
    t2 = speculative_throughput(td, tv, window=4, acceptance=0.9)
    assert t2 > t1
    t3 = speculative_throughput(td, tv, window=8, acceptance=0.9)
    assert t3 > t1


def test_specdecode_tree_prefers_small_windows():
    """Paper: tree-based needs small windows — the 2^K draft cost blows up."""
    td, tv = 1e-3, 1e-2
    small = speculative_throughput(td, tv, window=2, acceptance=0.7,
                                   scheme="tree")
    huge = speculative_throughput(td, tv, window=10, acceptance=0.7,
                                  scheme="tree")
    assert small > huge


def test_specdecode_large_draft_model_overhead():
    """Paper: a 70B draft for a 405B target has too much overhead vs 8B."""
    tv = 20e-3
    t8 = speculative_throughput(1e-3, tv, window=4, acceptance=0.8)
    t70 = speculative_throughput(8e-3, tv, window=4, acceptance=0.9)
    assert t8 > t70


# ------------------------------ timing + window guards ------------------------
def test_decode_steady_timing_fields():
    cfg = get_config("olmo_1b", smoke=True)
    params = init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    t = eng.decode_steady(prompts, n_steps=3, warmup=1)
    assert t.ttft > 0 and t.warmup == 1 and t.batch == 2
    assert len(t.step_times) == 3 and all(s > 0 for s in t.step_times)
    assert t.tpot == pytest.approx(sum(t.step_times) / 3)
    assert t.tokens_per_s == pytest.approx(2 / t.tpot)


def test_generate_window_overflow_raises():
    """Overflowing the KV cache must be a loud ValueError, not a silent
    out-of-range `.at[].set` drop."""
    cfg = get_config("olmo_1b", smoke=True)
    params = init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_batch=1, max_len=16)
    prompts = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    with pytest.raises(ValueError, match="max_len"):
        eng.generate(prompts, n_tokens=9)
    with pytest.raises(ValueError, match="max_len"):
        eng.decode_steady(prompts, n_steps=8, warmup=0)
    # the largest window that fits must not raise
    eng.generate(prompts, n_tokens=8)


def test_memory_threads_both_jitted_paths():
    """The cross-attention memory operand must reach the prefill AND the
    decode jitted functions — a dropped operand leaves logits unchanged."""
    cfg = get_config("llama32_vision_11b", smoke=True)
    params = init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_batch=1, max_len=32)
    prompts = jax.random.randint(KEY, (1, 4), 0, cfg.vocab)
    m1 = jnp.zeros((1, cfg.n_image_tokens, cfg.d_model))
    m2 = jnp.ones((1, cfg.n_image_tokens, cfg.d_model))
    pre1, cache1 = eng._prefill(params, prompts, m1)
    pre2, _ = eng._prefill(params, prompts, m2)
    assert not jnp.allclose(pre1, pre2)
    cache = eng._rehome(cache1, 1, 4)
    tok = jnp.argmax(pre1[:, -1], -1).astype(jnp.int32)
    dec1, _ = eng._decode(params, cache, tok, jnp.int32(4), m1)
    dec2, _ = eng._decode(params, cache, tok, jnp.int32(4), m2)
    assert not jnp.allclose(dec1, dec2)
    # and the end-to-end driver accepts it
    res = eng.generate(prompts, n_tokens=3, memory=m1)
    assert len(res.tokens) == 3


# ------------------------------ sampling determinism --------------------------
def test_sampled_generation_seeded_deterministic():
    cfg = get_config("olmo_1b", smoke=True)
    params = init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_batch=1, max_len=48)
    prompts = jax.random.randint(jax.random.PRNGKey(7), (1, 8), 0, cfg.vocab)
    kw = dict(n_tokens=8, temperature=1.0)
    a = eng.generate(prompts, rng=jax.random.PRNGKey(11), **kw)
    b = eng.generate(prompts, rng=jax.random.PRNGKey(11), **kw)
    assert a.tokens == b.tokens
    c = eng.generate(prompts, rng=jax.random.PRNGKey(12), **kw)
    assert c.tokens != a.tokens
    # per-step subkeys: a sampled run must not emit one token forever
    # (the degenerate fixed-key bug this engine refactor removed)
    flat = [t[0] for t in a.tokens]
    assert len(set(flat)) > 1


def test_sampling_without_rng_degrades_to_greedy():
    cfg = get_config("olmo_1b", smoke=True)
    params = init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_batch=1, max_len=32)
    prompts = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    hot = eng.generate(prompts, n_tokens=4, temperature=1.0, rng=None)
    cold = eng.generate(prompts, n_tokens=4)
    assert hot.tokens == cold.tokens


# ------------------------------ executable spec decode ------------------------
def test_specdecode_self_draft_bit_identical_to_greedy():
    """With the target as its own draft every proposal is accepted and the
    speculative stream must equal plain greedy decoding bit-for-bit."""
    from repro.serve.specdecode import speculative_generate
    cfg = get_config("olmo_1b", smoke=True)
    params = init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_batch=1, max_len=48)
    prompts = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0, cfg.vocab)
    n = 8
    plain = [t[0] for t in eng.generate(prompts, n_tokens=n).tokens]
    spec, rate, target_calls = speculative_generate(
        cfg, params, cfg, params, prompts, n_tokens=n, window=4)
    assert spec == plain
    assert rate == pytest.approx(1.0)
    # window-4 self-drafting emits 5 tokens per target call
    assert target_calls < n

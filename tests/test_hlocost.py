"""Trip-count-aware HLO cost model tests (launch/hlocost.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlocost


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_matmul_flops_exact():
    txt = _compile(lambda a, b: a @ b,
                   jax.ShapeDtypeStruct((256, 512), jnp.float32),
                   jax.ShapeDtypeStruct((512, 128), jnp.float32))
    s = hlocost.analyze(txt)
    assert s.flops == pytest.approx(2 * 256 * 512 * 128, rel=0.01)


def test_batched_dot_flops():
    txt = _compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                   jax.ShapeDtypeStruct((4, 64, 96), jnp.float32),
                   jax.ShapeDtypeStruct((4, 96, 32), jnp.float32))
    s = hlocost.analyze(txt)
    assert s.flops == pytest.approx(2 * 4 * 64 * 96 * 32, rel=0.01)


def test_scan_trip_count_scaling():
    """FLOPs must scale with the scan length — the exact failure mode of
    XLA's built-in cost_analysis this module exists to fix."""
    def make(n):
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

        def fn(w, x):
            def body(c, _):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, None, length=n)
            return out
        return hlocost.analyze(_compile(fn, w, x))

    s4, s16 = make(4), make(16)
    assert s16.while_trip_counts and 16 in s16.while_trip_counts
    ratio = s16.flops / s4.flops
    assert 3.0 < ratio < 5.0, ratio     # 16/4 = 4× the loop body


def test_nested_scan_multiplies():
    def fn(x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ ci), None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    s = hlocost.analyze(_compile(
        fn, jax.ShapeDtypeStruct((32, 32), jnp.float32)))
    # 15 total inner matmuls
    assert s.flops == pytest.approx(15 * 2 * 32 * 32 * 32, rel=0.15)


def test_bytes_accessed_nonzero_and_sane():
    txt = _compile(lambda a: a + 1.0,
                   jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
    s = hlocost.analyze(txt)
    nbytes = 1024 * 1024 * 4
    assert nbytes <= s.bytes_accessed <= 4 * nbytes


def test_collective_parsing_list_format():
    hlo = """
HloModule test, entry_computation_layout={()->f32[64]{0}}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main () -> f32[64] {
  %c = f32[64]{0} constant({...})
  ROOT %ar = f32[64]{0} all-reduce(%c), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    s = hlocost.analyze(hlo)
    assert s.collective_bytes["all-reduce"] == pytest.approx(
        2 * 64 * 4 * 3 / 4)      # 2n(S-1)/S with S=4
    assert s.collectives[0].participants == 4


def test_collective_parsing_iota_format():
    hlo = """
HloModule test, entry_computation_layout={()->f32[128]{0}}

ENTRY %main () -> f32[128] {
  %c = f32[16]{0} constant({...})
  ROOT %ag = f32[128]{0} all-gather(%c), replica_groups=[2,8]<=[16], dimensions={0}
}
"""
    s = hlocost.analyze(hlo)
    # AG link bytes: shard · (S-1) = 16·4 · 7
    assert s.collective_bytes["all-gather"] == pytest.approx(16 * 4 * 7)
    assert s.collectives[0].participants == 8


def test_collective_inside_while_scaled_by_trips():
    hlo = """
HloModule test, entry_computation_layout={()->f32[64]{0}}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64]{0} get-tuple-element(%p), index=1
  %ar = f32[64]{0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
  ROOT %t = (s32[], f32[64]{0}) tuple(%i, %ar)
}

%cond (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

ENTRY %main () -> f32[64] {
  %c0 = s32[] constant(0)
  %x0 = f32[64]{0} constant({...})
  %t0 = (s32[], f32[64]{0}) tuple(%c0, %x0)
  %w = (s32[], f32[64]{0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[64]{0} get-tuple-element(%w), index=1
}
"""
    s = hlocost.analyze(hlo)
    rec = s.collectives[0]
    assert rec.trips == 7
    assert s.collective_bytes["all-reduce"] == pytest.approx(
        7 * 2 * 64 * 4 * 1 / 2)


def test_schedule_report_sorted():
    hlo = """
HloModule test, entry_computation_layout={()->f32[8]{0}}

ENTRY %main () -> f32[8] {
  %a = f32[1024]{0} constant({...})
  %b = f32[8]{0} constant({...})
  %p1 = f32[1024]{0} collective-permute(%a), source_target_pairs={{0,1}}
  ROOT %p2 = f32[8]{0} collective-permute(%b), source_target_pairs={{0,1}}
}
"""
    s = hlocost.analyze(hlo)
    sched = hlocost.collective_schedule(s)
    assert sched[0]["total_link_bytes"] >= sched[1]["total_link_bytes"]

"""Solver engines vs brute force (the Gurobi-optimality-certificate analogue)."""
from __future__ import annotations

import itertools

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the dev extra (requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.graph import chain_graph, Kernel
from repro.core.solver import (bounds_to_assign, branch_and_bound,
                               design_space_size, enumerate_parallelism,
                               minmax_partition, minmax_partition_scalar,
                               minsum_partition)

from conftest import dags


def _brute_minmax(costs, p):
    n = len(costs)
    best = float("inf")
    for cuts in itertools.combinations(range(1, n), min(p, n) - 1):
        bounds = [0, *cuts, n]
        m = max(sum(costs[bounds[i]:bounds[i + 1]])
                for i in range(len(bounds) - 1))
        best = min(best, m)
    return best


@given(st.lists(st.floats(min_value=0.1, max_value=100.0),
                min_size=2, max_size=9),
       st.integers(min_value=1, max_value=4))
@settings(max_examples=150, deadline=None)
def test_minmax_partition_optimal(costs, p):
    bounds, obj = minmax_partition(costs, p)
    assert len(bounds) == min(p, len(costs))
    assert bounds[0] == 0
    # objective matches the returned split
    assign = bounds_to_assign(bounds, len(costs))
    groups = [sum(c for c, a in zip(costs, assign) if a == g)
              for g in range(max(assign) + 1)]
    assert obj == pytest.approx(max(groups), rel=1e-9)
    # and is optimal
    assert obj == pytest.approx(_brute_minmax(costs, p), rel=1e-9)


def _brute_minsum(costs, p_max, cap, pref):
    n = len(costs)
    best = float("inf")
    for p in range(1, min(p_max, n) + 1):
        for cuts in itertools.combinations(range(1, n), p - 1):
            bounds = [0, *cuts, n]
            if any(pref[bounds[i + 1]] - pref[bounds[i]] > cap
                   for i in range(len(bounds) - 1)):
                continue
            best = min(best, sum(max(costs[bounds[i]:bounds[i + 1]])
                                 for i in range(len(bounds) - 1)))
    return best


@given(st.lists(st.floats(min_value=0.1, max_value=10.0),
                min_size=2, max_size=8),
       st.integers(min_value=1, max_value=5),
       st.floats(min_value=5.0, max_value=100.0))
@settings(max_examples=150, deadline=None)
def test_minsum_partition_optimal(costs, p_max, cap):
    n = len(costs)
    pref = np.concatenate([[0.0], np.cumsum(costs)])

    def group_cost(i, j):
        return max(costs[i:j])

    def feasible(i, j):
        return pref[j] - pref[i] <= cap

    expect = _brute_minsum(costs, p_max, cap, pref)
    if not np.isfinite(expect):
        with pytest.raises(ValueError):
            minsum_partition(n, p_max, group_cost, feasible)
        return
    bounds, obj = minsum_partition(n, p_max, group_cost, feasible)
    assert obj == pytest.approx(expect, rel=1e-9)
    # split respects the capacity
    assign = bounds_to_assign(bounds, n)
    for g in range(max(assign) + 1):
        assert sum(c for c, a in zip(costs, assign) if a == g) <= cap * (1 + 1e-9)


@given(dags(max_kernels=6))
@settings(max_examples=30, deadline=None)
def test_branch_and_bound_beats_or_matches_contiguous_dp(g):
    """B&B searches the full precedence lattice; the DP restricts to
    contiguous topo intervals. B&B must never be worse; on min-max costs of
    this form it matches (the restriction is lossless)."""
    p_max = 3
    f = np.array([k.flops for k in g.kernels])
    order = g.topo_order

    def objective(assign):
        groups = np.zeros(p_max)
        for i, p in enumerate(assign):
            groups[p] += f[i]
        return groups.max()

    ba, bc = branch_and_bound(g, p_max, objective)
    costs = [f[i] for i in order]
    _, dp_obj = minmax_partition(costs, p_max)
    assert bc <= dp_obj * (1 + 1e-9)
    assert bc == pytest.approx(dp_obj, rel=1e-9)


@given(st.lists(st.floats(min_value=0.1, max_value=100.0),
                min_size=2, max_size=9),
       st.integers(min_value=1, max_value=4),
       st.floats(min_value=0.0, max_value=50.0))
@settings(max_examples=150, deadline=None)
def test_minmax_extra_vectorized_matches_scalar(costs, p, penalty):
    """The vectorized ``extra`` path must agree bit-for-bit with the scalar
    reference implementation (same boundaries, same objective, same
    tie-breaks)."""

    def extra(i, j):
        # deterministic, interval-dependent: boundary penalty + span term
        return penalty + 0.25 * (j - i)

    vb, vo = minmax_partition(costs, p, extra=extra)
    sb, so = minmax_partition_scalar(costs, p, extra=extra)
    assert vb == sb
    assert vo == so  # bit-identical, not approx

    # and the extra=None fast path agrees with the scalar reference too
    vb0, vo0 = minmax_partition(costs, p)
    sb0, so0 = minmax_partition_scalar(costs, p)
    assert vb0 == sb0
    assert vo0 == so0


@given(dags(max_kernels=6), st.integers(min_value=1, max_value=3))
@settings(max_examples=30, deadline=None)
def test_minmax_extra_agrees_with_bnb_on_dags(g, p):
    """On random DAGs (chain-connected, so monotone B&B assignments are
    contiguous intervals) the vectorized extra-path DP matches the exact
    branch & bound certifier restricted to the same group count."""
    order = g.topo_order
    f = np.array([k.flops for k in g.kernels])
    w = np.array([k.weight_bytes for k in g.kernels])
    costs = [f[i] for i in order]
    w_topo = np.array([w[i] for i in order])
    p_eff = min(p, g.n)

    def extra(i, j):
        return float(w_topo[i:j].sum()) * 1e-6

    def objective(assign):
        worst = 0.0
        for part in sorted(set(int(a) for a in assign)):
            members = [i for i in range(g.n) if assign[i] == part]
            lo, hi = min(members), max(members) + 1
            assert members == list(range(lo, hi))  # contiguity (chain DAG)
            worst = max(worst, float(sum(costs[lo:hi])) + extra(lo, hi))
        return worst

    def exactly_p(assign):
        return len(set(int(a) for a in assign)) == p_eff

    ba, bc = branch_and_bound(g, p_eff, objective, feasible=exactly_p)
    bounds, dp_obj = minmax_partition(costs, p_eff, extra=extra)
    assert len(bounds) == p_eff
    assert dp_obj == pytest.approx(bc, rel=1e-9)


def test_enumerate_parallelism_exact_cover():
    for n in (8, 24, 256):
        combos = enumerate_parallelism(n)
        assert all(tp * pp * dp == n for tp, pp, dp in combos)
        assert len(set(combos)) == len(combos)
        # number of ordered factorizations into 3 factors
        brute = sum(1 for tp in range(1, n + 1) if n % tp == 0
                    for pp in range(1, n + 1)
                    if (n // tp) % pp == 0)
        assert len(combos) == brute
    assert enumerate_parallelism(16, max_tp=4) == [
        c for c in enumerate_parallelism(16) if c[0] <= 4]


def test_design_space_size_matches_paper_scale():
    """Paper: O(10^295) for a trillion-param LLM on a thousand accelerators."""
    layer = chain_graph([Kernel(f"k{i}", 1.0) for i in range(96)],
                        [1.0] * 95)
    logsize = design_space_size(layer, p_max=96, n_chips=1024,
                                schemes_per_kernel=3)
    assert logsize > 100  # astronomically large, solved in seconds by the DP

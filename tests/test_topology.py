"""Collective cost model tests (paper §IV.C / ASTRA-sim composition)."""
from __future__ import annotations

import pytest

from repro.systems.chips import ICI, NVLINK, PCIE
from repro.systems.topology import (TOPOLOGIES, Topology, TopologyDim,
                                    dragonfly, fully_connected, ring, switch,
                                    torus2d, torus3d)

GB = 1e9


def test_ring_closed_forms():
    d = TopologyDim(8, "ring", ICI)
    n = 1e9
    bw = ICI.bandwidth
    assert d.all_gather(n) == pytest.approx(7 / 8 * n / bw + 7 * ICI.latency)
    assert d.reduce_scatter(n) == pytest.approx(d.all_gather(n))
    assert d.all_reduce(n) == pytest.approx(2 * d.all_gather(n))
    assert d.all_gather(0.0) == pytest.approx(7 * ICI.latency)
    assert TopologyDim(1, "ring", ICI).all_reduce(n) == 0.0


def test_fc_beats_ring_for_all_to_all():
    n = 1e9
    r = TopologyDim(16, "ring", PCIE)
    f = TopologyDim(16, "fc", PCIE)
    assert f.all_to_all(n) < r.all_to_all(n)
    assert f.all_gather(n) < r.all_gather(n)


def test_topology_families_chip_counts():
    for name in ("ring", "torus2d", "torus3d", "dgx1", "dgx2", "dragonfly",
                 "switch", "fc"):
        topo = TOPOLOGIES[name](1024, NVLINK)
        assert topo.total_chips == 1024, name


def test_torus_shapes():
    t2 = torus2d(256, ICI)
    assert sorted(d.size for d in t2.dims) == [16, 16]
    t3 = torus3d(512, ICI)
    sizes = sorted(d.size for d in t3.dims)
    assert sizes[0] * sizes[1] * sizes[2] == 512


def test_multidim_all_reduce_blueconnect():
    """Multi-dim AR = RS inward + AG outward on shrinking shards; must be
    cheaper than running the full AR on the flattened ring."""
    topo = torus2d(256, ICI)
    n = 1e9
    two_dim = topo.all_reduce(n, [0, 1])
    flat = ring(256, ICI).all_reduce(n, [0])
    assert two_dim < flat
    # and more expensive than a hypothetical single 16-ring on the same data
    assert two_dim > TopologyDim(16, "ring", ICI).all_reduce(n) * 0.99


def test_all_reduce_equals_rs_plus_ag_single_dim():
    topo = ring(8, ICI)
    n = 2e9
    assert topo.all_reduce(n, [0]) == pytest.approx(
        topo.reduce_scatter(n, [0]) + topo.all_gather(n, [0]))


def test_monotonic_in_payload():
    topo = dragonfly(64, PCIE)
    assert topo.all_to_all(2e9, [0, 1]) > topo.all_to_all(1e9, [0, 1])
    assert topo.p2p(2e9, [0]) > topo.p2p(1e9, [0])


def test_links_per_chip():
    assert TopologyDim(8, "ring", ICI).links_per_chip == 2.0
    assert TopologyDim(8, "fc", ICI).links_per_chip == 7.0
    assert TopologyDim(8, "switch", ICI).links_per_chip == 1.0
    assert TopologyDim(1, "ring", ICI).links_per_chip == 0.0
    assert torus2d(256, ICI).links_per_chip() == 4.0


def test_nvlink_dominates_pcie():
    n = 1e9
    for kind in ("ring", "fc", "switch"):
        slow = TopologyDim(16, kind, PCIE)
        fast = TopologyDim(16, kind, NVLINK)
        assert fast.all_reduce(n) < slow.all_reduce(n)

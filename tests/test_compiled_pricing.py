"""Compiled f32 pricing backend + drift-budget contract tests.

The house rule under test: ``pallas-compiled`` may price the candidate
mass in float32, but every *decision* made from its columns must be
provably identical to the f64 scalar reference. The layers:

* kernel — ``certify_f32`` holds the declared relative band on seeded
  random plan vectors; padded lengths bucket to powers of two above the
  tile; the one-row output probe memoizes per (formula, layout).
* banded selection — ``banded_winner_rows`` reproduces the serial scan
  on exact-duplicate iter-times, on adversarial pairs engineered to tie
  in f32 but order in f64, and on capacities sitting inside the band of
  the memory footprint; observed drift beyond the band raises
  ``DriftBandError`` instead of returning a selection.
* core — ``select_plans`` on the compiled backend returns the numpy
  reference's plans with exact feasibility bits; unknown backend
  spellings raise.
* engine — sweeps on both sides of the IPC boundary (serial in-process
  and the forced process pool) emit rows bit-identical to the numpy
  engine, and ``reprice_grid`` certifies whole dense grids in bounded
  chunks.
"""
from __future__ import annotations

import dataclasses
import os
import pickle

import numpy as np
import pytest

from repro.core import DSEEngine, SweepSpec, clear_caches
from repro.core.dse import build_system
from repro.core.interchip import (candidate_matrix, scalar_winner_rows,
                                  select_plans)
from repro.core.pricing import (PlanVector, exact_backend, is_approx_backend,
                                price_plans, resolve_backend, stack_plans)
from repro.kernels.pricing import (DEFAULT_BAND, DriftBandError,
                                   banded_winner_rows, certify_banded_rows,
                                   certify_f32, drift_band)
from repro.kernels.pricing.kernel import DEFAULT_TILE, F32_BLOCK, padded_length
from repro.kernels.pricing.ops import _probe_outputs, pallas_columns
from repro.search.grid import DenseGridSpec, ScaledWorkFn, scale_lattice
from repro.workloads.llm import LLAMA_68M, gpt_workload


# module-level so the workload builder is picklable under spawn semantics
def _tiny_work(system):
    return gpt_workload(LLAMA_68M, global_batch=64, microbatch=1)


SMOKE_SPEC = SweepSpec(n_chips=16, chips=("H100", "SN30"),
                       topologies=("torus2d", "dgx2"),
                       mem_net=(("DDR", "PCIe"), ("HBM", "NVLink")),
                       max_tp=16)


def _engine(**kwargs) -> DSEEngine:
    env_ctx = os.environ.get("DFMODEL_TEST_MP_CONTEXT")
    if env_ctx:
        kwargs.setdefault("mp_context", env_ctx)
    kwargs.setdefault("parallel", False)
    return DSEEngine(**kwargs)


@pytest.fixture(autouse=True)
def _fresh_caches():
    clear_caches()
    yield
    clear_caches()


def _vec(t_comp: float, mem: float = 8e9, cap: float = 1e12) -> PlanVector:
    """A plan vector whose iter_time is exactly ``t_comp`` and whose
    per-chip memory is exactly ``mem`` (pp=1, n_micro=1, zero backward
    multipliers and collectives collapse Eq. 7 to the forward stage)."""
    return PlanVector(
        t_comp_stage=t_comp, t_net_stage=0.0, t_p2p=0.0, t_dp=0.0,
        n_micro=1.0, tp=1.0, pp=1.0, bwd_flop_mult=0.0, bwd_comm_mult=0.0,
        opt_mult=0.0, model_flops=1e12, weight_bytes=mem,
        act_bytes_layer=0.0, layers_per_stage=1.0, stage_layers=1.0,
        n_chips=8.0, chip_peak=1e12, mem_capacity=cap,
        sys_peak_flops=8e12, sys_price=1e6, sys_power=1e4,
        intra_comp=0.0, intra_mem=0.0, intra_net=0.0, intra_total=0.0)


def _banded_vs_scalar(vectors, capacities, band=None):
    """Run the banded selection over compiled-f32 pricing and assert it
    reproduces the literal serial scan; returns the selection."""
    cols = stack_plans(vectors)
    f32 = price_plans(cols, backend="pallas-compiled")
    ref = price_plans(cols, backend="numpy")
    expected = scalar_winner_rows(ref["iter_time"].tolist(),
                                  ref["per_chip_mem_bytes"].tolist(),
                                  capacities)
    return certify_banded_rows(cols, f32, capacities, expected,
                               "pallas-compiled", band=band)


# --- kernel layer -------------------------------------------------------------
def test_certify_f32_within_band():
    report = certify_f32(512, seed=3)
    assert report["within_band"] is True
    assert report["band"] == DEFAULT_BAND
    assert 0.0 < report["max_drift"] <= DEFAULT_BAND
    assert "iter_time" in report["drift_by_column"]


def test_padded_length_buckets_to_powers_of_two():
    assert padded_length(0) == DEFAULT_TILE
    assert padded_length(1) == DEFAULT_TILE
    assert padded_length(DEFAULT_TILE) == DEFAULT_TILE
    assert padded_length(DEFAULT_TILE + 1) == 2 * DEFAULT_TILE
    assert padded_length(5 * DEFAULT_TILE) == 8 * DEFAULT_TILE
    # the f32 block is the tile for the compiled layout
    assert padded_length(F32_BLOCK + 1, F32_BLOCK) == 2 * F32_BLOCK
    # bucketed: O(log n) distinct executables across any batch-size mix
    sizes = {padded_length(n) for n in range(1, 4097)}
    assert sizes == {DEFAULT_TILE * (1 << k) for k in range(4)}


def test_probe_outputs_memoized():
    probes = []

    def formula(xp, cols):
        if xp is np:
            probes.append(int(len(cols["x"])))
        return {"y": cols["x"] * 2.0, "big": cols["x"] > 1.0}

    _probe_outputs.cache_clear()
    for _ in range(3):
        out = pallas_columns(formula, {"x": np.arange(5.0)})
    assert out["big"].dtype == np.bool_
    # the one-row probe ran exactly once across three dispatches
    assert probes == [1]


# --- banded selection ---------------------------------------------------------
def test_exact_duplicate_iter_times_pick_first_index():
    # four tiled copies of the same two-row pattern: every minimum is
    # duplicated, so any tie-break other than first-index diverges
    vectors = [_vec(2.0), _vec(1.0)] * 4
    sel = _banded_vs_scalar(vectors, [1e12, 5e9])
    assert sel.rows == [1, 1]


def test_f32_rounding_tie_resolved_by_exact_repricing():
    # a < b in f64 but float32(a) == float32(b); the larger value sits at
    # the LOWER index, so an f32-only argmin would pick row 0 — the band
    # re-prices both rows exactly and must land on row 1
    a, b = 1.0, 1.0 + 1e-9
    assert np.float32(a) == np.float32(b)
    vectors = [_vec(b), _vec(a), _vec(3.0)]
    sel = _banded_vs_scalar(vectors, [1e12])
    assert sel.rows == [1]
    assert sel.stats["band_hits"] >= 2          # both tied rows re-priced
    assert sel.winner_iter == [a]               # exact f64 value, not f32


def test_capacity_inside_band_resolved_exactly():
    # both rows' memory sits within f32 drift of the capacity: feasibility
    # is ambiguous in f32 and must be settled by exact re-pricing on both
    # sides of the boundary
    cap = float(2 ** 40) + 3.0
    vectors = [_vec(1.0, mem=cap + 1.0),       # faster but infeasible
               _vec(2.0, mem=cap - 1.0)]       # slower, feasible winner
    sel = _banded_vs_scalar(vectors, [cap])
    assert sel.rows == [1]
    assert sel.stats["ambiguous_mem"] == 2
    assert sel.winner_mem == [cap - 1.0]
    # and when nothing fits, the reference falls back to the global argmin
    sel2 = _banded_vs_scalar(vectors, [1.0])
    assert sel2.rows == [0]
    assert sel2.stats["fallback_caps"] == 1


def test_drift_beyond_band_raises():
    vectors = [_vec(1.0), _vec(2.0)]
    cols = stack_plans(vectors)
    ref = price_plans(cols, backend="numpy")
    corrupted = {"iter_time": ref["iter_time"] * 1.1,
                 "per_chip_mem_bytes": ref["per_chip_mem_bytes"]}
    with pytest.raises(DriftBandError, match="beyond the declared band"):
        banded_winner_rows(cols, corrupted, [1e12])


def test_winner_mismatch_raises():
    vectors = [_vec(1.0), _vec(2.0)]
    cols = stack_plans(vectors)
    f32 = price_plans(cols, backend="pallas-compiled")
    with pytest.raises(RuntimeError, match="different candidates"):
        certify_banded_rows(cols, f32, [1e12], [1], "pallas-compiled")


def test_drift_band_env_validation(monkeypatch):
    monkeypatch.delenv("DFMODEL_DRIFT_BAND", raising=False)
    assert drift_band() == DEFAULT_BAND
    monkeypatch.setenv("DFMODEL_DRIFT_BAND", "1e-6")
    assert drift_band() == 1e-6
    for bad in ("banana", "0.7", "-1e-3", "0", "inf", "nan"):
        monkeypatch.setenv("DFMODEL_DRIFT_BAND", bad)
        with pytest.raises(ValueError, match="DFMODEL_DRIFT_BAND"):
            drift_band()


# --- core backend plumbing ----------------------------------------------------
def test_backend_helpers_and_unknown_spelling():
    assert resolve_backend("pallas-compiled") == "pallas-compiled"
    assert is_approx_backend("pallas-compiled") is True
    assert is_approx_backend("pallas") is False
    assert exact_backend("pallas-compiled") == "numpy"
    assert exact_backend("jax") == "jax"
    with pytest.raises(ValueError, match="unknown pricing backend"):
        resolve_backend("pallas-compiled-f16")
    with pytest.raises(ValueError, match="unknown pricing backend"):
        price_plans(stack_plans([_vec(1.0)]), backend="compiled")


def test_select_plans_compiled_matches_numpy():
    system = build_system(("H100", "HBM", "NVLink", "torus2d"), 16)
    cands = candidate_matrix(_tiny_work(system), system, max_tp=16)
    assert len(cands) > 1
    mems = sorted(cands.selection()["per_chip_mem_bytes"].tolist())
    # capacities straddling the candidate spread, including one between
    # two footprints and one below all of them (fallback semantics)
    caps = [mems[-1] * 2.0, (mems[0] + mems[-1]) / 2.0, mems[0] * 0.5]
    want = select_plans(cands, caps, backend="numpy")
    got = select_plans(cands, caps, backend="pallas-compiled")
    for w, g in zip(want, got):
        assert (w.tp, w.pp, w.dp) == (g.tp, g.pp, g.dp)
        assert w.iter_time == g.iter_time
        assert w.feasible == g.feasible


# --- engine: both sides of the IPC boundary -----------------------------------
def test_engine_rows_identical_serial_and_pool():
    rows_ref = [p.row() for p in
                _engine(pricing_backend="numpy").sweep(_tiny_work,
                                                       SMOKE_SPEC)]
    assert rows_ref
    serial = _engine(pricing_backend="pallas-compiled")
    rows_serial = [p.row() for p in serial.sweep(_tiny_work, SMOKE_SPEC)]
    assert rows_serial == rows_ref
    drift = serial.last_drift_stats
    assert drift is not None and drift["backend"] == "pallas-compiled"
    assert drift["max_iter_drift"] <= drift["band"]

    pool = _engine(parallel=True, max_workers=2,
                   pricing_backend="pallas-compiled", price_chunk_rows=64)
    rows_pool = [p.row() for p in pool.sweep(_tiny_work, SMOKE_SPEC)]
    assert rows_pool == rows_ref
    drift = pool.last_drift_stats
    assert drift is not None and drift["groups"] > 0
    assert drift["rows"] == pool.last_plan_stats["priced"]


def test_engine_rejects_bad_chunk_rows():
    with pytest.raises(ValueError, match="price_chunk_rows"):
        DSEEngine(price_chunk_rows=0)
    eng = _engine(pricing_backend="numpy")
    with pytest.raises(ValueError, match="chunk_rows"):
        eng.reprice_grid(_tiny_work, SMOKE_SPEC, chunk_rows=-1)


# --- reprice_grid + dense grids ----------------------------------------------
def _tiny_dense() -> DenseGridSpec:
    return DenseGridSpec(n_chips=16, base_chips=("H100",),
                         chip_scales=(1.0, 1.25),
                         base_memories=("DDR", "HBM"),
                         memory_scales=(0.75, 1.0),
                         base_nets=("PCIe",), net_scales=(1.0,),
                         topologies=("torus2d",))


def test_reprice_grid_certifies_dense_grid():
    spec = _tiny_dense().spec()
    eng = _engine(pricing_backend="pallas-compiled", price_chunk_rows=256)
    rep = eng.reprice_grid(_tiny_work, spec)
    assert rep["winners_identical"] is True
    assert rep["cells"] == _tiny_dense().n_cells() == len(spec.grid())
    assert rep["priced_rows"] > 0 and rep["chunks"] >= 1
    assert rep["drift"] is not None
    assert rep["drift"]["max_iter_drift"] <= rep["drift"]["band"]
    assert 0.0 <= rep["repriced_frac"] <= 1.0
    # exact backends run the same harness with bit-identity certification
    rep_np = _engine(pricing_backend="numpy").reprice_grid(_tiny_work, spec)
    assert rep_np["winners_identical"] is True and rep_np["drift"] is None
    assert rep_np["priced_rows"] == rep["priced_rows"]


def test_dense_sizing_reaches_target_cells():
    d5 = DenseGridSpec.dense(100_000)
    assert d5.n_cells() >= 100_000
    assert len(set(d5.memory_scales)) == len(d5.memory_scales)
    scales = tuple(0.25 * (i + 1) for i in range(10))
    d6 = DenseGridSpec.dense(100_000, workload_scales=scales)
    assert d6.n_total_cells() >= 1_000_000
    assert len(d6.work_variants(_tiny_work)) == len(scales)


def test_scale_lattice_validation():
    assert scale_lattice(0.5, 2.0, 1) == (0.5,)
    lattice = scale_lattice(0.5, 2.0, 7)
    assert len(lattice) == 7 and lattice[0] == 0.5 and lattice[-1] == 2.0
    with pytest.raises(ValueError, match="lattice"):
        scale_lattice(0.5, 2.0, 0)
    with pytest.raises(ValueError, match="collapses"):
        scale_lattice(1.0, 1.0 + 1e-9, 5)


def test_scaled_work_fn_picklable_and_scales_batch():
    wf = ScaledWorkFn(_tiny_work, 2.0)
    system = build_system(("H100", "HBM", "NVLink", "torus2d"), 16)
    work = wf(system)
    base = _tiny_work(system)
    assert work.global_batch == 2 * base.global_batch
    assert work.name == f"{base.name}@b2"
    clone = pickle.loads(pickle.dumps(wf))(system)
    # graph objects compare by identity across pickling; the scaled
    # scalars are the contract
    assert (clone.name, clone.global_batch, clone.microbatch) == (
        work.name, work.global_batch, work.microbatch)
    # identity scale passes the workload through untouched
    unscaled = ScaledWorkFn(_tiny_work, 1.0)(system)
    assert (unscaled.name, unscaled.global_batch) == (base.name,
                                                      base.global_batch)

"""Pricing-phase certification: the batched numpy and jax backends must
reproduce the scalar reference *bit for bit* — on random plan vectors
(seeded generation, with a hypothesis variant when the dev extra is
installed, per the PR 1 convention) and end-to-end (phased sweep vs the
serial scalar sweep across chips/memories/topologies)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import clear_caches
from repro.core.dse import sweep
from repro.core.pricing import (FIELDS, PlanVector, batched_roofline,
                                price_plan_scalar, price_plans, stack_plans)
from repro.core.roofline import RooflineTerms, stack_terms
from repro.workloads.llm import LLAMA_68M, gpt_workload

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

OUT_KEYS = ("utilization", "cost_eff", "power_eff", "frac_compute",
            "frac_memory", "frac_network", "iter_time", "util_inter",
            "per_chip_mem_bytes", "feasible")


# --------------------------- vector generation -------------------------------
def _random_vector(rng: np.random.Generator) -> PlanVector:
    """A random-but-plausible plan vector, with the degenerate branches
    (no DP comm, no p2p, empty intra pass, inference-only multipliers)
    exercised at random."""
    tp = float(2 ** rng.integers(0, 7))
    pp = float(2 ** rng.integers(0, 5))
    n_layers = int(rng.integers(1, 130))
    lps = -(-n_layers // int(pp))  # ceil
    return PlanVector(
        t_comp_stage=float(rng.uniform(1e-6, 1.0)),
        t_net_stage=float(rng.uniform(0.0, 1.0)),
        t_p2p=float(rng.choice([0.0, rng.uniform(0.0, 0.1)])),
        t_dp=float(rng.choice([0.0, rng.uniform(0.0, 0.5)])),
        n_micro=float(rng.integers(1, 1025)),
        tp=tp, pp=pp,
        bwd_flop_mult=float(rng.choice([0.0, 2.0])),
        bwd_comm_mult=float(rng.choice([0.0, 1.0])),
        opt_mult=float(rng.choice([0.0, 8.0])),
        model_flops=float(rng.uniform(1e12, 1e21)),
        weight_bytes=float(rng.uniform(1e6, 1e13)),
        act_bytes_layer=float(rng.uniform(1e3, 1e10)),
        layers_per_stage=float(lps),
        stage_layers=float(max(1, lps)),
        n_chips=float(2 ** rng.integers(0, 11)),
        chip_peak=float(rng.uniform(1e13, 1e16)),
        mem_capacity=float(rng.uniform(1e9, 1e12)),
        sys_peak_flops=float(rng.uniform(1e15, 1e19)),
        sys_price=float(rng.uniform(1e5, 1e9)),
        sys_power=float(rng.uniform(1e3, 1e7)),
        intra_comp=float(rng.choice([0.0, rng.uniform(0.0, 1.0)])),
        intra_mem=float(rng.choice([0.0, rng.uniform(0.0, 1.0)])),
        intra_net=float(rng.choice([0.0, rng.uniform(0.0, 1.0)])),
        intra_total=float(rng.choice([0.0, rng.uniform(1e-9, 1.0)])),
    )


def _assert_bit_identical(vectors, backend, **kw):
    got = price_plans(vectors, backend=backend, **kw)
    ref = [price_plan_scalar(v) for v in vectors]
    for key in OUT_KEYS:
        col = got[key]
        want = np.array([r[key] for r in ref])
        if key == "feasible":
            assert col.dtype == np.bool_ or col.dtype == bool
            assert col.tolist() == want.astype(bool).tolist()
            continue
        # bit-for-bit: compare the raw float64 payloads, not approx
        assert col.dtype == np.float64
        mismatch = col.view(np.uint64) != want.view(np.uint64)
        assert not mismatch.any(), (
            f"{backend} backend: {key} differs at "
            f"{np.nonzero(mismatch)[0][:5]}")


# ------------------------- seeded property tests -----------------------------
def test_batched_numpy_matches_scalar_seeded():
    rng = np.random.default_rng(0)
    vectors = [_random_vector(rng) for _ in range(400)]
    _assert_bit_identical(vectors, "numpy")


def test_batched_jax_matches_scalar_seeded():
    pytest.importorskip("jax")
    rng = np.random.default_rng(1)
    vectors = [_random_vector(rng) for _ in range(200)]
    _assert_bit_identical(vectors, "jax")


def test_jax_jit_backend_is_close_but_not_certified():
    """jit=True lets XLA fuse into FMAs — allowed to differ in the last
    ulps, must still agree to rounding."""
    pytest.importorskip("jax")
    rng = np.random.default_rng(2)
    vectors = [_random_vector(rng) for _ in range(50)]
    got = price_plans(vectors, backend="jax", jit=True)
    ref = [price_plan_scalar(v) for v in vectors]
    for key in OUT_KEYS:
        if key == "feasible":
            continue
        np.testing.assert_allclose(
            got[key], np.array([r[key] for r in ref]), rtol=1e-12)


def test_stack_plans_shape_and_empty_batch():
    rng = np.random.default_rng(3)
    vectors = [_random_vector(rng) for _ in range(7)]
    cols = stack_plans(vectors)
    assert set(cols) == set(FIELDS)
    assert all(c.shape == (7,) and c.dtype == np.float64
               for c in cols.values())
    assert price_plans([]) == {} or all(
        len(v) == 0 for v in price_plans([]).values())


def test_unknown_backend_rejected():
    rng = np.random.default_rng(4)
    with pytest.raises(ValueError):
        price_plans([_random_vector(rng)], backend="cuda")


# ------------------------ hypothesis variant (dev extra) ---------------------
if HAVE_HYPOTHESIS:
    finite = st.floats(min_value=1e-9, max_value=1e18, allow_nan=False,
                       allow_infinity=False)
    maybe_zero = st.one_of(st.just(0.0), finite)

    @settings(max_examples=200, deadline=None)
    @given(t_comp=finite, t_net=maybe_zero, t_p2p=maybe_zero,
           t_dp=maybe_zero, n_micro=st.integers(1, 4096),
           tp=st.sampled_from([1, 2, 4, 8, 16, 32, 64]),
           pp=st.sampled_from([1, 2, 4, 8, 16]),
           bwd=st.sampled_from([0.0, 1.0, 2.0, 3.0]),
           intra_total=maybe_zero, w=finite, cap=finite)
    def test_pricing_property_hypothesis(t_comp, t_net, t_p2p, t_dp,
                                         n_micro, tp, pp, bwd, intra_total,
                                         w, cap):
        v = PlanVector(
            t_comp_stage=t_comp, t_net_stage=t_net, t_p2p=t_p2p, t_dp=t_dp,
            n_micro=float(n_micro), tp=float(tp), pp=float(pp),
            bwd_flop_mult=bwd, bwd_comm_mult=1.0, opt_mult=8.0,
            model_flops=1e18, weight_bytes=w, act_bytes_layer=w / 7.0,
            layers_per_stage=3.0, stage_layers=3.0, n_chips=64.0,
            chip_peak=1e15, mem_capacity=cap, sys_peak_flops=6.4e16,
            sys_price=1e7, sys_power=1e5, intra_comp=t_comp / 3.0,
            intra_mem=t_net / 5.0 if t_net else 0.0, intra_net=0.0,
            intra_total=intra_total)
        _assert_bit_identical([v], "numpy")


# ----------------------- end-to-end sweep certification ----------------------
def _tiny_work(system):
    return gpt_workload(LLAMA_68M, global_batch=64, microbatch=1)


_GRID = dict(n_chips=16,
             chips=("H100", "TPUv4", "SN30", "WSE2"),
             topologies=("torus2d", "dgx2"),
             mem_net=(("DDR", "PCIe"), ("HBM", "PCIe"), ("HBM", "NVLink")),
             max_tp=16)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_phased_sweep_rows_identical_to_scalar(backend):
    """The acceptance property: batched pricing returns DesignPoint.row()
    dicts element-identical to the serial scalar sweep, across every chip
    and memory of the grid."""
    if backend == "jax":
        pytest.importorskip("jax")
    clear_caches()
    ref = sweep(_tiny_work, phased=False, **_GRID)
    clear_caches()
    phased = sweep(_tiny_work, phased=True, pricing_backend=backend, **_GRID)
    assert len(phased) == len(ref) > 0
    assert [p.row() for p in phased] == [p.row() for p in ref]


# ------------------------------ batched roofline -----------------------------
def test_batched_roofline_matches_scalar_terms():
    rng = np.random.default_rng(5)
    terms = [RooflineTerms(name=f"cell{i}", chips=int(2 ** rng.integers(0, 10)),
                           hlo_flops=float(rng.uniform(1e12, 1e18)),
                           hlo_bytes=float(rng.uniform(1e9, 1e15)),
                           collective_bytes=float(
                               rng.choice([0.0, rng.uniform(1e6, 1e13)])),
                           model_flops=float(rng.uniform(1e12, 1e18)))
             for i in range(100)]
    got = batched_roofline(stack_terms(terms))
    for key, attr in [("t_compute", "t_compute"), ("t_memory", "t_memory"),
                      ("t_collective", "t_collective"), ("t_bound", "t_bound"),
                      ("roofline_fraction", "roofline_fraction"),
                      ("useful_flop_ratio", "useful_flop_ratio")]:
        want = np.array([getattr(t, attr) for t in terms])
        assert (got[key].view(np.uint64) == want.view(np.uint64)).all(), key


def test_batched_roofline_jax_matches_numpy():
    pytest.importorskip("jax")
    rng = np.random.default_rng(6)
    terms = [RooflineTerms(name=f"c{i}", chips=8,
                           hlo_flops=float(rng.uniform(1e12, 1e18)),
                           hlo_bytes=float(rng.uniform(1e9, 1e15)),
                           collective_bytes=float(rng.uniform(1e6, 1e13)),
                           model_flops=float(rng.uniform(1e12, 1e18)))
             for i in range(32)]
    cols = stack_terms(terms)
    a = batched_roofline(cols, backend="numpy")
    b = batched_roofline(cols, backend="jax")
    for key in a:
        assert (a[key].view(np.uint64) == b[key].view(np.uint64)).all(), key

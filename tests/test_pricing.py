"""Pricing-phase certification: the batched numpy, jax and pallas
(interpret-mode kernel) backends must reproduce the scalar reference
*bit for bit* — on random plan vectors (seeded generation, with a
hypothesis variant when the dev extra is installed, per the PR 1
convention) and end-to-end (phased sweep vs the serial scalar sweep
across chips/memories/topologies)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import clear_caches
from repro.core.dse import sweep
from repro.core.pricing import (FIELDS, PlanVector, batched_roofline,
                                price_plan_scalar, price_plans,
                                random_plan_vectors, stack_plans)
from repro.core.roofline import RooflineTerms, stack_terms
from repro.workloads.llm import LLAMA_68M, gpt_workload

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

OUT_KEYS = ("utilization", "cost_eff", "power_eff", "frac_compute",
            "frac_memory", "frac_network", "iter_time", "util_inter",
            "per_chip_mem_bytes", "feasible")


# Vector generation lives in repro.core.pricing.random_plan_vectors — ONE
# seeded generator shared with the pallas kernel's certify() harness, so
# every backend is certified against the same input distribution.
def _assert_bit_identical(vectors, backend, **kw):
    got = price_plans(vectors, backend=backend, **kw)
    ref = [price_plan_scalar(v) for v in vectors]
    for key in OUT_KEYS:
        col = got[key]
        want = np.array([r[key] for r in ref])
        if key == "feasible":
            assert col.dtype == np.bool_ or col.dtype == bool
            assert col.tolist() == want.astype(bool).tolist()
            continue
        # bit-for-bit: compare the raw float64 payloads, not approx
        assert col.dtype == np.float64
        mismatch = col.view(np.uint64) != want.view(np.uint64)
        assert not mismatch.any(), (
            f"{backend} backend: {key} differs at "
            f"{np.nonzero(mismatch)[0][:5]}")


# ------------------------- seeded property tests -----------------------------
def test_batched_numpy_matches_scalar_seeded():
    vectors = random_plan_vectors(400, seed=0)
    _assert_bit_identical(vectors, "numpy")


def test_batched_jax_matches_scalar_seeded():
    pytest.importorskip("jax")
    vectors = random_plan_vectors(200, seed=1)
    _assert_bit_identical(vectors, "jax")


def test_batched_pallas_matches_scalar_seeded():
    """The interpret-mode Pallas pricing kernel is certified to the same
    bit-exactness bar as the other backends — including batches that do
    not divide the kernel tile (the padded tail must be sliced off)."""
    pytest.importorskip("jax")
    vectors = random_plan_vectors(200, seed=8)
    _assert_bit_identical(vectors, "pallas")
    _assert_bit_identical(vectors[:7], "pallas")   # sub-tile batch


def test_pallas_kernel_certify_harness():
    pytest.importorskip("jax")
    from repro.kernels.pricing import certify

    report = certify(n=256, seed=1, tile=100)  # force a ragged last tile
    assert report["bit_identical"] and report["rows"] == 256


def test_jax_jit_backend_is_close_but_not_certified():
    """jit=True lets XLA fuse into FMAs — allowed to differ in the last
    ulps, must still agree to rounding."""
    pytest.importorskip("jax")
    vectors = random_plan_vectors(50, seed=2)
    got = price_plans(vectors, backend="jax", jit=True)
    ref = [price_plan_scalar(v) for v in vectors]
    for key in OUT_KEYS:
        if key == "feasible":
            continue
        np.testing.assert_allclose(
            got[key], np.array([r[key] for r in ref]), rtol=1e-12)


def test_stack_plans_shape_and_empty_batch():
    vectors = random_plan_vectors(7, seed=3)
    cols = stack_plans(vectors)
    assert set(cols) == set(FIELDS)
    assert all(c.shape == (7,) and c.dtype == np.float64
               for c in cols.values())
    assert price_plans([]) == {} or all(
        len(v) == 0 for v in price_plans([]).values())


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        price_plans(random_plan_vectors(1, seed=4), backend="cuda")


# ------------------------ hypothesis variant (dev extra) ---------------------
if HAVE_HYPOTHESIS:
    finite = st.floats(min_value=1e-9, max_value=1e18, allow_nan=False,
                       allow_infinity=False)
    maybe_zero = st.one_of(st.just(0.0), finite)

    @settings(max_examples=200, deadline=None)
    @given(t_comp=finite, t_net=maybe_zero, t_p2p=maybe_zero,
           t_dp=maybe_zero, n_micro=st.integers(1, 4096),
           tp=st.sampled_from([1, 2, 4, 8, 16, 32, 64]),
           pp=st.sampled_from([1, 2, 4, 8, 16]),
           bwd=st.sampled_from([0.0, 1.0, 2.0, 3.0]),
           intra_total=maybe_zero, w=finite, cap=finite)
    def test_pricing_property_hypothesis(t_comp, t_net, t_p2p, t_dp,
                                         n_micro, tp, pp, bwd, intra_total,
                                         w, cap):
        v = PlanVector(
            t_comp_stage=t_comp, t_net_stage=t_net, t_p2p=t_p2p, t_dp=t_dp,
            n_micro=float(n_micro), tp=float(tp), pp=float(pp),
            bwd_flop_mult=bwd, bwd_comm_mult=1.0, opt_mult=8.0,
            model_flops=1e18, weight_bytes=w, act_bytes_layer=w / 7.0,
            layers_per_stage=3.0, stage_layers=3.0, n_chips=64.0,
            chip_peak=1e15, mem_capacity=cap, sys_peak_flops=6.4e16,
            sys_price=1e7, sys_power=1e5, intra_comp=t_comp / 3.0,
            intra_mem=t_net / 5.0 if t_net else 0.0, intra_net=0.0,
            intra_total=intra_total)
        _assert_bit_identical([v], "numpy")


# ----------------------- end-to-end sweep certification ----------------------
def _tiny_work(system):
    return gpt_workload(LLAMA_68M, global_batch=64, microbatch=1)


_GRID = dict(n_chips=16,
             chips=("H100", "TPUv4", "SN30", "WSE2"),
             topologies=("torus2d", "dgx2"),
             mem_net=(("DDR", "PCIe"), ("HBM", "PCIe"), ("HBM", "NVLink")),
             max_tp=16)


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_phased_sweep_rows_identical_to_scalar(backend):
    """The acceptance property: batched pricing returns DesignPoint.row()
    dicts element-identical to the serial scalar sweep, across every chip
    and memory of the grid."""
    if backend in ("jax", "pallas"):
        pytest.importorskip("jax")
    clear_caches()
    ref = sweep(_tiny_work, phased=False, **_GRID)
    clear_caches()
    phased = sweep(_tiny_work, phased=True, pricing_backend=backend, **_GRID)
    assert len(phased) == len(ref) > 0
    assert [p.row() for p in phased] == [p.row() for p in ref]


# ------------------------------ batched roofline -----------------------------
def test_batched_roofline_matches_scalar_terms():
    rng = np.random.default_rng(5)
    terms = [RooflineTerms(name=f"cell{i}", chips=int(2 ** rng.integers(0, 10)),
                           hlo_flops=float(rng.uniform(1e12, 1e18)),
                           hlo_bytes=float(rng.uniform(1e9, 1e15)),
                           collective_bytes=float(
                               rng.choice([0.0, rng.uniform(1e6, 1e13)])),
                           model_flops=float(rng.uniform(1e12, 1e18)))
             for i in range(100)]
    got = batched_roofline(stack_terms(terms))
    for key, attr in [("t_compute", "t_compute"), ("t_memory", "t_memory"),
                      ("t_collective", "t_collective"), ("t_bound", "t_bound"),
                      ("roofline_fraction", "roofline_fraction"),
                      ("useful_flop_ratio", "useful_flop_ratio")]:
        want = np.array([getattr(t, attr) for t in terms])
        assert (got[key].view(np.uint64) == want.view(np.uint64)).all(), key


def test_batched_roofline_pallas_matches_numpy():
    pytest.importorskip("jax")
    rng = np.random.default_rng(9)
    terms = [RooflineTerms(name=f"p{i}", chips=8,
                           hlo_flops=float(rng.uniform(1e12, 1e18)),
                           hlo_bytes=float(rng.uniform(1e9, 1e15)),
                           collective_bytes=float(
                               rng.choice([0.0, rng.uniform(1e6, 1e13)])),
                           model_flops=float(rng.uniform(1e12, 1e18)))
             for i in range(64)]
    cols = stack_terms(terms)
    a = batched_roofline(cols, backend="numpy")
    b = batched_roofline(cols, backend="pallas")
    for key in a:
        assert (a[key].view(np.uint64) == b[key].view(np.uint64)).all(), key


def test_batched_roofline_jax_matches_numpy():
    pytest.importorskip("jax")
    rng = np.random.default_rng(6)
    terms = [RooflineTerms(name=f"c{i}", chips=8,
                           hlo_flops=float(rng.uniform(1e12, 1e18)),
                           hlo_bytes=float(rng.uniform(1e9, 1e15)),
                           collective_bytes=float(rng.uniform(1e6, 1e13)),
                           model_flops=float(rng.uniform(1e12, 1e18)))
             for i in range(32)]
    cols = stack_terms(terms)
    a = batched_roofline(cols, backend="numpy")
    b = batched_roofline(cols, backend="jax")
    for key in a:
        assert (a[key].view(np.uint64) == b[key].view(np.uint64)).all(), key

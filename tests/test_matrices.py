"""Property tests for the assignment-matrix formulation (paper Eqs. 1-4)."""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the dev extra (requirements-dev.txt)")
from hypothesis import given, settings

from repro.core.graph import DataflowGraph, Kernel, Tensor
from repro.core.matrices import (assignment_matrix, matrix_B, matrix_D,
                                 matrix_H, matrix_L, partition_summaries,
                                 upper_triangular_masks, validate_assignment)

from conftest import dags_with_assignments


@given(dags_with_assignments())
@settings(max_examples=200, deadline=None)
def test_matrix_identities(case):
    """The invariants the paper's MIP relies on, on random DAGs."""
    g, assign, p_max = case
    A = assignment_matrix(assign, p_max)
    B = matrix_B(g, A)
    D = matrix_D(g, A)
    L = matrix_L(g, A)
    H = matrix_H(g, A)

    # A·1 = 1 (one-hot rows)
    assert (A.sum(axis=1) == 1).all()

    part = assign
    for j, t in enumerate(g.tensors):
        ps = part[g.kernel_index(t.src)]
        pd = part[g.kernel_index(t.dst)]
        if ps == pd:
            # intra-partition: B one-hot at the shared partition, D/L empty
            assert B[j].sum() == 1 and B[j, ps]
            assert D[j].sum() == 0
            assert L[j].sum() == 0
        else:
            # cross-partition: D marks exactly the two endpoints
            assert B[j].sum() == 0
            assert D[j].sum() == 2 and D[j, ps] and D[j, pd]
            # L covers the closed interval [ps, pd]
            lo, hi = min(ps, pd), max(ps, pd)
            expect = np.zeros(p_max, dtype=bool)
            expect[lo:hi + 1] = True
            assert (L[j] == expect).all(), (ps, pd, L[j])
        # H = producer placement
        assert H[j].argmax() == ps and H[j].sum() == 1


@given(dags_with_assignments())
@settings(max_examples=100, deadline=None)
def test_partition_summaries_match_bruteforce(case):
    g, assign, p_max = case
    s = partition_summaries(g, assign, p_max)
    f = np.zeros(p_max)
    w = np.zeros(p_max)
    sram = np.zeros(p_max)
    xfer = np.zeros(p_max)
    for i, k in enumerate(g.kernels):
        f[assign[i]] += k.flops
        w[assign[i]] += k.weight_bytes
    for t in g.tensors:
        ps = assign[g.kernel_index(t.src)]
        pd = assign[g.kernel_index(t.dst)]
        if ps == pd:
            sram[ps] += t.bytes_
        else:
            xfer[ps] += t.bytes_
            xfer[pd] += t.bytes_
    np.testing.assert_allclose(s["flops"], f, rtol=1e-12)
    np.testing.assert_allclose(s["weight_bytes"], w, rtol=1e-12)
    np.testing.assert_allclose(s["sram_bytes"], sram, rtol=1e-12)
    np.testing.assert_allclose(s["dram_xfer"], xfer, rtol=1e-12)


def test_upper_triangular_masks():
    U_s, U_t = upper_triangular_masks(4)
    assert U_s[1, 1] and not U_t[1, 1]
    assert U_s[0, 3] and U_t[0, 3]
    assert not U_s[2, 1]


def test_validate_assignment_rejects_precedence_violation():
    g = DataflowGraph([Kernel("a", 1.0), Kernel("b", 1.0)],
                      [Tensor("t", "a", "b", 1.0)])
    A = assignment_matrix(np.array([1, 0]), 2)   # consumer before producer
    with pytest.raises(ValueError):
        validate_assignment(g, A)
    validate_assignment(g, assignment_matrix(np.array([0, 1]), 2))  # ok


def test_assignment_matrix_bounds():
    with pytest.raises(ValueError):
        assignment_matrix(np.array([0, 3]), 3)  # index == p_max
    with pytest.raises(ValueError):
        assignment_matrix(np.array([[0], [1]]), 2)  # not 1-D

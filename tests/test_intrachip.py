"""Intra-chip optimization pass tests (paper §V + §VII mappings)."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core.graph import DataflowGraph, Kernel, KernelKind, Tensor
from repro.core.intrachip import (evaluate_intra_assignment,
                                  optimize_intra_chip)
from repro.core.solver import branch_and_bound
from repro.systems.chips import DDR, SN10, TPU_V5E, HBM_V5E
from repro.workloads.llm import GPT3_175B, gpt_layer_graph


def _sharded_layer(tp=8):
    g = gpt_layer_graph(dataclasses.replace(GPT3_175B, batch=1))
    return g.scaled(flop_scale=1.0 / tp, bytes_scale=1.0 / tp)


def test_dataflow_upper_bounds_kbk():
    """Paper Fig 19: dataflow mapping performance is an upper bound of
    non-dataflow (kernel-by-kernel) mapping performance."""
    g = _sharded_layer()
    df = optimize_intra_chip(g, SN10, DDR, mode="dataflow")
    kbk = optimize_intra_chip(g, SN10, DDR, mode="kbk")
    assert df.total_time < kbk.total_time
    assert df.dram_traffic < kbk.dram_traffic


def test_partition_latency_is_max_of_terms():
    g = _sharded_layer()
    r = optimize_intra_chip(g, SN10, DDR)
    np.testing.assert_allclose(
        r.t_critical, np.maximum(np.maximum(r.t_comp, r.t_mem), r.t_net))
    assert r.total_time == pytest.approx(r.t_critical.sum())


def test_sram_constraint_respected():
    g = _sharded_layer()
    r = optimize_intra_chip(g, SN10, DDR, sram_headroom=0.9)
    assert (r.sram_used <= SN10.sram_capacity * 0.9 + 1e-6).all()


def test_more_sram_never_hurts():
    """Fig 19 trend: larger SRAM ⇒ more fusion ⇒ dataflow time no worse."""
    g = _sharded_layer()
    times = []
    for cap_mb in (150, 300, 500, 2000):
        chip = dataclasses.replace(SN10, sram_capacity=cap_mb * 1e6)
        times.append(optimize_intra_chip(g, chip, DDR).total_time)
    for a, b in zip(times, times[1:]):
        assert b <= a * (1 + 1e-9)


def test_more_dram_bw_helps_kbk_more_than_dataflow():
    g = _sharded_layer()
    slow = dataclasses.replace(DDR, bandwidth=100e9)
    fast = dataclasses.replace(DDR, bandwidth=600e9)
    df_gain = (optimize_intra_chip(g, SN10, slow).total_time
               / optimize_intra_chip(g, SN10, fast).total_time)
    kbk_gain = (optimize_intra_chip(g, SN10, slow, mode="kbk").total_time
                / optimize_intra_chip(g, SN10, fast, mode="kbk").total_time)
    assert kbk_gain > df_gain


def test_optimizer_beats_vendor_style_assignment():
    """§VII.C: the DFModel mapping beats the vendor's 4-partition mapping."""
    g = gpt_layer_graph(dataclasses.replace(GPT3_175B, batch=1)).scaled(
        1.0 / 8, 1.0 / 8)
    # vendor partitioning (§VII.B): {QKV}, {MHA1,Softmax,MHA2,Proj}, {FFN0},
    # {FFN1, Add}; norms ride with their consumers
    vendor_of = {"LN1": 0, "QKV": 0, "MHA1": 1, "Softmax": 1, "MHA2": 1,
                 "Proj": 1, "Add1": 1, "LN2": 1, "FFN0": 2, "FFN1": 3,
                 "Add2": 3}
    assign = [vendor_of[k.name] for k in g.kernels]
    vendor = evaluate_intra_assignment(g, assign, SN10, DDR)
    opt = optimize_intra_chip(g, SN10, DDR, p_max=8)
    assert opt.total_time <= vendor.total_time * (1 + 1e-9)


def test_dp_matches_branch_and_bound_small():
    """Interval-DP fusion == exact B&B over the assignment lattice."""
    ks = [Kernel(f"k{i}", flops=1e9 * (i + 1), kind=KernelKind.GEMM,
                 weight_bytes=1e6) for i in range(6)]
    ts = [Tensor(f"t{i}", f"k{i}", f"k{i+1}", 2e6) for i in range(5)]
    g = DataflowGraph(ks, ts)
    chip, mem = TPU_V5E, HBM_V5E
    dp = optimize_intra_chip(g, chip, mem, p_max=4)

    def objective(assign):
        return evaluate_intra_assignment(g, assign, chip, mem).total_time

    _, bb_cost = branch_and_bound(g, 4, objective)
    assert dp.total_time == pytest.approx(bb_cost, rel=1e-6)


def test_kbk_counts_all_dram_roundtrips():
    ks = [Kernel("a", 1e9, KernelKind.GEMM, weight_bytes=4e6),
          Kernel("b", 1e9, KernelKind.GEMM, weight_bytes=4e6)]
    g = DataflowGraph(ks, [Tensor("t", "a", "b", 8e6)])
    r = optimize_intra_chip(g, TPU_V5E, HBM_V5E, mode="kbk")
    # tensor stored by a, loaded by b, plus both weight streams
    assert r.dram_traffic == pytest.approx(2 * 8e6 + 2 * 4e6)


def test_weights_resident_mode_feasibility():
    """'resident' weights must fit in SRAM or the partitioning fails."""
    ks = [Kernel("a", 1e9, KernelKind.GEMM, weight_bytes=1e9),  # 1 GB weights
          Kernel("b", 1e9, KernelKind.GEMM, weight_bytes=1e9)]
    g = DataflowGraph(ks, [Tensor("t", "a", "b", 1e6)])
    with pytest.raises(ValueError):
        optimize_intra_chip(g, TPU_V5E, HBM_V5E, weights="resident")
    # auto mode streams the overflow instead
    r = optimize_intra_chip(g, TPU_V5E, HBM_V5E, weights="auto")
    assert r.total_time > 0

"""Elastic scaling: a checkpoint written under one mesh restores onto a
different topology (the restart-after-resize path of a multi-pod job)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"), JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, f"OUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_checkpoint_elastic_across_mesh_shapes(tmp_path):
    """Save on a (4, 2) mesh with FSDP; restore onto (2, 4) and keep
    training — losses must continue from the same state."""
    ckpt = tmp_path / "ckpt"
    script = f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import init_params, synth_batch
    from repro.parallel.logical import use_rules
    from repro.launch.mesh import make_axis_rules
    from repro.launch.shardings import (batch_shardings, opt_shardings,
                                        param_shardings)
    from repro.train.checkpoint import CheckpointManager
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.trainer import make_train_step

    cfg = get_config("olmo_1b", smoke=True)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    batches = [synth_batch(cfg, 8, 32, seed=s) for s in range(4)]
    mgr = CheckpointManager({str(ckpt)!r})

    def run_on(shape, params, opt, batches):
        mesh = jax.make_mesh(shape, ("data", "model"))
        with mesh, use_rules(make_axis_rules(mesh), mesh):
            ps = param_shardings(cfg, mesh, fsdp=True)
            os_ = opt_shardings(cfg, mesh, fsdp=True)
            bs = batch_shardings(cfg, mesh, 8)
            p = jax.device_put(params, ps)
            o = jax.device_put(opt, os_)
            fn = jax.jit(step, in_shardings=(ps, os_, bs),
                         out_shardings=(ps, os_, None))
            losses = []
            for b in batches:
                sb = {{k: jax.device_put(v, bs[k]) for k, v in b.items()}}
                p, o, m = fn(p, o, sb)
                losses.append(float(m["loss"]))
            return jax.device_get(p), jax.device_get(o), losses

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    # reference: all four steps on the (4,2) mesh
    _, _, ref = run_on((4, 2), params, opt, batches)

    # elastic: two steps on (4,2), checkpoint, resize to (2,4), resume
    p1, o1, l1 = run_on((4, 2), params, opt, batches[:2])
    mgr.save(2, {{"params": p1, "opt": o1}})
    _, tree = mgr.restore(2)
    tree["opt"]["step"] = jnp.asarray(tree["opt"]["step"], jnp.int32)
    _, _, l2 = run_on((2, 4), tree["params"], tree["opt"], batches[2:])

    got = l1 + l2
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-4)
    print("elastic resume OK", got)
    """
    _run(script)

"""Workload dataflow-graph builders: FLOP/byte accounting sanity."""
from __future__ import annotations

import dataclasses

import pytest

from repro.core.graph import KernelKind
from repro.workloads.dlrm import dlrm_layer_graph, dlrm_workload
from repro.workloads.fft import fft_graph, fft_workload
from repro.workloads.hpl import hpl_iteration_graph, hpl_workload
from repro.workloads.llm import (GPT3_175B, LLMShape, decode_layer_graph,
                                 embedding_graph, gpt_layer_graph,
                                 gpt_workload, lm_head_graph,
                                 mamba_layer_graph)


def test_gpt_layer_flops_match_2nd():
    """Σ GEMM FLOPs of one layer ≈ 2 · layer_params · tokens (linear parts)."""
    s = dataclasses.replace(GPT3_175B, batch=1)
    g = gpt_layer_graph(s)
    gemm_flops = sum(k.flops for k in g.kernels
                     if k.kind == KernelKind.GEMM)
    d = s.d_model
    layer_params = (d * s.n_heads * s.head_dim
                    + 2 * d * s.n_kv_heads * s.head_dim
                    + s.n_heads * s.head_dim * d
                    + (2 if not s.gated else 3) * d * s.d_ff)
    tokens = s.batch * s.seq
    assert gemm_flops == pytest.approx(2 * layer_params * tokens, rel=0.02)


def test_gpt_layer_weight_bytes():
    s = dataclasses.replace(GPT3_175B, batch=1)
    g = gpt_layer_graph(s)
    per_layer = g.total_weight_bytes()
    # 175B total over 96 layers + embeddings: per-layer weights ≈ 1.79B × 2B
    assert per_layer == pytest.approx(1.79e9 * 2, rel=0.15)


def test_workload_total_params_scale():
    work = gpt_workload(GPT3_175B, global_batch=256, microbatch=1)
    assert work.total_weight_bytes() == pytest.approx(175e9 * 2, rel=0.1)


def test_moe_layer_graph_has_router_and_experts():
    s = dataclasses.replace(GPT3_175B, batch=1, moe_experts=64, moe_top_k=8)
    g = gpt_layer_graph(s)
    kinds = {k.name: k.kind for k in g.kernels}
    assert kinds["Router"] == KernelKind.ROUTER
    # expert FFN weights carry the FULL expert table (memory), FLOPs only top-k
    ffn0 = g.kernel("FFN0")
    assert ffn0.weight_bytes == pytest.approx(64 * 2 * s.d_model * s.d_ff * 2)
    dense = gpt_layer_graph(dataclasses.replace(s, moe_experts=0))
    moe_ffn_flops = sum(k.flops for k in g.kernels if "FFN" in k.name)
    dense_ffn_flops = sum(k.flops for k in dense.kernels if "FFN" in k.name)
    # top-8 gated (3-mat) experts vs this config's 2-mat dense MLP ⇒ 12×
    assert moe_ffn_flops == pytest.approx(12 * dense_ffn_flops, rel=0.01)


def test_mamba_layer_graph_structure():
    s = dataclasses.replace(GPT3_175B, batch=1)
    g = mamba_layer_graph(s, d_state=128, expand=2)
    assert g.kernel("SSD").kind == KernelKind.SCAN
    assert g.topo_names()[0] == "InProj" and g.topo_names()[-1] == "OutProj"


def test_decode_graph_kv_traffic():
    s = dataclasses.replace(GPT3_175B, batch=8)
    g = decode_layer_graph(s, kv_len=32768)
    attn = g.kernel("AttnDec")
    expect = 2.0 * 8 * 32768 * s.n_kv_heads * s.head_dim * 2
    assert attn.weight_bytes == pytest.approx(expect)


def test_embedding_and_head_graphs():
    s = dataclasses.replace(GPT3_175B, batch=1)
    e, h = embedding_graph(s), lm_head_graph(s)
    assert e.kernel("Embed").weight_bytes == pytest.approx(
        s.vocab * s.d_model * 2)
    assert h.kernel("LMHead").flops == pytest.approx(
        2.0 * s.seq * s.d_model * s.vocab)


def test_dlrm_graph_embedding_dominates_memory():
    g = dlrm_layer_graph()
    emb = g.kernel("EmbLookup").weight_bytes
    mlp = sum(k.weight_bytes for k in g.kernels if "MLP" in k.name)
    assert emb > 100 * mlp
    work = dlrm_workload(params=793e9)
    assert work.layer_graph.total_weight_bytes() == pytest.approx(
        793e9 * 2, rel=0.05)


def test_hpl_update_dominates_flops():
    g = hpl_iteration_graph(n=5e6, nb=512)
    upd = g.kernel("Update").flops
    assert upd / g.total_flops() > 0.95
    assert hpl_workload().bwd_flop_mult == 0.0


def test_fft_graph_three_stages_two_transposes():
    g = fft_graph(1e12)
    kinds = [k.kind for k in g.kernels]
    assert kinds.count(KernelKind.FFT) == 3
    assert kinds.count(KernelKind.COMM) == 2
    # 5 N log2 N total FLOPs
    import math
    assert g.total_flops() == pytest.approx(5e12 * math.log2(1e12), rel=0.06)
    assert fft_workload().layer_graph.total_tensor_bytes() == pytest.approx(
        4 * 8e12, rel=0.01)

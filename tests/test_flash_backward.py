"""FlashAttention-2 backward Pallas kernels vs autodiff of the oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.backward import flash_attention_fwd_lse
from repro.kernels.flash_attention.ops import flash_attention_train
from repro.kernels.flash_attention.ref import flash_attention_ref

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("b,h,hkv,s,hd", [
    (1, 4, 4, 256, 64),      # MHA
    (2, 4, 1, 256, 64),      # MQA
    (1, 8, 2, 384, 64),      # GQA, non-power-of-two blocks
    (1, 2, 2, 256, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_backward_matches_autodiff(b, h, hkv, s, hd, causal):
    kq, kk, kv, kg = jax.random.split(KEY, 4)
    q = jax.random.normal(kq, (b, h, s, hd), jnp.float32)
    k = jax.random.normal(kk, (b, hkv, s, hd), jnp.float32)
    v = jax.random.normal(kv, (b, hkv, s, hd), jnp.float32)
    g = jax.random.normal(kg, (b, h, s, hd), jnp.float32)

    def loss_kernel(q, k, v):
        return jnp.sum(flash_attention_train(q, k, v, causal, 128, 128,
                                             True) * g)

    def loss_ref(q, k, v):
        return jnp.sum(flash_attention_ref(q, k, v, causal=causal)
                       .astype(jnp.float32) * g)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)


def test_fwd_lse_matches_softmax_normalizer():
    kq, kk, kv = jax.random.split(KEY, 3)
    b, h, s, hd = 1, 2, 256, 64
    q = jax.random.normal(kq, (b, h, s, hd), jnp.float32)
    k = jax.random.normal(kk, (b, h, s, hd), jnp.float32)
    v = jax.random.normal(kv, (b, h, s, hd), jnp.float32)
    o, lse = flash_attention_fwd_lse(q, k, v, causal=False, interpret=True)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.float32(hd))
    ref_lse = jax.scipy.special.logsumexp(logits, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(ref_lse),
                               rtol=1e-5, atol=1e-5)
    ref_o = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref_o),
                               rtol=1e-5, atol=1e-5)


def test_train_path_bf16():
    kq, kk, kv = jax.random.split(KEY, 3)
    b, h, s, hd = 1, 4, 256, 64
    q = jax.random.normal(kq, (b, h, s, hd), jnp.bfloat16)
    k = jax.random.normal(kk, (b, h, s, hd), jnp.bfloat16)
    v = jax.random.normal(kv, (b, h, s, hd), jnp.bfloat16)

    def loss(q, k, v):
        return jnp.sum(flash_attention_train(q, k, v, True, 128, 128,
                                             True).astype(jnp.float32))

    dq, dk, dv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    assert dq.dtype == jnp.bfloat16 and dk.dtype == jnp.bfloat16
    for t in (dq, dk, dv):
        assert bool(jnp.isfinite(t.astype(jnp.float32)).all())
